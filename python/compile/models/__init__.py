"""Model zoo (scaled-down faithful variants of the paper's models).

Every builder returns a compile.ir.Graph. Sizes are chosen so a full
Quant-Trim training run finishes in minutes on the CPU-PJRT backend while
keeping the quantization-relevant structure of the original architectures
(residual adds, attention QKV, depthwise conv + SE, encoder-decoder skips).
"""

from .mobilenet import mobilenetv3_slim
from .resnet import resnet18_slim, resnet50_slim
from .sam import nanosam_student, nanosam_teacher
from .unet import unet_slim
from .vit import vit_dinov2_slim

BUILDERS = {
    "resnet18": lambda: resnet18_slim(num_classes=100),
    "resnet18_c10": lambda: resnet18_slim(num_classes=10, name="resnet18_c10"),
    "resnet50": lambda: resnet50_slim(num_classes=100),
    "vit": lambda: vit_dinov2_slim(num_classes=100),
    "mobilenetv3": lambda: mobilenetv3_slim(num_classes=100),
    "unet": lambda: unet_slim(num_classes=8),
    "sam_student": nanosam_student,
    "sam_teacher": nanosam_teacher,
}
