"""DINOv2-proxy vision transformer (slim) with Quant-Trim quant points.

Per paper Table 8 ("Attention handling"): Q/K/V and output projections are
fake-quantized (per-tensor symmetric), attention scores stay FP; activation
quant points sit after each residual add and after the MLP GELU.
"""

from ..ir import Graph


def vit_dinov2_slim(num_classes=100, dim=128, depth=6, heads=4, mlp=256,
                    patch=4, image=32, name="vit"):
    g = Graph(name)
    x = g.input("image", (3, image, image))
    # patch embedding: conv stride=patch, then to token layout
    pe = g.conv2d("patch.c", x, dim, patch, stride=patch, pad=0)
    tok = g.to_tokens("patch.tok", pe)
    h = g.aq("patch.q", tok)
    for i in range(depth):
        ln1 = g.layernorm(f"blk{i}.ln1", h)
        att = g.attention(f"blk{i}.att", ln1, heads)
        a1 = g.add2(f"blk{i}.add1", h, att)
        q1 = g.aq(f"blk{i}.q1", a1)
        ln2 = g.layernorm(f"blk{i}.ln2", q1)
        f1 = g.linear(f"blk{i}.fc1", ln2, mlp)
        ge = g.act("gelu", f"blk{i}.gelu", f1)
        qg = g.aq(f"blk{i}.qg", ge)
        f2 = g.linear(f"blk{i}.fc2", qg, dim)
        a2 = g.add2(f"blk{i}.add2", q1, f2)
        h = g.aq(f"blk{i}.q2", a2)
    ln = g.layernorm("final.ln", h)
    pooled = g.tokmean("final.pool", ln)
    g.linear("head", pooled, num_classes)
    return g
