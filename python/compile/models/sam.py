"""NanoSAM2 encoder pair (paper §5.2, Figs 6-7, Table 10).

Student: ResNet-18-slim + FPN, trained with Quant-Trim while distilling from
the teacher's three FPN scales (Huber loss, weights [1, 1/4, 1/8]).
Teacher: a 2x-wider frozen encoder standing in for SAM-2.1 Hiera — we have no
SAM weights offline, so the teacher is a fixed randomly-initialized encoder;
the distillation *mechanics* (multi-scale feature matching under progressive
fake quant) are identical, which is what the experiment exercises
(DESIGN.md §2 substitution table).
"""

from .resnet import resnet_backbone_fpn


def nanosam_student():
    return resnet_backbone_fpn("sam_student", base=16, image=64, fpn_dim=32)


def nanosam_teacher():
    return resnet_backbone_fpn("sam_teacher", base=32, image=64, fpn_dim=32)
