"""CIFAR-style ResNet-18/50 (slim widths) with Quant-Trim quant points.

Activation quant points sit after every ReLU and after every residual add —
the "after common nonlinearities" placement of paper §3.4. Weight quant is
per-output-channel symmetric INT8 on every conv/linear.
"""

from ..ir import Graph


def _basic_block(g, name, x, cout, stride):
    cin = g.node(x).out_shape[0]
    c1 = g.conv2d(f"{name}.c1", x, cout, 3, stride=stride, bias=False)
    b1 = g.bn(f"{name}.bn1", c1)
    r1 = g.act("relu", f"{name}.r1", b1)
    q1 = g.aq(f"{name}.q1", r1)
    c2 = g.conv2d(f"{name}.c2", q1, cout, 3, bias=False)
    b2 = g.bn(f"{name}.bn2", c2)
    if stride != 1 or cin != cout:
        ds = g.conv2d(f"{name}.ds", x, cout, 1, stride=stride, pad=0, bias=False)
        dsb = g.bn(f"{name}.dsbn", ds)
        skip = dsb
    else:
        skip = x
    s = g.add2(f"{name}.add", b2, skip)
    r2 = g.act("relu", f"{name}.r2", s)
    return g.aq(f"{name}.q2", r2)


def _bottleneck(g, name, x, cmid, stride):
    cin = g.node(x).out_shape[0]
    cout = cmid * 4
    c1 = g.conv2d(f"{name}.c1", x, cmid, 1, pad=0, bias=False)
    b1 = g.bn(f"{name}.bn1", c1)
    r1 = g.act("relu", f"{name}.r1", b1)
    q1 = g.aq(f"{name}.q1", r1)
    c2 = g.conv2d(f"{name}.c2", q1, cmid, 3, stride=stride, bias=False)
    b2 = g.bn(f"{name}.bn2", c2)
    r2 = g.act("relu", f"{name}.r2", b2)
    q2 = g.aq(f"{name}.q2", r2)
    c3 = g.conv2d(f"{name}.c3", q2, cout, 1, pad=0, bias=False)
    b3 = g.bn(f"{name}.bn3", c3)
    if stride != 1 or cin != cout:
        ds = g.conv2d(f"{name}.ds", x, cout, 1, stride=stride, pad=0, bias=False)
        dsb = g.bn(f"{name}.dsbn", ds)
        skip = dsb
    else:
        skip = x
    s = g.add2(f"{name}.add", b3, skip)
    r3 = g.act("relu", f"{name}.r3", s)
    return g.aq(f"{name}.q3", r3)


def resnet18_slim(num_classes=100, base=16, image=32, name="resnet18"):
    g = Graph(name)
    x = g.input("image", (3, image, image))
    c = g.conv2d("stem.c", x, base, 3, bias=False)
    b = g.bn("stem.bn", c)
    r = g.act("relu", "stem.r", b)
    h = g.aq("stem.q", r)
    widths = [base, base * 2, base * 4, base * 8]
    for si, w in enumerate(widths):
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _basic_block(g, f"s{si}.b{bi}", h, w, stride)
    p = g.gap("gap", h)
    f = g.flatten("flat", p)
    g.linear("head", f, num_classes)
    return g


def resnet50_slim(num_classes=100, base=16, image=32, name="resnet50"):
    g = Graph(name)
    x = g.input("image", (3, image, image))
    c = g.conv2d("stem.c", x, base, 3, bias=False)
    b = g.bn("stem.bn", c)
    r = g.act("relu", "stem.r", b)
    h = g.aq("stem.q", r)
    widths = [base, base * 2, base * 4, base * 8]
    blocks = [3, 4, 6, 3]
    for si, (w, nb) in enumerate(zip(widths, blocks)):
        for bi in range(nb):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _bottleneck(g, f"s{si}.b{bi}", h, w, stride)
    p = g.gap("gap", h)
    f = g.flatten("flat", p)
    g.linear("head", f, num_classes)
    return g


def resnet_backbone_fpn(name, base=16, image=64, fpn_dim=32):
    """ResNet-18 backbone + 3-level FPN (NanoSAM2 encoder shape).

    Outputs the three FPN feature maps (deepest first), matching the
    three-scale distillation loss of paper §5.2.
    """
    g = Graph(name)
    x = g.input("image", (3, image, image))
    if image >= 128:
        # ImageNet-style stem at full resolution: stride-2 7x7 + maxpool,
        # as in the real NanoSAM2 ResNet encoder (4x downsample up front)
        c = g.conv2d("stem.c", x, base, 7, stride=2, bias=False)
        b = g.bn("stem.bn", c)
        r = g.act("relu", "stem.r", b)
        q = g.aq("stem.q", r)
        h = g.maxpool("stem.pool", q, 3, 2, pad=1)
    else:
        c = g.conv2d("stem.c", x, base, 3, bias=False)
        b = g.bn("stem.bn", c)
        r = g.act("relu", "stem.r", b)
        h = g.aq("stem.q", r)
    widths = [base, base * 2, base * 4, base * 8]
    taps = {}
    for si, w in enumerate(widths):
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = _basic_block(g, f"s{si}.b{bi}", h, w, stride)
        taps[si] = h
    # FPN lateral 1x1 convs on the last three stages + top-down pathway
    l3 = g.conv2d("fpn.l3", taps[3], fpn_dim, 1, pad=0)
    l2 = g.conv2d("fpn.l2", taps[2], fpn_dim, 1, pad=0)
    l1 = g.conv2d("fpn.l1", taps[1], fpn_dim, 1, pad=0)
    u3 = g.upsample2x("fpn.u3", l3)
    m2 = g.add2("fpn.m2", l2, u3)
    u2 = g.upsample2x("fpn.u2", m2)
    m1 = g.add2("fpn.m1", l1, u2)
    p3 = g.conv2d("fpn.p3", l3, fpn_dim, 3)
    p2 = g.conv2d("fpn.p2", m2, fpn_dim, 3)
    p1 = g.conv2d("fpn.p1", m1, fpn_dim, 3)
    g.outputs = [p3, p2, p1]
    return g
