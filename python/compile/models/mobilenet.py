"""MobileNetV3-Small (slim) — depthwise conv + squeeze-excite under Quant-Trim.

Depthwise convolutions and the SE sigmoid gate are the classic NPU
quantization stress points (per-channel weight ranges vary wildly); this model
exists to exercise exactly that path in the backends (paper Fig 11).
"""

from ..ir import Graph


def _se(g, name, x, c, reduce=4):
    s = g.gap(f"{name}.gap", x)
    f = g.flatten(f"{name}.flat", s)
    f1 = g.linear(f"{name}.fc1", f, max(c // reduce, 4))
    r = g.act("relu", f"{name}.relu", f1)
    f2 = g.linear(f"{name}.fc2", r, c)
    hs = g.act("hsigmoid", f"{name}.gate", f2)
    scale = g.reshape(f"{name}.rs", hs, (c, 1, 1))
    return g.mul2(f"{name}.mul", x, scale)


def _bneck(g, name, x, exp, cout, k, stride, se, act):
    cin = g.node(x).out_shape[0]
    e = g.conv2d(f"{name}.exp", x, exp, 1, pad=0, bias=False)
    eb = g.bn(f"{name}.expbn", e)
    ea = g.act(act, f"{name}.expact", eb)
    eq = g.aq(f"{name}.expq", ea)
    d = g.conv2d(f"{name}.dw", eq, exp, k, stride=stride, groups=exp, bias=False)
    db = g.bn(f"{name}.dwbn", d)
    da = g.act(act, f"{name}.dwact", db)
    dq = g.aq(f"{name}.dwq", da)
    if se:
        dq = _se(g, f"{name}.se", dq, exp)
    p = g.conv2d(f"{name}.proj", dq, cout, 1, pad=0, bias=False)
    pb = g.bn(f"{name}.projbn", p)
    if stride == 1 and cin == cout:
        pb = g.add2(f"{name}.res", pb, x)
    return g.aq(f"{name}.q", pb)


def mobilenetv3_slim(num_classes=100, image=32, name="mobilenetv3"):
    g = Graph(name)
    x = g.input("image", (3, image, image))
    c = g.conv2d("stem.c", x, 16, 3, stride=1, bias=False)
    b = g.bn("stem.bn", c)
    r = g.act("hswish", "stem.act", b)
    h = g.aq("stem.q", r)
    # (exp, cout, k, stride, se, act) — V3-small schedule adapted to 32x32
    cfg = [
        (16, 16, 3, 2, True, "relu"),
        (72, 24, 3, 2, False, "relu"),
        (88, 24, 3, 1, False, "relu"),
        (96, 40, 5, 2, True, "hswish"),
        (240, 40, 5, 1, True, "hswish"),
        (120, 48, 5, 1, True, "hswish"),
        (288, 96, 5, 2, True, "hswish"),
        (576, 96, 5, 1, True, "hswish"),
    ]
    for i, (exp, cout, k, s, se, act) in enumerate(cfg):
        h = _bneck(g, f"bn{i}", h, exp, cout, k, s, se, act)
    c2 = g.conv2d("headc", h, 288, 1, pad=0, bias=False)
    b2 = g.bn("headbn", c2)
    r2 = g.act("hswish", "headact", b2)
    q2 = g.aq("headq", r2)
    p = g.gap("gap", q2)
    f = g.flatten("flat", p)
    f1 = g.linear("fc1", f, 256)
    a1 = g.act("hswish", "fc1act", f1)
    qa = g.aq("fc1q", a1)
    g.linear("head", qa, num_classes)
    return g
