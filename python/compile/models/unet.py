"""U-Net (slim) for the COCO-proxy segmentation task (paper Figs 10, 11).

Encoder-decoder with channel-concat skip connections — concat of tensors with
very different dynamic ranges is a known static-INT8 failure mode, which is
why the paper benches U-Net on the NPUs.
"""

from ..ir import Graph


def _double(g, name, x, c):
    c1 = g.conv2d(f"{name}.c1", x, c, 3, bias=False)
    b1 = g.bn(f"{name}.bn1", c1)
    r1 = g.act("relu", f"{name}.r1", b1)
    q1 = g.aq(f"{name}.q1", r1)
    c2 = g.conv2d(f"{name}.c2", q1, c, 3, bias=False)
    b2 = g.bn(f"{name}.bn2", c2)
    r2 = g.act("relu", f"{name}.r2", b2)
    return g.aq(f"{name}.q2", r2)


def unet_slim(num_classes=8, base=16, image=64, name="unet"):
    g = Graph(name)
    x = g.input("image", (3, image, image))
    e1 = _double(g, "enc1", x, base)
    p1 = g.maxpool("pool1", e1, 2, 2)
    e2 = _double(g, "enc2", p1, base * 2)
    p2 = g.maxpool("pool2", e2, 2, 2)
    e3 = _double(g, "enc3", p2, base * 4)
    p3 = g.maxpool("pool3", e3, 2, 2)
    mid = _double(g, "mid", p3, base * 8)
    u3 = g.upsample2x("up3", mid)
    cat3 = g.concat("cat3", u3, e3)
    d3 = _double(g, "dec3", cat3, base * 4)
    u2 = g.upsample2x("up2", d3)
    cat2 = g.concat("cat2", u2, e2)
    d2 = _double(g, "dec2", cat2, base * 2)
    u1 = g.upsample2x("up1", d2)
    cat1 = g.concat("cat1", u1, e1)
    d1 = _double(g, "dec1", cat1, base)
    g.conv2d("seg", d1, num_classes, 1, pad=0)
    return g
