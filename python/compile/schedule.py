"""Quant-Trim curriculum (paper §3.3).

lambda_t = 0                                   t <  E_w   (FP32 warmup)
         = min(0.5, ((t-E_w)/(E_f-E_w))^4/2)   E_w <= t < E_f  (quartic ramp)
         = 0.5 + min(1, (t-E_f)/H)^2 / 2       t >= E_f  (quadratic to full)

The identical closed form is implemented in Rust
(rust/src/coordinator/schedule.rs); python/tests/test_schedule.py and the Rust
unit tests pin the same golden values so the two stay in lock-step.
"""

from dataclasses import dataclass


@dataclass
class Curriculum:
    e_w: int = 10      # warmup end (epochs)
    e_f: int = 50      # ramp end
    horizon: int = 20  # epochs from E_f to lambda=1
    lam_max: float = 1.0  # final blend cap (Table 8: ~0.8 for transformers)
    p_clip: float = 0.95   # reverse-pruning quantile
    prune_every: int = 5   # K
    beta: float = 0.5      # tau EMA momentum
    mu: float = 1e-2       # quantile EMA momentum (per step)
    p_hi: float = 0.999
    p_lo: float = 0.001

    def lam(self, t):
        """Blend coefficient at epoch t (float ok)."""
        if t < self.e_w:
            v = 0.0
        elif t < self.e_f:
            frac = (t - self.e_w) / float(self.e_f - self.e_w)
            v = min(0.5, (frac ** 4) * 0.5)
        else:
            frac = min(1.0, (t - self.e_f) / float(self.horizon))
            v = 0.5 + (frac ** 2) * 0.5
        return min(v, self.lam_max)

    def prune_now(self, t):
        """Reverse pruning fires at warmup end and every K epochs after."""
        return t >= self.e_w and (t - self.e_w) % self.prune_every == 0


# Defaults from paper Table 7 (CIFAR-100 column) and Table 9 (ablations).
# NOTE on mu: the paper's EMA momenta (1e-3..1e-2) assume ~100-epoch runs
# (tens of thousands of steps). Our reproduction compresses the curriculum
# ~5x for CPU-PJRT budgets, so the per-step momenta scale up by the same
# factor — otherwise the embedded QAT ranges never converge and the
# exported scales clip the trained activations (see DESIGN.md §Curriculum
# compression).
CIFAR = Curriculum(e_w=10, e_f=50, horizon=20, p_clip=0.90, prune_every=5, mu=5e-2)
SEG = Curriculum(e_w=15, e_f=30, horizon=20, p_clip=0.95, prune_every=5, mu=2e-2)
TRANSFORMER = Curriculum(e_w=10, e_f=50, horizon=20, lam_max=0.8,
                         p_clip=0.97, prune_every=15, mu=2e-2)
