"""JAX interpreter over the model IR (compile/ir.py).

One interpreter serves three roles, selected by QuantCtx.mode:
  train  — batch-stat BN (running stats EMA'd), progressive fake quant
  fp32   — running-stat BN, no quantization (the "ONNX FP32 reference")
  device — running-stat BN, full fake quant with frozen scales via the
           Pallas kernels: the static-INT8 "on-device" forward

Inputs are NCHW float32. Params / state / qstate are flat dicts keyed by
node-name-derived keys (see ir.param_specs etc.).
"""

import math

import jax
import jax.numpy as jnp
from jax import lax

BN_EPS = 1e-5
BN_MOM = 0.1  # running-stat EMA momentum (torch convention)


def _conv(x, w, stride, pad, groups):
    return lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def _pool(x, k, stride, pad, kind):
    if kind == "max":
        init, op = -jnp.inf, lax.max
    else:
        init, op = 0.0, lax.add
    out = lax.reduce_window(
        x, init, op, (1, 1, k, k), (1, 1, stride, stride),
        [(0, 0), (0, 0), (pad, pad), (pad, pad)],
    )
    if kind == "avg":
        out = out / float(k * k)
    return out


def apply_graph(graph, params, bn_state, x, ctx, train=False):
    """Run the graph. Returns (output, new_bn_state)."""
    vals = {}
    new_bn = dict(bn_state)
    for n in graph.nodes:
        k = n.kind
        if k == "input":
            vals[n.name] = x
            continue
        a = [vals[i] for i in n.inputs]
        v = None
        if k == "conv2d":
            w = ctx.weight(n.name, params[f"{n.name}.w"])
            v = _conv(a[0], w, n.attrs["stride"], n.attrs["pad"], n.attrs["groups"])
            if n.attrs["bias"]:
                v = v + params[f"{n.name}.b"].reshape(1, -1, 1, 1)
        elif k == "bn":
            g = params[f"{n.name}.gamma"].reshape(1, -1, 1, 1)
            b = params[f"{n.name}.beta"].reshape(1, -1, 1, 1)
            if train:
                mean = jnp.mean(a[0], axis=(0, 2, 3))
                var = jnp.var(a[0], axis=(0, 2, 3))
                new_bn[f"{n.name}.mean"] = (1 - BN_MOM) * bn_state[f"{n.name}.mean"] + BN_MOM * mean
                new_bn[f"{n.name}.var"] = (1 - BN_MOM) * bn_state[f"{n.name}.var"] + BN_MOM * var
            else:
                mean = bn_state[f"{n.name}.mean"]
                var = bn_state[f"{n.name}.var"]
            inv = lax.rsqrt(var + BN_EPS).reshape(1, -1, 1, 1)
            v = (a[0] - mean.reshape(1, -1, 1, 1)) * inv * g + b
        elif k == "relu":
            v = jnp.maximum(a[0], 0.0)
        elif k == "relu6":
            v = jnp.clip(a[0], 0.0, 6.0)
        elif k == "hswish":
            v = a[0] * jnp.clip(a[0] + 3.0, 0.0, 6.0) / 6.0
        elif k == "hsigmoid":
            v = jnp.clip(a[0] + 3.0, 0.0, 6.0) / 6.0
        elif k == "gelu":
            # tanh approximation — matches the Rust engine implementation
            c = math.sqrt(2.0 / math.pi)
            v = 0.5 * a[0] * (1.0 + jnp.tanh(c * (a[0] + 0.044715 * a[0] ** 3)))
        elif k == "silu":
            v = a[0] * jax.nn.sigmoid(a[0])
        elif k == "sigmoid":
            v = jax.nn.sigmoid(a[0])
        elif k == "add":
            v = a[0] + a[1]
        elif k == "mul":
            v = a[0] * a[1]
        elif k == "maxpool":
            v = _pool(a[0], n.attrs["k"], n.attrs["stride"], n.attrs["pad"], "max")
        elif k == "avgpool":
            v = _pool(a[0], n.attrs["k"], n.attrs["stride"], n.attrs["pad"], "avg")
        elif k == "gap":
            v = jnp.mean(a[0], axis=(2, 3), keepdims=True)
        elif k == "upsample2x":
            v = jnp.repeat(jnp.repeat(a[0], 2, axis=2), 2, axis=3)
        elif k == "concat":
            v = jnp.concatenate(a, axis=1)
        elif k == "flatten":
            v = a[0].reshape(a[0].shape[0], -1)
        elif k == "reshape":
            v = a[0].reshape((a[0].shape[0],) + tuple(n.attrs["shape"]))
        elif k == "linear":
            w = ctx.weight(n.name, params[f"{n.name}.w"])
            v = a[0] @ w.T
            if n.attrs["bias"]:
                v = v + params[f"{n.name}.b"]
        elif k == "layernorm":
            mean = jnp.mean(a[0], axis=-1, keepdims=True)
            var = jnp.var(a[0], axis=-1, keepdims=True)
            v = (a[0] - mean) * lax.rsqrt(var + 1e-6)
            v = v * params[f"{n.name}.gamma"] + params[f"{n.name}.beta"]
        elif k == "attention":
            v = _attention(n, params, a[0], ctx)
        elif k == "to_tokens":
            b, c, hh, ww = a[0].shape
            v = a[0].reshape(b, c, hh * ww).transpose(0, 2, 1)
        elif k == "tokmean":
            v = jnp.mean(a[0], axis=1)
        elif k == "aq":
            v = ctx.activation(n.name, a[0])
        else:
            raise ValueError(f"unknown node kind {k!r}")
        vals[n.name] = v
    outs = [vals[o] for o in graph.output_names]
    return (outs[0] if len(outs) == 1 else tuple(outs)), new_bn


def _attention(n, params, x, ctx):
    """Multi-head self-attention; QKV and output projections fake-quantized
    per-tensor, softmax scores kept FP (paper Table 8)."""
    b, t, d = x.shape
    h = n.attrs["heads"]
    dh = d // h

    def proj(mat_name, bias_name, inp):
        w = ctx.weight_scalar(f"{n.name}.{mat_name}", params[f"{n.name}.{mat_name}"])
        return inp @ w.T + params[f"{n.name}.{bias_name}"]

    q = proj("wq", "qb", x).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    kk = proj("wk", "kb", x).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    vv = proj("wv", "vb", x).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
    scores = (q @ kk.transpose(0, 1, 3, 2)) / math.sqrt(dh)
    att = jax.nn.softmax(scores, axis=-1)
    out = (att @ vv).transpose(0, 2, 1, 3).reshape(b, t, d)
    return proj("wo", "ob", out)
