"""Quant points: robust statistics, EMA state, progressive fake quantization.

This is the L2 glue between the model interpreter and the L1 kernels:
every weight tensor and every `aq` node in the graph passes through here.

Two numerically identical fake-quant implementations are available:

  * the Pallas kernels (kernels.fake_quant / kernels.blend), used in the
    exported device-forward artifact and benchmarked/validated by pytest;
  * a pure-jnp path (kernels.ref), used inside the *training* graph where the
    quant point runs at every tensor of every step — the interpret-mode grid
    machinery would dominate CPU step time (see DESIGN.md §Perf, L2).

python/tests/test_quant.py asserts the two paths agree bit-for-bit, which is
what licenses the swap.
"""

import jax.numpy as jnp
from jax import lax

from .kernels import fake_quant as fq_pallas
from .kernels import ref

S_MAX_ACT = 4096     # activation subsample budget inside the train graph
S_MAX_W = 100_000    # weight subsample (paper: 1e5)


def subsample(flat, s_max):
    n = flat.shape[0]
    if n > s_max:
        stride = -(-n // s_max)
        flat = flat[::stride]
    return flat


class QuantCtx:
    """Per-forward quantization context.

    mode:
      "fp32"    no fake quant (MAP baseline / plain eval)
      "train"   progressive fake quant, EMA stats updated, jnp path
      "device"  full fake quant (lam=1) with frozen stats, Pallas path —
                this is the exported static-INT8 device forward
    """

    def __init__(self, mode, qstate, lam=None, mu=1e-2, p_hi=0.999, p_lo=0.001,
                 p_hi_act=0.9999, fq_enabled=True, per_channel=True):
        self.mode = mode
        self.qstate = qstate
        self.new_qstate = dict(qstate)
        self.lam = lam
        self.mu = mu
        self.p_hi = p_hi
        self.p_lo = p_lo
        # Activation ranges track a near-max quantile rather than the weight
        # p99.9: the paper's blend passes gradients everywhere ("gradients
        # always follow FP32"), so nothing in the loss stops activations from
        # outgrowing a tight clip range — with p99.9 the train-time forward
        # saturates while the FP32 eval forward drifts arbitrarily far
        # (observed as a compensation spiral in short runs). Near-max ranges
        # keep train/eval forwards aligned; tail compression comes from
        # reverse pruning on the weights, as in the paper's Fig 2.
        self.p_hi_act = p_hi_act
        self.fq_enabled = fq_enabled
        self.per_channel = per_channel

    # ---- weights (symmetric INT8, per-output-channel) ----

    def weight(self, name, w):
        if self.mode == "fp32" or not self.fq_enabled:
            return w
        cout = w.shape[0]
        w2 = w.reshape(cout, -1)
        if self.mode == "train":
            # statistics are stop-grad: scales must not carry gradients
            # (paper: "gradients always follow FP32")
            aw = lax.stop_gradient(jnp.abs(w2))
            if self.per_channel:
                m = ref.empirical_quantile(aw, self.p_hi, axis=1)
            else:
                m = jnp.broadcast_to(ref.tensor_quantile(aw, self.p_hi, S_MAX_W), (cout,))
            m_ema = ref.ema(self.qstate[f"{name}.m"], m, self.mu)
            self.new_qstate[f"{name}.m"] = m_ema
        else:
            m_ema = self.qstate[f"{name}.m"]
        s = ref.weight_scale(m_ema).reshape(cout, *([1] * (w.ndim - 1)))
        if self.mode == "device":
            wq = fq_pallas.fake_quant_sym(w2, s.reshape(cout), channel_axis=0).reshape(w.shape)
            return wq
        wq = ref.fake_quant_sym(w, s)
        return w + self.lam * lax.stop_gradient(wq - w)

    def weight_scalar(self, name, w):
        """Per-tensor symmetric weight quant (attention matrices)."""
        if self.mode == "fp32" or not self.fq_enabled:
            return w
        if self.mode == "train":
            m = ref.tensor_quantile(lax.stop_gradient(jnp.abs(w)), self.p_hi, S_MAX_W)
            m_ema = ref.ema(self.qstate[f"{name}.m"], m, self.mu)
            self.new_qstate[f"{name}.m"] = m_ema
        else:
            m_ema = self.qstate[f"{name}.m"]
        s = ref.weight_scale(m_ema)
        if self.mode == "device":
            return fq_pallas.fake_quant_sym(w, s)
        wq = ref.fake_quant_sym(w, s)
        return w + self.lam * lax.stop_gradient(wq - w)

    # ---- activations (asymmetric UINT8, per-tensor) ----

    def activation(self, name, x):
        if self.mode == "fp32" or not self.fq_enabled:
            return x
        if self.mode == "train":
            # exact batch min/max (cheap: no sort). See p_hi_act note above —
            # subsampled quantiles systematically miss the rare spikes, which
            # both feeds the compensation spiral and mis-scales deployment.
            xs = lax.stop_gradient(x)
            lo = jnp.min(xs)
            hi = jnp.max(xs)
            lo_ema = ref.ema(self.qstate[f"{name}.lo"], lo, self.mu)
            hi_ema = ref.ema(self.qstate[f"{name}.hi"], hi, self.mu)
            self.new_qstate[f"{name}.lo"] = lo_ema
            self.new_qstate[f"{name}.hi"] = hi_ema
        else:
            lo_ema = self.qstate[f"{name}.lo"]
            hi_ema = self.qstate[f"{name}.hi"]
        s, z = ref.act_scale_zp(lo_ema, hi_ema)
        if self.mode == "device":
            return fq_pallas.fake_quant_asym(x, s, z)
        xq = ref.fake_quant_asym(x, s, z)
        return x + self.lam * lax.stop_gradient(xq - x)
