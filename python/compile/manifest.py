"""`.manifest` text format, shared with rust/src/runtime/manifest.rs.

One manifest per model. Sections:

    model <name>
    qir <file>
    ckpt <file>
    artifact <fn-name> <hlo-file>
    arg <fn-name> <idx> <role> <key> <dtype> <d0,d1,...|scalar>
    ret <fn-name> <idx> <role> <key> <dtype> <dims>

Roles: param | bn | qstate | opt_m | opt_v | step | data | label | scalar | out
Keys within a role are the sorted dict keys — identical to jax's dict
flattening order, so Rust can marshal state dict -> HLO args positionally.
"""


class Manifest:
    def __init__(self, model):
        self.lines = [f"model {model}"]

    def file(self, kind, path):
        self.lines.append(f"{kind} {path}")

    def artifact(self, fn, hlo_path):
        self.lines.append(f"artifact {fn} {hlo_path}")

    def arg(self, fn, idx, role, key, shape, dtype="f32"):
        dims = ",".join(str(d) for d in shape) if len(shape) else "scalar"
        self.lines.append(f"arg {fn} {idx} {role} {key} {dtype} {dims}")

    def ret(self, fn, idx, role, key, shape, dtype="f32"):
        dims = ",".join(str(d) for d in shape) if len(shape) else "scalar"
        self.lines.append(f"ret {fn} {idx} {role} {key} {dtype} {dims}")

    def save(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")
