"""AOT export: lower every training/eval/device graph to HLO text + emit the
QIR graph, initial checkpoint, and manifest that the Rust coordinator loads.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Run via `make artifacts`:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import ckpt, ir, train
from .manifest import Manifest
from .models import BUILDERS
from .schedule import CIFAR, SEG, TRANSFORMER


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree)


def _record(man, fn, role_trees_in, role_trees_out):
    """Record arg/ret order. role_trees: list of (role, tree) where tree is a
    dict (sorted-key order) or a bare array."""
    idx = 0
    for role, tree in role_trees_in:
        if isinstance(tree, dict):
            for k in sorted(tree):
                man.arg(fn, idx, role, k, np.shape(tree[k]),
                        "i32" if np.asarray(tree[k]).dtype == np.int32 else "f32")
                idx += 1
        else:
            man.arg(fn, idx, role, role, np.shape(tree),
                    "i32" if np.asarray(tree).dtype == np.int32 else "f32")
            idx += 1
    idx = 0
    for role, tree in role_trees_out:
        if isinstance(tree, dict):
            for k in sorted(tree):
                man.ret(fn, idx, role, k, np.shape(tree[k]))
                idx += 1
        else:
            man.ret(fn, idx, role, role, np.shape(tree))
            idx += 1


# model -> (task, train_batch, eval_batch, curriculum)
CONFIGS = {
    "resnet18": ("cls", 32, 64, CIFAR),
    "resnet18_c10": ("cls", 32, 64, CIFAR),
    "resnet50": ("cls", 32, 64, CIFAR),
    "vit": ("cls", 32, 64, TRANSFORMER),
    "mobilenetv3": ("cls", 32, 64, CIFAR),
    "unet": ("seg", 8, 8, SEG),
    "sam_student": ("distill", 8, 8, SEG),
}


def export_model(name, out_dir, quiet=False):
    task, bt, be, cur = CONFIGS[name]
    graph = BUILDERS[name]()
    man = Manifest(name)

    def log(msg):
        if not quiet:
            print(f"[aot] {name}: {msg}", flush=True)

    # --- static artifacts: QIR graph + init checkpoint
    qir_path = f"{name}.qir"
    with open(os.path.join(out_dir, qir_path), "w") as f:
        f.write(graph.to_text())
    man.file("qir", qir_path)

    params = train.init_params(graph, seed=0)
    bnst = train.init_bn_state(graph)
    qstate = train.init_qstate(graph, params, p_clip=cur.p_clip)
    m, v = train.init_opt(params)
    ck_path = f"{name}.init.qtckpt"
    merged = {}
    merged.update({f"param/{k}": x for k, x in params.items()})
    merged.update({f"bn/{k}": x for k, x in bnst.items()})
    merged.update({f"qstate/{k}": x for k, x in qstate.items()})
    ckpt.save(os.path.join(out_dir, ck_path), merged)
    man.file("ckpt", ck_path)
    log(f"{len(params)} param tensors, "
        f"{sum(int(np.prod(np.shape(p))) for p in params.values())} params")

    img = graph.node("image").out_shape  # (C, H, W)
    step0 = jnp.float32(0.0)
    lam0 = jnp.float32(0.0)
    lr0 = jnp.float32(3e-4)

    def dump(fn_name, fn, example_args, roles_in, roles_out):
        lowered = jax.jit(fn).lower(*_sds(example_args))
        path = f"{name}.{fn_name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        man.artifact(fn_name, path)
        _record(man, fn_name, roles_in, roles_out)
        log(f"exported {fn_name}")

    if task in ("cls", "seg"):
        x_t = np.zeros((bt,) + img, np.float32)
        if task == "cls":
            y_t = np.zeros((bt,), np.int32)
        else:
            y_t = np.zeros((bt,) + img[1:], np.int32)

        roles_out = [("param", params), ("bn", bnst), ("qstate", qstate),
                     ("opt_m", m), ("opt_v", v), ("step", step0),
                     ("loss", step0), ("metric", step0)]
        step_fn = train.make_train_step(graph, task=task, fq_enabled=True, mu=cur.mu)
        dump("train_step", step_fn,
             (params, bnst, qstate, m, v, step0, x_t, y_t, lam0, lr0),
             [("param", params), ("bn", bnst), ("qstate", qstate),
              ("opt_m", m), ("opt_v", v), ("step", step0),
              ("data", x_t), ("label", y_t), ("lam", lam0), ("lr", lr0)],
             roles_out)
        # the FP32/MAP step never reads lam — exclude it from the interface,
        # or jax's lowering DCEs the parameter and the positional contract
        # with the Rust marshaller breaks
        fp32_fn = train.make_train_step(graph, task=task, fq_enabled=False, mu=cur.mu)

        def fp32_step(params, bnst, qstate, m, v, step, x, y, lr):
            import jax.numpy as _jnp
            return fp32_fn(params, bnst, qstate, m, v, step, x, y, _jnp.float32(0.0), lr)

        dump("train_step_fp32", fp32_step,
             (params, bnst, qstate, m, v, step0, x_t, y_t, lr0),
             [("param", params), ("bn", bnst), ("qstate", qstate),
              ("opt_m", m), ("opt_v", v), ("step", step0),
              ("data", x_t), ("label", y_t), ("lr", lr0)],
             roles_out)

        x_e = np.zeros((be,) + img, np.float32)
        fwd = train.make_forward(graph)
        out_shape = (be,) + graph.node(graph.output).out_shape
        dump("forward", fwd, (params, bnst, x_e),
             [("param", params), ("bn", bnst), ("data", x_e)],
             [("out", np.zeros(out_shape, np.float32))])

        x_1 = np.zeros((1,) + img, np.float32)
        dump("forward_b1", fwd, (params, bnst, x_1),
             [("param", params), ("bn", bnst), ("data", x_1)],
             [("out", np.zeros((1,) + graph.node(graph.output).out_shape, np.float32))])

        dev = train.make_device_forward(graph)
        # exclude .tau from the device-forward interface: the function never
        # reads it, so jax's lowering DCEs those parameters and the positional
        # interface would no longer match the manifest
        qs_dev = {k: v for k, v in qstate.items() if not k.endswith(".tau")}
        dump("device_forward", dev, (params, bnst, qs_dev, x_e),
             [("param", params), ("bn", bnst), ("qstate", qs_dev), ("data", x_e)],
             [("out", np.zeros(out_shape, np.float32))])

    elif task == "distill":
        teacher = BUILDERS["sam_teacher"]()
        tparams = train.init_params(teacher, seed=7)
        tbnst = train.init_bn_state(teacher)
        tck = {f"param/{k}": x for k, x in tparams.items()}
        tck.update({f"bn/{k}": x for k, x in tbnst.items()})
        ckpt.save(os.path.join(out_dir, "sam_teacher.init.qtckpt"), tck)
        with open(os.path.join(out_dir, "sam_teacher.qir"), "w") as f:
            f.write(teacher.to_text())
        man.file("teacher_ckpt", "sam_teacher.init.qtckpt")
        man.file("teacher_qir", "sam_teacher.qir")

        x_t = np.zeros((bt,) + img, np.float32)
        dstep = train.make_distill_step(graph, teacher, mu=cur.mu)
        args = (params, bnst, qstate, m, v, step0, tparams, tbnst, x_t, lam0, lr0)
        roles_in = [("param", params), ("bn", bnst), ("qstate", qstate),
                    ("opt_m", m), ("opt_v", v), ("step", step0),
                    ("tparam", tparams), ("tbn", tbnst),
                    ("data", x_t), ("lam", lam0), ("lr", lr0)]
        roles_out = [("param", params), ("bn", bnst), ("qstate", qstate),
                     ("opt_m", m), ("opt_v", v), ("step", step0),
                     ("loss", step0), ("metric", step0)]
        dump("distill_step", dstep, args, roles_in, roles_out)

        # student forward (3 FPN scales) for feature-fidelity checks
        fwd = train.make_forward(graph)
        x_e = np.zeros((be,) + img, np.float32)
        outs = {f"feat{i}": np.zeros((be,) + graph.node(o).out_shape, np.float32)
                for i, o in enumerate(graph.output_names)}
        dump("forward", fwd, (params, bnst, x_e),
             [("param", params), ("bn", bnst), ("data", x_e)],
             [("out", outs)])

    # --- reverse pruning (per-curriculum p_clip; ablation model gets a sweep)
    taus = {k: qstate[k] for k in qstate if k.endswith(".tau")}
    pclips = (0.90, 0.95, 0.99) if name == "resnet18_c10" else (cur.p_clip,)
    for pc in pclips:
        rp = train.make_reverse_prune(graph, p_clip=pc, beta=cur.beta)
        fn_name = f"reverse_prune_{int(round(pc * 100))}"
        dump(fn_name, rp, (params, taus),
             [("param", params), ("tau", taus)],
             [("param", params), ("tau", taus)])

    man.save(os.path.join(out_dir, f"{name}.manifest"))
    log("manifest written")


def export_kernel_artifacts(out_dir, quiet=False):
    """Standalone L1 kernel HLOs for Rust-side kernel benches/cross-checks."""
    from .kernels import fake_quant as fq
    from .kernels import qmatmul as qmm
    from .kernels import ref

    man = Manifest("kernels")

    def qmatmul_fp(x, w):
        sx = jnp.float32(0.05)
        zx = jnp.float32(128.0)
        sw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-6) / 127.0
        wq = ref.quantize_sym(w, sw).astype(jnp.int8)
        return qmm.qmatmul(x, wq, sx, zx, sw)

    x = np.zeros((256, 256), np.float32)
    w = np.zeros((256, 256), np.float32)
    lowered = jax.jit(qmatmul_fp).lower(*_sds((x, w)))
    with open(os.path.join(out_dir, "kernel_qmatmul.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    man.artifact("qmatmul", "kernel_qmatmul.hlo.txt")
    man.arg("qmatmul", 0, "data", "x", (256, 256))
    man.arg("qmatmul", 1, "data", "w", (256, 256))
    man.ret("qmatmul", 0, "out", "out", (256, 256))

    def fq_fp(x):
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-6) / 127.0
        return fq.fake_quant_sym(x, s)

    xa = np.zeros((64, 4096), np.float32)
    lowered = jax.jit(fq_fp).lower(*_sds((xa,)))
    with open(os.path.join(out_dir, "kernel_fake_quant.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    man.artifact("fake_quant", "kernel_fake_quant.hlo.txt")
    man.arg("fake_quant", 0, "data", "x", (64, 4096))
    man.ret("fake_quant", 0, "out", "out", (64, 4096))

    man.save(os.path.join(out_dir, "kernels.manifest"))
    if not quiet:
        print("[aot] kernel artifacts written", flush=True)


def export_paper_scale_graphs(out_dir, quiet=False):
    """QIR-only exports at the paper's full input sizes (224^2 / 512^2) for
    the roofline perf model (Figs 3, 7, 11; Table 10). No training artifacts —
    the perf model needs only MAC/byte counts, so these cost nothing to emit
    and keep the latency/power *shape* reproduction at the paper's scale."""
    from .models.mobilenet import mobilenetv3_slim
    from .models.resnet import resnet50_slim, resnet_backbone_fpn
    from .models.unet import unet_slim
    from .models.vit import vit_dinov2_slim

    graphs = [
        resnet50_slim(num_classes=1000, base=64, image=224, name="resnet50_paper"),
        vit_dinov2_slim(num_classes=1000, dim=384, depth=12, heads=6, mlp=1536,
                        patch=16, image=224, name="vit_paper"),
        mobilenetv3_slim(num_classes=1000, image=224, name="mobilenetv3_paper"),
        unet_slim(num_classes=8, base=32, image=224, name="unet_paper"),
        resnet_backbone_fpn("sam_paper", base=64, image=512, fpn_dim=64),
    ]
    for g in graphs:
        with open(os.path.join(out_dir, f"{g.name}.qir"), "w") as f:
            f.write(g.to_text())
        if not quiet:
            print(f"[aot] paper-scale graph {g.name}.qir", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(CONFIGS))
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    export_kernel_artifacts(args.out_dir, args.quiet)
    export_paper_scale_graphs(args.out_dir, args.quiet)
    for name in args.models.split(","):
        export_model(name, args.out_dir, args.quiet)
    # stamp for make's up-to-date check
    with open(os.path.join(args.out_dir, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
