"""Training-graph construction: init, AdamW, Quant-Trim train step, eval and
device forwards, reverse pruning (Algorithm 1).

Everything here is built to be lowered ONCE by aot.py and then driven from the
Rust coordinator: functions take/return flat dicts of arrays; flattening order
for the HLO interface is sorted key order (jax's own dict flattening order),
recorded in the manifest.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from . import ir, jax_exec
from .kernels import ref
from .kernels import reverse_prune as rp_pallas
from .quant import QuantCtx

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# ---------------------------------------------------------------- init

def init_params(graph, seed=0):
    rng = np.random.default_rng(seed)
    out = {}
    for name, shape, kind in ir.param_specs(graph):
        if kind in ("conv_w", "linear_w"):
            fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
            std = math.sqrt(2.0 / fan_in)
            out[name] = rng.normal(0.0, std, size=shape).astype(np.float32)
        elif kind == "bias":
            out[name] = np.zeros(shape, np.float32)
        elif kind in ("bn", "ln"):
            fill = 1.0 if name.endswith(".gamma") else 0.0
            out[name] = np.full(shape, fill, np.float32)
    return out


def init_bn_state(graph):
    out = {}
    for name, shape in ir.bn_state_specs(graph):
        fill = 1.0 if name.endswith(".var") else 0.0
        out[name] = np.full(shape, fill, np.float32)
    return out


def _np_quantile(x, p, axis=None):
    """Paper-definition empirical quantile (x_(ceil(pn)), no interpolation) —
    numpy twin of kernels.ref.empirical_quantile."""
    xs = np.sort(x, axis=axis if axis is not None else None)
    if axis is None:
        n = xs.size
        return xs[min(n - 1, max(0, math.ceil(p * n) - 1))]
    n = xs.shape[axis]
    idx = min(n - 1, max(0, math.ceil(p * n) - 1))
    return np.take(xs, idx, axis=axis)


def init_qstate(graph, params, p_hi=0.999, p_clip=0.95):
    """Quant statistics seeded from the initial weights so the EMA starts in
    the right ballpark (activations start at a generic [0, 6] range)."""
    out = {}
    for name, shape in ir.qstate_specs(graph):
        base = name.rsplit(".", 1)[0]
        if name.endswith(".m"):
            # conv/linear: qstate "node.m" <- param "node.w";
            # attention:   qstate "node.wq.m" <- param "node.wq"
            w = np.asarray(params[f"{base}.w"]) if f"{base}.w" in params \
                else np.asarray(params[base])
            if shape == ():
                out[name] = np.float32(_np_quantile(np.abs(w).ravel(), p_hi))
            else:
                w2 = np.abs(w.reshape(w.shape[0], -1))
                out[name] = _np_quantile(w2, p_hi, axis=1).astype(np.float32)
        elif name.endswith(".tau"):
            w = np.asarray(params[f"{base}.w"]) if f"{base}.w" in params \
                else np.asarray(params[f"{base}.wq"])
            out[name] = np.float32(_np_quantile(np.abs(w).ravel(), p_clip))
        elif name.endswith(".lo"):
            out[name] = np.float32(0.0)
        elif name.endswith(".hi"):
            out[name] = np.float32(6.0)
    return out


def init_opt(params):
    zeros = {k: np.zeros_like(np.asarray(v)) for k, v in params.items()}
    return zeros, {k: v.copy() for k, v in zeros.items()}


# ---------------------------------------------------------------- losses

def softmax_xent(logits, labels):
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logz, labels[:, None], axis=1))


def seg_xent(logits, labels):
    """logits (B, C, H, W), labels (B, H, W) int32."""
    logz = jax.nn.log_softmax(logits, axis=1)
    picked = jnp.take_along_axis(logz, labels[:, None, :, :], axis=1)
    return -jnp.mean(picked)


def huber(x, delta=1.0):
    ax = jnp.abs(x)
    return jnp.where(ax <= delta, 0.5 * x * x, delta * (ax - 0.5 * delta))


# ---------------------------------------------------------------- steps

def _adamw(params, grads, m, v, step, lr, wd):
    step = step + 1.0
    new_p, new_m, new_v = {}, {}, {}
    bc1 = 1.0 - ADAM_B1 ** step
    bc2 = 1.0 - ADAM_B2 ** step
    for k in params:
        g = grads[k]
        mk = ADAM_B1 * m[k] + (1 - ADAM_B1) * g
        vk = ADAM_B2 * v[k] + (1 - ADAM_B2) * g * g
        upd = (mk / bc1) / (jnp.sqrt(vk / bc2) + ADAM_EPS)
        new_p[k] = params[k] - lr * (upd + wd * params[k])
        new_m[k] = mk
        new_v[k] = vk
    return new_p, new_m, new_v, step


def make_train_step(graph, task="cls", fq_enabled=True, mu=1e-2, wd=0.01,
                    per_channel=True):
    """Returns fn(params, bnst, qstate, m, v, step, x, y, lam, lr) ->
    (params, bnst, qstate, m, v, step, loss, metric)."""

    def loss_fn(params, bnst, qstate, x, y, lam):
        ctx = QuantCtx("train", qstate, lam=lam, mu=mu, fq_enabled=fq_enabled,
                       per_channel=per_channel)
        logits, new_bn = jax_exec.apply_graph(graph, params, bnst, x, ctx, train=True)
        if task == "cls":
            loss = softmax_xent(logits, y)
            metric = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
        else:
            loss = seg_xent(logits, y)
            metric = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, (new_bn, ctx.new_qstate, metric)

    def step_fn(params, bnst, qstate, m, v, step, x, y, lam, lr):
        (loss, (new_bn, new_q, metric)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bnst, qstate, x, y, lam)
        new_p, new_m, new_v, new_step = _adamw(params, grads, m, v, step, lr, wd)
        return new_p, new_bn, new_q, new_m, new_v, new_step, loss, metric

    return step_fn


def make_distill_step(student, teacher, mu=1e-2, wd=1e-4, scale_w=(1.0, 0.25, 0.125)):
    """Three-scale FPN Huber distillation (paper §5.2) with Quant-Trim on the
    student. Teacher params/bn are frozen inputs."""

    def loss_fn(params, bnst, qstate, tparams, tbnst, x, lam):
        ctx = QuantCtx("train", qstate, lam=lam, mu=mu)
        sfeats, new_bn = jax_exec.apply_graph(student, params, bnst, x, ctx, train=True)
        tctx = QuantCtx("fp32", {})
        tfeats, _ = jax_exec.apply_graph(teacher, tparams, tbnst, x, tctx, train=False)
        loss = 0.0
        for w, sf, tf in zip(scale_w, sfeats, tfeats):
            loss = loss + w * jnp.mean(huber(sf - jax.lax.stop_gradient(tf)))
        # feature-alignment metric: mean per-scale MSE (Fig 6 quantitative proxy)
        mse = jnp.mean((sfeats[0] - tfeats[0]) ** 2)
        return loss, (new_bn, ctx.new_qstate, mse)

    def step_fn(params, bnst, qstate, m, v, step, tparams, tbnst, x, lam, lr):
        (loss, (new_bn, new_q, mse)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, bnst, qstate, tparams, tbnst, x, lam)
        new_p, new_m, new_v, new_step = _adamw(params, grads, m, v, step, lr, wd)
        return new_p, new_bn, new_q, new_m, new_v, new_step, loss, mse

    return step_fn


def make_forward(graph):
    """FP32 eval forward (the ONNX-reference analogue)."""

    def fwd(params, bnst, x):
        ctx = QuantCtx("fp32", {})
        out, _ = jax_exec.apply_graph(graph, params, bnst, x, ctx, train=False)
        return out

    return fwd


def make_device_forward(graph):
    """Static-INT8 device forward: full fake quant, frozen scales, Pallas
    kernels. Cross-checks the Rust integer engine."""

    def fwd(params, bnst, qstate, x):
        ctx = QuantCtx("device", qstate)
        out, _ = jax_exec.apply_graph(graph, params, bnst, x, ctx, train=False)
        return out

    return fwd


def make_reverse_prune(graph, p_clip=0.95, beta=0.5):
    """fn(params, taus) -> (clipped params, updated taus). Pallas clip kernel.

    tau EMA: tau' = (1-beta) tau + beta * Q_{|w|}(p_clip); w <- clip(w, ±tau').
    """
    wkeys = []
    for n in graph.nodes:
        if n.kind in ("conv2d", "linear"):
            wkeys.append((f"{n.name}.w", f"{n.name}.tau", None))
        elif n.kind == "attention":
            for p in ("wq", "wk", "wv", "wo"):
                wkeys.append((f"{n.name}.{p}", f"{n.name}.tau", p))

    def prune(params, taus):
        new_p = dict(params)
        new_t = dict(taus)
        for wk, tk, _sub in wkeys:
            w = params[wk]
            that = ref.tensor_quantile(jnp.abs(w), p_clip)
            tnew = (1.0 - beta) * new_t[tk] + beta * that
            new_t[tk] = tnew
            new_p[wk] = rp_pallas.reverse_prune(w, tnew)
        return new_p, new_t

    return prune
