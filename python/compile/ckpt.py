"""`.qtckpt` binary checkpoint format, shared with rust/src/ckpt/.

Layout (little-endian):
    magic   b"QTCK"
    u32     version (1)
    u32     record count
  per record:
    u16     name length, then name bytes (utf-8)
    u8      dtype (0 = f32)
    u8      ndim
    u32*n   dims
    raw     f32 data, C-contiguous
"""

import struct

import numpy as np

MAGIC = b"QTCK"
VERSION = 1


def save(path, tensors):
    """tensors: dict name -> np.ndarray (float32). Written in sorted key order
    (the same order the HLO interface uses)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name in sorted(tensors):
            arr = np.asarray(tensors[name], dtype=np.float32)
            if arr.ndim and not arr.flags.c_contiguous:
                # NB: np.ascontiguousarray would promote 0-d arrays to 1-d,
                # breaking the scalar contract with the Rust reader
                arr = np.ascontiguousarray(arr)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def load(path):
    with open(path, "rb") as f:
        data = f.read()
    assert data[:4] == MAGIC, "bad magic"
    version, count = struct.unpack_from("<II", data, 4)
    assert version == VERSION
    off = 12
    out = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off:off + nlen].decode()
        off += nlen
        dtype, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", data, off) if ndim else ()
        off += 4 * ndim
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, dtype=np.float32, count=n, offset=off).reshape(dims)
        off += 4 * n
        out[name] = arr.copy()
    return out
