"""Build-time Python package: Layer-2 JAX model/training graphs and Layer-1
Pallas kernels, AOT-lowered to HLO text artifacts consumed by the Rust
coordinator. Never imported at runtime."""
