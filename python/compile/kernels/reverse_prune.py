"""Pallas reverse-pruning kernel (L1).

w <- clip(w, -tau, tau): pin the scale-setting weight tails at the EMA'd
quantile threshold. Applied every K epochs after warmup (Algorithm 1, line 4).
Per-channel tau rides along as a (ROW_BLK, 1) block, same layout trick as
fake_quant.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLK = 8
COL_BLK = 128


def _rp_kernel(w_ref, tau_ref, o_ref):
    tau = tau_ref[...]  # (rows, 1)
    o_ref[...] = jnp.clip(w_ref[...], -tau, tau)


@jax.jit
def reverse_prune_2d(w, tau):
    """w: (R, C); tau: (R, 1) per-channel or (1, 1) per-tensor thresholds."""
    r, c = w.shape
    if tau.shape[0] == 1 and r > 1:
        tau = jnp.broadcast_to(tau, (r, 1))
    pr = (-r) % ROW_BLK
    pc = (-c) % COL_BLK
    if pr or pc:
        w = jnp.pad(w, ((0, pr), (0, pc)))
    taup = jnp.pad(tau, ((0, w.shape[0] - r), (0, 0)), constant_values=1.0)
    grid = (w.shape[0] // ROW_BLK, w.shape[1] // COL_BLK)
    out = pl.pallas_call(
        _rp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
            pl.BlockSpec((ROW_BLK, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=True,
    )(w, taup)
    return out[:r, :c]


def reverse_prune(w, tau, channel_axis=None):
    """Arbitrary-rank tail pinning.

    channel_axis=None -> scalar tau; otherwise tau has shape (w.shape[axis],).
    """
    if channel_axis is None:
        w2 = w.reshape(1, -1)
        t2 = jnp.asarray(tau, w.dtype).reshape(1, 1)
        return reverse_prune_2d(w2, t2).reshape(w.shape)
    wm = jnp.moveaxis(w, channel_axis, 0)
    shp = wm.shape
    out = reverse_prune_2d(wm.reshape(shp[0], -1), jnp.asarray(tau, w.dtype).reshape(shp[0], 1))
    return jnp.moveaxis(out.reshape(shp), 0, channel_axis)
