"""Pallas int8-simulated matmul (L1 hot spot).

The deployed-NPU inner loop: quantize the activation tile asymmetrically,
the weight tile is symmetric INT8, accumulate (xq - zx) @ wq in int32, and
requantize the finished tile back to float with the combined scale sx*sw.
Fusing quantize -> int-matmul -> requantize in one kernel means the activation
tile is quantized exactly once while VMEM-resident — the NPU-SRAM dataflow the
paper's backends rely on, re-expressed for the TPU memory hierarchy
(DESIGN.md §Hardware-Adaptation).

Block shapes: (BM, BK) x (BK, BN) with BM=BN=BK=128 — MXU-shaped tiles. The
K grid dimension is innermost so the int32 accumulator tile stays resident in
the output block across the K loop (revolving accumulation pattern).

interpret=True only on CPU; the int32 dot lowers to an XLA dot with
preferred_element_type=s32, which is exactly the arithmetic the Rust engine
implements.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM = 128
BN = 128
BK = 128


def _qmm_kernel(x_ref, w_ref, sx_ref, zx_ref, sw_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    sx = sx_ref[0, 0]
    zx = zx_ref[0, 0]
    xq = jnp.clip(jnp.round(x_ref[...] / sx) + zx, 0.0, 255.0).astype(jnp.int32)
    zq = jnp.round(zx).astype(jnp.int32)
    # weights arrive pre-quantized as int8 values stored in int8
    wq = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        xq - zq,
        wq,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        sw = sw_ref[0, 0]
        o_ref[...] = acc_ref[...].astype(jnp.float32) * (sx * sw)


@jax.jit
def qmatmul(x, wq_int8, sx, zx, sw):
    """Int8-simulated matmul: float x (M,K) times pre-quantized w (K,N) int8.

    Returns float32 (M, N). Matches kernels.ref.qmatmul_int8 with
    wq_int8 = quantize_sym(w, sw).astype(int8).
    """
    m, kdim = x.shape
    k2, n = wq_int8.shape
    assert kdim == k2
    pm, pk, pn = (-m) % BM, (-kdim) % BK, (-n) % BN
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(wq_int8, ((0, pk), (0, pn)))
    grid = (xp.shape[0] // BM, wp.shape[1] // BN, xp.shape[1] // BK)
    sx2 = jnp.asarray(sx, jnp.float32).reshape(1, 1)
    zx2 = jnp.asarray(zx, jnp.float32).reshape(1, 1)
    sw2 = jnp.asarray(sw, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.int32)],
        interpret=True,
    )(xp, wp, sx2, zx2, sw2)
    return out[:m, :n]
