"""Pallas progressive-blend kernel (L1).

x~ = x + lambda * (x^ - x), the curriculum interpolation between the FP32 and
fake-quantized forward. A trivially bandwidth-bound elementwise kernel; the
point of fusing it is that during the ramp both x and x^ are live, and the
blend is the last op before the tensor leaves VMEM.

The caller (compile/quant.py) wraps the fake-quant term in stop_gradient, so
gradients follow FP32 exactly as in the paper (STE).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLK = 8
COL_BLK = 128


def _blend_kernel(x_ref, xq_ref, lam_ref, o_ref):
    lam = lam_ref[0, 0]
    x = x_ref[...]
    o_ref[...] = x + lam * (xq_ref[...] - x)


@jax.jit
def blend_2d(x, xq, lam):
    """x, xq: (R, C); lam: scalar blend coefficient."""
    r, c = x.shape
    pr = (-r) % ROW_BLK
    pc = (-c) % COL_BLK
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
        xq = jnp.pad(xq, ((0, pr), (0, pc)))
    lam2 = jnp.asarray(lam, x.dtype).reshape(1, 1)
    grid = (x.shape[0] // ROW_BLK, x.shape[1] // COL_BLK)
    out = pl.pallas_call(
        _blend_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
            pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, xq, lam2)
    return out[:r, :c]


def blend(x, xq, lam):
    """Arbitrary-rank progressive blend."""
    x2 = x.reshape(1, -1) if x.ndim != 2 else x
    xq2 = xq.reshape(1, -1) if xq.ndim != 2 else xq
    out = blend_2d(x2, xq2, lam)
    return out.reshape(x.shape)
