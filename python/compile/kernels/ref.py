"""Pure-jnp oracles for every Pallas kernel.

These are the correctness contracts: each Pallas kernel in this package must
match its oracle bit-for-bit (integer paths) or to float tolerance (blend /
requantize paths). The Rust integer engine (rust/src/engine/) implements the
same arithmetic; conventions shared across all three implementations:

  * round ties-to-even (jnp.round semantics == Rust f32::round_ties_even)
  * symmetric INT8 weights:      q in [-128, 127], zero_point = 0
  * asymmetric UINT8 activations: q in [0, 255]
  * scale_w = max(m, eps) / 127          (2^{b-1} - 1)
  * scale_a = max(hi - lo, eps) / 255    (2^b - 1)
  * zero_point_a = clip(round(-lo / s), 0, 255)
  * integer matmul accumulates in int32
"""

import jax.numpy as jnp

EPS = 1e-6
QMIN_W, QMAX_W = -128, 127
QMIN_A, QMAX_A = 0, 255


def quantize_sym(x, s, qmin=QMIN_W, qmax=QMAX_W):
    """Symmetric quantize to the integer grid (returns float-valued ints)."""
    return jnp.clip(jnp.round(x / s), qmin, qmax)


def fake_quant_sym(x, s, qmin=QMIN_W, qmax=QMAX_W):
    """Symmetric quantize-dequantize. s broadcasts against x (per-channel ok)."""
    return quantize_sym(x, s, qmin, qmax) * s


def quantize_asym(x, s, z, qmin=QMIN_A, qmax=QMAX_A):
    return jnp.clip(jnp.round(x / s) + z, qmin, qmax)


def fake_quant_asym(x, s, z, qmin=QMIN_A, qmax=QMAX_A):
    return (quantize_asym(x, s, z, qmin, qmax) - z) * s


def blend(x, xq, lam):
    """Progressive blend x~ = x + lam * (x^ - x). (stop_grad applied by caller)."""
    return x + lam * (xq - x)


def reverse_prune(w, tau):
    """Pin weight tails at the quantile threshold tau (scalar or per-channel)."""
    return jnp.clip(w, -tau, tau)


def weight_scale(m_ema, eps=EPS):
    return jnp.maximum(m_ema, eps) / float(QMAX_W)


def act_scale_zp(lo_ema, hi_ema, eps=EPS):
    s = jnp.maximum(hi_ema - lo_ema, eps) / float(QMAX_A)
    z = jnp.clip(jnp.round(-lo_ema / s), QMIN_A, QMAX_A)
    return s, z


def ema(prev, new, mu):
    return (1.0 - mu) * prev + mu * new


def qmatmul_int8(x, w, sx, zx, sw):
    """Reference int8-simulated matmul.

    x : (M, K) float32 activations, quantized asymmetrically with (sx, zx)
    w : (K, N) float32 weights, quantized symmetrically with per-tensor sw
    Returns float32 (M, N): sx*sw * (xq - zx) @ wq, accumulated in int32.
    """
    xq = quantize_asym(x, sx, zx).astype(jnp.int32)
    wq = quantize_sym(w, sw).astype(jnp.int32)
    zq = jnp.round(zx).astype(jnp.int32)
    acc = (xq - zq) @ wq  # int32 accumulation
    return acc.astype(jnp.float32) * (sx * sw)


def empirical_quantile(x, p, axis=-1):
    """Paper-definition empirical quantile: x_(ceil(p*n)) of the order
    statistics (no interpolation). Static index -> lowers to sort + slice,
    and matches rust/src/calib exactly."""
    import math as _math

    xs = jnp.sort(x, axis=axis)
    n = x.shape[axis]
    idx = min(n - 1, max(0, int(_math.ceil(p * n)) - 1))
    return jnp.take(xs, idx, axis=axis)


def tensor_quantile(x, p, s_max=100_000):
    """Empirical p-quantile on a deterministic strided subsample, |S| <= s_max.

    Matches the paper's \\hat{Q}^{(S)}: for large tensors statistics are
    computed on a subsample. We use a fixed-stride subsample (not RNG) so the
    exported HLO is deterministic and the Rust side can reproduce it.
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    if n > s_max:
        stride = -(-n // s_max)  # ceil div
        flat = flat[::stride]
    return empirical_quantile(flat, p)
