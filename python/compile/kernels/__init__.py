"""Layer-1 Pallas kernels for Quant-Trim.

Every kernel here has a pure-jnp oracle in `ref.py`; the pytest suite in
python/tests/ sweeps shapes/dtypes with hypothesis and asserts agreement.
All kernels lower with interpret=True (CPU-PJRT executable HLO).
"""

from . import blend, fake_quant, qmatmul, ref, reverse_prune  # noqa: F401
