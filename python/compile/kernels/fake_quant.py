"""Pallas fake-quantization kernels (L1).

Quantize-dequantize is the inner loop of Quant-Trim training: it runs at every
quant point (every weight tensor, every designated activation site) on every
forward. The kernel fuses round/clip/dequant on a VMEM-resident tile so the
tensor makes exactly one HBM->VMEM->HBM round trip.

TPU mapping (see DESIGN.md §Hardware-Adaptation): tiles are (ROW_BLK, 128) —
lane dimension 128 matches the VPU/MXU lane width; per-channel scales ride
along as a (ROW_BLK, 1) block so a channel's scale is resident with its rows.
On CPU we lower with interpret=True (plain HLO), which is the only executable
path for the PJRT CPU client; the BlockSpec structure is what carries over to
a real TPU lowering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLK = 8
COL_BLK = 128


def _fq_sym_kernel(x_ref, s_ref, o_ref, *, qmin, qmax):
    x = x_ref[...]
    s = s_ref[...]  # (rows, 1) broadcasts over columns
    q = jnp.clip(jnp.round(x / s), qmin, qmax)
    o_ref[...] = q * s


def _fq_asym_kernel(x_ref, s_ref, z_ref, o_ref, *, qmin, qmax):
    x = x_ref[...]
    s = s_ref[...]
    z = z_ref[...]
    q = jnp.clip(jnp.round(x / s) + z, qmin, qmax)
    o_ref[...] = (q - z) * s


def _pad2(x, rb, cb):
    r, c = x.shape
    pr = (-r) % rb
    pc = (-c) % cb
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x, r, c


@functools.partial(jax.jit, static_argnames=("qmin", "qmax"))
def fake_quant_sym_2d(x, s, qmin=-128, qmax=127):
    """Symmetric quant-dequant over a 2-D view.

    x: (R, C) float32.  s: (R, 1) per-row scales (rows = channels) or (1, 1).
    """
    r, c = x.shape
    if s.shape[0] == 1 and r > 1:
        s = jnp.broadcast_to(s, (r, 1))
    xp, r0, c0 = _pad2(x, ROW_BLK, COL_BLK)
    sp = jnp.pad(s, ((0, xp.shape[0] - r), (0, 0)), constant_values=1.0)
    grid = (xp.shape[0] // ROW_BLK, xp.shape[1] // COL_BLK)
    out = pl.pallas_call(
        functools.partial(_fq_sym_kernel, qmin=float(qmin), qmax=float(qmax)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
            pl.BlockSpec((ROW_BLK, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, sp)
    return out[:r0, :c0]


@functools.partial(jax.jit, static_argnames=("qmin", "qmax"))
def fake_quant_asym_2d(x, s, z, qmin=0, qmax=255):
    """Asymmetric quant-dequant over a 2-D view. s, z: (R, 1) or (1, 1)."""
    r, c = x.shape
    if s.shape[0] == 1 and r > 1:
        s = jnp.broadcast_to(s, (r, 1))
        z = jnp.broadcast_to(z, (r, 1))
    xp, r0, c0 = _pad2(x, ROW_BLK, COL_BLK)
    pr = xp.shape[0] - r
    sp = jnp.pad(s, ((0, pr), (0, 0)), constant_values=1.0)
    zp = jnp.pad(z, ((0, pr), (0, 0)))
    grid = (xp.shape[0] // ROW_BLK, xp.shape[1] // COL_BLK)
    out = pl.pallas_call(
        functools.partial(_fq_asym_kernel, qmin=float(qmin), qmax=float(qmax)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
            pl.BlockSpec((ROW_BLK, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((ROW_BLK, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLK, COL_BLK), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, sp, zp)
    return out[:r0, :c0]


def fake_quant_sym(x, s, qmin=-128, qmax=127, channel_axis=None):
    """Symmetric quant-dequant on an arbitrary-rank tensor.

    channel_axis=None  -> per-tensor (s scalar)
    channel_axis=k     -> per-channel along axis k (s shape (C,))
    """
    if channel_axis is None:
        x2 = x.reshape(1, -1)
        s2 = jnp.asarray(s, x.dtype).reshape(1, 1)
        return fake_quant_sym_2d(x2, s2, qmin, qmax).reshape(x.shape)
    xm = jnp.moveaxis(x, channel_axis, 0)
    shp = xm.shape
    x2 = xm.reshape(shp[0], -1)
    s2 = jnp.asarray(s, x.dtype).reshape(shp[0], 1)
    out = fake_quant_sym_2d(x2, s2, qmin, qmax).reshape(shp)
    return jnp.moveaxis(out, 0, channel_axis)


def fake_quant_asym(x, s, z, qmin=0, qmax=255):
    """Asymmetric per-tensor quant-dequant on an arbitrary-rank tensor."""
    x2 = x.reshape(1, -1)
    s2 = jnp.asarray(s, x.dtype).reshape(1, 1)
    z2 = jnp.asarray(z, x.dtype).reshape(1, 1)
    return fake_quant_asym_2d(x2, s2, z2, qmin, qmax).reshape(x.shape)
