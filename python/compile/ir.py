"""Model graph IR shared by the JAX interpreter (L2) and the Rust engine (L3).

A model is a topologically-ordered list of Nodes. The same Graph object is

  * interpreted by compile/jax_exec.py to build the training / eval / device
    forwards that aot.py lowers to HLO text, and
  * serialized to `.qir` text that the Rust deployment simulator parses
    (rust/src/qir/). Single source of truth — no drift between what we train
    and what the simulated vendor compilers consume.

Shapes exclude the batch dimension. Layout is NCHW / (tokens, dim).

Node kinds (attrs in brackets):
  input[shape]                       graph input
  conv2d[cin,cout,kh,kw,stride,pad,groups,bias]   params: .w (O,I/g,kh,kw), .b
  bn[c]                              params: .gamma,.beta  state: .mean,.var
  relu / relu6 / hswish / hsigmoid / gelu / silu / sigmoid
  add / mul                          two inputs (mul broadcasts (C,1,1) scale)
  maxpool[k,stride,pad] / avgpool[k,stride,pad] / gap
  upsample2x                         nearest-neighbour
  concat                             channel concat, two inputs
  flatten                            (C,H,W) -> (C*H*W,)
  reshape[shape]
  linear[din,dout,bias]              params: .w (out,in), .b
  layernorm[d]                       params: .gamma,.beta   input (T,D)
  attention[d,heads]                 params: .wq/.wk/.wv/.wo (+ .bq/.bk/.bv/.bo)
                                     softmax scores stay FP (paper Table 8)
  aq                                 activation quant point
                                     qstate: .lo,.hi  (asymmetric per-tensor)
Weight-bearing nodes (conv2d, linear, attention) additionally own qstate:
  .m    per-output-channel |w| quantile EMA (attention: per-matrix scalars)
  .tau  reverse-pruning threshold EMA (per-tensor)
"""

from dataclasses import dataclass, field


@dataclass
class Node:
    kind: str
    name: str
    inputs: list
    attrs: dict = field(default_factory=dict)
    out_shape: tuple = ()


class Graph:
    """Builder + container. Node names are unique and double as param prefixes."""

    def __init__(self, name):
        self.name = name
        self.nodes = []
        self._by_name = {}
        self.outputs = None  # list of node names; defaults to [last node]

    def add(self, kind, name, inputs, out_shape, **attrs):
        if name in self._by_name:
            raise ValueError(f"duplicate node name {name!r}")
        node = Node(kind, name, list(inputs), attrs, tuple(out_shape))
        self.nodes.append(node)
        self._by_name[name] = node
        return name

    def node(self, name):
        return self._by_name[name]

    @property
    def output(self):
        return self.nodes[-1].name

    @property
    def output_names(self):
        return self.outputs if self.outputs is not None else [self.output]

    # ---- builder helpers (shape inference inline) ----

    def input(self, name, shape):
        return self.add("input", name, [], shape)

    def conv2d(self, name, x, cout, k, stride=1, pad=None, groups=1, bias=True):
        cin, h, w = self.node(x).out_shape
        if pad is None:
            pad = k // 2
        ho = (h + 2 * pad - k) // stride + 1
        wo = (w + 2 * pad - k) // stride + 1
        return self.add("conv2d", name, [x], (cout, ho, wo), cin=cin, cout=cout,
                        kh=k, kw=k, stride=stride, pad=pad, groups=groups,
                        bias=int(bias))

    def bn(self, name, x):
        c = self.node(x).out_shape[0]
        return self.add("bn", name, [x], self.node(x).out_shape, c=c)

    def act(self, kind, name, x):
        return self.add(kind, name, [x], self.node(x).out_shape)

    def aq(self, name, x):
        return self.add("aq", name, [x], self.node(x).out_shape)

    def add2(self, name, a, b):
        return self.add("add", name, [a, b], self.node(a).out_shape)

    def mul2(self, name, a, b):
        return self.add("mul", name, [a, b], self.node(a).out_shape)

    def maxpool(self, name, x, k, stride, pad=0):
        c, h, w = self.node(x).out_shape
        ho = (h + 2 * pad - k) // stride + 1
        wo = (w + 2 * pad - k) // stride + 1
        return self.add("maxpool", name, [x], (c, ho, wo), k=k, stride=stride, pad=pad)

    def avgpool(self, name, x, k, stride, pad=0):
        c, h, w = self.node(x).out_shape
        ho = (h + 2 * pad - k) // stride + 1
        wo = (w + 2 * pad - k) // stride + 1
        return self.add("avgpool", name, [x], (c, ho, wo), k=k, stride=stride, pad=pad)

    def gap(self, name, x):
        c = self.node(x).out_shape[0]
        return self.add("gap", name, [x], (c, 1, 1))

    def upsample2x(self, name, x):
        c, h, w = self.node(x).out_shape
        return self.add("upsample2x", name, [x], (c, 2 * h, 2 * w))

    def concat(self, name, a, b):
        ca, h, w = self.node(a).out_shape
        cb, _, _ = self.node(b).out_shape
        return self.add("concat", name, [a, b], (ca + cb, h, w))

    def flatten(self, name, x):
        shp = self.node(x).out_shape
        n = 1
        for d in shp:
            n *= d
        return self.add("flatten", name, [x], (n,))

    def reshape(self, name, x, shape):
        return self.add("reshape", name, [x], shape, shape=tuple(shape))

    def linear(self, name, x, dout, bias=True):
        shp = self.node(x).out_shape
        din = shp[-1]
        return self.add("linear", name, [x], shp[:-1] + (dout,), din=din,
                        dout=dout, bias=int(bias))

    def layernorm(self, name, x):
        shp = self.node(x).out_shape
        return self.add("layernorm", name, [x], shp, d=shp[-1])

    def attention(self, name, x, heads):
        t, d = self.node(x).out_shape
        return self.add("attention", name, [x], (t, d), d=d, heads=heads)

    def to_tokens(self, name, x):
        """(C, H, W) -> (H*W, C) token layout for transformer blocks."""
        c, h, w = self.node(x).out_shape
        return self.add("to_tokens", name, [x], (h * w, c))

    def tokmean(self, name, x):
        """(T, D) -> (D,) mean pooling over tokens."""
        t, d = self.node(x).out_shape
        return self.add("tokmean", name, [x], (d,))

    # ---- serialization ----

    def to_text(self):
        """Serialize to .qir text: one node per line.

        node <kind> <name> inputs=a,b shape=c,h,w key=val ...
        """
        lines = [f"qir {self.name} v1",
                 "outputs " + ",".join(self.output_names)]
        for n in self.nodes:
            parts = [f"node {n.kind} {n.name}"]
            parts.append("inputs=" + (",".join(n.inputs) if n.inputs else "-"))
            parts.append("shape=" + ",".join(str(d) for d in n.out_shape))
            for k in sorted(n.attrs):
                if n.kind == "reshape" and k == "shape":
                    continue  # redundant with out_shape; would collide with
                    # the node-level shape= field in the text format
                v = n.attrs[k]
                if isinstance(v, (tuple, list)):
                    v = "x".join(str(i) for i in v)
                parts.append(f"{k}={v}")
            lines.append(" ".join(parts))
        return "\n".join(lines) + "\n"


WEIGHT_KINDS = ("conv2d", "linear", "attention")


def param_specs(graph):
    """Ordered (name, shape, kind) for every parameter tensor in the graph."""
    specs = []
    for n in graph.nodes:
        if n.kind == "conv2d":
            a = n.attrs
            specs.append((f"{n.name}.w", (a["cout"], a["cin"] // a["groups"], a["kh"], a["kw"]), "conv_w"))
            if a["bias"]:
                specs.append((f"{n.name}.b", (a["cout"],), "bias"))
        elif n.kind == "linear":
            a = n.attrs
            specs.append((f"{n.name}.w", (a["dout"], a["din"]), "linear_w"))
            if a["bias"]:
                specs.append((f"{n.name}.b", (a["dout"],), "bias"))
        elif n.kind == "attention":
            d = n.attrs["d"]
            for p in ("wq", "wk", "wv", "wo"):
                specs.append((f"{n.name}.{p}", (d, d), "linear_w"))
                specs.append((f"{n.name}.{p[1]}b", (d,), "bias"))
        elif n.kind == "bn":
            c = n.attrs["c"]
            specs.append((f"{n.name}.gamma", (c,), "bn"))
            specs.append((f"{n.name}.beta", (c,), "bn"))
        elif n.kind == "layernorm":
            d = n.attrs["d"]
            specs.append((f"{n.name}.gamma", (d,), "ln"))
            specs.append((f"{n.name}.beta", (d,), "ln"))
    return specs


def bn_state_specs(graph):
    specs = []
    for n in graph.nodes:
        if n.kind == "bn":
            c = n.attrs["c"]
            specs.append((f"{n.name}.mean", (c,)))
            specs.append((f"{n.name}.var", (c,)))
    return specs


def qstate_specs(graph):
    """Ordered (name, shape) for quantization statistics state."""
    specs = []
    for n in graph.nodes:
        if n.kind == "conv2d":
            specs.append((f"{n.name}.m", (n.attrs["cout"],)))
            specs.append((f"{n.name}.tau", ()))
        elif n.kind == "linear":
            specs.append((f"{n.name}.m", (n.attrs["dout"],)))
            specs.append((f"{n.name}.tau", ()))
        elif n.kind == "attention":
            for p in ("wq", "wk", "wv", "wo"):
                specs.append((f"{n.name}.{p}.m", ()))
            specs.append((f"{n.name}.tau", ()))
        elif n.kind == "aq":
            specs.append((f"{n.name}.lo", ()))
            specs.append((f"{n.name}.hi", ()))
    return specs
