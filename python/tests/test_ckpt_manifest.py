"""Checkpoint + manifest formats: roundtrip and the scalar contract that the
Rust readers rely on."""

import os
import tempfile

import numpy as np

from compile import ckpt
from compile.manifest import Manifest


def test_ckpt_roundtrip_with_scalars():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.qtckpt")
        tensors = {
            "param/a.w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "qstate/a.tau": np.float32(0.25),  # 0-d scalar MUST stay 0-d
            "bn/x.mean": np.zeros(7, np.float32),
        }
        ckpt.save(path, tensors)
        back = ckpt.load(path)
        assert set(back) == set(tensors)
        assert back["qstate/a.tau"].shape == ()
        assert back["qstate/a.tau"] == np.float32(0.25)
        np.testing.assert_array_equal(back["param/a.w"], tensors["param/a.w"])


def test_ckpt_noncontiguous_input():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.qtckpt")
        base = np.arange(24, dtype=np.float32).reshape(4, 6)
        ckpt.save(path, {"w": base.T})  # transposed view: non-contiguous
        back = ckpt.load(path)
        np.testing.assert_array_equal(back["w"], base.T)


def test_manifest_text_shape():
    m = Manifest("demo")
    m.file("qir", "demo.qir")
    m.artifact("fwd", "demo.fwd.hlo.txt")
    m.arg("fwd", 0, "param", "a.w", (2, 3))
    m.arg("fwd", 1, "lam", "lam", ())
    m.ret("fwd", 0, "out", "out", (1, 10))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "demo.manifest")
        m.save(path)
        lines = open(path).read().strip().split("\n")
    assert lines[0] == "model demo"
    assert "arg fwd 1 lam lam f32 scalar" in lines
    assert "ret fwd 0 out out f32 1,10" in lines
