"""L2 model-graph sanity: IR construction, shape inference, spec generation,
QIR serialization roundtrip, and the training step's semantic invariants
(quantization off == quantization on at lambda=0, STE gradient flow)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import ir, train
from compile.models import BUILDERS
from compile.quant import QuantCtx
from compile import jax_exec


@pytest.fixture(scope="module")
def tiny():
    g = ir.Graph("tiny")
    x = g.input("image", (3, 8, 8))
    c = g.conv2d("c1", x, 8, 3, bias=False)
    b = g.bn("bn1", c)
    r = g.act("relu", "r1", b)
    q = g.aq("q1", r)
    p = g.gap("gap", q)
    f = g.flatten("flat", p)
    g.linear("head", f, 4)
    return g


@pytest.mark.parametrize("name", list(BUILDERS))
def test_builders_produce_valid_graphs(name):
    g = BUILDERS[name]()
    # unique names, defined inputs (topological order)
    seen = set()
    for n in g.nodes:
        for i in n.inputs:
            assert i in seen, f"{n.name} references undefined {i}"
        assert n.name not in seen
        seen.add(n.name)
    for o in g.output_names:
        assert o in seen
    # every graph has at least one quant point and one weight node
    assert any(n.kind == "aq" for n in g.nodes)
    assert any(n.kind in ir.WEIGHT_KINDS for n in g.nodes)


@pytest.mark.parametrize("name", ["resnet18_c10", "vit", "mobilenetv3", "unet"])
def test_forward_shapes(name):
    g = BUILDERS[name]()
    params = train.init_params(g, seed=0)
    bnst = train.init_bn_state(g)
    x = np.zeros((2,) + g.node("image").out_shape, np.float32)
    ctx = QuantCtx("fp32", {})
    out, _ = jax_exec.apply_graph(g, params, bnst, jnp.array(x), ctx, train=False)
    expect = (2,) + g.node(g.output).out_shape
    assert out.shape == expect


def test_qir_serialization_roundtrip(tiny):
    text = tiny.to_text()
    assert text.startswith("qir tiny v1")
    # reparse via the same textual contract the Rust side uses
    lines = text.strip().split("\n")
    assert lines[1] == "outputs head"
    assert any("node conv2d c1" in l for l in lines)
    assert any("cin=3" in l and "cout=8" in l for l in lines)


def test_param_specs_cover_all_references(tiny):
    params = train.init_params(tiny, seed=1)
    specs = {name for name, _, _ in ir.param_specs(tiny)}
    assert specs == set(params)
    qspecs = dict(ir.qstate_specs(tiny))
    assert "c1.m" in qspecs and qspecs["c1.m"] == (8,)
    assert "c1.tau" in qspecs and qspecs["c1.tau"] == ()
    assert "q1.lo" in qspecs and "q1.hi" in qspecs


def test_lambda_zero_train_equals_fp32_forward(tiny):
    """At lambda=0 the quant-trim forward must equal plain FP32 (train path
    uses batch BN, so compare in eval mode with fake-quant ctx at lam=0)."""
    params = train.init_params(tiny, seed=2)
    bnst = train.init_bn_state(tiny)
    qstate = train.init_qstate(tiny, params)
    x = jnp.array(np.random.default_rng(0).standard_normal((2, 3, 8, 8)), jnp.float32)
    ctx0 = QuantCtx("train", qstate, lam=jnp.float32(0.0))
    y0, _ = jax_exec.apply_graph(tiny, params, bnst, x, ctx0, train=False)
    ctxf = QuantCtx("fp32", {})
    yf, _ = jax_exec.apply_graph(tiny, params, bnst, x, ctxf, train=False)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(yf), atol=1e-6)


def test_gradients_flow_at_full_fake_quant(tiny):
    """STE: gradients must be nonzero for all params even at lambda=1."""
    params = train.init_params(tiny, seed=3)
    bnst = train.init_bn_state(tiny)
    qstate = train.init_qstate(tiny, params)
    x = jnp.array(np.random.default_rng(1).standard_normal((4, 3, 8, 8)), jnp.float32)
    y = jnp.array([0, 1, 2, 3], jnp.int32)

    def loss(p):
        ctx = QuantCtx("train", qstate, lam=jnp.float32(1.0))
        logits, _ = jax_exec.apply_graph(tiny, p, bnst, x, ctx, train=True)
        return train.softmax_xent(logits, y)

    grads = jax.grad(loss)(params)
    for k, gv in grads.items():
        assert np.all(np.isfinite(np.asarray(gv))), f"non-finite grad for {k}"
    # the conv weight specifically must receive signal through the STE
    assert float(jnp.abs(grads["c1.w"]).max()) > 0.0


def test_train_step_updates_state_and_qstats(tiny):
    params = train.init_params(tiny, seed=4)
    bnst = train.init_bn_state(tiny)
    qstate = train.init_qstate(tiny, params)
    m, v = train.init_opt(params)
    step = train.make_train_step(tiny, task="cls", mu=0.1)
    x = np.random.default_rng(2).standard_normal((4, 3, 8, 8)).astype(np.float32)
    y = np.array([0, 1, 2, 3], np.int32)
    out = jax.jit(step)(params, bnst, qstate, m, v, jnp.float32(0), x, y,
                        jnp.float32(0.5), jnp.float32(1e-3))
    new_p, new_bn, new_q, _, _, new_step, loss, acc = out
    assert float(new_step) == 1.0
    assert np.isfinite(float(loss))
    assert 0.0 <= float(acc) <= 1.0
    # params moved, bn stats moved, activation stats moved toward batch range
    assert not np.allclose(np.asarray(new_p["c1.w"]), params["c1.w"])
    assert not np.allclose(np.asarray(new_bn["bn1.mean"]), bnst["bn1.mean"])
    assert float(new_q["q1.hi"]) != float(qstate["q1.hi"])


def test_reverse_prune_pins_at_tau(tiny):
    params = train.init_params(tiny, seed=5)
    qstate = train.init_qstate(tiny, params, p_clip=0.9)
    taus = {k: v for k, v in qstate.items() if k.endswith(".tau")}
    rp = train.make_reverse_prune(tiny, p_clip=0.9, beta=1.0)
    new_p, new_t = jax.jit(rp)(params, taus)
    for wk in ("c1.w", "head.w"):
        base = wk.rsplit(".", 1)[0]
        tau = float(new_t[f"{base}.tau"])
        assert float(jnp.abs(new_p[wk]).max()) <= tau + 1e-6
        # tau == p90 quantile of |w| (beta=1: no EMA memory)
        w = np.abs(np.asarray(params[wk]).ravel())
        idx = min(len(w) - 1, max(0, int(np.ceil(0.9 * len(w))) - 1))
        assert abs(tau - np.sort(w)[idx]) < 1e-6
