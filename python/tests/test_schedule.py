"""Curriculum golden values — identical assertions exist in
rust/src/coordinator/schedule.rs so the two implementations cannot drift."""

from compile.schedule import Curriculum


def test_golden_lambda_values():
    c = Curriculum(e_w=10, e_f=50, horizon=20)
    assert c.lam(0) == 0.0
    assert c.lam(9) == 0.0
    assert c.lam(10) == 0.0
    assert abs(c.lam(30) - 0.03125) < 1e-12
    assert abs(c.lam(45) - 0.2930908203125) < 1e-12
    assert abs(c.lam(50) - 0.5) < 1e-12
    assert abs(c.lam(60) - 0.625) < 1e-12
    assert c.lam(70) == 1.0
    assert c.lam(1000) == 1.0


def test_lambda_monotone():
    c = Curriculum(e_w=10, e_f=50, horizon=20)
    vals = [c.lam(t) for t in range(120)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert all(0.0 <= v <= 1.0 for v in vals)


def test_transformer_cap():
    c = Curriculum(e_w=10, e_f=50, horizon=20, lam_max=0.8)
    assert c.lam(1000) == 0.8


def test_prune_schedule():
    c = Curriculum(e_w=10, e_f=50, horizon=20, prune_every=5)
    assert not c.prune_now(9)
    assert c.prune_now(10)
    assert not c.prune_now(12)
    assert c.prune_now(15)
