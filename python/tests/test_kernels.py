"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, swept over
shapes/values with hypothesis. This is the core correctness signal for the
quantization arithmetic shared by all three implementations."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blend, fake_quant, qmatmul, ref, reverse_prune

SETTINGS = dict(max_examples=25, deadline=None)


def rand_arr(rng, r, c, scale=1.0):
    return (rng.standard_normal((r, c)) * scale).astype(np.float32)


@settings(**SETTINGS)
@given(
    r=st.integers(1, 17),
    c=st.integers(1, 300),
    scale=st.floats(0.01, 4.0),
    seed=st.integers(0, 2**31),
)
def test_fake_quant_sym_matches_ref(r, c, scale, seed):
    rng = np.random.default_rng(seed)
    x = rand_arr(rng, r, c, scale)
    s = (np.abs(rng.standard_normal((r, 1))) * 0.05 + 0.01).astype(np.float32)
    got = fake_quant.fake_quant_sym_2d(jnp.array(x), jnp.array(s))
    want = ref.fake_quant_sym(jnp.array(x), jnp.array(s))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(
    r=st.integers(1, 9),
    c=st.integers(1, 257),
    seed=st.integers(0, 2**31),
)
def test_fake_quant_asym_matches_ref(r, c, seed):
    rng = np.random.default_rng(seed)
    x = rand_arr(rng, r, c, 2.0)
    s = (np.abs(rng.standard_normal((r, 1))) * 0.05 + 0.01).astype(np.float32)
    z = np.round(rng.uniform(0, 255, (r, 1))).astype(np.float32)
    got = fake_quant.fake_quant_asym_2d(jnp.array(x), jnp.array(s), jnp.array(z))
    want = ref.fake_quant_asym(jnp.array(x), jnp.array(s), jnp.array(z))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(
    n=st.integers(1, 4000),
    lam=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31),
)
def test_blend_matches_ref(n, lam, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    xq = rng.standard_normal(n).astype(np.float32)
    got = blend.blend(jnp.array(x), jnp.array(xq), lam)
    want = ref.blend(jnp.array(x), jnp.array(xq), lam)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@settings(**SETTINGS)
@given(
    c=st.integers(1, 12),
    k=st.integers(1, 200),
    seed=st.integers(0, 2**31),
)
def test_reverse_prune_matches_ref_per_channel(c, k, seed):
    rng = np.random.default_rng(seed)
    w = rand_arr(rng, c, k, 0.3)
    tau = (np.abs(rng.standard_normal(c)) * 0.2 + 0.01).astype(np.float32)
    got = reverse_prune.reverse_prune(jnp.array(w), jnp.array(tau), channel_axis=0)
    want = ref.reverse_prune(jnp.array(w), jnp.array(tau).reshape(c, 1))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # invariant: pinned at the boundary
    assert np.all(np.abs(np.asarray(got)) <= tau.reshape(c, 1) + 1e-7)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31),
)
def test_qmatmul_matches_int32_reference(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((m, k)) + 0.5).astype(np.float32)
    w = (rng.standard_normal((k, n)) * 0.1).astype(np.float32)
    sx, zx = 0.02, 12.0
    sw = float(max(np.abs(w).max(), 1e-6) / 127.0)
    wq = np.asarray(ref.quantize_sym(jnp.array(w), sw)).astype(np.int8)
    got = qmatmul.qmatmul(jnp.array(x), jnp.array(wq), sx, zx, sw)
    want = ref.qmatmul_int8(jnp.array(x), jnp.array(w), jnp.array(np.float32(sx)),
                            jnp.array(np.float32(zx)), jnp.array(np.float32(sw)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)


def test_fake_quant_output_on_grid():
    """Quant-dequant output must land exactly on the integer grid."""
    rng = np.random.default_rng(0)
    x = rand_arr(rng, 4, 128, 2.0)
    s = np.full((4, 1), 0.05, np.float32)
    y = np.asarray(fake_quant.fake_quant_sym_2d(jnp.array(x), jnp.array(s)))
    grid = np.round(y / 0.05)
    np.testing.assert_allclose(y, grid * 0.05, atol=1e-6)
    assert grid.min() >= -128 and grid.max() <= 127


def test_fake_quant_idempotent():
    """fq(fq(x)) == fq(x) — quantization is a projection."""
    rng = np.random.default_rng(1)
    x = rand_arr(rng, 2, 300, 1.0)
    s = np.full((2, 1), 0.03, np.float32)
    y1 = fake_quant.fake_quant_sym_2d(jnp.array(x), jnp.array(s))
    y2 = fake_quant.fake_quant_sym_2d(y1, jnp.array(s))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_empirical_quantile_paper_definition():
    """Golden values shared with rust/src/tensor (empirical_quantile)."""
    data = jnp.array([float(i) for i in range(1, 11)])
    assert float(ref.empirical_quantile(data, 0.5)) == 5.0
    assert float(ref.empirical_quantile(data, 0.05)) == 1.0
    assert float(ref.empirical_quantile(data, 0.90)) == 9.0
    assert float(ref.empirical_quantile(data, 0.91)) == 10.0


def test_act_scale_zp_matches_rust_golden():
    """ref.act_scale_zp(-1, 2) -> s=3/255, z=85 (same golden in quantized.rs)."""
    s, z = ref.act_scale_zp(jnp.float32(-1.0), jnp.float32(2.0))
    assert abs(float(s) - 3.0 / 255.0) < 1e-8
    assert float(z) == 85.0
