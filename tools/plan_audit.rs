//! Static deployment auditor CLI (`engine::verify` over the deploy matrix).
//!
//! Smoke mode — audit every backend × integer precision × activation-scaling
//! cell of the simulated fleet on the synthetic seeded checkpoints, prove
//! i32-accumulator non-overflow and clean plan liveness per cell, and write
//! the per-layer saturation-risk table to `AUDIT.txt` (uploaded as a CI
//! artifact). Exits 1 when any cell carries an ERROR finding:
//!
//!   cargo run --release --bin plan_audit -- --smoke
//!
//! Sabotage mode — deliberately corrupt a cloned plan one violation class at
//! a time and check the verifier catches each one. Exits 2 (nonzero) when
//! every class is caught, 0 when the verifier MISSED a corruption — so CI's
//! negative step can assert `! plan_audit --sabotage all`:
//!
//!   cargo run --release --bin plan_audit -- --sabotage all
//!   cargo run --release --bin plan_audit -- --sabotage stale-read

use std::fmt::Write as _;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use quant_trim::backends::{all_backends, BackendSpec, CheckpointView, PtqOptions, RangeSource};
use quant_trim::coordinator::experiment::synthetic_state;
use quant_trim::engine::verify::{Sabotage, Severity};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::synth::{self, SynthModel};
use quant_trim::testutil::Rng;

fn flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

/// Worst-case (lo, hi) over the calibration tensors — the audit's input
/// interval, mirroring how the backends derive the input range.
fn input_range(batches: &[Tensor]) -> (f32, f32) {
    let mut lo = f32::MAX;
    let mut hi = f32::MIN;
    for b in batches {
        for &v in &b.data {
            if v.is_finite() {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
    }
    if lo > hi {
        (-2.5, 2.5)
    } else {
        (lo, hi)
    }
}

/// Seeded stand-in calibration tensors for a `3 x hw x hw` input.
fn calib_batches(hw: usize, seed: u64) -> Vec<Tensor> {
    (0..2)
        .map(|i| {
            let n = 8 * 3 * hw * hw;
            Tensor::new(vec![8, 3, hw, hw], Rng::new(seed + i).normal_vec(n, 1.0))
        })
        .collect()
}

/// Risk bucket for the saturation table: HIGH = proven-dangerous bounds
/// (overflow region or >25% requant clipping), MED = elevated (visible
/// clipping or outlier-inflated scales), LOW = comfortably in range.
fn risk_label(headroom_bits: f64, clip: f64, scale_ratio: f64) -> &'static str {
    if clip > 0.25 || headroom_bits < 1.0 {
        "HIGH"
    } else if clip > 0.05 || scale_ratio > 8.0 {
        "MED"
    } else {
        "LOW"
    }
}

/// Audit one compiled cell and append its verdict + layer table to `out`.
/// Returns the number of ERROR findings in the cell.
#[allow(clippy::too_many_arguments)]
fn audit_cell(
    out: &mut String,
    be: &BackendSpec,
    model_label: &str,
    sm: &SynthModel,
    prec: Precision,
    scaling: ActScaling,
    calib: &[Tensor],
) -> Result<usize> {
    let state = synthetic_state(sm);
    let view = CheckpointView {
        graph: &sm.graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let dep = be
        .compile_scaled(view, prec, scaling, RangeSource::Calibration, calib, PtqOptions::default())
        .with_context(|| {
            format!("{}: compiling {model_label} at {:?}/{:?}", be.name, prec, scaling)
        })?;
    let report = dep.audit(Some(input_range(calib)))?;
    let errors = report.findings.iter().filter(|f| f.severity == Severity::Error).count();
    let warns = report.findings.iter().filter(|f| f.severity == Severity::Warning).count();

    let flagged = report.flagged_nodes();
    let audited =
        be.perf_audited(&dep.model.graph, dep.precision, dep.act_scaling, 1, &|n| {
            flagged.contains(n)
        });

    let verdict = if errors > 0 { "FAIL" } else { "ok" };
    let _ = writeln!(
        out,
        "\n--- {model_label} on {:<14} req {:>4}/{:<7} eff {:>4}/{:<7} [{verdict}] \
         errors={errors} warnings={warns} fps={:.0} fps_audited={:.0}",
        be.name,
        prec.label(),
        scaling.label(),
        dep.precision.label(),
        dep.act_scaling.label(),
        dep.perf_b1.fps,
        audited.fps,
    );
    let _ = writeln!(
        out,
        "{:<14} {:<13} {:>4} {:>5} {:>13} {:>13} {:>9} {:>7} {:>7}  {}",
        "layer", "kind", "bits", "K", "acc_lo", "acc_hi", "headroom", "clip%", "scaleX", "risk"
    );
    for la in &report.layers {
        let _ = writeln!(
            out,
            "{:<14} {:<13} {:>4} {:>5} {:>13} {:>13} {:>8.2}b {:>6.1}% {:>7.2}  {}",
            la.node,
            la.kind,
            la.bits,
            la.k,
            la.acc.lo,
            la.acc.hi,
            la.headroom_bits,
            la.clip * 100.0,
            la.scale_ratio,
            risk_label(la.headroom_bits, la.clip, la.scale_ratio),
        );
    }
    if report.layers.is_empty() {
        let _ = writeln!(out, "(no integer GEMM layers at this precision/scaling)");
    }
    for f in &report.findings {
        if f.severity >= Severity::Warning {
            let _ = writeln!(out, "  {f}");
        }
    }
    Ok(errors)
}

fn smoke() -> Result<ExitCode> {
    let models: Vec<(&str, SynthModel, Vec<Tensor>)> = vec![
        ("resnet-like", synth::resnet_like(16, 16), calib_batches(16, 0xCA11B_01)),
        ("vit-like", synth::vit_like(), calib_batches(8, 0xCA11B_02)),
    ];
    let mut out = String::new();
    let _ = writeln!(
        out,
        "=== Static plan audit (smoke): backend x {{INT8, INT4}} x {{static, dynamic}} ===\n\
         Each cell: plan liveness/aliasing replay, qparam sanity, and interval analysis\n\
         proving the i32 accumulator of every integer GEMM stays in range for the\n\
         actual K dims and weight payloads. headroom = log2(i32::MAX / worst |acc|)."
    );
    let mut cells = 0usize;
    let mut failed = 0usize;
    for (label, sm, calib) in &models {
        for be in all_backends() {
            for prec in [Precision::Int8, Precision::Int4] {
                for scaling in [ActScaling::Static, ActScaling::Dynamic] {
                    cells += 1;
                    let errors = audit_cell(&mut out, &be, label, sm, prec, scaling, calib)?;
                    if errors > 0 {
                        failed += 1;
                    }
                }
            }
        }
    }
    let _ = writeln!(
        out,
        "\n=== audit summary: {cells} deploy-matrix cells, {failed} with ERROR findings ==="
    );
    if failed == 0 {
        let _ = writeln!(
            out,
            "every cell proves i32-accumulator non-overflow and clean plan liveness"
        );
    }
    print!("{out}");
    std::fs::write("AUDIT.txt", &out)?;
    println!("wrote AUDIT.txt");
    Ok(if failed > 0 { ExitCode::from(1) } else { ExitCode::SUCCESS })
}

/// Corrupt a cloned plan per violation class and report whether the
/// verifier catches each one. Exit 2 = all caught (the expected outcome,
/// nonzero so CI's negative step sees a failing command); exit 0 = at least
/// one corruption slipped through.
fn sabotage(which: &str) -> Result<ExitCode> {
    let classes: Vec<Sabotage> = if which == "all" {
        Sabotage::ALL.to_vec()
    } else {
        vec![Sabotage::parse(which)
            .with_context(|| format!("unknown sabotage class {which:?} (try: all, alias, \
                                      stale-read, uncovered-output, scratch-under, bogus-swap, \
                                      bad-qparam, tier-mismatch)"))?]
    };
    let sm = synth::resnet_like(16, 16);
    let state = synthetic_state(&sm);
    let calib = calib_batches(16, 0xCA11B_03);
    let be = all_backends()
        .into_iter()
        .find(|b| b.precisions.contains(&Precision::Int8))
        .context("no INT8-capable backend in the fleet")?;
    let view = CheckpointView {
        graph: &sm.graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let dep =
        be.compile(view, Precision::Int8, RangeSource::Calibration, &calib, PtqOptions::default())?;

    let mut missed = 0usize;
    for c in classes {
        let findings = dep.model.verify_sabotaged(c)?;
        let caught = findings
            .iter()
            .any(|f| f.severity == Severity::Error && f.code == c.expected_code());
        println!(
            "sabotage {:<18} expected {:<22} -> {}",
            c.name(),
            c.expected_code(),
            if caught { "caught" } else { "MISSED" }
        );
        if !caught {
            for f in &findings {
                println!("    {f}");
            }
            missed += 1;
        }
    }
    if missed > 0 {
        println!("verifier MISSED {missed} corruption class(es)");
        return Ok(ExitCode::SUCCESS);
    }
    println!("verifier caught every injected corruption (exiting nonzero to prove it)");
    Ok(ExitCode::from(2))
}

fn main() -> Result<ExitCode> {
    if let Some(which) = arg("--sabotage") {
        return sabotage(&which);
    }
    if flag("--smoke") {
        return smoke();
    }
    bail!("usage: plan_audit --smoke | plan_audit --sabotage <class|all>");
}
