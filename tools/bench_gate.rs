//! CI bench regression gate: compares a bench JSON emitted by
//! `cargo bench` (BENCH_engine.json, BENCH_server.json, BENCH_chaos.json)
//! against the committed baseline in `BENCH_baseline/` and fails on
//! regressions beyond tolerance.
//!
//! Gated metrics, selected by key suffix:
//! * `*_speedup` — floor-gated: `cur >= base * (1 - tol)`. Ratios of two
//!   measurements from the SAME run (planned vs interpreter, 4w vs 1w) —
//!   machine-independent, so a committed baseline is meaningful across CI
//!   runners.
//! * `*_p95_ms` — ceiling-gated: `cur <= base * (1 + tol)`. Tail latency of
//!   the device-paced serving scenarios; pacing (not host speed) dominates,
//!   so gate with a generous tolerance.
//! * `*_violation_rate` — ceiling-gated: `cur <= base * (1 + tol) + 0.02`.
//!   The absolute slack keeps a near-zero baseline gateable (a pure ratio
//!   ceiling on 0.0 would reject ANY violation).
//!
//! A gated key present in the baseline but missing from the current run is
//! a failure (a silently-dropped metric must not pass the gate). Raw `_us`
//! medians are printed for context but not gated: absolute microseconds on
//! shared runners are noise.
//!
//!   cargo run --release --bin bench_gate -- BENCH_baseline/engine.json BENCH_engine.json
//!   cargo run --release --bin bench_gate -- <baseline> <current> --tolerance 0.15
//!
//! Exit codes: 0 pass, 1 regression, 2 usage/parse error.

use std::collections::BTreeMap;
use std::process::ExitCode;

/// Minimal flat-JSON number extraction: every `"key": <number>` pair. The
/// bench emitters write flat objects; no vendored JSON crate is available
/// (offline build), and this stays robust to added keys.
fn parse_numbers(src: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    let bytes = src.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] != b'"' {
            i += 1;
            continue;
        }
        let Some(end) = src[i + 1..].find('"').map(|e| i + 1 + e) else { break };
        let key = &src[i + 1..end];
        let mut j = end + 1;
        while j < bytes.len() && (bytes[j] as char).is_whitespace() {
            j += 1;
        }
        if j >= bytes.len() || bytes[j] != b':' {
            i = end + 1;
            continue;
        }
        j += 1;
        while j < bytes.len() && (bytes[j] as char).is_whitespace() {
            j += 1;
        }
        // Non-finite tokens (Rust's {} / serde-style bare NaN / inf):
        // captured as non-finite f64 so the gate can REJECT a poisoned
        // metric instead of treating it as absent.
        let rest = &src[j..];
        let (neg, body) = match rest.strip_prefix('-') {
            Some(r) => (true, r),
            None => (false, rest),
        };
        let lower = body.get(..8).unwrap_or(body).to_ascii_lowercase();
        let nonfinite = if lower.starts_with("nan") {
            Some((3usize, f64::NAN))
        } else if lower.starts_with("infinity") {
            Some((8, f64::INFINITY))
        } else if lower.starts_with("inf") {
            Some((3, f64::INFINITY))
        } else {
            None
        };
        if let Some((len, v)) = nonfinite {
            out.insert(key.to_string(), if neg { -v } else { v });
            i = j + len + usize::from(neg);
            continue;
        }
        let start = j;
        while j < bytes.len() && matches!(bytes[j], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
            j += 1;
        }
        if j > start {
            if let Ok(v) = src[start..j].parse::<f64>() {
                out.insert(key.to_string(), v);
            }
        }
        i = j.max(end + 1);
    }
    out
}

fn load(path: &str) -> Result<BTreeMap<String, f64>, String> {
    let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    let nums = parse_numbers(&src);
    if nums.is_empty() {
        return Err(format!("{path}: no numeric fields found"));
    }
    Ok(nums)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().collect();
    let mut tolerance = 0.15f64;
    let mut paths: Vec<&str> = Vec::new();
    let mut i = 1;
    while i < args.len() {
        if args[i] == "--tolerance" {
            match args.get(i + 1).and_then(|v| v.parse::<f64>().ok()) {
                Some(t) if (0.0..1.0).contains(&t) => tolerance = t,
                _ => {
                    eprintln!("bench_gate: --tolerance needs a value in [0, 1)");
                    return ExitCode::from(2);
                }
            }
            i += 2;
        } else {
            paths.push(&args[i]);
            i += 1;
        }
    }
    if paths.len() != 2 {
        eprintln!("usage: bench_gate <baseline.json> <current.json> [--tolerance 0.15]");
        return ExitCode::from(2);
    }
    let (baseline_path, current_path) = (paths[0], paths[1]);
    let (baseline, current) = match (load(baseline_path), load(current_path)) {
        (Ok(b), Ok(c)) => (b, c),
        (b, c) => {
            for e in [b.err(), c.err()].into_iter().flatten() {
                eprintln!("bench_gate: {e}");
            }
            return ExitCode::from(2);
        }
    };

    println!("bench gate: {current_path} vs {baseline_path} (tolerance {:.0}%)", tolerance * 100.0);
    let is_gated = |key: &str| {
        key.ends_with("_speedup") || key.ends_with("_p95_ms") || key.ends_with("_violation_rate")
    };
    let mut gated = 0usize;
    let mut failures = 0usize;
    for (key, &base) in &baseline {
        let Some(&cur) = current.get(key) else {
            if is_gated(key) {
                eprintln!("  FAIL {key}: present in baseline, missing from current run");
                failures += 1;
            }
            continue;
        };
        if is_gated(key) && !(base.is_finite() && cur.is_finite()) {
            // a NaN/inf in a gated metric means the bench itself is broken
            gated += 1;
            eprintln!(
                "  FAIL {key}: non-finite value (current {cur}, baseline {base}) in a gated metric"
            );
            failures += 1;
            continue;
        }
        if key.ends_with("_speedup") {
            gated += 1;
            let floor = base * (1.0 - tolerance);
            let ok = cur >= floor;
            println!(
                "  {} {key}: {cur:.2} vs baseline {base:.2} (floor {floor:.2})",
                if ok { "ok  " } else { "FAIL" }
            );
            if !ok {
                failures += 1;
            }
        } else if key.ends_with("_p95_ms") {
            gated += 1;
            let ceiling = base * (1.0 + tolerance);
            let ok = cur <= ceiling;
            println!(
                "  {} {key}: {cur:.2} vs baseline {base:.2} (ceiling {ceiling:.2})",
                if ok { "ok  " } else { "FAIL" }
            );
            if !ok {
                failures += 1;
            }
        } else if key.ends_with("_violation_rate") {
            gated += 1;
            // absolute slack so a near-zero baseline stays gateable
            let ceiling = base * (1.0 + tolerance) + 0.02;
            let ok = cur <= ceiling;
            println!(
                "  {} {key}: {cur:.4} vs baseline {base:.4} (ceiling {ceiling:.4})",
                if ok { "ok  " } else { "FAIL" }
            );
            if !ok {
                failures += 1;
            }
        } else if key.ends_with("_us") {
            println!("  info {key}: {cur:.1} us (baseline machine: {base:.1} us, not gated)");
        }
    }
    if gated == 0 {
        eprintln!("bench_gate: baseline has no gated metrics (*_speedup, *_p95_ms, *_violation_rate)");
        return ExitCode::from(2);
    }
    if failures > 0 {
        eprintln!("bench_gate: {failures} metric(s) regressed >{:.0}%", tolerance * 100.0);
        return ExitCode::from(1);
    }
    println!("bench_gate: all {gated} gated metric(s) within tolerance");
    ExitCode::SUCCESS
}

#[cfg(test)]
mod tests {
    use super::parse_numbers;

    #[test]
    fn parses_flat_numeric_pairs() {
        let m = parse_numbers(r#"{"a_speedup": 2.5, "b_us": 104.0, "c": -3e2}"#);
        assert_eq!(m["a_speedup"], 2.5);
        assert_eq!(m["b_us"], 104.0);
        assert_eq!(m["c"], -300.0);
    }

    #[test]
    fn parses_non_finite_tokens_as_non_finite_values() {
        let m = parse_numbers(
            r#"{"a_speedup": NaN, "b_p95_ms": -inf, "c": Infinity, "d": -NaN, "e": 1.5}"#,
        );
        assert!(m["a_speedup"].is_nan());
        assert_eq!(m["b_p95_ms"], f64::NEG_INFINITY);
        assert_eq!(m["c"], f64::INFINITY);
        assert!(m["d"].is_nan());
        assert_eq!(m["e"], 1.5);
    }

    #[test]
    fn string_values_are_still_skipped() {
        let m = parse_numbers(r#"{"name": "engine", "x_speedup": 2.0}"#);
        assert!(!m.contains_key("name"));
        assert_eq!(m["x_speedup"], 2.0);
    }
}
