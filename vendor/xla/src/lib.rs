//! Offline stub of the PJRT/XLA bindings (`xla-rs` API subset).
//!
//! The vendored crate set has no PJRT plugin, so every entry point that
//! would touch a real runtime returns [`Error::Unavailable`] from
//! `PjRtClient::cpu()` onward. All call sites in the workspace either guard
//! on `Runtime::cpu()` succeeding or on `artifacts/` existing, so tests and
//! benches skip cleanly instead of failing to build. Swap this path crate
//! for the real bindings to light the AOT/PJRT bridge back up.

use std::fmt::{self, Display};

/// Stub error: the PJRT runtime is not baked into this build.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "PJRT unavailable in this build (stub xla crate): {what}")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Stub of the PJRT client. `cpu()` always fails; nothing downstream of a
/// client can therefore ever be reached at runtime.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host literal (stub): carries no data; constructors succeed so marshalling
/// code compiles, but nothing can execute against them.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        let msg = format!("{err}");
        assert!(msg.contains("PJRT unavailable"), "{msg}");
    }

    #[test]
    fn literal_constructors_compile() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2]).is_ok());
        let l2 = Literal::vec1(&[1i32]);
        assert!(l2.to_vec::<i32>().is_err());
    }
}
