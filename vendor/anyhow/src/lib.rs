//! Vendored, API-compatible subset of the `anyhow` crate.
//!
//! The container builds fully offline, so the real crates.io `anyhow` cannot
//! be fetched; this shim provides the surface the workspace actually uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on both `Result`
//! and `Option`, including results that already carry an `anyhow::Error`),
//! and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Error state is a flattened message chain (outermost context first), which
//! is what every call site here formats with `{}` / `{:?}` anyway.

use std::fmt::{self, Debug, Display};

/// Drop-in stand-in for `anyhow::Error`: an owned message chain.
///
/// Deliberately does NOT implement `std::error::Error` — exactly like the
/// real `anyhow::Error` — so the blanket `From<E: std::error::Error>` impl
/// below does not overlap with the reflexive `From<Error> for Error`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string() }
    }

    /// Attach outer context to this error.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // flatten the source chain into the message
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[doc(hidden)]
pub mod private {
    use super::Error;

    /// Internal unification of "things that can become an `Error`": every
    /// std error AND `Error` itself (the same trick the real anyhow uses so
    /// `Context` works on `Result<T, anyhow::Error>` too). Public but
    /// doc-hidden: it only exists as a bound for the `Context` impls.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: private::IntoError,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::core::result::Result::Err($crate::Error::msg(::std::format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err().into());
        let r = r.context("loading file");
        let msg = format!("{}", r.unwrap_err());
        assert!(msg.starts_with("loading file"), "{msg}");
        assert!(msg.contains("gone"), "{msg}");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<u32> = Err(anyhow!("base {}", 7));
        let msg = format!("{}", r.with_context(|| "outer").unwrap_err());
        assert_eq!(msg, "outer: base 7");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<String> {
            let s = String::from_utf8(vec![0x61])?;
            let n: usize = "12".parse()?;
            Ok(format!("{s}{n}"))
        }
        assert_eq!(f().unwrap(), "a12");
    }
}
