//! Regenerates every TABLE of the paper's evaluation (DESIGN.md §4 index):
//!
//!   Table 1  — ResNet on Hardware B (W8/ABF16): QT vs MAP on-device metrics
//!   Table 2  — ResNet on Hardware D (W8/A8)
//!   Table 3  — SNR: QT calib-only vs MAP + Equalization + AdaRound (HW A)
//!   Table 4/5/6 — device capability sheets (static, from backends::devices)
//!   Table 7/8   — curriculum hyperparameters
//!   Table 10 — NanoSAM2 backbone 2kx2k tiled runtime + price/W
//!
//! Uses trained checkpoints cached by `examples/train_cifar` when present
//! (run `make repro` first for the full-fidelity numbers); falls back to a
//! quick in-process training run otherwise.
//!
//!   cargo bench --bench paper_tables

use anyhow::Result;

use quant_trim::backends::{all_backends, backend_by_name, PtqOptions, RangeSource};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::{
    artifacts_dir, deploy_and_eval, reference_metrics, train_with_validation, Task,
};
use quant_trim::coordinator::{Curriculum, TrainConfig, TrainState};
use quant_trim::data::ClsSpec;
use quant_trim::perfmodel::{tiles_for, Precision};
use quant_trim::runtime::Runtime;

fn main() -> Result<()> {
    let Ok(dir) = artifacts_dir() else {
        println!("(artifacts/ not built — run `make artifacts` first; skipping paper tables)");
        return Ok(());
    };
    let model = "resnet18";
    let task = Task::Cls(ClsSpec::cifar100());

    // --- checkpoints (cached from train_cifar, else quick runs)
    let Ok(rt) = Runtime::cpu() else {
        println!("(PJRT unavailable in this build; skipping paper tables)");
        return Ok(());
    };
    let mut get_state = |qt: bool| -> Result<TrainState> {
        let suffix = if qt { "qt" } else { "map" };
        let p = dir.join(format!("{model}.trained_{suffix}.qtckpt"));
        if p.exists() {
            return Ok(TrainState::from_checkpoint(&Checkpoint::load(p)?));
        }
        eprintln!("(no cached {suffix} checkpoint — quick 10-epoch training run)");
        let cur = Curriculum::cifar().scaled_to(10, 100);
        let cfg = if qt {
            TrainConfig::quant_trim(10, 16, cur)
        } else {
            TrainConfig::map_baseline(10, 16, cur)
        };
        let (tr, _) = train_with_validation(&rt, &dir, model, cfg, task, 0, false)?;
        Ok(tr.state)
    };
    let qt = get_state(true)?;
    let map = get_state(false)?;

    let graph = quant_trim::coordinator::experiment::perf_graph(&dir, model)?;
    let eval: Vec<_> = (0..8).map(|i| task.batch(64, 0x5EED_0000 + i)).collect();
    let calib: Vec<_> = (0..4).map(|i| task.batch(16, 0xCA11B_00 + i).images).collect();

    // --- Tables 1 & 2
    for (tno, bname, prec) in
        [(1, "hardware_b", Precision::Int8), (2, "hardware_d", Precision::Int8)]
    {
        let be = backend_by_name(bname).unwrap();
        println!("\n=== Table {tno}: {model} on {bname} ({}) ===", prec.label());
        println!(
            "{:<12} {:>14} {:>14} {:>9} {:>17} {:>17}",
            "Method", "Top-1 (FP32)", "Top-5 (FP32)", "MSE", "Brier (FP32)", "ECE (FP32)"
        );
        let mut mses = Vec::new();
        for (label, st, src) in [
            ("Quant-Trim", &qt, RangeSource::QatScales),
            ("MAP", &map, RangeSource::Calibration),
        ] {
            let m =
                deploy_and_eval(&be, &graph, st, prec, src, PtqOptions::default(), &calib, &eval)?;
            let (t1, t5, br, ec) = reference_metrics(&graph, st, &eval)?;
            println!(
                "{:<12} {:>6.2} ({:>5.2}) {:>6.2} ({:>5.2}) {:>9.5} {:>8.5} ({:.5}) {:>8.5} ({:.5})",
                label, m.top1 * 100.0, t1 * 100.0, m.top5 * 100.0, t5 * 100.0,
                m.logit_mse, m.brier, br, m.ece, ec
            );
            mses.push(m.logit_mse);
        }
        let perf = be.perf(&graph, prec, 1);
        println!("modelled: {:.0} FPS, {:.2} ms", perf.fps, perf.latency_ms);
        println!(
            "paper shape (QT MSE < MAP MSE): {}",
            if mses[0] < mses[1] { "REPRODUCED" } else { "NOT reproduced" }
        );
    }

    // --- Table 3
    println!("\n=== Table 3: output-layer SNR on hardware_a (A8W8 INT) ===");
    let ha = backend_by_name("hardware_a").unwrap();
    let qt_m = deploy_and_eval(
        &ha, &graph, &qt, Precision::Int8, RangeSource::Calibration,
        PtqOptions::default(), &calib, &eval,
    )?;
    let map_m = deploy_and_eval(
        &ha, &graph, &map, Precision::Int8, RangeSource::Calibration,
        PtqOptions { equalization: true, adaround: true }, &calib, &eval,
    )?;
    println!("{:<46} {:>9}", "Training Method", "SNR (dB)");
    println!("{:<46} {:>9.2}", "Quant-Trim (Calibration Only)", qt_m.snr_db);
    println!("{:<46} {:>9.2}", "Baseline (Equalization + Adaround)", map_m.snr_db);
    println!(
        "paper shape (QT > baseline): {}",
        if qt_m.snr_db > map_m.snr_db { "REPRODUCED" } else { "NOT reproduced" }
    );

    // --- Tables 4-6: device sheets
    println!("\n=== Tables 4-6: device fleet ===");
    println!(
        "{:<18} {:<22} {:<18} {:>9} {:>9} {:>7} {:>7}",
        "device", "form factor", "link", "INT8 TOPS", "F16/BF16", "peak W", "price"
    );
    for b in all_backends() {
        println!(
            "{:<18} {:<22} {:<18} {:>9.1} {:>9.1} {:>7.1} {:>6.0}€",
            b.name,
            b.device.form_factor,
            b.device.link,
            b.device.tops_int8,
            b.device.tflops_fp16.max(b.device.tflops_bf16),
            b.device.peak_w,
            b.device.price_eur
        );
    }

    // --- Tables 7-8: curricula
    println!("\n=== Tables 7-8: curriculum defaults ===");
    for (name, c) in [
        ("CIFAR-100", Curriculum::cifar()),
        ("Segm. (COCO)", Curriculum::seg()),
        ("Transformer", Curriculum::transformer()),
    ] {
        println!(
            "{:<14} E_w={:<3} E_f={:<3} H={:<3} lam_max={:<4} p_clip={:<5} K={:<3} mu={}",
            name, c.e_w, c.e_f, c.horizon, c.lam_max, c.p_clip, c.prune_every, c.mu
        );
    }

    // --- Table 10: NanoSAM2 tiled runtime
    let sam = quant_trim::coordinator::experiment::perf_graph(&dir, "sam")?;
    let tiles = tiles_for(2000, 512, 0.5);
    println!("\n=== Table 10: NanoSAM2 backbone, one 2kx2k image ({tiles} tiles) ===");
    println!(
        "{:<18} {:<8} {:<16} {:>9} {:>11} {:>9} {:>13}",
        "Hardware", "Type", "Runtime env", "Peak W", "Runtime s", "Price", "Price/W (k€)"
    );
    let rows: &[(&str, &str, Precision)] = &[
        ("rtx3090", "GPU", Precision::Fp16),
        ("jetson_orin_nano", "SOM", Precision::Fp16),
        ("hardware_a", "M.2", Precision::Int8),
        ("hardware_b", "M.2", Precision::Bf16),
        ("hardware_c", "SoC", Precision::Int8),
        ("hardware_d", "M.2", Precision::Int8),
    ];
    let mut fastest_npu = f64::MAX;
    let mut jetson_time = 0.0;
    for (name, kind, prec) in rows {
        let be = backend_by_name(name).unwrap();
        let r = be.perf(&sam, *prec, 1);
        let total = r.latency_ms / 1e3 * tiles as f64;
        if *name == "hardware_a" {
            fastest_npu = total;
        }
        if name.starts_with("jetson") {
            jetson_time = total;
        }
        println!(
            "{:<18} {:<8} {:<16} {:>9.1} {:>11.3} {:>8.0}€ {:>13.4}",
            be.device.name,
            kind,
            prec.label(),
            be.device.peak_w,
            total,
            be.device.price_eur,
            be.device.price_eur / be.device.peak_w / 1000.0
        );
    }
    println!(
        "paper shape (HW A ~6x faster than Jetson at ~5W): ratio {:.1}x -> {}",
        jetson_time / fastest_npu,
        if fastest_npu < jetson_time { "REPRODUCED" } else { "NOT reproduced" }
    );
    Ok(())
}
