//! Regenerates every FIGURE's data series (DESIGN.md §4 index):
//!
//!   Fig 2  — weight-tail compression + activation-range narrowing (QT vs MAP)
//!   Fig 3  — power-throughput trade-off, DINOv2-proxy + ResNet, all devices
//!   Fig 4/5/10 — training-dynamics curves (from cached run logs if present;
//!            full curves come from examples/train_cifar — they need minutes
//!            of training, not bench time)
//!   Fig 7  — NanoSAM2 end-to-end latency ordering across accelerators
//!   Fig 8/9 — ablation convergence + weight distributions (examples/ablation)
//!   Fig 11 — MobileNetV3s + U-Net power/perf across devices
//!
//!   cargo bench --bench paper_figures [fig3|fig7|fig11|fig2]

use anyhow::Result;

use quant_trim::backends::all_backends;
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::experiment::artifacts_dir;
use quant_trim::coordinator::TrainState;
use quant_trim::metrics::dist_summary;
use quant_trim::perfmodel::{tiles_for, Precision};

fn want(which: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| a == which)
}

fn power_throughput(dir: &std::path::Path, model: &str, fig: &str) -> Result<()> {
    let graph = quant_trim::coordinator::experiment::perf_graph(&dir, model)?;
    println!("\n=== {fig}: {model} — batch=1 FPS vs power (color=device, marker=precision, filled=vendor runtime) ===");
    println!(
        "{:<18} {:<5} {:<8} {:>10} {:>9} {:>9} {:>11}",
        "device", "prec", "runtime", "FPS", "peak W", "avg W", "mJ/inf"
    );
    for be in all_backends() {
        for prec in be.precisions.clone() {
            let r = be.perf(&graph, prec, 1);
            println!(
                "{:<18} {:<5} {:<8} {:>10.1} {:>9.2} {:>9.2} {:>11.3}",
                be.name, prec.label(), "vendor", r.fps, r.peak_power_w, r.avg_power_w,
                r.energy_mj_per_inf
            );
            if be.runtime_boost > 1.0 {
                let n = be.perf_naive(&graph, prec, 1);
                println!(
                    "{:<18} {:<5} {:<8} {:>10.1} {:>9.2} {:>9.2} {:>11.3}",
                    be.name, prec.label(), "naive", n.fps, n.peak_power_w, n.avg_power_w,
                    n.energy_mj_per_inf
                );
            }
        }
    }
    // shape assertions the paper reports
    let trt = all_backends().into_iter().find(|b| b.name == "jetson_orin_nano").unwrap();
    let f16_trt = trt.perf(&graph, Precision::Fp16, 1).fps;
    let f16_naive = trt.perf_naive(&graph, Precision::Fp16, 1).fps;
    let f32_trt = trt.perf(&graph, Precision::Fp32, 1).fps;
    println!(
        "shapes: TRT-FP16 {:.0} FPS vs naive {:.0} ({}x, paper: ~2.5x); FP16 vs FP32 {:.1}x (paper: 2-3x)",
        f16_trt,
        f16_naive,
        (f16_trt / f16_naive).round(),
        f16_trt / f32_trt
    );
    Ok(())
}

fn main() -> Result<()> {
    let Ok(dir) = artifacts_dir() else {
        println!("(artifacts/ not built — run `make artifacts` first; skipping paper figures)");
        return Ok(());
    };

    if want("fig2") {
        println!("=== Fig 2: distributional effect of Quant-Trim ===");
        let mut shown = false;
        for (label, file) in [
            ("Quant-Trim", "resnet18.trained_qt.qtckpt"),
            ("MAP", "resnet18.trained_map.qtckpt"),
        ] {
            let p = dir.join(file);
            if !p.exists() {
                continue;
            }
            shown = true;
            let st = TrainState::from_checkpoint(&Checkpoint::load(p)?);
            let mut all: Vec<f32> = Vec::new();
            for (k, t) in &st.params {
                if k.ends_with(".w") {
                    all.extend_from_slice(&t.data);
                }
            }
            let d = dist_summary(&all);
            println!(
                "{:<12} |w|: p50={:.4} p99={:.4} p99.9={:.4} max={:.4} tail_ratio={:.2} kurtosis={:.2}",
                label, d.p50, d.p99, d.p999, d.max, d.tail_ratio, d.kurtosis
            );
        }
        if !shown {
            println!("(run examples/train_cifar first to produce trained checkpoints)");
        }
    }

    if want("fig3") {
        // ResNet-50 and the DINOv2 proxy, as in the paper's Fig 3 panels
        power_throughput(&dir, "vit", "Fig 3 (left, DINOv2 proxy)")?;
        power_throughput(&dir, "resnet50", "Fig 3 (right, ResNet-50)")?;
    }

    if want("fig7") {
        let sam = quant_trim::coordinator::experiment::perf_graph(&dir, "sam")?;
        let tiles = tiles_for(2000, 512, 0.5);
        println!("\n=== Fig 7: NanoSAM2 e2e across accelerators (512^2 tiles, 50% overlap, {tiles} tiles for 2k images) ===");
        let mut rows: Vec<(String, f64, f64)> = Vec::new();
        for (name, prec) in [
            ("rtx3090", Precision::Fp16),
            ("jetson_orin_nano", Precision::Fp16),
            ("jetson_agx_orin", Precision::Fp16),
            ("hardware_a", Precision::Int8),
            ("hardware_b", Precision::Bf16),
            ("hardware_d", Precision::Int8),
        ] {
            let be = all_backends().into_iter().find(|b| b.name == name).unwrap();
            let r = be.perf(&sam, prec, 1);
            rows.push((format!("{name} ({})", prec.label()), r.latency_ms, r.peak_power_w));
        }
        rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        for (n, lat, w) in &rows {
            println!("{:<28} {:>8.3} ms/tile @ {:>5.1} W", n, lat, w);
        }
    }

    if want("fig4") || want("fig5") || want("fig10") || want("fig8") {
        println!("\n=== Figs 4/5/8/10: training-dynamics curves ===");
        println!("(generated by the training drivers — minutes of training, not bench time)");
        println!("  Fig 4:  cargo run --release --example train_cifar -- --model vit");
        println!("  Fig 5:  cargo run --release --example train_cifar -- --model resnet18");
        println!("  Fig 8:  cargo run --release --example ablation");
        println!("  Fig 10: cargo run --release --example train_cifar -- --model unet --task seg");
        for f in ["results/experiments_run1.log"] {
            if std::path::Path::new(f).exists() {
                println!("  (cached curves found in {f}: grep '\\[curve\\]' / '\\[fig8\\]')");
            }
        }
    }

    if want("fig11") {
        power_throughput(&dir, "mobilenetv3", "Fig 11 (MobileNetV3-Small)")?;
        power_throughput(&dir, "unet", "Fig 11 (U-Net)")?;
    }

    Ok(())
}
