//! Engine hot-path microbenchmarks (the §Perf L3 profile): integer GEMM,
//! f32 GEMM (reference vs planned tiled), im2col, conv f32 vs i8 vs packed
//! i4, weight quantization, and the headline planned-executor-vs-interpreter
//! model benchmark on a synthetic ResNet-style conv net (runs with no
//! artifacts) at FP32, INT8, INT4 and dynamic-scaled INT8 (live-batch
//! ranges, calibration-free). Custom harness (testutil::bench):
//! 20 warmup + 200 timed iterations, medians — the paper's protocol.
//!
//! Emits `BENCH_engine.json` (plan vs interpreter medians + speedups,
//! int4-vs-int8, dyn-vs-static, warm-vs-cold `ExecScratch` rows with
//! the `steady_state_speedup` of the zero-allocation arena+pool executor
//! over PR-4-style allocate-per-call execution, and the kernel-tier rows:
//! the detected `kernel_tier`, the planned-int8 `simd_speedup` over a
//! scalar-forced twin, and the kernel-level `simd_gemm_speedup`) for the
//! perf trajectory; CI gates regressions against
//! `BENCH_baseline/engine.json` via `tools/bench_gate.rs`.
//!
//!   cargo bench --bench engine_hotpath

use std::collections::BTreeMap;

use quant_trim::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use quant_trim::calib::{calibrate, CalibMethod};
use quant_trim::ckpt::Checkpoint;
use quant_trim::coordinator::TrainState;
use quant_trim::data::{gen_cls_batch, ClsSpec};
use quant_trim::engine::{
    fp32_model, ops, ActMode, CompiledModel, ExecConfig, ExecScratch, KernelTier, WeightMode,
};
use quant_trim::perfmodel::Precision;
use quant_trim::qir::passes;
use quant_trim::tensor::{QuantScheme, QWeight, RoundMode, Tensor};
use quant_trim::testutil::{bench, synth, Rng};

fn main() {
    println!("=== engine hot paths (20 warmup + 200 timed, medians) ===");
    let mut rng = Rng::new(0xBE7C);

    // integer GEMM at the resnet stage-2 conv shape: (1024 rows, 288 cols) x 64
    let rows = 1024;
    let cols = 288;
    let cout = 64;
    let xq: Vec<u8> = (0..rows * cols).map(|_| rng.below(256) as u8).collect();
    let wq: Vec<i8> = (0..cout * cols).map(|_| rng.below(255) as i8).collect();
    let scales = vec![0.01f32; cout];
    let mut out = vec![0.0f32; rows * cout];
    let macs = (rows * cols * cout) as f64;
    let r = bench("gemm_i8 1024x288x64", 20, 200, || {
        ops::gemm_i8(&xq, rows, cols, &wq, cout, &scales, 0.02, 128, None, &mut out, cout, 0);
    });
    r.print();
    println!("    -> {:.2} GMAC/s int8", macs / r.median_us / 1e3);

    // f32 GEMM same shape: reference serial kernel vs planned tiled kernel
    let xf: Vec<f32> = rng.normal_vec(rows * cols, 1.0);
    let wf: Vec<f32> = rng.normal_vec(cout * cols, 0.1);
    let col = ops::Im2Col { rows, cols, data: xf.clone() };
    let r = bench("gemm_f32 (reference) 1024x288x64", 20, 200, || {
        ops::gemm_f32(&col, &wf, cout, &mut out, cout, 0);
    });
    r.print();
    println!("    -> {:.2} GMAC/s f32 serial", macs / r.median_us / 1e3);
    let r = bench("gemm_f32_tiled (planned) 1024x288x64", 20, 200, || {
        ops::gemm_f32_tiled(&xf, rows, cols, &wf, cout, None, None, &mut out, cout, 0);
    });
    r.print();
    println!("    -> {:.2} GMAC/s f32 tiled+parallel", macs / r.median_us / 1e3);

    // im2col on a (8, 32, 16, 16) activation, 3x3
    let x = Tensor::new(vec![8, 32, 16, 16], rng.normal_vec(8 * 32 * 16 * 16, 1.0));
    bench("im2col 8x32x16x16 k3", 20, 200, || {
        std::hint::black_box(ops::im2col_group(&x, 0, 1, 3, 3, 1, 1, 16, 16));
    })
    .print();

    // conv f32 vs i8, resnet block shape
    let w = Tensor::new(vec![64, 32, 3, 3], rng.normal_vec(64 * 32 * 9, 0.1));
    bench("conv2d_f32 8x32x16x16 -> 64", 5, 40, || {
        std::hint::black_box(ops::conv2d_f32(&x, &w, None, 1, 1, 1));
    })
    .print();
    bench("conv2d_f32_fused (planned)    ", 5, 40, || {
        std::hint::black_box(ops::conv2d_f32_fused(&x, &w, None, 1, 1, 1, Some(ops::Act::Relu)));
    })
    .print();
    let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
    bench("conv2d_i8  8x32x16x16 -> 64", 5, 40, || {
        std::hint::black_box(ops::conv2d_i8(&x, &qw, None, 1, 1, 1, 0.02, 128, RoundMode::TiesEven));
    })
    .print();
    // packed int4 weights through the same entry point (nibble-unpacking GEMM)
    let qw4 = QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4);
    bench("conv2d_i4  8x32x16x16 -> 64", 5, 40, || {
        std::hint::black_box(ops::conv2d_i8(&x, &qw4, None, 1, 1, 1, 0.02, 128, RoundMode::TiesEven));
    })
    .print();

    // weight + activation quantization
    let big = Tensor::new(vec![256, 1152], rng.normal_vec(256 * 1152, 0.1));
    bench("QWeight::quantize per-channel 256x1152", 20, 200, || {
        std::hint::black_box(QWeight::quantize(&big, QuantScheme::PerChannelSym, RoundMode::TiesEven));
    })
    .print();

    // kernel-tier comparison on the packed int8 linear kernel (scalar tier
    // vs the tier the plan would pick on this machine)
    let (simd_gemm_scalar_us, simd_gemm_simd_us) = simd_gemm_bench(&mut rng);

    // ---- headline: planned executor vs legacy interpreter on a synthetic
    // ResNet-style conv net (3x32x32), both precision paths -------------
    let report = plan_vs_interpreter();
    write_bench_json(&report, simd_gemm_scalar_us, simd_gemm_simd_us);

    // end-to-end engine inference on real artifacts when present
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("resnet18_c10.manifest").exists() {
        artifact_benches(&dir, &mut rng);
    } else {
        println!("(artifacts/ not built: skipping exported-model + PJRT benches)");
    }
}

/// Kernel-level tier comparison: the packed int8 linear GEMM at the resnet
/// stage-2 GEMM shape, weights packed once for the scalar tier and once for
/// the tier `ExecPlan::compile` would pick here. Outputs are asserted
/// bit-identical before timing; the ratio is the `simd_gemm_speedup` row.
fn simd_gemm_bench(rng: &mut Rng) -> (f64, f64) {
    fn run(
        x: &[f32],
        rows: usize,
        p: &ops::PackedQW,
        sxw: &[f32],
        xq: &mut Vec<u8>,
        out: &mut [f32],
    ) {
        let round = RoundMode::TiesEven;
        ops::linear_int_packed(x, rows, p, None, 0.02, 128, round, sxw, None, xq, out);
    }

    let (rows, din, dout) = (1024usize, 288usize, 64usize);
    let tier = KernelTier::detect();
    let w = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.1));
    let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
    let ps = ops::PackedQW::pack_for(&qw, 1, KernelTier::Scalar);
    let pv = ops::PackedQW::pack_for(&qw, 1, tier);
    let x: Vec<f32> = rng.normal_vec(rows * din, 1.0);
    let sxw: Vec<f32> = qw.scales.iter().map(|&s| 0.02 * s).collect();
    let mut xq = Vec::new();
    let mut out_s = vec![0.0f32; rows * dout];
    let mut out_v = vec![0.0f32; rows * dout];
    run(&x, rows, &ps, &sxw, &mut xq, &mut out_s);
    run(&x, rows, &pv, &sxw, &mut xq, &mut out_v);
    assert_eq!(out_s, out_v, "kernel tiers must produce bit-identical outputs");
    let rs = bench("linear_i8 packed scalar tier 1024x288x64", 20, 200, || {
        run(&x, rows, &ps, &sxw, &mut xq, &mut out_s);
    });
    rs.print();
    let rv = bench(&format!("linear_i8 packed {} tier 1024x288x64", tier.label()), 20, 200, || {
        run(&x, rows, &pv, &sxw, &mut xq, &mut out_v);
    });
    rv.print();
    println!(
        "    -> simd gemm speedup ({} vs scalar): {:.2}x",
        tier.label(),
        rs.median_us / rv.median_us
    );
    (rs.median_us, rv.median_us)
}

struct PlanReport {
    /// Label of the tier the plan resolved on this machine.
    kernel_tier: &'static str,
    fp32_interp_us: f64,
    fp32_plan_us: f64,
    int8_interp_us: f64,
    int8_plan_us: f64,
    /// Same int8 deployment forced onto the scalar tier via `ExecConfig`.
    int8_plan_scalar_us: f64,
    int4_interp_us: f64,
    int4_plan_us: f64,
    dyn_interp_us: f64,
    dyn_plan_us: f64,
    /// Fresh-`ExecScratch`-per-call planned run (PR-4 allocate-per-call).
    int8_plan_cold_us: f64,
    /// Reused-`ExecScratch` planned run (zero-allocation steady state).
    int8_plan_warm_us: f64,
}

fn plan_vs_interpreter() -> PlanReport {
    println!("\n=== planned executor vs legacy interpreter (synthetic resnet, b=1) ===");
    let sm = synth::resnet_like(32, 64);
    let (graph, params, _f, fused) = passes::fuse_conv_bn_act(&sm.graph, &sm.params, &sm.bn).unwrap();
    println!("lowered graph: {} nodes ({} activations fused)", graph.nodes.len(), fused);
    let mut rng = Rng::new(0xBEEF);
    let x = Tensor::new(vec![1, 3, 32, 32], rng.normal_vec(3 * 32 * 32, 1.0));

    // FP32 path
    let fp = fp32_model(graph.clone(), params.clone(), BTreeMap::new());
    fp.plan().unwrap(); // compile outside the timed region
    let ri = bench("resnet-like fp32 interpreter b=1", 10, 120, || {
        std::hint::black_box(fp.run_interpreted(&x).unwrap());
    });
    ri.print();
    let rp = bench("resnet-like fp32 planned     b=1", 10, 120, || {
        std::hint::black_box(fp.run(&x).unwrap());
    });
    rp.print();
    println!("    -> fp32 speedup: {:.2}x", ri.median_us / rp.median_us);

    // INT8 path (W8/A8, per-channel, ties-even — hardware_d style)
    let batches: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 32, 32], rng.normal_vec(2 * 3 * 32 * 32, 1.0))).collect();
    let ranges = calibrate(&fp, &batches, CalibMethod::MinMax).unwrap().ranges;
    let mut qweights = std::collections::HashMap::new();
    for n in graph.weight_nodes() {
        let key = format!("{}.w", n.name);
        if let Some(w) = params.get(&key) {
            qweights.insert(key, QWeight::quantize(w, QuantScheme::PerChannelSym, RoundMode::TiesEven));
        }
    }
    let m8 = CompiledModel::new(
        graph.clone(),
        params.clone(),
        BTreeMap::new(),
        qweights.clone(),
        ranges,
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    let tier = m8.plan().unwrap().kernel_tier();
    println!("plan resolved kernel tier: {}", tier.label());
    // sanity: the planned int8 executor is bit-exact vs the interpreter
    assert_eq!(
        m8.run(&x).unwrap()[0].data,
        m8.run_interpreted(&x).unwrap()[0].data,
        "planned int8 executor must be bit-exact"
    );
    let ri8 = bench("resnet-like int8 interpreter b=1", 10, 120, || {
        std::hint::black_box(m8.run_interpreted(&x).unwrap());
    });
    ri8.print();
    let rp8 = bench("resnet-like int8 planned     b=1", 10, 120, || {
        std::hint::black_box(m8.run(&x).unwrap());
    });
    rp8.print();
    println!("    -> int8 speedup: {:.2}x", ri8.median_us / rp8.median_us);

    // the same int8 deployment forced onto the scalar tier: the ratio is
    // the model-level SIMD dispatch win (`simd_speedup`, gated in CI)
    let m8s = CompiledModel::new(
        graph.clone(),
        params.clone(),
        BTreeMap::new(),
        qweights.clone(),
        m8.act_ranges.clone(),
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier: Some(KernelTier::Scalar),
        },
    );
    m8s.plan().unwrap();
    assert_eq!(
        m8s.run(&x).unwrap()[0].data,
        m8.run(&x).unwrap()[0].data,
        "scalar-tier planned int8 must be bit-identical to the detected tier"
    );
    let rp8s = bench("resnet-like int8 planned scalar-tier", 10, 120, || {
        std::hint::black_box(m8s.run(&x).unwrap());
    });
    rp8s.print();
    println!(
        "    -> simd speedup ({} vs scalar, planned int8): {:.2}x",
        tier.label(),
        rp8s.median_us / rp8.median_us
    );

    // INT4 path (W4/A8, same ranges, packed-nibble weights)
    let mut qweights4 = std::collections::HashMap::new();
    for n in graph.weight_nodes() {
        let key = format!("{}.w", n.name);
        if let Some(w) = params.get(&key) {
            qweights4.insert(
                key,
                QWeight::quantize_bits(w, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4),
            );
        }
    }
    let m4 = CompiledModel::new(
        graph.clone(),
        params.clone(),
        BTreeMap::new(),
        qweights4,
        m8.act_ranges.clone(),
        ExecConfig {
            weight_mode: WeightMode::Int4,
            act_mode: ActMode::Int8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    m4.plan().unwrap();
    assert_eq!(
        m4.run(&x).unwrap()[0].data,
        m4.run_interpreted(&x).unwrap()[0].data,
        "planned int4 executor must be bit-exact"
    );
    let ri4 = bench("resnet-like int4 interpreter b=1", 10, 120, || {
        std::hint::black_box(m4.run_interpreted(&x).unwrap());
    });
    ri4.print();
    let rp4 = bench("resnet-like int4 planned     b=1", 10, 120, || {
        std::hint::black_box(m4.run(&x).unwrap());
    });
    rp4.print();
    println!("    -> int4 speedup: {:.2}x", ri4.median_us / rp4.median_us);
    println!("    -> int4 vs int8 (planned): {:.2}x", rp8.median_us / rp4.median_us);

    // DYNAMIC activation scaling (W8/A8-dyn): same i8 weights, NO ranges —
    // every quantization point scans the live batch (ops::dyn_qparams)
    let mdyn = CompiledModel::new(
        graph.clone(),
        params.clone(),
        BTreeMap::new(),
        qweights,
        std::collections::HashMap::new(), // calibration-free
        ExecConfig {
            weight_mode: WeightMode::Int8,
            act_mode: ActMode::DynInt8 { round: RoundMode::TiesEven },
            kernel_tier: None,
        },
    );
    mdyn.plan().unwrap();
    assert_eq!(
        mdyn.run(&x).unwrap()[0].data,
        mdyn.run_interpreted(&x).unwrap()[0].data,
        "planned dynamic int8 executor must be bit-exact"
    );
    let rid = bench("resnet-like dyn8 interpreter b=1", 10, 120, || {
        std::hint::black_box(mdyn.run_interpreted(&x).unwrap());
    });
    rid.print();
    let rpd = bench("resnet-like dyn8 planned     b=1", 10, 120, || {
        std::hint::black_box(mdyn.run(&x).unwrap());
    });
    rpd.print();
    println!("    -> dyn8 speedup: {:.2}x", rid.median_us / rpd.median_us);
    println!("    -> dyn vs static int8 (planned): {:.2}x", rp8.median_us / rpd.median_us);

    // STEADY STATE: warm (caller-owned ExecScratch reused across runs —
    // zero allocations, persistent pool) vs cold (a fresh scratch every
    // call, i.e. the PR-4 allocate-per-call behaviour on today's kernels)
    let plan8 = m8.plan().unwrap();
    let mut scratch = ExecScratch::new();
    plan8.execute_with(&x, &mut scratch).unwrap(); // warmup sizes the arena
    let rcold = bench("resnet-like int8 planned cold-scratch b=1", 10, 120, || {
        let mut fresh = ExecScratch::new();
        std::hint::black_box(plan8.execute_with(&x, &mut fresh).unwrap());
    });
    rcold.print();
    let rwarm = bench("resnet-like int8 planned warm-scratch b=1", 10, 120, || {
        std::hint::black_box(plan8.execute_with(&x, &mut scratch).unwrap());
    });
    rwarm.print();
    let ss = rcold.median_us / rwarm.median_us;
    println!("    -> steady-state speedup (warm arena vs allocate-per-call): {ss:.2}x");

    PlanReport {
        kernel_tier: tier.label(),
        int8_plan_cold_us: rcold.median_us,
        int8_plan_warm_us: rwarm.median_us,
        fp32_interp_us: ri.median_us,
        fp32_plan_us: rp.median_us,
        int8_interp_us: ri8.median_us,
        int8_plan_us: rp8.median_us,
        int8_plan_scalar_us: rp8s.median_us,
        int4_interp_us: ri4.median_us,
        int4_plan_us: rp4.median_us,
        dyn_interp_us: rid.median_us,
        dyn_plan_us: rpd.median_us,
    }
}

fn write_bench_json(r: &PlanReport, gemm_scalar_us: f64, gemm_simd_us: f64) {
    let json = format!(
        "{{\n  \"bench\": \"engine_hotpath/plan_vs_interpreter\",\n  \"model\": \"synthetic resnet-like 3x32x32, b=1\",\n  \"kernel_tier\": \"{}\",\n  \"fp32_interp_us\": {:.1},\n  \"fp32_plan_us\": {:.1},\n  \"fp32_speedup\": {:.2},\n  \"int8_interp_us\": {:.1},\n  \"int8_plan_us\": {:.1},\n  \"int8_speedup\": {:.2},\n  \"int8_plan_scalar_us\": {:.1},\n  \"simd_speedup\": {:.2},\n  \"simd_gemm_scalar_us\": {:.1},\n  \"simd_gemm_simd_us\": {:.1},\n  \"simd_gemm_speedup\": {:.2},\n  \"int4_interp_us\": {:.1},\n  \"int4_plan_us\": {:.1},\n  \"int4_speedup\": {:.2},\n  \"int4_vs_int8_planned\": {:.2},\n  \"dyn_interp_us\": {:.1},\n  \"dyn_plan_us\": {:.1},\n  \"dyn_speedup\": {:.2},\n  \"dyn_vs_static_planned\": {:.2},\n  \"int8_plan_cold_us\": {:.1},\n  \"int8_plan_warm_us\": {:.1},\n  \"steady_state_speedup\": {:.2}\n}}\n",
        r.kernel_tier,
        r.fp32_interp_us,
        r.fp32_plan_us,
        r.fp32_interp_us / r.fp32_plan_us,
        r.int8_interp_us,
        r.int8_plan_us,
        r.int8_interp_us / r.int8_plan_us,
        r.int8_plan_scalar_us,
        r.int8_plan_scalar_us / r.int8_plan_us,
        gemm_scalar_us,
        gemm_simd_us,
        gemm_scalar_us / gemm_simd_us,
        r.int4_interp_us,
        r.int4_plan_us,
        r.int4_interp_us / r.int4_plan_us,
        r.int8_plan_us / r.int4_plan_us,
        r.dyn_interp_us,
        r.dyn_plan_us,
        r.dyn_interp_us / r.dyn_plan_us,
        r.int8_plan_us / r.dyn_plan_us,
        r.int8_plan_cold_us,
        r.int8_plan_warm_us,
        r.int8_plan_cold_us / r.int8_plan_warm_us,
    );
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_engine.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

fn artifact_benches(dir: &std::path::Path, rng: &mut Rng) {
    let graph = quant_trim::qir::Graph::load(dir.join("resnet18_c10.qir")).unwrap();
    let state = TrainState::from_checkpoint(
        &Checkpoint::load(dir.join("resnet18_c10.init.qtckpt")).unwrap(),
    );
    let task = ClsSpec::cifar10();
    let calib: Vec<Tensor> = (0..2).map(|i| gen_cls_batch(task, 8, 500 + i).images).collect();
    let be = backend_by_name("hardware_d").unwrap();
    let view = CheckpointView {
        graph: &graph,
        params: &state.params,
        bn: &state.bn,
        qstate: &state.qstate,
    };
    let dep = be
        .compile(view, Precision::Int8, RangeSource::Calibration, &calib, PtqOptions::default())
        .unwrap();
    let b1 = gen_cls_batch(task, 1, 3).images;
    let r = bench("engine resnet18 int8 planned b=1", 20, 200, || {
        std::hint::black_box(dep.model.run(&b1).unwrap());
    });
    r.print();
    println!("    -> {:.1} FPS measured (rust engine)", 1e6 / r.median_us);
    let r = bench("engine resnet18 int8 interp  b=1", 20, 200, || {
        std::hint::black_box(dep.model.run_interpreted(&b1).unwrap());
    });
    r.print();
    let b8 = gen_cls_batch(task, 8, 3).images;
    let r = bench("engine resnet18 int8 planned b=8", 3, 20, || {
        std::hint::black_box(dep.model.run(&b8).unwrap());
    });
    r.print();
    println!("    -> {:.1} FPS measured at batch 8", 8e6 / r.median_us);

    // PJRT-executed Pallas kernels (the L1 artifacts)
    if let Ok(rt) = quant_trim::runtime::Runtime::cpu() {
        let man = quant_trim::runtime::Manifest::load(dir.join("kernels.manifest")).unwrap();
        let f = rt.load_fn(&man, "fake_quant").unwrap();
        let xk = Tensor::new(vec![64, 4096], rng.normal_vec(64 * 4096, 1.0));
        bench("pallas fake_quant 64x4096 (PJRT)", 20, 200, || {
            std::hint::black_box(f.call_tensors(std::slice::from_ref(&xk)).unwrap());
        })
        .print();
        let f = rt.load_fn(&man, "qmatmul").unwrap();
        let a = Tensor::new(vec![256, 256], rng.normal_vec(256 * 256, 1.0));
        let w2 = Tensor::new(vec![256, 256], rng.normal_vec(256 * 256, 0.05));
        let r = bench("pallas qmatmul 256^3 (PJRT, interpret)", 3, 15, || {
            std::hint::black_box(f.call_tensors(&[a.clone(), w2.clone()]).unwrap());
        });
        r.print();
        println!(
            "    -> {:.3} GMAC/s (interpret-mode grid loop; structure, not speed, is the target)",
            (256f64 * 256.0 * 256.0) / r.median_us / 1e3
        );
    } else {
        println!("(PJRT unavailable in this build: skipping Pallas kernel benches)");
    }
}
