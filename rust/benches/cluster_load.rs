//! Cluster-tier load bench: drives 1 -> 4 router-attached nodes over
//! loopback HTTP and writes `BENCH_cluster.json` — per-node-count aggregate
//! throughput, the headline `cluster_scaling_2n_speedup` / `_4n_speedup`
//! ratios (floor-gated by `tools/bench_gate.rs` via the `*_speedup` suffix),
//! and a rebalance row: how long a graceful node leave takes end to end and
//! how many in-flight requests it lost (must be 0).
//!
//! Nodes serve a sleep-paced echo model (one worker, one-request batches),
//! so aggregate throughput is pinned by consistent-hash placement rather
//! than host CPU speed: the measured speedup is the fabric's, and the
//! committed baseline is meaningful across CI runners.
//!
//!   cargo bench --bench cluster_load            # 1, 2 and 4 nodes
//!   cargo bench --bench cluster_load -- --smoke # 1 and 2 nodes (CI job)

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;
use quant_trim::coordinator::cluster::{infer, ClusterNode, NodeConfig, Router, RouterConfig};
use quant_trim::coordinator::server::{BatchModel, BatchPolicy, ServerConfig, ServerDeployment};
use quant_trim::tensor::Tensor;

/// Simulated device service time per request: large enough that placement,
/// not host scheduling jitter, dominates the wall clock.
const DELAY_MS: u64 = 6;

/// Requests per round. Keys `load-key-0..96` split 49/47 over 2 nodes and
/// 26/23/22/25 over 4 (deterministic `stable_hash` placement at 128 vnodes).
const TOTAL: usize = 96;

/// Echo model paced by a fixed sleep; the first pixel identifies which
/// request a response answered.
struct PacedEcho {
    delay: Duration,
}

impl BatchModel for PacedEcho {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        std::thread::sleep(self.delay);
        let n = images.shape[0];
        let sz: usize = images.shape[1..].iter().product();
        let mut out = Tensor::zeros(&[n, 1]);
        for (i, o) in out.data.iter_mut().enumerate() {
            *o = images.data[i * sz];
        }
        Ok(out)
    }
    fn max_batch(&self) -> usize {
        1
    }
}

fn node_config() -> NodeConfig {
    NodeConfig {
        server: ServerConfig {
            workers: 1,
            queue_depth: 256,
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: Duration::from_millis(1),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
        heartbeat_every: Duration::from_millis(50),
        ..NodeConfig::default()
    }
}

fn start_cluster(n_nodes: usize, prefix: &str) -> (Router, Vec<ClusterNode>) {
    let router = Router::start(RouterConfig::default()).expect("router start");
    let nodes: Vec<ClusterNode> = (0..n_nodes)
        .map(|i| {
            ClusterNode::start(
                format!("{prefix}{i}"),
                vec![ServerDeployment::new(
                    "echo",
                    PacedEcho { delay: Duration::from_millis(DELAY_MS) },
                )],
                node_config(),
                Some(router.addr()),
            )
            .expect("node start")
        })
        .collect();
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.members() < n_nodes {
        assert!(Instant::now() < deadline, "nodes did not register in time");
        std::thread::sleep(Duration::from_millis(10));
    }
    (router, nodes)
}

struct Round {
    nodes: usize,
    throughput_rps: f64,
    elapsed_ms: f64,
    busiest_share: f64,
    served_nodes: usize,
}

impl Round {
    fn print(&self) {
        println!(
            "{} node(s): {:>8.1} rps aggregate   {:>7.1} ms wall   busiest share {:.2}",
            self.nodes, self.throughput_rps, self.elapsed_ms, self.busiest_share
        );
    }

    fn json(&self) -> String {
        format!(
            "    {{\"nodes\": {}, \"throughput_rps\": {:.1}, \"elapsed_ms\": {:.1}, \"busiest_share\": {:.3}, \"served_nodes\": {}}}",
            self.nodes, self.throughput_rps, self.elapsed_ms, self.busiest_share, self.served_nodes
        )
    }
}

/// One scaling round: `TOTAL` concurrent requests (one client thread each,
/// so every node's backlog is fully submitted up front) against an n-node
/// cluster. Wall clock = the busiest node's serial service time.
fn scaling_round(n_nodes: usize) -> Round {
    let (router, nodes) = start_cluster(n_nodes, "scale-n");
    let router_addr = router.addr();
    let by_node: Mutex<BTreeMap<String, usize>> = Mutex::new(BTreeMap::new());
    let started = Instant::now();
    std::thread::scope(|scope| {
        let by_node = &by_node;
        for i in 0..TOTAL {
            scope.spawn(move || {
                let image = Tensor::full(&[1, 2], i as f32);
                let reply = infer(
                    router_addr,
                    Some("echo"),
                    Some(&format!("load-key-{i}")),
                    &image,
                    None,
                    Duration::from_secs(30),
                )
                .expect("loopback transport");
                assert!(reply.is_served(), "request {i}: {:?}", reply.error);
                assert_eq!(reply.logits.as_ref().unwrap().data, vec![i as f32]);
                *by_node.lock().unwrap().entry(reply.node.unwrap()).or_insert(0) += 1;
            });
        }
    });
    let elapsed = started.elapsed();
    for node in nodes {
        node.shutdown();
    }
    router.shutdown();
    let shares = by_node.into_inner().unwrap();
    let busiest = shares.values().copied().max().unwrap_or(0);
    Round {
        nodes: n_nodes,
        throughput_rps: TOTAL as f64 / elapsed.as_secs_f64().max(1e-9),
        elapsed_ms: elapsed.as_secs_f64() * 1e3,
        busiest_share: busiest as f64 / TOTAL as f64,
        served_nodes: shares.len(),
    }
}

struct Rebalance {
    leave_ms: f64,
    reroute_ms: f64,
    lost_requests: usize,
}

/// Rebalance latency: under continuous traffic, gracefully remove one of
/// `n_nodes` nodes and measure (a) the leave itself — deregister + drain +
/// close — and (b) how long until a key the leaver owned is served again by
/// a survivor. Counts every non-200 answer during the window as lost.
fn rebalance_round(n_nodes: usize) -> Rebalance {
    let (router, mut nodes) = start_cluster(n_nodes, "rebal-n");
    let router_addr = router.addr();

    // find a probe key the victim currently owns
    let victim_id = nodes[0].id().to_string();
    let mut probe = None;
    for i in 0..256 {
        let key = format!("rebal-key-{i}");
        let reply = infer(
            router_addr,
            Some("echo"),
            Some(&key),
            &Tensor::full(&[1, 2], 0.0),
            None,
            Duration::from_secs(30),
        )
        .expect("probe transport");
        assert!(reply.is_served());
        if reply.node.as_deref() == Some(victim_id.as_str()) {
            probe = Some(key);
            break;
        }
    }
    let probe = probe.expect("some key lands on the victim at 128 vnodes");

    let lost = Mutex::new(0usize);
    let stop = std::sync::atomic::AtomicBool::new(false);
    let mut leave_ms = 0.0;
    let mut reroute_ms = 0.0;
    std::thread::scope(|scope| {
        // background traffic across many keys while the victim leaves
        for t in 0..4 {
            let lost = &lost;
            let stop = &stop;
            scope.spawn(move || {
                let mut i = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let key = format!("rebal-bg-{t}-{}", i % 16);
                    let reply = infer(
                        router_addr,
                        Some("echo"),
                        Some(&key),
                        &Tensor::full(&[1, 2], i as f32),
                        None,
                        Duration::from_secs(30),
                    )
                    .expect("bg transport");
                    if reply.status != 200 {
                        *lost.lock().unwrap() += 1;
                    }
                    i += 1;
                }
            });
        }
        std::thread::sleep(Duration::from_millis(50)); // let traffic build
        let victim = nodes.remove(0);
        let t0 = Instant::now();
        victim.shutdown();
        leave_ms = t0.elapsed().as_secs_f64() * 1e3;
        // the probe key must be served by a survivor — immediately, since
        // /leave updated the ring before the listener closed
        let t1 = Instant::now();
        let reply = infer(
            router_addr,
            Some("echo"),
            Some(&probe),
            &Tensor::full(&[1, 2], 1.0),
            None,
            Duration::from_secs(30),
        )
        .expect("probe transport after leave");
        assert!(reply.is_served(), "probe after leave: {:?}", reply.error);
        assert_ne!(reply.node.as_deref(), Some(victim_id.as_str()));
        reroute_ms = t1.elapsed().as_secs_f64() * 1e3;
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });
    for node in nodes {
        node.shutdown();
    }
    router.shutdown();
    Rebalance { leave_ms, reroute_ms, lost_requests: lost.into_inner().unwrap() }
}

fn write_json(path: &std::path::Path, json: &str) {
    match std::fs::write(path, json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let manifest = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let node_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4] };

    println!(
        "=== cluster load bench ({} mode, {TOTAL} requests/round, {DELAY_MS} ms/request pacing) ===",
        if smoke { "smoke" } else { "full" }
    );
    println!("host cpus: {cpus}\n");

    let rounds: Vec<Round> = node_counts.iter().map(|&n| scaling_round(n)).collect();
    for r in &rounds {
        r.print();
    }

    let tp_of = |n: usize| {
        rounds.iter().find(|r| r.nodes == n).map(|r| r.throughput_rps).unwrap_or(0.0)
    };
    let speedup_2n = tp_of(2) / tp_of(1).max(1e-9);
    println!("\ncluster scaling: 2 nodes vs 1 = {speedup_2n:.2}x");
    let speedup_4n = if smoke {
        None
    } else {
        let s = tp_of(4) / tp_of(1).max(1e-9);
        println!("cluster scaling: 4 nodes vs 1 = {s:.2}x");
        if s < 3.0 {
            println!("WARNING: expected >= 3x aggregate throughput from 1 -> 4 nodes");
        }
        Some(s)
    };

    let rebalance = rebalance_round(if smoke { 2 } else { 4 });
    println!(
        "\nrebalance: leave {:.1} ms, reroute {:.1} ms, lost requests {}",
        rebalance.leave_ms, rebalance.reroute_ms, rebalance.lost_requests
    );
    if rebalance.lost_requests > 0 {
        println!("WARNING: a graceful leave must lose zero accepted requests");
    }

    let gate_4n = match speedup_4n {
        Some(s) => format!("\n  \"cluster_scaling_4n_speedup\": {s:.2},"),
        None => String::new(),
    };
    let rows: Vec<String> = rounds.iter().map(Round::json).collect();
    let json = format!(
        "{{\n  \"bench\": \"cluster_load{}\",\n  \"host_cpus\": {cpus},\n  \"requests_per_round\": {TOTAL},\n  \"pacing_ms\": {DELAY_MS},\n  \"cluster_scaling_2n_speedup\": {speedup_2n:.2},{gate_4n}\n  \"rebalance_leave_ms\": {:.1},\n  \"rebalance_reroute_ms\": {:.1},\n  \"rebalance_lost_requests\": {},\n  \"rounds\": [\n{}\n  ]\n}}\n",
        if smoke { " --smoke" } else { "" },
        rebalance.leave_ms,
        rebalance.reroute_ms,
        rebalance.lost_requests,
        rows.join(",\n"),
    );
    write_json(&manifest.join("BENCH_cluster.json"), &json);
}
