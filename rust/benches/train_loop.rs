//! Native Quant-Trim training-loop bench: times the pure-Rust train step,
//! the atomic checkpoint save/load path, and the headline **kill-and-resume
//! speedup** — a full from-scratch run vs resuming the same run from its
//! last epoch checkpoint. Writes `BENCH_train.json`; the CI train-smoke job
//! gates `train_resume_speedup` (floor) against
//! `BENCH_baseline/train.json` via `tools/bench_gate.rs`.
//!
//! The speedup is a ratio of two runs in the SAME process on the SAME
//! synthetic model, so it is machine-independent: resuming a 6-epoch run
//! with one epoch left must be much cheaper than retraining all six. A
//! ratio near 1.0 means resume silently restarted from scratch.
//!
//!   cargo bench --bench train_loop

use std::time::Instant;

use quant_trim::coordinator::qtrain::{NativeTrainer, QtConfig, RunControls};
use quant_trim::testutil::synth;

const EPOCHS: usize = 6;
const STEPS: usize = 4;

fn scratch(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("qt_bench_train_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create bench scratch dir");
    dir
}

fn main() {
    let cfg = QtConfig::tiny(EPOCHS, STEPS);
    let sm = synth::resnet_like(8, 8);

    // Full from-scratch run.
    let dir_full = scratch("full");
    let mut full = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let t0 = Instant::now();
    let rep = full.train(&dir_full, RunControls::default()).expect("full run");
    let full_us = t0.elapsed().as_micros() as f64;
    assert_eq!(rep.logs.len(), EPOCHS);
    let step_us = full_us / (EPOCHS * STEPS) as f64;

    // Killed run: checkpoint EPOCHS-1 epochs, then die mid-run.
    let dir_kill = scratch("kill");
    let mut killed = NativeTrainer::new(sm.graph.clone(), sm.params.clone(), sm.bn.clone(), cfg.clone());
    let rep = killed
        .train(
            &dir_kill,
            RunControls { abort_after_steps: Some((EPOCHS - 1) * STEPS), ..Default::default() },
        )
        .expect("killed run");
    assert!(rep.aborted);
    drop(killed);

    // Resume: manifest parse + checkpoint load + ONE remaining epoch.
    let t0 = Instant::now();
    let mut resumed = NativeTrainer::resume(sm.graph.clone(), cfg.clone(), &dir_kill)
        .expect("resume parses")
        .expect("manifest present");
    let rep = resumed.train(&dir_kill, RunControls::default()).expect("resumed run");
    let resume_us = t0.elapsed().as_micros() as f64;
    assert_eq!(rep.logs.len(), 1, "exactly one epoch left after the kill");

    let speedup = full_us / resume_us.max(1.0);

    // Checkpoint save/load microbench on the trained state.
    let ck = resumed.state.to_checkpoint_full();
    let ck_path = dir_kill.join("bench_probe.qtckpt");
    let reps = 20;
    let t0 = Instant::now();
    for _ in 0..reps {
        ck.save(&ck_path).expect("checkpoint save");
    }
    let save_us = t0.elapsed().as_micros() as f64 / reps as f64;
    let t0 = Instant::now();
    for _ in 0..reps {
        quant_trim::ckpt::Checkpoint::load(&ck_path).expect("checkpoint load");
    }
    let load_us = t0.elapsed().as_micros() as f64 / reps as f64;

    println!("train_loop bench  ({EPOCHS} epochs x {STEPS} steps, synthetic resnet-like 3x8x8)");
    println!("  full run        {:>10.0} us", full_us);
    println!("  resume (1 ep)   {:>10.0} us", resume_us);
    println!("  resume speedup  {:>10.2} x", speedup);
    println!("  train step      {:>10.0} us", step_us);
    println!("  ckpt save       {:>10.0} us", save_us);
    println!("  ckpt load       {:>10.0} us", load_us);

    let json = format!(
        "{{\n  \"bench\": \"train_loop\",\n  \"model\": \"synthetic resnet-like 3x8x8, native Quant-Trim trainer\",\n  \"epochs\": {EPOCHS},\n  \"steps_per_epoch\": {STEPS},\n  \"train_resume_speedup\": {speedup:.3},\n  \"train_full_us\": {full_us:.0},\n  \"train_resume_us\": {resume_us:.0},\n  \"train_step_us\": {step_us:.1},\n  \"checkpoint_save_us\": {save_us:.1},\n  \"checkpoint_load_us\": {load_us:.1}\n}}\n"
    );
    std::fs::write("BENCH_train.json", &json).expect("write BENCH_train.json");
    println!("wrote BENCH_train.json");

    let _ = std::fs::remove_dir_all(&dir_full);
    let _ = std::fs::remove_dir_all(&dir_kill);
}
