//! Serving-fabric load bench: sweeps workers × batch-policy × backend over
//! the concurrent batching server and writes `BENCH_server.json`
//! (throughput_rps, p50/p95 latency, mean batch occupancy per config, plus
//! the headline 4-worker-vs-1-worker speedup).
//!
//! Deployments are the real int8 engine compiled per simulated backend, but
//! **device-paced**: each batch holds its worker for at least the roofline
//! perf model's device latency (with a floor), because the host CPU computes
//! the exact logits faster than the edge NPUs it simulates — un-paced, this
//! bench would measure host CPU speed instead of the serving fabric's
//! scheduling across the fleet. Closed-loop load, no artifacts needed.
//!
//!   cargo bench --bench server_load

use std::time::{Duration, Instant};

use quant_trim::coordinator::experiment::compile_serving_fleet;
use quant_trim::coordinator::server::{
    BatchPolicy, Server, ServerConfig, ServerDeployment, ServerStats,
};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

/// Minimum simulated device service time per batch (ms). Large enough that
/// worker scaling, not host CPU contention, dominates the sweep.
const FLOOR_MS: f64 = 5.0;

struct Sweep {
    backend: String,
    workers: usize,
    max_batch: usize,
    throughput_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    mean_batch: f64,
    occupancy: f64,
    served: usize,
    errors: usize,
    rejected: usize,
}

impl Sweep {
    fn print(&self) {
        println!(
            "{:<22} workers {}  max_batch {}  ->  {:>8.1} rps   p50 {:>6.2} ms   p95 {:>6.2} ms   mean batch {:.2} ({:.0}% occupancy)",
            self.backend,
            self.workers,
            self.max_batch,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.mean_batch,
            self.occupancy * 100.0,
        );
    }

    fn json(&self) -> String {
        format!(
            "    {{\"backend\": \"{}\", \"workers\": {}, \"max_batch\": {}, \"throughput_rps\": {:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"mean_batch\": {:.2}, \"occupancy\": {:.3}, \"served\": {}, \"errors\": {}, \"rejected\": {}}}",
            self.backend,
            self.workers,
            self.max_batch,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.mean_batch,
            self.occupancy,
            self.served,
            self.errors,
            self.rejected,
        )
    }
}

/// Closed-loop drive: `clients` threads, each submitting `per_client`
/// requests round-robin across `names`, retrying on backpressure. Every
/// request must come back with logits.
fn drive(
    fleet: Vec<ServerDeployment>,
    names: &[&str],
    workers: usize,
    max_batch: usize,
    clients: usize,
    per_client: usize,
) -> (f64, ServerStats) {
    let server = Server::start(
        fleet,
        ServerConfig {
            workers,
            queue_depth: 64,
            policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(2) },
        },
    )
    .expect("server start");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            s.spawn(move || {
                let mut rng = Rng::new(0x10AD + c as u64);
                let img = Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0));
                for r in 0..per_client {
                    let name = names[(c + r) % names.len()];
                    let mut image = img.clone();
                    loop {
                        match server.submit_image(image, Some(name)) {
                            Ok(rx) => {
                                let resp = rx.recv().expect("every request gets a response");
                                assert!(
                                    resp.result.is_ok(),
                                    "deployment {name} failed: {:?}",
                                    resp.result
                                );
                                break;
                            }
                            Err(e) => {
                                // bounded queue pushed back: retry shortly
                                std::thread::sleep(Duration::from_micros(200));
                                image = e.into_request().image;
                            }
                        }
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    let total = clients * per_client;
    assert_eq!(stats.served, total, "all {total} submitted requests must be served");
    assert_eq!(stats.errors, 0);
    (total as f64 / elapsed, stats)
}

fn int8_fleet(backend: &str, max_batch: usize) -> Vec<ServerDeployment> {
    int8_fleet_of(&[backend], max_batch)
}

fn int8_fleet_of(backends: &[&str], max_batch: usize) -> Vec<ServerDeployment> {
    let sm = synth::resnet_like(16, 8);
    let mut rng = Rng::new(0xCA11B);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let specs: Vec<(&str, Option<Precision>, ActScaling)> =
        backends.iter().map(|&b| (b, Some(Precision::Int8), ActScaling::Static)).collect();
    compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &specs,
        &calib,
        max_batch,
        Some(Duration::from_secs_f64(FLOOR_MS / 1e3)),
    )
    .expect("fleet compile")
}

fn main() {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("=== serving-fabric load bench (closed loop, device-paced int8 engine) ===");
    println!("host cpus: {cpus}   pacing floor: {FLOOR_MS} ms/batch\n");

    let backends = ["hardware_a", "hardware_d", "rk3588"];
    let mut sweeps: Vec<Sweep> = Vec::new();
    for backend in backends {
        for max_batch in [1usize, 4] {
            for workers in [1usize, 2, 4] {
                let fleet = int8_fleet(backend, max_batch);
                let (tp, stats) = drive(fleet, &[backend], workers, max_batch, 16, 13);
                let sweep = Sweep {
                    backend: backend.to_string(),
                    workers,
                    max_batch,
                    throughput_rps: tp,
                    p50_ms: stats.p50_ms,
                    p95_ms: stats.p95_ms,
                    mean_batch: stats.mean_batch,
                    occupancy: stats.mean_batch / max_batch as f64,
                    served: stats.served,
                    errors: stats.errors,
                    rejected: stats.rejected,
                };
                sweep.print();
                sweeps.push(sweep);
            }
        }
        println!();
    }

    // one server fronting the whole fleet: mixed traffic round-robins the
    // three simulated NPUs through the multi-deployment router
    let fleet = int8_fleet_of(&backends, 4);
    let (tp, stats) = drive(fleet, &backends, 4, 4, 24, 12);
    let fleet_sweep = Sweep {
        backend: "fleet(a+d+rk3588)".to_string(),
        workers: 4,
        max_batch: 4,
        throughput_rps: tp,
        p50_ms: stats.p50_ms,
        p95_ms: stats.p95_ms,
        mean_batch: stats.mean_batch,
        occupancy: stats.mean_batch / 4.0,
        served: stats.served,
        errors: stats.errors,
        rejected: stats.rejected,
    };
    fleet_sweep.print();
    sweeps.push(fleet_sweep);

    // headline scaling: same deployment + policy, 4 workers vs 1
    let tp_of = |workers: usize| {
        sweeps
            .iter()
            .find(|s| s.backend == "hardware_a" && s.max_batch == 4 && s.workers == workers)
            .map(|s| s.throughput_rps)
            .unwrap_or(0.0)
    };
    let speedup = tp_of(4) / tp_of(1).max(1e-9);
    println!("\nworkers speedup (hardware_a int8, max_batch 4): 4w vs 1w = {speedup:.2}x");
    if speedup < 2.0 {
        println!("WARNING: expected >= 2x scaling from 1 -> 4 workers");
    }

    let rows: Vec<String> = sweeps.iter().map(Sweep::json).collect();
    let json = format!(
        "{{\n  \"bench\": \"server_load\",\n  \"model\": \"synthetic resnet-like 3x16x16, int8 engine, device-paced\",\n  \"host_cpus\": {cpus},\n  \"pacing_floor_ms\": {FLOOR_MS},\n  \"workers_speedup_4v1\": {speedup:.2},\n  \"sweeps\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
    );
    let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_server.json");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
