//! Serving-fabric load bench: sweeps workers × batch-policy × backend over
//! the concurrent batching server and writes `BENCH_server.json`
//! (throughput_rps, p50/p95 latency, mean batch occupancy per config, plus
//! the headline 4-worker-vs-1-worker speedup), then runs the **chaos
//! scenario suite** — burst / diurnal / brownout / panic-storm traffic with
//! seeded fault injection — emitting p50/p95/p99, SLO-violation rate and
//! shed/retry/breaker/restart counters per scenario.
//!
//! Deployments are the real int8 engine compiled per simulated backend, but
//! **device-paced**: each batch holds its worker for at least the roofline
//! perf model's device latency (with a floor), because the host CPU computes
//! the exact logits faster than the edge NPUs it simulates — un-paced, this
//! bench would measure host CPU speed instead of the serving fabric's
//! scheduling across the fleet. Closed-loop load, no artifacts needed.
//!
//!   cargo bench --bench server_load                # sweeps + chaos suite
//!   cargo bench --bench server_load -- --chaos-only  # scenario suite only,
//!                                                  # writes BENCH_chaos.json
//!                                                  # (the CI chaos-smoke job)

use std::num::NonZeroUsize;
use std::time::{Duration, Instant};

use quant_trim::coordinator::experiment::compile_serving_fleet;
use quant_trim::coordinator::faults::{Brownout, BrownoutMode, FaultPlan, FaultyModel};
use quant_trim::coordinator::server::{
    BatchPolicy, BreakerPolicy, Priority, Server, ServerConfig, ServerDeployment, ServerStats,
    SubmitError,
};
use quant_trim::perfmodel::{ActScaling, Precision};
use quant_trim::tensor::Tensor;
use quant_trim::testutil::{synth, Rng};

/// Minimum simulated device service time per batch (ms). Large enough that
/// worker scaling, not host CPU contention, dominates the sweep.
const FLOOR_MS: f64 = 5.0;

/// Fault seed for the chaos scenarios: fixed so the injected schedule —
/// and therefore the scenario counters — replays run to run.
const CHAOS_SEED: u64 = 0xC4A0_5EED;

struct Sweep {
    backend: String,
    workers: usize,
    max_batch: usize,
    throughput_rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    mean_batch: f64,
    occupancy: f64,
    served: usize,
    errors: usize,
    rejected: usize,
}

impl Sweep {
    fn print(&self) {
        println!(
            "{:<22} workers {}  max_batch {}  ->  {:>8.1} rps   p50 {:>6.2} ms   p95 {:>6.2} ms   mean batch {:.2} ({:.0}% occupancy)",
            self.backend,
            self.workers,
            self.max_batch,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.mean_batch,
            self.occupancy * 100.0,
        );
    }

    fn json(&self) -> String {
        format!(
            "    {{\"backend\": \"{}\", \"workers\": {}, \"max_batch\": {}, \"throughput_rps\": {:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"mean_batch\": {:.2}, \"occupancy\": {:.3}, \"served\": {}, \"errors\": {}, \"rejected\": {}}}",
            self.backend,
            self.workers,
            self.max_batch,
            self.throughput_rps,
            self.p50_ms,
            self.p95_ms,
            self.mean_batch,
            self.occupancy,
            self.served,
            self.errors,
            self.rejected,
        )
    }
}

/// Closed-loop drive: `clients` threads, each submitting `per_client`
/// requests round-robin across `names`, retrying on backpressure. Every
/// request must come back with logits.
fn drive(
    fleet: Vec<ServerDeployment>,
    names: &[&str],
    workers: usize,
    max_batch: usize,
    clients: usize,
    per_client: usize,
) -> (f64, ServerStats) {
    let server = Server::start(
        fleet,
        ServerConfig {
            workers,
            queue_depth: 64,
            policy: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(2),
                slo_margin: None,
            },
            ..ServerConfig::default()
        },
    )
    .expect("server start");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            s.spawn(move || {
                let mut rng = Rng::new(0x10AD + c as u64);
                let img = Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0));
                for r in 0..per_client {
                    let name = names[(c + r) % names.len()];
                    let mut image = img.clone();
                    loop {
                        match server.submit_image(image, Some(name)) {
                            Ok(rx) => {
                                let resp = rx.recv().expect("every request gets a response");
                                assert!(
                                    resp.result.is_ok(),
                                    "deployment {name} failed: {:?}",
                                    resp.result
                                );
                                break;
                            }
                            Err(e) => {
                                // bounded queue pushed back: retry shortly
                                std::thread::sleep(Duration::from_micros(200));
                                image = e.into_request().image;
                            }
                        }
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    let total = clients * per_client;
    assert_eq!(stats.served, total, "all {total} submitted requests must be served");
    assert_eq!(stats.errors, 0);
    (total as f64 / elapsed, stats)
}

fn int8_fleet(backend: &str, max_batch: usize) -> Vec<ServerDeployment> {
    int8_fleet_of(&[backend], max_batch)
}

fn int8_fleet_of(backends: &[&str], max_batch: usize) -> Vec<ServerDeployment> {
    let sm = synth::resnet_like(16, 8);
    let mut rng = Rng::new(0xCA11B);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    let specs: Vec<(&str, Option<Precision>, ActScaling)> =
        backends.iter().map(|&b| (b, Some(Precision::Int8), ActScaling::Static)).collect();
    compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &specs,
        &calib,
        max_batch,
        Some(Duration::from_secs_f64(FLOOR_MS / 1e3)),
    )
    .expect("fleet compile")
}

/// hardware_d at INT8 + INT4 behind one router; `compile_serving_fleet`
/// wires the INT4 sibling as the INT8 entry's breaker fallback.
fn int8_with_int4_sibling(max_batch: usize) -> Vec<ServerDeployment> {
    let sm = synth::resnet_like(16, 8);
    let mut rng = Rng::new(0xCA11B);
    let calib: Vec<Tensor> =
        (0..2).map(|_| Tensor::new(vec![2, 3, 16, 16], rng.normal_vec(2 * 3 * 256, 1.0))).collect();
    compile_serving_fleet(
        &sm.graph,
        &sm.params,
        &sm.bn,
        &[
            ("hardware_d", Some(Precision::Int8), ActScaling::Static),
            ("hardware_d", Some(Precision::Int4), ActScaling::Static),
        ],
        &calib,
        max_batch,
        Some(Duration::from_secs_f64(FLOOR_MS / 1e3)),
    )
    .expect("sibling fleet compile")
}

// ---------------------------------------------------------------------------
// Chaos scenario suite
// ---------------------------------------------------------------------------

struct ScenarioResult {
    name: &'static str,
    throughput_rps: f64,
    stats: ServerStats,
}

impl ScenarioResult {
    fn print(&self) {
        let s = &self.stats;
        println!(
            "{:<12} {:>7.1} rps  p50/p95/p99 {:>6.2}/{:>6.2}/{:>6.2} ms  viol {:.4}",
            self.name,
            self.throughput_rps,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.slo_violation_rate(),
        );
        println!(
            "             served {} errors {} expired {} shed {} retried {} degraded {} breaker_trips {} panics {} restarts {}",
            s.served,
            s.errors,
            s.expired,
            s.shed,
            s.retried,
            s.degraded,
            s.breaker_trips,
            s.worker_panics,
            s.workers_restarted,
        );
    }

    fn json(&self) -> String {
        let s = &self.stats;
        format!(
            "    {{\"scenario\": \"{}\", \"throughput_rps\": {:.1}, \"p50_ms\": {:.3}, \"p95_ms\": {:.3}, \"p99_ms\": {:.3}, \"violation_rate\": {:.4}, \"served\": {}, \"errors\": {}, \"expired\": {}, \"shed\": {}, \"retried\": {}, \"degraded\": {}, \"breaker_trips\": {}, \"worker_panics\": {}, \"workers_restarted\": {}}}",
            self.name,
            self.throughput_rps,
            s.p50_ms,
            s.p95_ms,
            s.p99_ms,
            s.slo_violation_rate(),
            s.served,
            s.errors,
            s.expired,
            s.shed,
            s.retried,
            s.degraded,
            s.breaker_trips,
            s.worker_panics,
            s.workers_restarted,
        )
    }

    /// Top-level gated keys (unique per scenario: the gate's flat JSON
    /// parser would merge duplicate keys across scenario rows).
    fn gate_keys(&self) -> String {
        format!(
            "  \"chaos_{0}_p95_ms\": {1:.3},\n  \"chaos_{0}_violation_rate\": {2:.4},",
            self.name,
            self.stats.p95_ms,
            self.stats.slo_violation_rate(),
        )
    }
}

fn chaos_config(workers: usize, shed_watermark: Option<usize>) -> ServerConfig {
    ServerConfig {
        workers,
        queue_depth: 64,
        policy: BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
            // SLO lane: flush pending batches 5 ms ahead of the most urgent
            // request deadline
            slo_margin: Some(Duration::from_millis(5)),
        },
        breaker: BreakerPolicy { trip_after: 5, cooldown: Duration::from_millis(100) },
        shed_watermark,
        ..ServerConfig::default()
    }
}

/// Open-loop scenario drive: each client submits its whole schedule (with a
/// per-request arrival gap), then collects every reply. Faulted deployments
/// may answer with errors — the invariant exercised here is that every
/// accepted request is answered at all.
fn chaos_drive(
    fleet: Vec<ServerDeployment>,
    names: &[&str],
    cfg: ServerConfig,
    clients: usize,
    per_client: usize,
    deadline: Option<Duration>,
    low_prio_every: usize,
    gap: impl Fn(usize, usize) -> Duration + Sync,
) -> (f64, ServerStats) {
    let server = Server::start(fleet, cfg).expect("server start");
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let server = &server;
            let gap = &gap;
            s.spawn(move || {
                let mut rng = Rng::new(CHAOS_SEED + c as u64);
                let img = Tensor::new(vec![3, 16, 16], rng.normal_vec(3 * 256, 1.0));
                let mut rxs = Vec::with_capacity(per_client);
                for r in 0..per_client {
                    let name = names[(c + r) % names.len()];
                    let pri = if low_prio_every > 0 && r % low_prio_every == 0 {
                        Priority::Low
                    } else {
                        Priority::Normal
                    };
                    let dl = deadline.map(|d| Instant::now() + d);
                    let mut image = img.clone();
                    loop {
                        match server.submit_image_with(image, Some(name), dl, pri) {
                            Ok(rx) => {
                                rxs.push(rx);
                                break;
                            }
                            Err(SubmitError::Shed(_)) => break, // admission control shed it
                            Err(e) => {
                                std::thread::sleep(Duration::from_micros(200));
                                image = e.into_request().image;
                            }
                        }
                    }
                    let g = gap(c, r);
                    if !g.is_zero() {
                        std::thread::sleep(g);
                    }
                }
                for rx in rxs {
                    // served, failed, or expired — but always answered
                    rx.recv().expect("every accepted request gets a response");
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();
    (stats.served as f64 / elapsed.max(1e-9), stats)
}

fn run_chaos_suite() -> Vec<ScenarioResult> {
    let deadline = Some(Duration::from_millis(400));
    let mut out = Vec::new();

    // burst: 20 ms quiet gaps between 8-request bursts, healthy backend —
    // baseline for the SLO machinery itself (violation rate should be ~0)
    let (tp, stats) = chaos_drive(
        int8_fleet("hardware_d", 4),
        &["hardware_d"],
        chaos_config(2, None),
        8,
        24,
        deadline,
        0,
        |_c, r| if r % 8 == 7 { Duration::from_millis(20) } else { Duration::ZERO },
    );
    out.push(ScenarioResult { name: "burst", throughput_rps: tp, stats });

    // diurnal: alternating high/low arrival-rate phases with admission
    // control — low-priority traffic is shed when the peak phase floods the
    // queue past the watermark
    let (tp, stats) = chaos_drive(
        int8_fleet("hardware_d", 4),
        &["hardware_d"],
        chaos_config(2, Some(16)),
        12,
        24,
        deadline,
        3,
        |_c, r| {
            if (r / 6) % 2 == 0 {
                Duration::ZERO // peak phase
            } else {
                Duration::from_millis(8) // trough phase
            }
        },
    );
    out.push(ScenarioResult { name: "diurnal", throughput_rps: tp, stats });

    // brownout: the INT8 deployment fails transiently for a sustained window
    // (seeded) while its INT4 sibling stays healthy — retries + breaker
    // degrade traffic to INT4 and revert after the window
    let plan = FaultPlan {
        seed: CHAOS_SEED,
        brownout: Some(Brownout { from_call: 8, calls: 40, mode: BrownoutMode::Fail }),
        ..FaultPlan::default()
    };
    let fleet: Vec<ServerDeployment> = int8_with_int4_sibling(4)
        .into_iter()
        .map(|d| if d.name == "hardware_d@INT8" { FaultyModel::wrap(d, plan) } else { d })
        .collect();
    let (tp, stats) = chaos_drive(
        fleet,
        &["hardware_d@INT8"],
        chaos_config(2, None),
        8,
        24,
        deadline,
        0,
        |_c, _r| Duration::from_millis(1),
    );
    out.push(ScenarioResult { name: "brownout", throughput_rps: tp, stats });

    // panic storm: every 9th model call panics (plus a sprinkle of seeded
    // transient errors) — workers contain and recycle; every request is
    // still answered
    let plan = FaultPlan {
        seed: CHAOS_SEED,
        transient_prob: 0.05,
        panic_every: NonZeroUsize::new(9),
        ..FaultPlan::default()
    };
    let fleet: Vec<ServerDeployment> =
        int8_fleet("hardware_d", 4).into_iter().map(|d| FaultyModel::wrap(d, plan)).collect();
    let (tp, stats) = chaos_drive(
        fleet,
        &["hardware_d"],
        chaos_config(2, None),
        8,
        24,
        deadline,
        0,
        |_c, _r| Duration::from_millis(1),
    );
    out.push(ScenarioResult { name: "panic_storm", throughput_rps: tp, stats });

    out
}

fn write_json(path: &std::path::Path, json: &str) {
    match std::fs::write(path, json) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

fn main() {
    let chaos_only = std::env::args().any(|a| a == "--chaos-only");
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let manifest = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));

    if chaos_only {
        println!("=== chaos scenario suite (seed {CHAOS_SEED:#x}, device-paced int8 engine) ===\n");
        let scenarios = run_chaos_suite();
        for sc in &scenarios {
            sc.print();
        }
        let gates: Vec<String> = scenarios.iter().map(ScenarioResult::gate_keys).collect();
        let rows: Vec<String> = scenarios.iter().map(ScenarioResult::json).collect();
        let json = format!(
            "{{\n  \"bench\": \"server_load --chaos-only\",\n  \"host_cpus\": {cpus},\n  \"fault_seed\": {CHAOS_SEED},\n{}\n  \"scenarios\": [\n{}\n  ]\n}}\n",
            gates.join("\n"),
            rows.join(",\n"),
        );
        write_json(&manifest.join("BENCH_chaos.json"), &json);
        return;
    }

    println!("=== serving-fabric load bench (closed loop, device-paced int8 engine) ===");
    println!("host cpus: {cpus}   pacing floor: {FLOOR_MS} ms/batch\n");

    let backends = ["hardware_a", "hardware_d", "rk3588"];
    let mut sweeps: Vec<Sweep> = Vec::new();
    for backend in backends {
        for max_batch in [1usize, 4] {
            for workers in [1usize, 2, 4] {
                let fleet = int8_fleet(backend, max_batch);
                let (tp, stats) = drive(fleet, &[backend], workers, max_batch, 16, 13);
                let sweep = Sweep {
                    backend: backend.to_string(),
                    workers,
                    max_batch,
                    throughput_rps: tp,
                    p50_ms: stats.p50_ms,
                    p95_ms: stats.p95_ms,
                    mean_batch: stats.mean_batch,
                    occupancy: stats.mean_batch / max_batch as f64,
                    served: stats.served,
                    errors: stats.errors,
                    rejected: stats.rejected,
                };
                sweep.print();
                sweeps.push(sweep);
            }
        }
        println!();
    }

    // one server fronting the whole fleet: mixed traffic round-robins the
    // three simulated NPUs through the multi-deployment router
    let fleet = int8_fleet_of(&backends, 4);
    let (tp, stats) = drive(fleet, &backends, 4, 4, 24, 12);
    let fleet_sweep = Sweep {
        backend: "fleet(a+d+rk3588)".to_string(),
        workers: 4,
        max_batch: 4,
        throughput_rps: tp,
        p50_ms: stats.p50_ms,
        p95_ms: stats.p95_ms,
        mean_batch: stats.mean_batch,
        occupancy: stats.mean_batch / 4.0,
        served: stats.served,
        errors: stats.errors,
        rejected: stats.rejected,
    };
    fleet_sweep.print();
    sweeps.push(fleet_sweep);

    // headline scaling: same deployment + policy, 4 workers vs 1
    let tp_of = |workers: usize| {
        sweeps
            .iter()
            .find(|s| s.backend == "hardware_a" && s.max_batch == 4 && s.workers == workers)
            .map(|s| s.throughput_rps)
            .unwrap_or(0.0)
    };
    let speedup = tp_of(4) / tp_of(1).max(1e-9);
    println!("\nworkers speedup (hardware_a int8, max_batch 4): 4w vs 1w = {speedup:.2}x");
    if speedup < 2.0 {
        println!("WARNING: expected >= 2x scaling from 1 -> 4 workers");
    }

    println!("\n=== chaos scenario suite (seed {CHAOS_SEED:#x}) ===\n");
    let scenarios = run_chaos_suite();
    for sc in &scenarios {
        sc.print();
    }

    let gates: Vec<String> = scenarios.iter().map(ScenarioResult::gate_keys).collect();
    let rows: Vec<String> = sweeps.iter().map(Sweep::json).collect();
    let chaos_rows: Vec<String> = scenarios.iter().map(ScenarioResult::json).collect();
    let json = format!(
        "{{\n  \"bench\": \"server_load\",\n  \"model\": \"synthetic resnet-like 3x16x16, int8 engine, device-paced\",\n  \"host_cpus\": {cpus},\n  \"pacing_floor_ms\": {FLOOR_MS},\n  \"fault_seed\": {CHAOS_SEED},\n  \"workers_speedup_4v1\": {speedup:.2},\n{}\n  \"sweeps\": [\n{}\n  ],\n  \"scenarios\": [\n{}\n  ]\n}}\n",
        gates.join("\n"),
        rows.join(",\n"),
        chaos_rows.join(",\n"),
    );
    write_json(&manifest.join("BENCH_server.json"), &json);
}
