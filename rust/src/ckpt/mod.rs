//! `.qtckpt` checkpoint reader/writer — binary twin of `python/compile/ckpt.py`.
//!
//! Checkpoints hold the full training state as named f32 tensors with
//! role prefixes: `param/...`, `bn/...`, `qstate/...` (and `opt_m/`, `opt_v/`,
//! `meta/...` once training has started on the Rust side).
//!
//! Durability contract (version 2):
//! - every file ends with an FNV-1a 64 checksum over all preceding bytes, so
//!   bit-flips are detected at load instead of yielding garbage tensors;
//! - `save` is atomic: bytes go to a unique temp file in the destination
//!   directory, are fsynced, then renamed over the target (plus a
//!   best-effort directory fsync), so a crash mid-save leaves either the
//!   old file or the new one, never a torn write;
//! - `from_bytes` is fully bounds-checked and returns `Err` on truncated,
//!   corrupt, or adversarial input — it never panics. Version-1 files
//!   (no checksum) are still accepted for backward compatibility.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"QTCK";
const VERSION: u32 = 2;
/// Oldest on-disk version `from_bytes` still accepts (pre-checksum format).
const LEGACY_VERSION: u32 = 1;
const MAX_NDIM: usize = 8;
const CHECKSUM_LEN: usize = 8;

/// FNV-1a 64-bit hash — the trailing checksum of version-2 `.qtckpt` files.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Atomically replace `path` with `bytes`: unique temp file in the same
/// directory, `write` + `fsync`, `rename`, then best-effort directory fsync.
/// A crash at any point leaves either the previous file or the new one.
pub fn write_atomic(path: impl AsRef<Path>, bytes: &[u8]) -> Result<()> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let path = path.as_ref();
    let dir: PathBuf = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p.to_path_buf(),
        _ => PathBuf::from("."),
    };
    let base = path
        .file_name()
        .and_then(|n| n.to_str())
        .unwrap_or("qtckpt");
    let tmp = dir.join(format!(
        ".{base}.{}.{}.tmp",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let res = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp).with_context(|| format!("create {tmp:?}"))?;
        f.write_all(bytes)?;
        f.sync_all().with_context(|| format!("fsync {tmp:?}"))?;
        drop(f);
        std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
        Ok(())
    })();
    if res.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    res?;
    // Directory fsync makes the rename itself durable; not all platforms
    // allow opening a directory for sync, so failures are non-fatal.
    if let Ok(d) = std::fs::File::open(&dir) {
        d.sync_all().ok();
    }
    Ok(())
}

/// Bounds-checked little-endian reader over a byte slice.
struct Cur<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .off
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .with_context(|| {
                format!(
                    "truncated .qtckpt: need {n} bytes at offset {}, have {}",
                    self.off,
                    self.buf.len()
                )
            })?;
        let s = &self.buf[self.off..end];
        self.off = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().expect("len 2")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("len 4")))
    }
}

/// An ordered (BTreeMap — sorted keys, matching jax dict flattening order)
/// collection of named tensors.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {:?}", path.as_ref()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf).with_context(|| format!("load {:?}", path.as_ref()))
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < 12 {
            bail!("truncated .qtckpt: {} bytes, need at least 12", buf.len());
        }
        if &buf[..4] != MAGIC {
            bail!("bad .qtckpt magic");
        }
        let version = u32::from_le_bytes(buf[4..8].try_into().expect("len 4"));
        let body = match version {
            LEGACY_VERSION => buf,
            VERSION => {
                if buf.len() < 12 + CHECKSUM_LEN {
                    bail!("truncated .qtckpt: missing checksum trailer");
                }
                let split = buf.len() - CHECKSUM_LEN;
                let want = u64::from_le_bytes(buf[split..].try_into().expect("len 8"));
                let got = fnv1a64(&buf[..split]);
                if want != got {
                    bail!("corrupt .qtckpt: checksum mismatch (stored {want:#018x}, computed {got:#018x})");
                }
                &buf[..split]
            }
            v => bail!("unsupported .qtckpt version {v}"),
        };
        let mut cur = Cur { buf: body, off: 8 };
        let count = cur.u32()? as usize;
        // Each record is at least 4 bytes (nlen + dtype + ndim); an
        // adversarial count can't force work beyond the buffer size.
        if count > body.len() / 4 {
            bail!("corrupt .qtckpt: tensor count {count} exceeds file capacity");
        }
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = cur.u16()? as usize;
            let name = std::str::from_utf8(cur.take(nlen)?)
                .context("corrupt .qtckpt: tensor name is not utf-8")?
                .to_string();
            let dtype = cur.u8()?;
            if dtype != 0 {
                bail!("unsupported dtype {dtype} for {name}");
            }
            let ndim = cur.u8()? as usize;
            if ndim > MAX_NDIM {
                bail!("corrupt .qtckpt: {name} claims {ndim} dims (max {MAX_NDIM})");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(cur.u32()? as usize);
            }
            let n = shape
                .iter()
                .try_fold(1usize, |a, &d| a.checked_mul(d))
                .with_context(|| format!("corrupt .qtckpt: {name} element count overflows"))?;
            let nbytes = n
                .checked_mul(4)
                .with_context(|| format!("corrupt .qtckpt: {name} byte size overflows"))?;
            let raw = cur.take(nbytes)?;
            let mut data = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().expect("len 4")));
            }
            tensors.insert(name, Tensor::new(shape, data));
        }
        if cur.off != body.len() {
            bail!(
                "corrupt .qtckpt: {} trailing bytes after last tensor",
                body.len() - cur.off
            );
        }
        Ok(Checkpoint { tensors })
    }

    /// Serialized version-2 bytes, checksum trailer included. Deterministic:
    /// identical tensor maps produce identical bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(0);
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let sum = fnv1a64(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Atomic, checksummed save: temp file + fsync + rename.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        write_atomic(path, &self.to_bytes())
    }

    /// All tensors under a `role/` prefix, with the prefix stripped,
    /// in sorted-key order.
    pub fn section(&self, role: &str) -> Vec<(String, &Tensor)> {
        let prefix = format!("{role}/");
        self.tensors
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .map(|(k, v)| (k[prefix.len()..].to_string(), v))
            .collect()
    }

    pub fn get(&self, key: &str) -> Option<&Tensor> {
        self.tensors.get(key)
    }

    pub fn insert(&mut self, key: impl Into<String>, t: Tensor) {
        self.tensors.insert(key.into(), t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut ck = Checkpoint::new();
        ck.insert("param/a.w", Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        ck.insert("qstate/a.m", Tensor::scalar(0.5));
        ck
    }

    #[test]
    fn roundtrip() {
        let ck = sample();
        let dir = std::env::temp_dir().join("qt_ckpt_test.qtckpt");
        ck.save(&dir).unwrap();
        let ck2 = Checkpoint::load(&dir).unwrap();
        assert_eq!(ck2.tensors.len(), 2);
        assert_eq!(ck2.get("param/a.w").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ck2.get("qstate/a.m").unwrap().shape.len(), 0);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn sections_are_sorted_and_stripped() {
        let mut ck = Checkpoint::new();
        ck.insert("param/b", Tensor::scalar(2.0));
        ck.insert("param/a", Tensor::scalar(1.0));
        ck.insert("bn/x", Tensor::scalar(3.0));
        let sec = ck.section("param");
        assert_eq!(sec.len(), 2);
        assert_eq!(sec[0].0, "a");
        assert_eq!(sec[1].0, "b");
    }

    #[test]
    fn serialization_is_deterministic() {
        assert_eq!(sample().to_bytes(), sample().to_bytes());
    }

    #[test]
    fn legacy_v1_files_still_load() {
        // Hand-build the pre-checksum version-1 layout.
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes());
        let name = b"param/w";
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
        out.push(0); // dtype f32
        out.push(1); // ndim
        out.extend_from_slice(&3u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let ck = Checkpoint::from_bytes(&out).unwrap();
        assert_eq!(ck.get("param/w").unwrap().data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn every_truncation_errors() {
        let bytes = sample().to_bytes();
        for len in 0..bytes.len() {
            assert!(
                Checkpoint::from_bytes(&bytes[..len]).is_err(),
                "truncation to {len} of {} bytes must not parse",
                bytes.len()
            );
        }
    }

    #[test]
    fn every_bit_flip_errors() {
        let bytes = sample().to_bytes();
        for i in 0..bytes.len() {
            for bit in 0..8 {
                let mut bad = bytes.clone();
                bad[i] ^= 1 << bit;
                assert!(
                    Checkpoint::from_bytes(&bad).is_err(),
                    "bit flip at byte {i} bit {bit} must not parse"
                );
            }
        }
    }

    #[test]
    fn adversarial_headers_error_without_panic() {
        // Absurd tensor count.
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Checkpoint::from_bytes(&out).is_err());
        // Shape product that overflows usize (v1 so no checksum shields it).
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&1u16.to_le_bytes());
        out.push(b'w');
        out.push(0);
        out.push(8);
        for _ in 0..8 {
            out.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        assert!(Checkpoint::from_bytes(&out).is_err());
    }
}
