//! `.qtckpt` checkpoint reader/writer — binary twin of `python/compile/ckpt.py`.
//!
//! Checkpoints hold the full training state as named f32 tensors with
//! role prefixes: `param/...`, `bn/...`, `qstate/...` (and `opt_m/`, `opt_v/`
//! once training has started on the Rust side).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"QTCK";
const VERSION: u32 = 1;

/// An ordered (BTreeMap — sorted keys, matching jax dict flattening order)
/// collection of named tensors.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {:?}", path.as_ref()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }

    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        if buf.len() < 12 || &buf[..4] != MAGIC {
            bail!("bad .qtckpt magic");
        }
        let version = u32::from_le_bytes(buf[4..8].try_into()?);
        if version != VERSION {
            bail!("unsupported .qtckpt version {version}");
        }
        let count = u32::from_le_bytes(buf[8..12].try_into()?) as usize;
        let mut off = 12;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = u16::from_le_bytes(buf[off..off + 2].try_into()?) as usize;
            off += 2;
            let name = std::str::from_utf8(&buf[off..off + nlen])?.to_string();
            off += nlen;
            let dtype = buf[off];
            let ndim = buf[off + 1] as usize;
            off += 2;
            if dtype != 0 {
                bail!("unsupported dtype {dtype} for {name}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(buf[off..off + 4].try_into()?) as usize);
                off += 4;
            }
            let n: usize = shape.iter().product();
            let mut data = Vec::with_capacity(n);
            for i in 0..n {
                data.push(f32::from_le_bytes(buf[off + 4 * i..off + 4 * i + 4].try_into()?));
            }
            off += 4 * n;
            tensors.insert(name, Tensor::new(shape, data));
        }
        Ok(Checkpoint { tensors })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.push(0);
            out.push(t.shape.len() as u8);
            for &d in &t.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &t.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {:?}", path.as_ref()))?;
        f.write_all(&out)?;
        Ok(())
    }

    /// All tensors under a `role/` prefix, with the prefix stripped,
    /// in sorted-key order.
    pub fn section(&self, role: &str) -> Vec<(String, &Tensor)> {
        let prefix = format!("{role}/");
        self.tensors
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .map(|(k, v)| (k[prefix.len()..].to_string(), v))
            .collect()
    }

    pub fn get(&self, key: &str) -> Option<&Tensor> {
        self.tensors.get(key)
    }

    pub fn insert(&mut self, key: impl Into<String>, t: Tensor) {
        self.tensors.insert(key.into(), t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ck = Checkpoint::new();
        ck.insert("param/a.w", Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        ck.insert("qstate/a.m", Tensor::scalar(0.5));
        let dir = std::env::temp_dir().join("qt_ckpt_test.qtckpt");
        ck.save(&dir).unwrap();
        let ck2 = Checkpoint::load(&dir).unwrap();
        assert_eq!(ck2.tensors.len(), 2);
        assert_eq!(ck2.get("param/a.w").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(ck2.get("qstate/a.m").unwrap().shape.len(), 0);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn sections_are_sorted_and_stripped() {
        let mut ck = Checkpoint::new();
        ck.insert("param/b", Tensor::scalar(2.0));
        ck.insert("param/a", Tensor::scalar(1.0));
        ck.insert("bn/x", Tensor::scalar(3.0));
        let sec = ck.section("param");
        assert_eq!(sec.len(), 2);
        assert_eq!(sec[0].0, "a");
        assert_eq!(sec[1].0, "b");
    }
}
