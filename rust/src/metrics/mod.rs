//! Evaluation metrics reported in the paper: Top-1/Top-5, logit MSE vs the
//! FP32 reference, Brier score, ECE, SNR, mIoU, plus the distribution
//! statistics behind Figs 2 and 9.

use crate::tensor::{empirical_quantile, Tensor};

/// Top-1 / Top-5 accuracy from logits (N, C) and labels.
pub fn topk_accuracy(logits: &Tensor, labels: &[i32]) -> (f64, f64) {
    let n = logits.shape[0];
    let c = logits.shape[1];
    let mut top1 = 0usize;
    let mut top5 = 0usize;
    for i in 0..n {
        let row = &logits.data[i * c..(i + 1) * c];
        let y = labels[i] as usize;
        let ly = row[y];
        let better = row.iter().filter(|&&v| v > ly).count();
        if better == 0 {
            top1 += 1;
        }
        if better < 5 {
            top5 += 1;
        }
    }
    (top1 as f64 / n as f64, top5 as f64 / n as f64)
}

/// NaN-safe argmax over one logit row: NaN entries are skipped, ties go to
/// the first maximum, and a row with no finite-comparable entry (empty or
/// all-NaN) returns `None` so callers can count it as a miss instead of
/// panicking on `partial_cmp`.
pub fn nan_safe_argmax(row: &[f32]) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        match best {
            Some((_, bv)) if v <= bv => {}
            _ => best = Some((i, v)),
        }
    }
    best.map(|(i, _)| i)
}

pub fn softmax_row(row: &[f32]) -> Vec<f32> {
    let mx = row.iter().fold(f32::MIN, |m, &v| m.max(v));
    let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

/// Multiclass Brier score: mean over samples of sum_c (p_c - onehot_c)^2.
pub fn brier(logits: &Tensor, labels: &[i32]) -> f64 {
    let n = logits.shape[0];
    let c = logits.shape[1];
    let mut total = 0.0f64;
    for i in 0..n {
        let p = softmax_row(&logits.data[i * c..(i + 1) * c]);
        for (j, &pj) in p.iter().enumerate() {
            let y = if j == labels[i] as usize { 1.0 } else { 0.0 };
            total += ((pj - y) as f64).powi(2);
        }
    }
    total / n as f64
}

/// Expected calibration error, 15 equal-width confidence bins.
pub fn ece(logits: &Tensor, labels: &[i32], bins: usize) -> f64 {
    let n = logits.shape[0];
    let c = logits.shape[1];
    let mut bin_conf = vec![0.0f64; bins];
    let mut bin_acc = vec![0.0f64; bins];
    let mut bin_n = vec![0usize; bins];
    for i in 0..n {
        let p = softmax_row(&logits.data[i * c..(i + 1) * c]);
        let (pred, conf) =
            p.iter().enumerate().fold((0usize, 0.0f32), |(bi, bv), (j, &v)| {
                if v > bv {
                    (j, v)
                } else {
                    (bi, bv)
                }
            });
        let b = ((conf as f64 * bins as f64) as usize).min(bins - 1);
        bin_conf[b] += conf as f64;
        bin_acc[b] += if pred == labels[i] as usize { 1.0 } else { 0.0 };
        bin_n[b] += 1;
    }
    let mut e = 0.0;
    for b in 0..bins {
        if bin_n[b] == 0 {
            continue;
        }
        let conf = bin_conf[b] / bin_n[b] as f64;
        let acc = bin_acc[b] / bin_n[b] as f64;
        e += (bin_n[b] as f64 / n as f64) * (conf - acc).abs();
    }
    e
}

/// Paper's backend-drift metric: MSE between on-device and reference logits,
/// mean over samples of the squared L2 distance.
pub fn logit_mse(device: &Tensor, reference: &Tensor) -> f64 {
    assert_eq!(device.shape, reference.shape);
    let n = device.shape[0];
    let mut total = 0.0f64;
    for (a, b) in device.data.iter().zip(reference.data.iter()) {
        total += ((a - b) as f64).powi(2);
    }
    total / n as f64
}

/// Signal-to-noise ratio (dB) of a deployed tensor vs the FP32 reference:
/// 10 log10( sum ref^2 / sum (ref - out)^2 ).
pub fn snr_db(reference: &[f32], output: &[f32]) -> f64 {
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    for (r, o) in reference.iter().zip(output.iter()) {
        sig += (*r as f64).powi(2);
        noise += ((*r - *o) as f64).powi(2);
    }
    if noise <= 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

/// Mean IoU for segmentation: logits (N, C, H, W) vs labels (N, H, W).
pub fn miou(logits: &Tensor, labels: &[i32], num_classes: usize) -> f64 {
    let n = logits.shape[0];
    let c = logits.shape[1];
    let hw = logits.shape[2] * logits.shape[3];
    let mut inter = vec![0u64; num_classes];
    let mut uni = vec![0u64; num_classes];
    for i in 0..n {
        for p in 0..hw {
            let mut best = 0usize;
            let mut bv = f32::MIN;
            for ci in 0..c {
                let v = logits.data[(i * c + ci) * hw + p];
                if v > bv {
                    bv = v;
                    best = ci;
                }
            }
            let y = labels[i * hw + p] as usize;
            if best == y {
                inter[y] += 1;
                uni[y] += 1;
            } else {
                uni[y] += 1;
                uni[best] += 1;
            }
        }
    }
    let mut total = 0.0;
    let mut seen = 0;
    for k in 0..num_classes {
        if uni[k] > 0 {
            total += inter[k] as f64 / uni[k] as f64;
            seen += 1;
        }
    }
    if seen == 0 {
        0.0
    } else {
        total / seen as f64
    }
}

/// Pixel accuracy for segmentation.
pub fn pixel_acc(logits: &Tensor, labels: &[i32]) -> f64 {
    let n = logits.shape[0];
    let c = logits.shape[1];
    let hw = logits.shape[2] * logits.shape[3];
    let mut correct = 0u64;
    for i in 0..n {
        for p in 0..hw {
            let mut best = 0usize;
            let mut bv = f32::MIN;
            for ci in 0..c {
                let v = logits.data[(i * c + ci) * hw + p];
                if v > bv {
                    bv = v;
                    best = ci;
                }
            }
            if best == labels[i * hw + p] as usize {
                correct += 1;
            }
        }
    }
    correct as f64 / (n * hw) as f64
}

/// Distribution summary used for Figs 2 and 9: tail quantiles + excess
/// kurtosis of a weight/activation sample.
#[derive(Clone, Debug)]
pub struct DistSummary {
    pub p50: f32,
    pub p99: f32,
    pub p999: f32,
    pub max: f32,
    pub kurtosis: f64,
    /// |x| range ratio max/p99 — the "scale inflation" factor reverse
    /// pruning attacks.
    pub tail_ratio: f32,
}

pub fn dist_summary(data: &[f32]) -> DistSummary {
    let abs: Vec<f32> = data.iter().map(|v| v.abs()).collect();
    let p50 = empirical_quantile(&abs, 0.50);
    let p99 = empirical_quantile(&abs, 0.99);
    let p999 = empirical_quantile(&abs, 0.999);
    let max = abs.iter().fold(0.0f32, |m, &v| m.max(v));
    let n = data.len() as f64;
    let mean = data.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var = data.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    let m4 = data.iter().map(|&v| (v as f64 - mean).powi(4)).sum::<f64>() / n;
    let kurtosis = if var > 0.0 { m4 / (var * var) - 3.0 } else { 0.0 };
    DistSummary { p50, p99, p999, max, kurtosis, tail_ratio: max / p99.max(1e-12) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn logits2(rows: Vec<Vec<f32>>) -> Tensor {
        let n = rows.len();
        let c = rows[0].len();
        Tensor::new(vec![n, c], rows.into_iter().flatten().collect())
    }

    #[test]
    fn topk_basics() {
        let l = logits2(vec![vec![0.1, 0.9, 0.0], vec![0.9, 0.1, 0.0]]);
        let (t1, t5) = topk_accuracy(&l, &[1, 1]);
        assert_eq!(t1, 0.5);
        assert_eq!(t5, 1.0);
    }

    #[test]
    fn brier_perfect_prediction_near_zero() {
        let l = logits2(vec![vec![100.0, 0.0], vec![0.0, 100.0]]);
        assert!(brier(&l, &[0, 1]) < 1e-6);
        // uniform prediction on 2 classes: brier = 2*(0.5)^2 = 0.5
        let u = logits2(vec![vec![0.0, 0.0]]);
        assert!((brier(&u, &[0]) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn ece_confident_and_correct_is_zero() {
        let l = logits2(vec![vec![100.0, 0.0]; 10]);
        assert!(ece(&l, &vec![0; 10], 15) < 1e-6);
        // confident but always wrong -> ece near 1
        assert!(ece(&l, &vec![1; 10], 15) > 0.9);
    }

    #[test]
    fn snr_increases_with_fidelity() {
        let r = vec![1.0f32, -2.0, 3.0, -4.0];
        let close: Vec<f32> = r.iter().map(|v| v * 1.001).collect();
        let far: Vec<f32> = r.iter().map(|v| v * 1.3).collect();
        assert!(snr_db(&r, &close) > snr_db(&r, &far));
        assert!(snr_db(&r, &r.clone()).is_infinite());
    }

    #[test]
    fn miou_perfect_is_one() {
        // 1 sample, 2 classes, 2x2: logits pick class = label
        let mut l = Tensor::zeros(&[1, 2, 2, 2]);
        let labels = [0, 1, 1, 0];
        for p in 0..4 {
            l.data[labels[p] as usize * 4 + p] = 5.0;
        }
        assert!((miou(&l, &labels, 2) - 1.0).abs() < 1e-9);
        assert!((pixel_acc(&l, &labels) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dist_summary_detects_heavy_tails() {
        let mut rng = crate::testutil::Rng::new(5);
        let normal: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let heavy: Vec<f32> = (0..20_000).map(|_| rng.heavy_tail(0.005, 30.0)).collect();
        let dn = dist_summary(&normal);
        let dh = dist_summary(&heavy);
        assert!(dh.kurtosis > dn.kurtosis + 1.0);
        assert!(dh.tail_ratio > dn.tail_ratio);
    }

    #[test]
    fn nan_safe_argmax_skips_nan_and_handles_degenerate_rows() {
        assert_eq!(nan_safe_argmax(&[1.0, 3.0, 2.0]), Some(1));
        // NaN entries are skipped wherever they sit, including a NaN max.
        assert_eq!(nan_safe_argmax(&[f32::NAN, 3.0, 2.0]), Some(1));
        assert_eq!(nan_safe_argmax(&[1.0, f32::NAN, 2.0]), Some(2));
        // Ties go to the first maximum.
        assert_eq!(nan_safe_argmax(&[2.0, 2.0, 1.0]), Some(0));
        // Infinities are ordinary values, not errors.
        assert_eq!(nan_safe_argmax(&[f32::NEG_INFINITY, f32::INFINITY]), Some(1));
        // Degenerate rows report None instead of panicking.
        assert_eq!(nan_safe_argmax(&[f32::NAN, f32::NAN]), None);
        assert_eq!(nan_safe_argmax(&[]), None);
    }
}
