//! Activation calibration: the offline range-estimation step every static-INT8
//! NPU toolchain runs on a representative dataset (paper Table 4 "PTQ calib").
//!
//! Observers (per vendor style):
//! * MinMax       — RKNN-style, cheapest, most outlier-fragile
//! * Percentile   — clip at p/1-p quantiles (Hailo-style)
//! * Entropy      — KL-divergence threshold search (TensorRT-style)
//! * Mse          — pick the clip minimizing quant-dequant MSE (compiler-
//!                  provided static scaling, Hardware D style)
//!
//! Also hosts the Table 3 baseline: AdaRound-like weight rounding (adaround.rs).

pub mod adaround;

use std::collections::HashMap;

use anyhow::Result;

use crate::engine::CompiledModel;
use crate::tensor::{empirical_quantile, Tensor};
use crate::testutil::Rng;

/// Range-estimation observer a vendor toolchain runs over the calibration
/// set (one per compiler style — see the module docs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CalibMethod {
    /// Exact observed min/max (RKNN-style; cheapest, outlier-fragile).
    MinMax,
    /// Clip at the p / 1-p empirical quantiles of the sample (Hailo-style),
    /// clamped to the observed range.
    Percentile(f64),
    /// KL-divergence threshold search over an amplitude histogram
    /// (TensorRT-style).
    Entropy,
    /// Grid search for the clip minimizing u8 quant-dequant MSE
    /// (compiler-provided static scaling, Hardware D style).
    Mse,
}

/// Streaming per-node statistics with a bounded reservoir sample.
struct NodeStats {
    lo: f32,
    hi: f32,
    reservoir: Vec<f32>,
    seen: u64,
}

const RESERVOIR: usize = 32_768;

impl NodeStats {
    fn new() -> Self {
        NodeStats { lo: f32::MAX, hi: f32::MIN, reservoir: Vec::new(), seen: 0 }
    }

    fn update(&mut self, t: &Tensor, rng: &mut Rng) {
        for &v in &t.data {
            // NaN/inf samples (corrupt capture frames) must not poison the
            // range or land in the reservoir the observers derive clips from
            if !v.is_finite() {
                continue;
            }
            self.lo = self.lo.min(v);
            self.hi = self.hi.max(v);
            self.seen += 1;
            if self.reservoir.len() < RESERVOIR {
                self.reservoir.push(v);
            } else {
                // reservoir sampling keeps a uniform subsample
                let j = (rng.next_u64() % self.seen) as usize;
                if j < RESERVOIR {
                    self.reservoir[j] = v;
                }
            }
        }
    }
}

/// Result of calibration: static (lo, hi) per node output.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    /// Derived clip range per node name, consumed as `CompiledModel::act_ranges`.
    pub ranges: HashMap<String, (f32, f32)>,
}

/// Run the FP32 model over the calibration batches and derive static ranges
/// with the chosen observer.
pub fn calibrate(
    model: &CompiledModel,
    batches: &[Tensor],
    method: CalibMethod,
) -> Result<Calibration> {
    let mut stats: HashMap<String, NodeStats> = HashMap::new();
    let mut rng = Rng::new(0xCA11B);
    for x in batches {
        let mut obs = |name: &str, t: &Tensor| {
            stats.entry(name.to_string()).or_insert_with(NodeStats::new).update(t, &mut rng);
        };
        model.run_observe(x, &mut obs)?;
    }
    let mut ranges = HashMap::new();
    for (name, s) in stats {
        let range = derive_range(&s, method);
        ranges.insert(name, range);
    }
    Ok(Calibration { ranges })
}

fn derive_range(s: &NodeStats, method: CalibMethod) -> (f32, f32) {
    if s.reservoir.is_empty() {
        return (0.0, 1.0);
    }
    match method {
        CalibMethod::MinMax => (s.lo, s.hi),
        CalibMethod::Percentile(p) => {
            let lo = empirical_quantile(&s.reservoir, 1.0 - p);
            let hi = empirical_quantile(&s.reservoir, p);
            // clamp the clip range to the OBSERVED range: the reservoir is a
            // subsample, and the previous expression `lo.min(s.lo.max(lo))`
            // always evaluated to `lo` — a no-op that never applied the
            // observed bounds on either side
            (lo.max(s.lo), hi.min(s.hi))
        }
        CalibMethod::Entropy => entropy_range(s),
        CalibMethod::Mse => mse_range(s),
    }
}

/// KL-divergence threshold search over a 2048-bin histogram of the sample
/// (TensorRT-style, simplified to the positive+negative amplitude axis).
fn entropy_range(s: &NodeStats) -> (f32, f32) {
    const BINS: usize = 2048;
    const LEVELS: usize = 256;
    let amax = s.reservoir.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
    let mut hist = vec![0.0f64; BINS];
    for &v in &s.reservoir {
        let b = ((v.abs() / amax) * (BINS as f32 - 1.0)) as usize;
        hist[b.min(BINS - 1)] += 1.0;
    }
    let mut best_kl = f64::MAX;
    let mut best_t = BINS;
    // candidate thresholds from 25% up
    let start = BINS / 4;
    for t in (start..=BINS).step_by(16) {
        let kl = kl_for_threshold(&hist, t, LEVELS);
        if kl < best_kl {
            best_kl = kl;
            best_t = t;
        }
    }
    let clip = amax * best_t as f32 / BINS as f32;
    // preserve asymmetry of the observed range within the clip amplitude
    (s.lo.max(-clip), s.hi.min(clip))
}

fn kl_for_threshold(hist: &[f64], t: usize, levels: usize) -> f64 {
    // reference distribution: clip everything beyond t into the edge bin
    let mut p: Vec<f64> = hist[..t].to_vec();
    let outliers: f64 = hist[t..].iter().sum();
    if let Some(last) = p.last_mut() {
        *last += outliers;
    }
    // candidate: quantize p to `levels` bins, then expand back
    let mut q = vec![0.0f64; t];
    let merge = (t as f64 / levels as f64).max(1.0);
    for lv in 0..levels {
        let a = (lv as f64 * merge) as usize;
        let b = (((lv + 1) as f64 * merge) as usize).min(t);
        if a >= b {
            continue;
        }
        let total: f64 = p[a..b].iter().sum();
        let nonzero = p[a..b].iter().filter(|&&v| v > 0.0).count().max(1);
        let fill = total / nonzero as f64;
        for i in a..b {
            if p[i] > 0.0 {
                q[i] = fill;
            }
        }
    }
    let sp: f64 = p.iter().sum();
    let sq: f64 = q.iter().sum();
    if sp <= 0.0 || sq <= 0.0 {
        return f64::MAX;
    }
    let mut kl = 0.0;
    for i in 0..t {
        let pi = p[i] / sp;
        let qi = q[i] / sq;
        if pi > 0.0 && qi > 0.0 {
            kl += pi * (pi / qi).ln();
        } else if pi > 0.0 {
            kl += pi * 10.0; // heavy penalty for zero support
        }
    }
    kl
}

/// Grid-search the clip range minimizing u8 quant-dequant MSE on the sample.
fn mse_range(s: &NodeStats) -> (f32, f32) {
    let mut best = (s.lo, s.hi);
    let mut best_err = f64::MAX;
    for frac in [1.0f32, 0.99, 0.97, 0.95, 0.92, 0.88, 0.84, 0.80, 0.75, 0.70] {
        let lo = s.lo * frac;
        let hi = s.hi * frac;
        let (sc, zp) = crate::tensor::act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
        let mut err = 0.0f64;
        for &v in &s.reservoir {
            let q = ((v / sc).round_ties_even() + zp as f32).clamp(0.0, 255.0);
            let d = (q - zp as f32) * sc;
            err += ((v - d) as f64).powi(2);
        }
        if err < best_err {
            best_err = err;
            best = (lo, hi);
        }
    }
    best
}

/// Ranges taken from the Quant-Trim checkpoint's embedded QAT statistics
/// (aq-node lo/hi EMAs) instead of a calibration run — the "QAT scales
/// embedded in the graph" path of paper Table 4.
pub fn ranges_from_qstate(
    qstate: &std::collections::BTreeMap<String, Tensor>,
    graph: &crate::qir::Graph,
) -> Calibration {
    let mut ranges = HashMap::new();
    for n in &graph.nodes {
        if n.kind == "aq" {
            if let (Some(lo), Some(hi)) =
                (qstate.get(&format!("{}.lo", n.name)), qstate.get(&format!("{}.hi", n.name)))
            {
                ranges.insert(n.name.clone(), (lo.data[0], hi.data[0]));
            }
        }
    }
    Calibration { ranges }
}

/// Propagate known ranges to nodes that calibration didn't cover, walking the
/// graph and reusing the producer's range through shape/range-preserving ops.
/// Ensures every compute-node input has a static range (QAT-scale deployments
/// only know ranges at aq points).
pub fn propagate_ranges(graph: &crate::qir::Graph, calib: &mut Calibration, input_range: (f32, f32)) {
    for n in &graph.nodes {
        if calib.ranges.contains_key(&n.name) {
            continue;
        }
        let r = match n.kind.as_str() {
            "input" => input_range,
            // range-preserving (or range-shrinking) ops inherit producer range
            "reshape" | "flatten" | "to_tokens" | "maxpool" | "upsample2x" | "aq" | "gap"
            | "avgpool" | "tokmean" => {
                n.inputs.first().and_then(|i| calib.ranges.get(i)).copied().unwrap_or(input_range)
            }
            "relu" => {
                let (_, hi) = n
                    .inputs
                    .first()
                    .and_then(|i| calib.ranges.get(i))
                    .copied()
                    .unwrap_or(input_range);
                (0.0, hi.max(1e-6))
            }
            "relu6" => (0.0, 6.0),
            "hsigmoid" | "sigmoid" => (0.0, 1.0),
            "concat" | "add" => {
                let mut lo = f32::MAX;
                let mut hi = f32::MIN;
                for i in &n.inputs {
                    if let Some(&(l, h)) = calib.ranges.get(i) {
                        lo = lo.min(l);
                        hi = hi.max(h);
                    }
                }
                if n.kind == "add" {
                    // conservative: sum can reach the sum of extremes
                    (lo.min(0.0) * 1.5, hi.max(1e-6) * 1.5)
                } else {
                    (lo.min(0.0), hi.max(1e-6))
                }
            }
            _ => {
                // compute nodes without calibrated output: inherit producer,
                // widened (weights can amplify)
                let (lo, hi) = n
                    .inputs
                    .first()
                    .and_then(|i| calib.ranges.get(i))
                    .copied()
                    .unwrap_or(input_range);
                (lo.min(0.0) * 2.0 - 1.0, hi * 2.0 + 1.0)
            }
        };
        calib.ranges.insert(n.name.clone(), r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn stats_from(vals: &[f32]) -> NodeStats {
        let mut s = NodeStats::new();
        let mut rng = Rng::new(1);
        s.update(&Tensor::new(vec![vals.len()], vals.to_vec()), &mut rng);
        s
    }

    #[test]
    fn minmax_covers_outliers_percentile_clips_them() {
        let mut rng = Rng::new(9);
        let mut vals: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
        vals.push(100.0); // outlier
        let s = stats_from(&vals);
        let (_, hi_mm) = derive_range(&s, CalibMethod::MinMax);
        let (_, hi_p) = derive_range(&s, CalibMethod::Percentile(0.999));
        assert!(hi_mm >= 100.0);
        assert!(hi_p < 10.0, "percentile should clip the outlier, got {hi_p}");
    }

    #[test]
    fn mse_range_clips_heavy_tails() {
        let mut rng = Rng::new(11);
        let vals: Vec<f32> = (0..20_000).map(|_| rng.heavy_tail(0.001, 50.0)).collect();
        let s = stats_from(&vals);
        let (lo, hi) = derive_range(&s, CalibMethod::Mse);
        assert!(hi < s.hi || lo > s.lo, "mse calibration should shrink the range");
    }

    #[test]
    fn entropy_range_reasonable_on_gaussian() {
        let mut rng = Rng::new(13);
        let vals: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let s = stats_from(&vals);
        let (lo, hi) = derive_range(&s, CalibMethod::Entropy);
        assert!(hi > 1.0 && hi < 6.0, "hi {hi}");
        assert!(lo < -1.0 && lo > -6.0, "lo {lo}");
    }

    #[test]
    fn percentile_clip_clamps_to_observed_range() {
        // regression for the no-op clamp `(lo.min(s.lo.max(lo)), hi)`: with
        // observed bounds tighter than the reservoir (the streaming-stats
        // contract a future observer may rely on), the clip range must be
        // clamped into [s.lo, s.hi] on BOTH sides
        let mut s = stats_from(&[-10.0, -9.0, -8.0, 8.0, 9.0, 10.0]);
        s.lo = -5.0;
        s.hi = 5.0;
        let (lo, hi) = derive_range(&s, CalibMethod::Percentile(0.999));
        assert!(lo >= -5.0, "lo {lo} escaped the observed range");
        assert!(hi <= 5.0, "hi {hi} escaped the observed range");
    }

    #[test]
    fn percentile_near_half_and_one_stay_ordered() {
        let mut rng = Rng::new(21);
        let vals: Vec<f32> = (0..5_000).map(|_| rng.normal()).collect();
        let s = stats_from(&vals);
        // p = 1.0 degenerates to the full observed range (== MinMax here)
        assert_eq!(derive_range(&s, CalibMethod::Percentile(1.0)), (s.lo, s.hi));
        // p -> 0.5 collapses toward the median: still ordered and finite
        for p in [0.5, 0.501, 0.55] {
            let (lo, hi) = derive_range(&s, CalibMethod::Percentile(p));
            assert!(lo <= hi, "p={p}: ({lo}, {hi}) out of order");
            assert!(lo.is_finite() && hi.is_finite());
        }
    }

    #[test]
    fn non_finite_samples_never_poison_the_range() {
        // NaN/inf capture glitches are skipped by the observer; a batch with
        // SOME finite data calibrates from that data alone
        let s = stats_from(&[f32::NAN, -1.0, f32::INFINITY, 2.0, f32::NEG_INFINITY]);
        assert_eq!((s.lo, s.hi), (-1.0, 2.0));
        assert_eq!(s.reservoir.len(), 2);
        for m in [CalibMethod::MinMax, CalibMethod::Percentile(0.999), CalibMethod::Mse] {
            let (lo, hi) = derive_range(&s, m);
            assert!(lo.is_finite() && hi.is_finite() && lo <= hi, "{m:?}: ({lo}, {hi})");
        }
    }

    #[test]
    fn nan_only_and_empty_batches_fall_back_to_default_range() {
        // an all-NaN batch leaves the reservoir empty -> default (0, 1) grid
        let s = stats_from(&[f32::NAN, f32::NAN]);
        for m in [CalibMethod::MinMax, CalibMethod::Percentile(0.999), CalibMethod::Entropy, CalibMethod::Mse] {
            assert_eq!(derive_range(&s, m), (0.0, 1.0), "{m:?}");
        }
        // zero calibration batches: calibrate() observes nothing at all
        let g = crate::qir::Graph::parse(
            "qir p v1\noutputs r\n\
             node input image inputs=- shape=1,2,2\n\
             node relu r inputs=image shape=1,2,2\n",
        )
        .unwrap();
        let model = crate::engine::fp32_model(g, Default::default(), Default::default());
        let c = calibrate(&model, &[], CalibMethod::MinMax).unwrap();
        assert!(c.ranges.is_empty());
    }

    #[test]
    fn propagate_fills_every_node() {
        let g = crate::qir::Graph::parse(
            "qir p v1\noutputs head\n\
             node input image inputs=- shape=3,4,4\n\
             node conv2d c1 inputs=image shape=4,4,4 bias=0 cin=3 cout=4 groups=1 kh=3 kw=3 pad=1 stride=1\n\
             node relu r1 inputs=c1 shape=4,4,4\n\
             node aq q1 inputs=r1 shape=4,4,4\n\
             node gap g1 inputs=q1 shape=4,1,1\n\
             node flatten f1 inputs=g1 shape=4\n\
             node linear head inputs=f1 shape=2 bias=1 din=4 dout=2\n",
        )
        .unwrap();
        let mut calib = Calibration::default();
        calib.ranges.insert("q1".into(), (0.0, 3.0));
        propagate_ranges(&g, &mut calib, (-2.0, 2.0));
        for n in &g.nodes {
            assert!(calib.ranges.contains_key(&n.name), "missing range for {}", n.name);
        }
    }
}
