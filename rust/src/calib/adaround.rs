//! AdaRound-like adaptive weight rounding (Nagel et al. 2020) — the second
//! half of the paper's Table 3 baseline ("Equalization + Adaround").
//!
//! Full AdaRound learns a per-weight rounding mask by gradient descent on a
//! rectified-sigmoid relaxation. We implement the sequential error-feedback
//! variant: weights of each output channel are rounded one at a time, and
//! each rounding decision (floor vs ceil) is taken to minimize the running
//! output error on calibration activations — the same objective (layer output
//! MSE), optimized greedily. This matches AdaRound's behaviour qualitatively:
//! it beats nearest-rounding on calibration data, at extra compile cost.

use crate::tensor::{QWeight, Tensor};

/// Round one layer's weights adaptively.
///
/// * `w`      — float weights, (cout, k) flattened per output channel
/// * `scales` — per-channel (or singleton) symmetric scales
/// * `xcal`   — calibration input activations for this layer, (samples, k)
///
/// Returns i8 weights with the same layout as nearest-rounding would produce,
/// but with rounding chosen to minimize sum over samples of squared output
/// error.
pub fn adaround_layer(w: &Tensor, scales: &[f32], xcal: &[f32], k: usize) -> Vec<i8> {
    let cout = w.shape[0];
    let per = w.data.len() / cout;
    debug_assert_eq!(per, k);
    let samples = if k == 0 { 0 } else { xcal.len() / k };
    let mut out = vec![0i8; w.data.len()];
    for c in 0..cout {
        let s = scales[c.min(scales.len() - 1)].max(1e-12);
        // running residual error per sample: e_m = sum_j (w_j - s*q_j) x_{m,j}
        let mut resid = vec![0.0f32; samples];
        for j in 0..k {
            let wv = w.data[c * k + j];
            let lo = (wv / s).floor().clamp(-128.0, 127.0);
            let hi = (lo + 1.0).clamp(-128.0, 127.0);
            // error contribution of each choice across samples
            let (mut err_lo, mut err_hi) = (0.0f64, 0.0f64);
            for m in 0..samples {
                let x = xcal[m * k + j];
                let e_lo = resid[m] + (wv - s * lo) * x;
                let e_hi = resid[m] + (wv - s * hi) * x;
                err_lo += (e_lo as f64) * (e_lo as f64);
                err_hi += (e_hi as f64) * (e_hi as f64);
            }
            let q = if err_lo <= err_hi { lo } else { hi };
            for m in 0..samples {
                resid[m] += (wv - s * q) * xcal[m * k + j];
            }
            out[c * k + j] = q as i8;
        }
    }
    out
}

/// Apply adaptive rounding to a prepared QWeight given calibration inputs.
/// Rebuilds through `from_parts` so the precomputed row sums track the
/// refined payload.
pub fn refine_qweight(w_float: &Tensor, qw: &QWeight, xcal: &[f32], k: usize) -> QWeight {
    let data = adaround_layer(w_float, &qw.scales, xcal, k);
    QWeight::from_parts(qw.shape.clone(), data, qw.scales.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{QuantScheme, RoundMode};
    use crate::testutil::Rng;

    /// Output MSE of a rounding choice on the calibration set.
    fn output_mse(w: &Tensor, q: &[i8], scales: &[f32], xcal: &[f32], k: usize) -> f64 {
        let cout = w.shape[0];
        let samples = xcal.len() / k;
        let mut err = 0.0f64;
        for c in 0..cout {
            let s = scales[c.min(scales.len() - 1)];
            for m in 0..samples {
                let mut e = 0.0f32;
                for j in 0..k {
                    e += (w.data[c * k + j] - s * q[c * k + j] as f32) * xcal[m * k + j];
                }
                err += (e as f64) * (e as f64);
            }
        }
        err
    }

    #[test]
    fn adaround_beats_nearest_rounding_on_calibration_mse() {
        let mut rng = Rng::new(21);
        let k = 32;
        let cout = 8;
        let w = Tensor::new(vec![cout, k], rng.normal_vec(cout * k, 0.1));
        let xcal: Vec<f32> = rng.normal_vec(64 * k, 1.0);
        let nearest = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let ada = adaround_layer(&w, &nearest.scales, &xcal, k);
        let e_nearest = output_mse(&w, &nearest.data, &nearest.scales, &xcal, k);
        let e_ada = output_mse(&w, &ada, &nearest.scales, &xcal, k);
        assert!(
            e_ada <= e_nearest,
            "adaround ({e_ada}) must not be worse than nearest ({e_nearest})"
        );
    }

    #[test]
    fn adaround_stays_within_one_step_of_nearest() {
        let mut rng = Rng::new(22);
        let k = 16;
        let w = Tensor::new(vec![2, k], rng.normal_vec(2 * k, 0.2));
        let nearest = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let xcal: Vec<f32> = rng.normal_vec(16 * k, 1.0);
        let ada = adaround_layer(&w, &nearest.scales, &xcal, k);
        for (a, b) in ada.iter().zip(nearest.data.iter()) {
            assert!((*a as i32 - *b as i32).abs() <= 1, "adaround moved more than one level");
        }
    }
}
