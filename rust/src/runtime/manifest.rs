//! `.manifest` parser — text twin of `python/compile/manifest.py`.
//!
//! The manifest pins the positional HLO interface of every exported function:
//! which state-dict entry feeds parameter *i*, and which tuple element of the
//! result is which updated state entry. The coordinator marshals purely from
//! this — no shape knowledge is hard-coded in Rust.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One positional argument or return slot of an exported function.
#[derive(Clone, Debug)]
pub struct Slot {
    pub idx: usize,
    pub role: String,
    pub key: String,
    pub dtype: DType,
    /// Empty = scalar.
    pub shape: Vec<usize>,
}

/// An exported HLO function: file + full positional interface.
#[derive(Clone, Debug, Default)]
pub struct FnSpec {
    pub name: String,
    pub hlo_file: String,
    pub args: Vec<Slot>,
    pub rets: Vec<Slot>,
}

/// Parsed manifest for one model.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub model: String,
    pub dir: PathBuf,
    /// kind -> file (qir, ckpt, teacher_ckpt, teacher_qir, ...)
    pub files: BTreeMap<String, String>,
    pub fns: BTreeMap<String, FnSpec>,
}

fn parse_slot(parts: &[&str]) -> Result<(String, Slot)> {
    // <fn> <idx> <role> <key> <dtype> <dims>
    if parts.len() != 6 {
        bail!("malformed slot line: {:?}", parts);
    }
    let fn_name = parts[0].to_string();
    let idx: usize = parts[1].parse()?;
    let dtype = match parts[4] {
        "f32" => DType::F32,
        "i32" => DType::I32,
        other => bail!("unknown dtype {other}"),
    };
    let shape = if parts[5] == "scalar" {
        vec![]
    } else {
        parts[5]
            .split(',')
            .map(|s| s.parse::<usize>().map_err(Into::into))
            .collect::<Result<Vec<_>>>()?
    };
    Ok((
        fn_name,
        Slot { idx, role: parts[2].to_string(), key: parts[3].to_string(), dtype, shape },
    ))
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        let mut m = Manifest {
            dir: path.parent().unwrap_or(Path::new(".")).to_path_buf(),
            ..Default::default()
        };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("{path:?}:{}", lineno + 1);
            match parts[0] {
                "model" => m.model = parts.get(1).map(|s| s.to_string()).unwrap_or_default(),
                "artifact" => {
                    if parts.len() != 3 {
                        bail!("{}: malformed artifact line", ctx());
                    }
                    let spec = m.fns.entry(parts[1].to_string()).or_default();
                    spec.name = parts[1].to_string();
                    spec.hlo_file = parts[2].to_string();
                }
                "arg" => {
                    let (f, slot) = parse_slot(&parts[1..]).with_context(ctx)?;
                    m.fns.entry(f.clone()).or_default().args.push(slot);
                }
                "ret" => {
                    let (f, slot) = parse_slot(&parts[1..]).with_context(ctx)?;
                    m.fns.entry(f.clone()).or_default().rets.push(slot);
                }
                kind => {
                    if parts.len() == 2 {
                        m.files.insert(kind.to_string(), parts[1].to_string());
                    } else {
                        bail!("{}: unrecognized line {line:?}", ctx());
                    }
                }
            }
        }
        // sanity: slots must be dense and ordered
        for spec in m.fns.values() {
            for (i, s) in spec.args.iter().enumerate() {
                if s.idx != i {
                    bail!("fn {} arg order corrupt at {}", spec.name, i);
                }
            }
            for (i, s) in spec.rets.iter().enumerate() {
                if s.idx != i {
                    bail!("fn {} ret order corrupt at {}", spec.name, i);
                }
            }
        }
        Ok(m)
    }

    pub fn hlo_path(&self, fn_name: &str) -> Result<PathBuf> {
        let spec = self
            .fns
            .get(fn_name)
            .with_context(|| format!("no fn {fn_name} in manifest for {}", self.model))?;
        Ok(self.dir.join(&spec.hlo_file))
    }

    pub fn file_path(&self, kind: &str) -> Result<PathBuf> {
        let f = self
            .files
            .get(kind)
            .with_context(|| format!("no file kind {kind} in manifest for {}", self.model))?;
        Ok(self.dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parse_minimal() {
        let dir = std::env::temp_dir();
        let p = dir.join("qt_manifest_test.manifest");
        let mut f = std::fs::File::create(&p).unwrap();
        writeln!(f, "model demo").unwrap();
        writeln!(f, "qir demo.qir").unwrap();
        writeln!(f, "artifact fwd demo.fwd.hlo.txt").unwrap();
        writeln!(f, "arg fwd 0 param a.w f32 2,3").unwrap();
        writeln!(f, "arg fwd 1 data x f32 1,3").unwrap();
        writeln!(f, "ret fwd 0 out out f32 1,2").unwrap();
        drop(f);
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.model, "demo");
        let spec = &m.fns["fwd"];
        assert_eq!(spec.args.len(), 2);
        assert_eq!(spec.args[0].key, "a.w");
        assert_eq!(spec.args[0].shape, vec![2, 3]);
        assert_eq!(spec.rets[0].shape, vec![1, 2]);
        assert!(m.hlo_path("fwd").unwrap().ends_with("demo.fwd.hlo.txt"));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn scalar_and_i32_slots() {
        let dir = std::env::temp_dir();
        let p = dir.join("qt_manifest_test2.manifest");
        std::fs::write(
            &p,
            "model m\nartifact f a.hlo.txt\narg f 0 lam lam f32 scalar\narg f 1 label y i32 8\n",
        )
        .unwrap();
        let m = Manifest::load(&p).unwrap();
        assert!(m.fns["f"].args[0].shape.is_empty());
        assert_eq!(m.fns["f"].args[1].dtype, DType::I32);
        std::fs::remove_file(p).ok();
    }
}
