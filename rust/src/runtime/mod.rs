//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client. This is the only module that touches the `xla` crate.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Outputs are lowered with `return_tuple=True`, so every call returns one
//! tuple literal which we decompose against the manifest's ret slots.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

pub use manifest::{DType, FnSpec, Manifest, Slot};

use crate::tensor::Tensor;

/// Shared PJRT client (CPU). Create once, clone-free; executables borrow it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one exported function from its manifest entry.
    pub fn load_fn(&self, man: &Manifest, fn_name: &str) -> Result<LoadedFn> {
        let path = man.hlo_path(fn_name)?;
        let spec = man.fns[fn_name].clone();
        self.load_fn_from(&path, spec)
    }

    pub fn load_fn_from(&self, path: &Path, spec: FnSpec) -> Result<LoadedFn> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {path:?}"))?;
        Ok(LoadedFn { exe, spec })
    }
}

/// A compiled executable plus its positional interface.
pub struct LoadedFn {
    exe: xla::PjRtLoadedExecutable,
    pub spec: FnSpec,
}

impl LoadedFn {
    /// Execute with marshalled literals; returns the decomposed result tuple.
    pub fn call(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.spec.args.len() {
            bail!(
                "fn {}: expected {} args, got {}",
                self.spec.name,
                self.spec.args.len(),
                args.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.spec.rets.len() {
            bail!(
                "fn {}: expected {} rets, got {}",
                self.spec.name,
                self.spec.rets.len(),
                outs.len()
            );
        }
        Ok(outs)
    }

    /// Execute with `Tensor` inputs (all-f32 interface helper for tests and
    /// single-tensor kernels).
    pub fn call_tensors(&self, args: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = args.iter().map(tensor_to_literal).collect::<Result<_>>()?;
        let outs = self.call(&lits)?;
        outs.iter()
            .zip(self.spec.rets.iter())
            .map(|(l, s)| literal_to_tensor(l, &s.shape))
            .collect()
    }
}

/// f32 Tensor -> PJRT literal with the tensor's shape.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // rank-0: reshape the 1-element vector to a scalar
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// i32 labels -> literal.
pub fn i32_to_literal(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Literal (f32) -> Tensor with the manifest-declared shape.
pub fn literal_to_tensor(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>()?;
    if shape.iter().product::<usize>() != data.len() {
        bail!("literal size {} != manifest shape {:?}", data.len(), shape);
    }
    Ok(Tensor::new(shape.to_vec(), data))
}

/// Cache of compiled functions for a model (compile once, call many).
pub struct FnCache<'rt> {
    rt: &'rt Runtime,
    man: Manifest,
    cache: HashMap<String, LoadedFn>,
}

impl<'rt> FnCache<'rt> {
    pub fn new(rt: &'rt Runtime, man: Manifest) -> Self {
        FnCache { rt, man, cache: HashMap::new() }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    pub fn get(&mut self, fn_name: &str) -> Result<&LoadedFn> {
        if !self.cache.contains_key(fn_name) {
            let f = self.rt.load_fn(&self.man, fn_name)?;
            self.cache.insert(fn_name.to_string(), f);
        }
        Ok(&self.cache[fn_name])
    }
}
