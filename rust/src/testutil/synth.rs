//! Synthetic deterministic models for engine tests and benches: a small
//! ResNet-style conv net (residual add, maxpool padding, depthwise conv,
//! SE gate, aq requant point) and a ViT-style transformer block
//! (to_tokens, layernorm, attention, gelu MLP, tokmean). Weights are
//! seeded, so two builds are bit-identical — these stand in for exported
//! artifacts when `make artifacts` has not run (the planned-vs-interpreted
//! exactness suite and the engine_hotpath bench both run on them).

use std::collections::BTreeMap;

use crate::qir::Graph;
use crate::tensor::Tensor;
use crate::testutil::Rng;

pub struct SynthModel {
    pub graph: Graph,
    pub params: BTreeMap<String, Tensor>,
    pub bn: BTreeMap<String, Tensor>,
}

fn normal_t(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), rng.normal_vec(n, std))
}

fn bn_state(
    rng: &mut Rng,
    params: &mut BTreeMap<String, Tensor>,
    bn: &mut BTreeMap<String, Tensor>,
    name: &str,
    c: usize,
) {
    let gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal()).collect();
    let beta: Vec<f32> = (0..c).map(|_| 0.1 * rng.normal()).collect();
    let mean: Vec<f32> = (0..c).map(|_| 0.05 * rng.normal()).collect();
    let var: Vec<f32> = (0..c).map(|_| 0.5 + rng.normal().abs() * 0.5).collect();
    params.insert(format!("{name}.gamma"), Tensor::new(vec![c], gamma));
    params.insert(format!("{name}.beta"), Tensor::new(vec![c], beta));
    bn.insert(format!("{name}.mean"), Tensor::new(vec![c], mean));
    bn.insert(format!("{name}.var"), Tensor::new(vec![c], var));
}

/// ResNet-style conv net on a `3 x hw x hw` image (`hw` divisible by 4)
/// with `c` channels: conv+bn+relu stem, padded maxpool, residual block with
/// an `aq` requant point, depthwise conv + hswish, an SE gate
/// (gap→1x1 conv→hsigmoid→mul), avgpool, gap, linear head (10 classes).
/// Tests use small widths; the engine_hotpath bench uses a wide variant so
/// the GEMMs cross the parallel-dispatch threshold.
pub fn resnet_like(hw: usize, c: usize) -> SynthModel {
    assert!(hw >= 8 && hw % 4 == 0, "hw must be >= 8 and divisible by 4");
    assert!(c >= 8, "c must be >= 8");
    let h2 = hw / 2;
    let h4 = h2 / 2;
    let text = format!(
        "qir synthres v1\noutputs head\n\
         node input image inputs=- shape=3,{hw},{hw}\n\
         node conv2d c1 inputs=image shape={c},{hw},{hw} bias=0 cin=3 cout={c} groups=1 kh=3 kw=3 pad=1 stride=1\n\
         node bn b1 inputs=c1 shape={c},{hw},{hw} c={c}\n\
         node relu r1 inputs=b1 shape={c},{hw},{hw}\n\
         node maxpool mp inputs=r1 shape={c},{h2},{h2} k=3 stride=2 pad=1\n\
         node conv2d c2 inputs=mp shape={c},{h2},{h2} bias=0 cin={c} cout={c} groups=1 kh=3 kw=3 pad=1 stride=1\n\
         node bn b2 inputs=c2 shape={c},{h2},{h2} c={c}\n\
         node relu r2 inputs=b2 shape={c},{h2},{h2}\n\
         node aq q1 inputs=r2 shape={c},{h2},{h2}\n\
         node conv2d c3 inputs=q1 shape={c},{h2},{h2} bias=1 cin={c} cout={c} groups=1 kh=3 kw=3 pad=1 stride=1\n\
         node bn b3 inputs=c3 shape={c},{h2},{h2} c={c}\n\
         node add a1 inputs=b3,mp shape={c},{h2},{h2}\n\
         node relu r3 inputs=a1 shape={c},{h2},{h2}\n\
         node conv2d cdw inputs=r3 shape={c},{h2},{h2} bias=0 cin={c} cout={c} groups={c} kh=3 kw=3 pad=1 stride=1\n\
         node hswish hs inputs=cdw shape={c},{h2},{h2}\n\
         node gap seg inputs=hs shape={c},1,1\n\
         node conv2d sefc inputs=seg shape={c},1,1 bias=1 cin={c} cout={c} groups=1 kh=1 kw=1 pad=0 stride=1\n\
         node hsigmoid seh inputs=sefc shape={c},1,1\n\
         node mul sem inputs=hs,seh shape={c},{h2},{h2}\n\
         node avgpool ap inputs=sem shape={c},{h4},{h4} k=2 stride=2 pad=0\n\
         node gap g1 inputs=ap shape={c},1,1\n\
         node flatten f1 inputs=g1 shape={c}\n\
         node linear head inputs=f1 shape=10 bias=1 din={c} dout=10\n"
    );
    let graph = Graph::parse(&text).expect("synth resnet graph parses");
    let mut rng = Rng::new(0x5EED_0001);
    let mut params = BTreeMap::new();
    let mut bn = BTreeMap::new();
    params.insert("c1.w".into(), normal_t(&mut rng, &[c, 3, 3, 3], 0.15));
    bn_state(&mut rng, &mut params, &mut bn, "b1", c);
    params.insert("c2.w".into(), normal_t(&mut rng, &[c, c, 3, 3], 0.08));
    bn_state(&mut rng, &mut params, &mut bn, "b2", c);
    params.insert("c3.w".into(), normal_t(&mut rng, &[c, c, 3, 3], 0.08));
    params.insert("c3.b".into(), normal_t(&mut rng, &[c], 0.05));
    bn_state(&mut rng, &mut params, &mut bn, "b3", c);
    params.insert("cdw.w".into(), normal_t(&mut rng, &[c, 1, 3, 3], 0.2));
    params.insert("sefc.w".into(), normal_t(&mut rng, &[c, c, 1, 1], 0.15));
    params.insert("sefc.b".into(), normal_t(&mut rng, &[c], 0.1));
    params.insert("head.w".into(), normal_t(&mut rng, &[10, c], 0.2));
    params.insert("head.b".into(), normal_t(&mut rng, &[10], 0.05));
    SynthModel { graph, params, bn }
}

/// ViT-style block on a 3x8x8 image: patch-embed conv, to_tokens,
/// pre-norm attention with residual, gelu MLP with residual, tokmean,
/// linear head (10 classes).
pub fn vit_like() -> SynthModel {
    let d = 32usize;
    let text = format!(
        "qir synthvit v1\noutputs head\n\
         node input image inputs=- shape=3,8,8\n\
         node conv2d pe inputs=image shape={d},2,2 bias=1 cin=3 cout={d} groups=1 kh=4 kw=4 pad=0 stride=4\n\
         node to_tokens tok inputs=pe shape=4,{d}\n\
         node layernorm ln1 inputs=tok shape=4,{d} d={d}\n\
         node attention att inputs=ln1 shape=4,{d} d={d} heads=4\n\
         node add ra inputs=att,tok shape=4,{d}\n\
         node layernorm ln2 inputs=ra shape=4,{d} d={d}\n\
         node linear mlp inputs=ln2 shape=4,{d} bias=1 din={d} dout={d}\n\
         node gelu gl inputs=mlp shape=4,{d}\n\
         node add rb inputs=gl,ra shape=4,{d}\n\
         node tokmean tm inputs=rb shape={d}\n\
         node linear head inputs=tm shape=10 bias=1 din={d} dout=10\n"
    );
    let graph = Graph::parse(&text).expect("synth vit graph parses");
    let mut rng = Rng::new(0x5EED_0002);
    let mut params = BTreeMap::new();
    params.insert("pe.w".into(), normal_t(&mut rng, &[d, 3, 4, 4], 0.12));
    params.insert("pe.b".into(), normal_t(&mut rng, &[d], 0.05));
    for ln in ["ln1", "ln2"] {
        let gamma: Vec<f32> = (0..d).map(|_| 1.0 + 0.05 * rng.normal()).collect();
        let beta: Vec<f32> = (0..d).map(|_| 0.05 * rng.normal()).collect();
        params.insert(format!("{ln}.gamma"), Tensor::new(vec![d], gamma));
        params.insert(format!("{ln}.beta"), Tensor::new(vec![d], beta));
    }
    for (mat, bias) in [("wq", "qb"), ("wk", "kb"), ("wv", "vb"), ("wo", "ob")] {
        params.insert(format!("att.{mat}"), normal_t(&mut rng, &[d, d], 0.12));
        params.insert(format!("att.{bias}"), normal_t(&mut rng, &[d], 0.02));
    }
    params.insert("mlp.w".into(), normal_t(&mut rng, &[d, d], 0.12));
    params.insert("mlp.b".into(), normal_t(&mut rng, &[d], 0.02));
    params.insert("head.w".into(), normal_t(&mut rng, &[10, d], 0.2));
    params.insert("head.b".into(), normal_t(&mut rng, &[10], 0.05));
    SynthModel { graph, params, bn: BTreeMap::new() }
}

/// Seeded random small CNN for property tests: a conv+bn+act stem, then
/// 1-3 blocks that independently draw kernel size, activation kind, an
/// optional `aq` requant point, an optional residual add, and an optional
/// 2x pool downsample, ending in gap → flatten → linear head. Every op kind
/// drawn here is covered by the planner and the interval analysis, so the
/// soundness suite can sweep many topologies without hand-writing graphs.
/// Deterministic in `seed`.
pub fn random_cnn(seed: u64) -> SynthModel {
    use std::fmt::Write as _;

    let mut rng = Rng::new(seed ^ 0x5EED_0003);
    let mut hw = 8 + 4 * rng.below(2); // 8 or 12
    let c = 8 + 8 * rng.below(2); // 8 or 16
    let acts = ["relu", "relu6", "hswish", "silu", "gelu"];
    let depth = 1 + rng.below(3);

    let mut text = String::from("qir synthrand v1\noutputs head\n");
    let mut params = BTreeMap::new();
    let mut bn = BTreeMap::new();
    let _ = writeln!(text, "node input image inputs=- shape=3,{hw},{hw}");
    let _ = writeln!(
        text,
        "node conv2d c0 inputs=image shape={c},{hw},{hw} bias=0 cin=3 cout={c} groups=1 \
         kh=3 kw=3 pad=1 stride=1"
    );
    params.insert("c0.w".into(), normal_t(&mut rng, &[c, 3, 3, 3], 0.15));
    let _ = writeln!(text, "node bn b0 inputs=c0 shape={c},{hw},{hw} c={c}");
    bn_state(&mut rng, &mut params, &mut bn, "b0", c);
    let _ = writeln!(text, "node relu r0 inputs=b0 shape={c},{hw},{hw}");
    let mut cur = "r0".to_string();

    for i in 0..depth {
        let block_in = cur.clone();
        if rng.below(2) == 0 {
            let _ = writeln!(text, "node aq q{i} inputs={cur} shape={c},{hw},{hw}");
            cur = format!("q{i}");
        }
        let (kh, pad) = if rng.below(2) == 0 { (3, 1) } else { (1, 0) };
        let bias = rng.below(2);
        let _ = writeln!(
            text,
            "node conv2d c{n} inputs={cur} shape={c},{hw},{hw} bias={bias} cin={c} cout={c} \
             groups=1 kh={kh} kw={kh} pad={pad} stride=1",
            n = i + 1
        );
        params.insert(format!("c{}.w", i + 1), normal_t(&mut rng, &[c, c, kh, kh], 0.08));
        if bias == 1 {
            params.insert(format!("c{}.b", i + 1), normal_t(&mut rng, &[c], 0.05));
        }
        let _ = writeln!(text, "node bn b{n} inputs=c{n} shape={c},{hw},{hw} c={c}", n = i + 1);
        bn_state(&mut rng, &mut params, &mut bn, &format!("b{}", i + 1), c);
        let act = acts[rng.below(acts.len())];
        let _ = writeln!(text, "node {act} a{i} inputs=b{n} shape={c},{hw},{hw}", n = i + 1);
        cur = format!("a{i}");
        if rng.below(2) == 0 {
            let _ = writeln!(text, "node add res{i} inputs={cur},{block_in} shape={c},{hw},{hw}");
            cur = format!("res{i}");
        }
        if hw >= 8 && rng.below(2) == 0 {
            let kind = if rng.below(2) == 0 { "maxpool" } else { "avgpool" };
            hw /= 2;
            let _ = writeln!(
                text,
                "node {kind} p{i} inputs={cur} shape={c},{hw},{hw} k=2 stride=2 pad=0"
            );
            cur = format!("p{i}");
        }
    }
    let _ = writeln!(text, "node gap g1 inputs={cur} shape={c},1,1");
    let _ = writeln!(text, "node flatten f1 inputs=g1 shape={c}");
    let _ = writeln!(text, "node linear head inputs=f1 shape=10 bias=1 din={c} dout=10");
    params.insert("head.w".into(), normal_t(&mut rng, &[10, c], 0.2));
    params.insert("head.b".into(), normal_t(&mut rng, &[10], 0.05));

    let graph = Graph::parse(&text).expect("synth random graph parses");
    SynthModel { graph, params, bn }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::fp32_model;

    #[test]
    fn synth_models_run_and_are_deterministic() {
        let sm = resnet_like(16, 16);
        let x = Tensor::new(vec![2, 3, 16, 16], Rng::new(7).normal_vec(2 * 3 * 256, 1.0));
        let m = fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone());
        let y = m.run(&x).unwrap();
        assert_eq!(y[0].shape, vec![2, 10]);
        let sm2 = resnet_like(16, 16);
        assert_eq!(sm.params["c1.w"].data, sm2.params["c1.w"].data);

        let sv = vit_like();
        let xv = Tensor::new(vec![2, 3, 8, 8], Rng::new(9).normal_vec(2 * 3 * 64, 1.0));
        let mv = fp32_model(sv.graph.clone(), sv.params.clone(), BTreeMap::new());
        let yv = mv.run(&xv).unwrap();
        assert_eq!(yv[0].shape, vec![2, 10]);
        assert!(yv[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn random_cnn_parses_runs_and_is_deterministic() {
        for seed in 0u64..6 {
            let sm = random_cnn(seed);
            let hw = sm.graph.nodes[0].shape[1];
            let x = Tensor::new(vec![2, 3, hw, hw], Rng::new(11).normal_vec(2 * 3 * hw * hw, 1.0));
            let m = fp32_model(sm.graph.clone(), sm.params.clone(), sm.bn.clone());
            let y = m.run(&x).unwrap();
            assert_eq!(y[0].shape, vec![2, 10], "seed {seed}");
            assert!(y[0].data.iter().all(|v| v.is_finite()), "seed {seed}");
            let sm2 = random_cnn(seed);
            assert_eq!(sm.params["c0.w"].data, sm2.params["c0.w"].data, "seed {seed}");
            assert_eq!(sm.graph.nodes.len(), sm2.graph.nodes.len(), "seed {seed}");
        }
    }
}
