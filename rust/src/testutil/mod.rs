//! Test support: deterministic PRNG, a small property-testing harness
//! (the vendored crate set has no proptest; this covers the invariant-sweep
//! use cases we need, with shrinking on failure for scalar cases), and
//! seeded synthetic models ([`synth`]) for engine tests/benches that must
//! run without exported artifacts.

pub mod synth;

/// xorshift64* — deterministic, dependency-free PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Heavy-tailed sample: normal with occasional large outliers — the
    /// activation regime the paper targets.
    pub fn heavy_tail(&mut self, outlier_p: f32, outlier_scale: f32) -> f32 {
        let v = self.normal();
        if self.uniform() < outlier_p {
            v * outlier_scale
        } else {
            v
        }
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }
}

/// Minimal bench harness (the vendored crate set has no criterion):
/// 20 warmup + N timed iterations, median over runs — the paper's
/// measurement protocol (§A.3).
pub struct BenchResult {
    pub name: String,
    pub median_us: f64,
    pub mean_us: f64,
    pub p95_us: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} median {:>10.1} us   mean {:>10.1} us   p95 {:>10.1} us   ({} iters)",
            self.name, self.median_us, self.mean_us, self.p95_us, self.iters
        );
    }
}

/// Time `f` with `warmup` warmup calls and `iters` timed calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let p95 = times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)];
    BenchResult { name: name.to_string(), median_us: median, mean_us: mean, p95_us: p95, iters }
}

/// Run `prop` against `cases` generated inputs; panics with the seed and case
/// index on first failure so it can be replayed.
pub fn prop_check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(0x5EED + name.len() as u64);
    for i in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property {name} failed at case {i}: input = {input:?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let vs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = vs.iter().sum::<f32>() / n as f32;
        let var = vs.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn prop_check_passes_trivial() {
        prop_check("abs-nonneg", 100, |r| r.normal(), |x| x.abs() >= 0.0);
    }
}
