//! Graph transformation passes applied by the simulated vendor compilers.
//!
//! * `fold_bn` — fold BatchNorm (running stats) into the preceding conv,
//!   the universal first step of every NPU toolchain.
//! * `fuse_conv_act` / `fuse_conv_bn_act` — tag a conv's sole-consumer
//!   activation as an `act=` attribute so the engine runs it in the GEMM
//!   epilogue (including the i8 requantization epilogue), exactly as real
//!   INT8 compiler stacks lower conv→bn→activation.
//! * `cross_layer_equalization` — rescale adjacent conv channel ranges
//!   (Nagel et al.; the "Equalization" half of the paper's Table 3 baseline).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::qir::{Graph, Node};
use crate::tensor::Tensor;

const BN_EPS: f32 = 1e-5;

/// Rebuild a graph from parts (validates and re-indexes).
pub fn rebuild(name: String, nodes: Vec<Node>, outputs: Vec<String>) -> Result<Graph> {
    let mut text = format!("qir {name} v1\noutputs {}\n", outputs.join(","));
    for n in &nodes {
        let inputs = if n.inputs.is_empty() { "-".to_string() } else { n.inputs.join(",") };
        let shape =
            n.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
        let attrs = n
            .attrs
            .iter()
            .map(|(k, v)| format!(" {k}={v}"))
            .collect::<String>();
        text.push_str(&format!("node {} {} inputs={inputs} shape={shape}{attrs}\n", n.kind, n.name));
    }
    Graph::parse(&text)
}

/// Per-output-channel |gamma / sqrt(var+eps)| factors applied to each folded
/// conv's weights — needed to transport embedded QAT weight statistics
/// (computed on unfolded weights) onto the folded graph.
pub type FoldFactors = BTreeMap<String, Vec<f32>>;

/// Fold every `conv2d -> bn` pair (bn the sole consumer) into the conv.
/// Returns the new graph, transformed parameters, and the fold factors.
pub fn fold_bn(
    graph: &Graph,
    params: &BTreeMap<String, Tensor>,
    bn: &BTreeMap<String, Tensor>,
) -> Result<(Graph, BTreeMap<String, Tensor>, FoldFactors)> {
    let counts = graph.consumer_counts();
    let mut new_params = params.clone();
    let mut factors: FoldFactors = BTreeMap::new();
    // bn node name -> conv node name, for bns being folded
    let mut folded: BTreeMap<String, String> = BTreeMap::new();
    for n in &graph.nodes {
        if n.kind != "bn" {
            continue;
        }
        let prod = graph.node(&n.inputs[0]);
        let Some(prod) = prod else { continue };
        if prod.kind != "conv2d" || counts.get(&prod.name).copied().unwrap_or(0) != 1 {
            continue;
        }
        let gamma = &params[&format!("{}.gamma", n.name)];
        let beta = &params[&format!("{}.beta", n.name)];
        let mean = bn.get(&format!("{}.mean", n.name)).context("missing bn mean")?;
        let var = bn.get(&format!("{}.var", n.name)).context("missing bn var")?;
        let wkey = format!("{}.w", prod.name);
        let w = new_params.get(&wkey).context("missing conv weight")?.clone();
        let cout = w.shape[0];
        let per = w.data.len() / cout;
        let had_bias = prod.attr_bool("bias");
        let old_b = if had_bias {
            new_params[&format!("{}.b", prod.name)].clone()
        } else {
            Tensor::zeros(&[cout])
        };
        let mut wn = w.clone();
        let mut bnew = Tensor::zeros(&[cout]);
        let mut facs = vec![1.0f32; cout];
        for c in 0..cout {
            let inv = (var.data[c] + BN_EPS).sqrt().recip();
            let s = gamma.data[c] * inv;
            facs[c] = s.abs();
            for i in 0..per {
                wn.data[c * per + i] *= s;
            }
            bnew.data[c] = (old_b.data[c] - mean.data[c]) * s + beta.data[c];
        }
        factors.insert(prod.name.clone(), facs);
        new_params.insert(wkey, wn);
        new_params.insert(format!("{}.b", prod.name), bnew);
        new_params.remove(&format!("{}.gamma", n.name));
        new_params.remove(&format!("{}.beta", n.name));
        folded.insert(n.name.clone(), prod.name.clone());
    }
    // rewrite graph: drop folded bn nodes, rewire consumers, set bias=1
    let mut nodes: Vec<Node> = Vec::new();
    for n in &graph.nodes {
        if folded.contains_key(&n.name) {
            continue;
        }
        let mut n2 = n.clone();
        if n2.kind == "conv2d" && folded.values().any(|c| c == &n2.name) {
            n2.attrs.insert("bias".into(), "1".into());
        }
        for i in n2.inputs.iter_mut() {
            if let Some(conv) = folded.get(i) {
                *i = conv.clone();
            }
        }
        nodes.push(n2);
    }
    let outputs = graph
        .outputs
        .iter()
        .map(|o| folded.get(o).cloned().unwrap_or_else(|| o.clone()))
        .collect();
    let g = rebuild(graph.name.clone(), nodes, outputs)?;
    Ok((g, new_params, factors))
}

/// Activations the engine can run in a conv's GEMM epilogue (one definition
/// with `engine::ops::Act`; `Act::from_kind` accepts exactly these).
const FUSABLE_ACTS: &[&str] = &["relu", "relu6", "hswish", "hsigmoid", "sigmoid", "silu", "gelu"];

/// Fuse every `conv2d -> activation` pair where the activation is the conv's
/// sole consumer: the activation node is dropped, the conv is tagged with an
/// `act=<kind>` attribute, and consumers are rewired to the conv. Numerics
/// are unchanged (same scalar function, applied in the kernel epilogue);
/// the node count — and with it the modelled per-op dispatch overhead —
/// shrinks. Returns the rewritten graph and the number of fused pairs.
pub fn fuse_conv_act(graph: &Graph) -> Result<(Graph, usize)> {
    let counts = graph.consumer_counts();
    // act node name -> conv node name, and conv -> act kind
    let mut fused: BTreeMap<String, String> = BTreeMap::new();
    let mut conv_act: BTreeMap<String, String> = BTreeMap::new();
    for n in &graph.nodes {
        if !FUSABLE_ACTS.contains(&n.kind.as_str()) {
            continue;
        }
        let Some(prod) = graph.node(&n.inputs[0]) else { continue };
        if prod.kind != "conv2d" || counts.get(&prod.name).copied().unwrap_or(0) != 1 {
            continue;
        }
        if prod.attrs.contains_key("act") || conv_act.contains_key(&prod.name) {
            continue;
        }
        fused.insert(n.name.clone(), prod.name.clone());
        conv_act.insert(prod.name.clone(), n.kind.clone());
    }
    let mut nodes: Vec<Node> = Vec::new();
    for n in &graph.nodes {
        if fused.contains_key(&n.name) {
            continue;
        }
        let mut n2 = n.clone();
        if let Some(kind) = conv_act.get(&n2.name) {
            n2.attrs.insert("act".into(), kind.clone());
        }
        for i in n2.inputs.iter_mut() {
            if let Some(conv) = fused.get(i) {
                *i = conv.clone();
            }
        }
        nodes.push(n2);
    }
    let outputs = graph
        .outputs
        .iter()
        .map(|o| fused.get(o).cloned().unwrap_or_else(|| o.clone()))
        .collect();
    let nf = fused.len();
    Ok((rebuild(graph.name.clone(), nodes, outputs)?, nf))
}

/// The standard vendor lowering: BN fold, then conv+activation fusion.
/// Returns the lowered graph, transformed params, the BN fold factors, and
/// the number of fused activations.
pub fn fuse_conv_bn_act(
    graph: &Graph,
    params: &BTreeMap<String, Tensor>,
    bn: &BTreeMap<String, Tensor>,
) -> Result<(Graph, BTreeMap<String, Tensor>, FoldFactors, usize)> {
    let (g, p, factors) = fold_bn(graph, params, bn)?;
    let (g2, fused) = fuse_conv_act(&g)?;
    Ok((g2, p, factors, fused))
}

/// Cross-layer equalization on conv->act->conv chains (groups=1 both sides).
/// Scales output channel c of conv1 by 1/s and input channel c of conv2 by s,
/// s = sqrt(r1_c / r2_c), valid through ReLU-family activations and aq nodes.
pub fn cross_layer_equalization(
    graph: &Graph,
    params: &mut BTreeMap<String, Tensor>,
) -> usize {
    let counts = graph.consumer_counts();
    let mut pairs: Vec<(String, String)> = Vec::new();
    for n in &graph.nodes {
        if n.kind != "conv2d" || n.attr_usize("groups").unwrap_or(1) != 1 {
            continue;
        }
        // only relu-family epilogues are eligible, matching the chain walk
        // below: exact for relu (positively homogeneous); relu6 is the
        // standard CLE approximation (Nagel et al. apply equalization to
        // ReLU6 nets accepting that the clamp point moves) — anything else
        // (sigmoid-family, gelu) would change the function outright
        if let Some(a) = n.attrs.get("act") {
            if a != "relu" && a != "relu6" {
                continue;
            }
        }
        // walk a single-consumer chain through relu/relu6/aq to the next conv
        let mut cur = n.name.clone();
        loop {
            if counts.get(&cur).copied().unwrap_or(0) != 1 {
                break;
            }
            let consumer = graph.nodes.iter().find(|m| m.inputs.contains(&cur));
            let Some(c) = consumer else { break };
            match c.kind.as_str() {
                "relu" | "relu6" | "aq" => {
                    cur = c.name.clone();
                }
                "conv2d" if c.attr_usize("groups").unwrap_or(1) == 1 => {
                    pairs.push((n.name.clone(), c.name.clone()));
                    break;
                }
                _ => break,
            }
        }
    }
    for (a, b) in &pairs {
        let w1k = format!("{a}.w");
        let w2k = format!("{b}.w");
        let (Some(w1), Some(w2)) = (params.get(&w1k).cloned(), params.get(&w2k).cloned()) else {
            continue;
        };
        let cout1 = w1.shape[0];
        let per1 = w1.data.len() / cout1;
        let cin2 = w2.shape[1];
        if cin2 != cout1 {
            continue;
        }
        let cout2 = w2.shape[0];
        let khw2 = w2.shape[2] * w2.shape[3];
        let mut w1n = w1.clone();
        let mut w2n = w2.clone();
        let mut b1n = params.get(&format!("{a}.b")).cloned();
        for c in 0..cout1 {
            let r1 = w1.data[c * per1..(c + 1) * per1]
                .iter()
                .fold(0.0f32, |m, &v| m.max(v.abs()));
            let mut r2 = 0.0f32;
            for o in 0..cout2 {
                for i in 0..khw2 {
                    r2 = r2.max(w2.data[(o * cin2 + c) * khw2 + i].abs());
                }
            }
            if r1 <= 1e-12 || r2 <= 1e-12 {
                continue;
            }
            let s = (r1 / r2).sqrt();
            for i in 0..per1 {
                w1n.data[c * per1 + i] /= s;
            }
            if let Some(b) = b1n.as_mut() {
                b.data[c] /= s;
            }
            for o in 0..cout2 {
                for i in 0..khw2 {
                    w2n.data[(o * cin2 + c) * khw2 + i] *= s;
                }
            }
        }
        params.insert(w1k, w1n);
        params.insert(w2k, w2n);
        if let Some(b) = b1n {
            params.insert(format!("{a}.b"), b);
        }
    }
    pairs.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{fp32_model, ops};

    fn demo_graph() -> Graph {
        Graph::parse(
            "qir d v1\noutputs r\n\
             node input image inputs=- shape=2,4,4\n\
             node conv2d c inputs=image shape=3,4,4 bias=0 cin=2 cout=3 groups=1 kh=3 kw=3 pad=1 stride=1\n\
             node bn b inputs=c shape=3,4,4 c=3\n\
             node relu r inputs=b shape=3,4,4\n",
        )
        .unwrap()
    }

    fn demo_state() -> (BTreeMap<String, Tensor>, BTreeMap<String, Tensor>) {
        let mut params = BTreeMap::new();
        let wn: usize = 3 * 2 * 3 * 3;
        params.insert(
            "c.w".to_string(),
            Tensor::new(vec![3, 2, 3, 3], (0..wn).map(|i| (i as f32) * 0.01 - 0.2).collect()),
        );
        params.insert("b.gamma".to_string(), Tensor::new(vec![3], vec![1.0, 0.5, 2.0]));
        params.insert("b.beta".to_string(), Tensor::new(vec![3], vec![0.1, -0.1, 0.0]));
        let mut bn = BTreeMap::new();
        bn.insert("b.mean".to_string(), Tensor::new(vec![3], vec![0.05, -0.02, 0.1]));
        bn.insert("b.var".to_string(), Tensor::new(vec![3], vec![1.0, 0.5, 2.0]));
        (params, bn)
    }

    #[test]
    fn bn_fold_preserves_output() {
        let g = demo_graph();
        let (params, bn) = demo_state();
        let x = Tensor::new(
            vec![1, 2, 4, 4],
            (0..32).map(|i| (i as f32) * 0.1 - 1.5).collect(),
        );
        let m0 = fp32_model(g.clone(), params.clone(), bn.clone());
        let y0 = m0.run(&x).unwrap();
        let (g2, p2, _facs) = fold_bn(&g, &params, &bn).unwrap();
        assert!(g2.node("b").is_none(), "bn node should be gone");
        let m1 = fp32_model(g2, p2, BTreeMap::new());
        let y1 = m1.run(&x).unwrap();
        for (a, b) in y0[0].data.iter().zip(y1[0].data.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn conv_bn_act_fusion_preserves_fp32_outputs() {
        // conv+bn+relu folded+fused graph must compute the same function as
        // the unfused graph in FP32 (the fusion half is numerically exact;
        // the BN fold carries the usual rearrangement tolerance)
        let g = demo_graph();
        let (params, bn) = demo_state();
        let x = Tensor::new(vec![2, 2, 4, 4], (0..64).map(|i| (i as f32) * 0.07 - 2.0).collect());
        let y0 = fp32_model(g.clone(), params.clone(), bn.clone()).run(&x).unwrap();
        let (g2, p2, _facs, fused) = fuse_conv_bn_act(&g, &params, &bn).unwrap();
        assert_eq!(fused, 1, "relu should fuse into the folded conv");
        assert!(g2.node("b").is_none() && g2.node("r").is_none(), "bn and relu nodes must be gone");
        let conv = g2.node("c").unwrap();
        assert_eq!(conv.attrs.get("act").map(|s| s.as_str()), Some("relu"));
        assert_eq!(g2.outputs, vec!["c".to_string()], "graph output rewired to the fused conv");
        let y1 = fp32_model(g2, p2, BTreeMap::new()).run(&x).unwrap();
        for (a, b) in y0[0].data.iter().zip(y1[0].data.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn fusion_skips_multi_consumer_convs() {
        // conv output feeds both relu and add: must NOT fuse
        let g = Graph::parse(
            "qir m v1\noutputs s\n\
             node input image inputs=- shape=2,4,4\n\
             node conv2d c inputs=image shape=2,4,4 bias=0 cin=2 cout=2 groups=1 kh=1 kw=1 pad=0 stride=1\n\
             node relu r inputs=c shape=2,4,4\n\
             node add s inputs=r,c shape=2,4,4\n",
        )
        .unwrap();
        let (g2, fused) = fuse_conv_act(&g).unwrap();
        assert_eq!(fused, 0);
        assert!(g2.node("r").is_some());
    }

    #[test]
    fn equalization_preserves_function_and_balances_ranges() {
        // conv(1x1) -> relu -> conv(1x1), no bias
        let g = Graph::parse(
            "qir e v1\noutputs c2\n\
             node input image inputs=- shape=2,2,2\n\
             node conv2d c1 inputs=image shape=2,2,2 bias=0 cin=2 cout=2 groups=1 kh=1 kw=1 pad=0 stride=1\n\
             node relu r inputs=c1 shape=2,2,2\n\
             node conv2d c2 inputs=r shape=2,2,2 bias=0 cin=2 cout=2 groups=1 kh=1 kw=1 pad=0 stride=1\n",
        )
        .unwrap();
        let mut params = BTreeMap::new();
        // channel 0 of c1 huge, channel 1 tiny — classic imbalance
        params.insert("c1.w".into(), Tensor::new(vec![2, 2, 1, 1], vec![8.0, 4.0, 0.01, 0.02]));
        params.insert("c2.w".into(), Tensor::new(vec![2, 2, 1, 1], vec![0.01, 2.0, 0.02, 1.0]));
        let x = Tensor::new(vec![1, 2, 2, 2], vec![0.3, -0.2, 0.5, 0.1, -0.4, 0.2, 0.6, -0.1]);
        let before = {
            let m = fp32_model(g.clone(), params.clone(), BTreeMap::new());
            m.run(&x).unwrap()[0].clone()
        };
        let n = cross_layer_equalization(&g, &mut params);
        assert_eq!(n, 1);
        let after = {
            let m = fp32_model(g.clone(), params.clone(), BTreeMap::new());
            m.run(&x).unwrap()[0].clone()
        };
        for (a, b) in before.data.iter().zip(after.data.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        // ranges balanced: per-channel |w| max of c1 should be closer together
        let w1 = &params["c1.w"];
        let r0 = w1.data[0..2].iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let r1 = w1.data[2..4].iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(r0 / r1 < 8.0 / 0.02 / 10.0, "ranges should contract: {r0} {r1}");
        let _ = ops::conv2d_f32; // keep import used
    }
}
