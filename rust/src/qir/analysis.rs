//! Static interval (worst-case range) analysis over QIR graphs.
//!
//! Pure math layer of the plan auditor (`engine::verify`): everything here
//! works on plain slices and attrs — no engine types — so the same transfer
//! functions serve the compiled integer engine, the interpreter, and tests.
//! The contract is **soundness, not tightness**: every transfer returns an
//! interval that contains all values the corresponding kernel can produce
//! (including quantization error, saturation, and f32 round-off slop), at
//! the cost of some conservatism. `engine::verify` layers the
//! engine-specific context (dequantized weights, qparams, narrowing mode)
//! on top and turns the propagated intervals into findings.
//!
//! The one genuinely load-bearing result: combined with the per-row integer
//! payload sums in [`acc_bounds`], the propagated intervals *prove* that no
//! i8×i8→i32 accumulator in a deployment can overflow — per layer, at the
//! graph's actual K dimensions, for both 8- and 4-bit weight grids.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::Graph;

/// Relative widening applied after every op for f32 summation round-off.
/// A K-term f32 dot product carries relative error ~K·2⁻²⁴; 1e-4 covers
/// K up to ~1000 with two orders of magnitude to spare.
pub const SUM_REL: f64 = 1e-4;
/// Absolute widening floor (covers denormal flushing and ±0 slop).
pub const ABS_SLOP: f64 = 1e-6;

/// A closed interval of f64 values (±∞ endpoints allowed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    pub lo: f64,
    pub hi: f64,
}

impl Interval {
    pub fn new(lo: f64, hi: f64) -> Interval {
        debug_assert!(!(lo > hi), "inverted interval [{lo}, {hi}]");
        Interval { lo, hi }
    }

    pub fn point(v: f64) -> Interval {
        Interval { lo: v, hi: v }
    }

    /// The whole real line — the sound answer when nothing tighter holds.
    pub fn full() -> Interval {
        Interval { lo: f64::NEG_INFINITY, hi: f64::INFINITY }
    }

    pub fn hull(self, o: Interval) -> Interval {
        Interval { lo: self.lo.min(o.lo), hi: self.hi.max(o.hi) }
    }

    /// Hull with a single point (e.g. the implicit 0 of a padded pool).
    pub fn with(self, v: f64) -> Interval {
        Interval { lo: self.lo.min(v), hi: self.hi.max(v) }
    }

    pub fn add(self, o: Interval) -> Interval {
        Interval { lo: self.lo + o.lo, hi: self.hi + o.hi }
    }

    /// Interval product. A NaN corner (0·∞) degrades to the full line —
    /// conservative, never unsound.
    pub fn mul(self, o: Interval) -> Interval {
        let ps = [self.lo * o.lo, self.lo * o.hi, self.hi * o.lo, self.hi * o.hi];
        if ps.iter().any(|p| p.is_nan()) {
            return Interval::full();
        }
        Interval {
            lo: ps.iter().fold(f64::INFINITY, |a, &b| a.min(b)),
            hi: ps.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b)),
        }
    }

    /// Largest magnitude in the interval.
    pub fn amax(self) -> f64 {
        self.lo.abs().max(self.hi.abs())
    }

    /// Widen both endpoints outward by `rel · amax + abs`.
    pub fn widen(self, rel: f64, abs: f64) -> Interval {
        let m = self.amax();
        let pad = if m.is_finite() { rel * m + abs } else { abs };
        Interval { lo: self.lo - pad, hi: self.hi + pad }
    }

    pub fn contains(self, v: f64) -> bool {
        v >= self.lo && v <= self.hi
    }

    pub fn is_finite(self) -> bool {
        self.lo.is_finite() && self.hi.is_finite()
    }
}

/// An asymmetric u8 activation grid: representable dequantized values are
/// `(q - zp) · scale` for `q ∈ [0, 255]`.
#[derive(Clone, Copy, Debug)]
pub struct QuantGrid {
    pub scale: f64,
    pub zp: i32,
}

impl QuantGrid {
    pub fn new(scale: f32, zp: i32) -> QuantGrid {
        QuantGrid { scale: scale as f64, zp }
    }

    /// Smallest representable value, `(0 - zp) · scale`.
    pub fn lo(&self) -> f64 {
        -(self.zp as f64) * self.scale
    }

    /// Largest representable value, `(255 - zp) · scale`.
    pub fn hi(&self) -> f64 {
        (255.0 - self.zp as f64) * self.scale
    }

    /// Sound transfer of quantize-then-dequantize: the map is monotone with
    /// |x̂ − x| ≤ scale/2 inside the grid, and saturates to the grid edges
    /// outside it — so endpoint images (widened a half step, clamped to the
    /// grid hull) bound every output.
    pub fn quantize(&self, x: Interval) -> Interval {
        let half = self.scale * 0.5 * (1.0 + 1e-3) + 1e-9;
        let (glo, ghi) = (self.lo(), self.hi());
        Interval::new((x.lo - half).clamp(glo, ghi), (x.hi + half).clamp(glo, ghi))
    }

    /// Fraction of the incoming range that saturates: how far `x` spills
    /// past the grid hull, relative to the grid span. 0.0 = no clipping
    /// possible; 0.5 = the worst-case input overshoots by half a grid span.
    pub fn clip_excess(&self, x: Interval) -> f64 {
        let span = (self.hi() - self.lo()).max(1e-12);
        let over = (x.hi - self.hi()).max(0.0);
        let under = (self.lo() - x.lo).max(0.0);
        (over.max(under) / span).max(0.0)
    }
}

/// Sound transfer of *dynamic* per-tensor quant-dequant (`dyn_qparams` +
/// requant): the runtime widens the live range to span zero and uses step
/// `s = (hi_w − lo_w)/255`, so with the live range contained in `x` the
/// error is at most one worst-case step (half for value rounding, half for
/// zero-point rounding).
pub fn dyn_quantize(x: Interval) -> Interval {
    let lo_w = x.lo.min(0.0);
    let hi_w = x.hi.max(x.lo + 1e-6).max(0.0);
    let s_max = ((hi_w - lo_w) / 255.0).max(1e-6 / 255.0);
    let pad = s_max * (1.0 + 1e-3) + 1e-9;
    Interval::new(x.lo - pad, x.hi + pad)
}

/// Per-output-row affine summary of a weight matrix: positive-coefficient
/// sum, negative-coefficient sum, and bias per row. Gives the *exact*
/// per-row extreme of `Σ w·x + b` over a scalar input interval (the affine
/// image of a box is attained at a corner, picked by coefficient sign).
#[derive(Clone, Debug, Default)]
pub struct AffineRows {
    pub pos: Vec<f64>,
    pub neg: Vec<f64>,
    pub bias: Vec<f64>,
}

impl AffineRows {
    /// Summarize a row-major `(rows, k)` weight matrix. Grouped conv
    /// weights flatten to exactly this layout (each output channel's row
    /// spans only its own group), so callers pass conv weights unchanged.
    pub fn from_weights(w: &[f32], rows: usize, bias: Option<&[f32]>) -> AffineRows {
        let rows = rows.max(1);
        let per = w.len() / rows;
        let mut pos = vec![0.0f64; rows];
        let mut neg = vec![0.0f64; rows];
        for r in 0..rows {
            for &v in &w[r * per..(r + 1) * per] {
                let v = v as f64;
                if v > 0.0 {
                    pos[r] += v;
                } else {
                    neg[r] += v;
                }
            }
        }
        let bias = match bias {
            Some(b) => b.iter().map(|&v| v as f64).collect(),
            None => Vec::new(),
        };
        AffineRows { pos, neg, bias }
    }

    fn bias_at(&self, r: usize) -> f64 {
        self.bias.get(r).copied().unwrap_or(0.0)
    }

    /// Interval of `Σ_j w_rj x_j + b_r` over all rows, for `x_j ∈ x`.
    pub fn apply(&self, x: Interval) -> Interval {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for r in 0..self.pos.len() {
            let b = self.bias_at(r);
            lo = lo.min(self.pos[r] * x.lo + self.neg[r] * x.hi + b);
            hi = hi.max(self.pos[r] * x.hi + self.neg[r] * x.lo + b);
        }
        if lo > hi {
            return Interval::point(0.0);
        }
        Interval::new(lo, hi)
    }

    /// Upper bound on `Σ|w||x| + |b|` over rows — the magnitude the f32
    /// round-off widening is relative to.
    pub fn mag(&self, x: Interval) -> f64 {
        let m = x.amax();
        (0..self.pos.len())
            .map(|r| (self.pos[r] - self.neg[r]) * m + self.bias_at(r).abs())
            .fold(0.0f64, f64::max)
    }
}

/// Interval image of an activation by QIR kind name. Monotone activations
/// map endpoints; the valley-shaped ones (hswish/silu/gelu) are unimodal,
/// so endpoints plus a padded global minimum cover every interior point.
/// Returns `None` for kinds that are not activations.
pub fn act_interval(kind: &str, x: Interval) -> Option<Interval> {
    let f: fn(f64) -> f64 = match kind {
        "relu" => |v| v.max(0.0),
        "relu6" => |v| v.clamp(0.0, 6.0),
        "hsigmoid" => |v| (v + 3.0).clamp(0.0, 6.0) / 6.0,
        "sigmoid" => |v| 1.0 / (1.0 + (-v).exp()),
        "hswish" => |v| v * (v + 3.0).clamp(0.0, 6.0) / 6.0,
        "silu" => |v| v / (1.0 + (-v).exp()),
        "gelu" => |v| {
            let c = (2.0f64 / std::f64::consts::PI).sqrt();
            0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
        },
        _ => return None,
    };
    let (a, b) = (f(x.lo), f(x.hi));
    let mut lo = a.min(b);
    let mut hi = a.max(b);
    // Valley functions: if the interval reaches any negative input, union
    // in the (slightly padded) global minimum; the true minima are
    // hswish −0.375 @ −1.5, silu ≈ −0.27846 @ −1.2784, gelu ≈ −0.1700.
    let min_pad = match kind {
        "hswish" => Some(-0.3755),
        "silu" => Some(-0.2790),
        "gelu" => Some(-0.1705),
        _ => None,
    };
    if let Some(m) = min_pad {
        if x.lo < 0.0 {
            lo = lo.min(m);
        }
    }
    Some(Interval::new(lo, hi))
}

/// Interval of a layernorm output, **independent of the input**: for a
/// population-variance layernorm over `d` elements the z-score obeys
/// `|z| ≤ √(d−1)` (extremal when one element carries all the deviation;
/// the variance-floor `eps` only shrinks it), so the output is bounded by
/// the per-channel affine `γ_c z + β_c`.
pub fn layernorm_interval(d: usize, gamma: &[f32], beta: &[f32]) -> Interval {
    let d = d.max(1) as f64;
    let zb = (d - 1.0).sqrt();
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    let n = gamma.len().max(beta.len()).max(1);
    for c in 0..n {
        let g = gamma.get(c).copied().unwrap_or(1.0).abs() as f64;
        let b = beta.get(c).copied().unwrap_or(0.0) as f64;
        lo = lo.min(-g * zb + b);
        hi = hi.max(g * zb + b);
    }
    Interval::new(lo, hi)
}

/// Worst-case i32 accumulator bounds of a requantizing u8×i8 GEMM row
/// sweep. `pos`/`neg` are the per-row sums of positive / negative integer
/// weight payload values, `row_sums` the full per-row payload sums (the
/// zero-point correction term), and `zx ∈ [zx_lo, zx_hi]` the activation
/// zero point. Activations are u8 ∈ [0, 255] after clamping, so:
///
/// * raw accumulator: `acc_r ∈ [255·neg_r, 255·pos_r]` — and every partial
///   sum over ANY subset of the K terms, in ANY order, because each term
///   `xq·wq` lies in `[255·min(wq,0), 255·max(wq,0)]` (an interval that
///   contains 0) and interval sums are order-free. This is what lets the
///   SIMD tiers split a row across vector lanes: every lane-partial i32 is
///   itself inside the bound, so the no-overflow proof is layout- and
///   tier-independent;
/// * corrected value: `acc_r − zx·row_sum_r`;
/// * `max_abs` covers every i32 intermediate (raw acc, correction term,
///   corrected result) — the quantity that must stay below `i32::MAX`.
#[derive(Clone, Copy, Debug, Default)]
pub struct AccBounds {
    pub lo: i64,
    pub hi: i64,
    pub max_abs: i64,
}

pub fn acc_bounds(pos: &[i64], neg: &[i64], row_sums: &[i64], zx_lo: i64, zx_hi: i64) -> AccBounds {
    let mut out = AccBounds::default();
    for r in 0..pos.len() {
        let (acc_lo, acc_hi) = (255 * neg[r], 255 * pos[r]);
        let rs = row_sums.get(r).copied().unwrap_or(0);
        let (c0, c1) = (zx_lo * rs, zx_hi * rs);
        let (corr_lo, corr_hi) = (c0.min(c1), c0.max(c1));
        let lo = acc_lo - corr_hi;
        let hi = acc_hi - corr_lo;
        out.lo = out.lo.min(lo);
        out.hi = out.hi.max(hi);
        for v in [acc_lo, acc_hi, corr_lo, corr_hi, lo, hi] {
            out.max_abs = out.max_abs.max(v.abs());
        }
    }
    out
}

/// [`acc_bounds`] when only the grid is known (no payload): every weight at
/// the largest magnitude the bit-width allows, every activation at 255.
pub fn acc_bounds_grid(k: usize, weight_bits: u8) -> AccBounds {
    let wmax: i64 = if weight_bits == 4 { 8 } else { 128 };
    let k = k as i64;
    AccBounds { lo: -2 * 255 * wmax * k, hi: 2 * 255 * wmax * k, max_abs: 2 * 255 * wmax * k }
}

/// Accumulator headroom in bits: `log2(i32::MAX / max_abs)`. Negative
/// means a provable overflow is reachable.
pub fn headroom_bits(b: AccBounds) -> f64 {
    (i32::MAX as f64 / b.max_abs.max(1) as f64).log2()
}

/// How a compute node's input is quantized before its integer GEMM.
#[derive(Clone, Copy, Debug, Default)]
pub enum InputQuant {
    /// Float kernel — input used as-is.
    #[default]
    None,
    /// Static asymmetric grid from the producer's calibrated range.
    Static(QuantGrid),
    /// Per-tensor dynamic quantization from the live batch.
    Dynamic,
}

impl InputQuant {
    fn apply(&self, x: Interval) -> Interval {
        match self {
            InputQuant::None => x,
            InputQuant::Static(g) => g.quantize(x),
            InputQuant::Dynamic => dyn_quantize(x),
        }
    }

    fn clip(&self, x: Interval) -> f64 {
        match self {
            InputQuant::Static(g) => g.clip_excess(x),
            _ => 0.0,
        }
    }
}

/// Value-analysis context of an attention node: the v/o projections drive
/// the output bound (softmax rows are convex combinations of v rows, so
/// q/k only pick the weights); `o_quant` is the output projection's input
/// quantization — on static integer deployments that grid comes from the
/// *block input* range (the engine's proxy), which is exactly where
/// requant saturation risk concentrates.
#[derive(Clone, Debug, Default)]
pub struct AttnCtx {
    pub v: AffineRows,
    pub o: AffineRows,
    pub in_quant: InputQuant,
    pub o_quant: InputQuant,
}

/// Per-node analysis context supplied by the caller (`engine::verify`
/// builds it from a `CompiledModel`; tests build it by hand). Everything
/// defaults to "no extra semantics" so shape-only nodes need no entry.
#[derive(Clone, Debug, Default)]
pub struct NodeCtx {
    /// conv2d / linear weight summary (dequantized under integer modes).
    pub affine: Option<AffineRows>,
    /// Input quantization in front of this node's integer GEMM.
    pub in_quant: InputQuant,
    /// Folded batchnorm (scale, shift) per channel.
    pub bn: Option<(Vec<f32>, Vec<f32>)>,
    /// Layernorm (gamma, beta).
    pub ln: Option<(Vec<f32>, Vec<f32>)>,
    /// Attention projections.
    pub attn: Option<AttnCtx>,
    /// Static requantization grid of an `aq` node.
    pub quant: Option<QuantGrid>,
    /// `aq` node running dynamic per-tensor requantization.
    pub dyn_quant: bool,
}

/// Global propagation knobs (activation storage narrowing, round-off).
#[derive(Clone, Copy, Debug)]
pub struct PropagateCfg {
    /// Interval of the graph input tensor.
    pub input: Interval,
    /// Per-node relative widening for narrowed activation storage
    /// (bf16: 2⁻⁸; f16: 2⁻¹⁰; 0.0 for f32/int8 paths).
    pub narrow_rel: f64,
    /// Values at or above this magnitude overflow the storage format to
    /// ±∞ (f16: 65504); `None` = no finite overflow threshold.
    pub inf_threshold: Option<f64>,
    /// Relative f32 round-off widening applied after every op.
    pub sum_rel: f64,
}

impl Default for PropagateCfg {
    fn default() -> PropagateCfg {
        PropagateCfg {
            input: Interval::new(-2.5, 2.5),
            narrow_rel: 0.0,
            inf_threshold: None,
            sum_rel: SUM_REL,
        }
    }
}

/// Result of propagating one node.
#[derive(Clone, Copy, Debug)]
pub struct NodeReport {
    /// Sound bound on every element this node can output.
    pub out: Interval,
    /// Worst-case static-grid clipping excess at this node's quantization
    /// point(s) (see [`QuantGrid::clip_excess`]); 0.0 = saturation-free.
    pub clip: f64,
}

/// Propagate worst-case value intervals through a graph in topological
/// order. Returns a per-node [`NodeReport`]; fails on unknown node kinds
/// or missing producers (a malformed graph, not an analysis result).
pub fn propagate(
    graph: &Graph,
    ctx: &BTreeMap<String, NodeCtx>,
    cfg: &PropagateCfg,
) -> Result<BTreeMap<String, NodeReport>> {
    let default_ctx = NodeCtx::default();
    let mut out: BTreeMap<String, NodeReport> = BTreeMap::new();
    for n in &graph.nodes {
        let nc = ctx.get(&n.name).unwrap_or(&default_ctx);
        let get = |i: usize| -> Result<Interval> {
            let name = n
                .inputs
                .get(i)
                .with_context(|| format!("analysis: node {} missing input {i}", n.name))?;
            Ok(out
                .get(name)
                .with_context(|| format!("analysis: node {} reads unanalyzed {name}", n.name))?
                .out)
        };
        let mut clip = 0.0f64;
        let mut iv = match n.kind.as_str() {
            "input" => cfg.input,
            "conv2d" | "linear" => {
                let x = get(0)?;
                clip = nc.in_quant.clip(x);
                let xq = nc.in_quant.apply(x);
                let aff = nc
                    .affine
                    .as_ref()
                    .with_context(|| format!("analysis: no weight summary for {}", n.name))?;
                let y = aff.apply(xq).widen(0.0, cfg.sum_rel * aff.mag(xq));
                match n.attrs.get("act") {
                    Some(kind) => act_interval(kind, y)
                        .with_context(|| format!("analysis: unknown fused act at {}", n.name))?,
                    None => y,
                }
            }
            "bn" => {
                let x = get(0)?;
                let (scale, shift) = nc
                    .bn
                    .as_ref()
                    .with_context(|| format!("analysis: no bn fold for {}", n.name))?;
                let mut lo = f64::INFINITY;
                let mut hi = f64::NEG_INFINITY;
                for c in 0..scale.len().max(1) {
                    let s = scale.get(c).copied().unwrap_or(1.0) as f64;
                    let b = shift.get(c).copied().unwrap_or(0.0) as f64;
                    let y = x.mul(Interval::point(s)).add(Interval::point(b));
                    lo = lo.min(y.lo);
                    hi = hi.max(y.hi);
                }
                Interval::new(lo, hi)
            }
            "relu" | "relu6" | "hswish" | "hsigmoid" | "sigmoid" | "silu" | "gelu" => {
                act_interval(&n.kind, get(0)?).expect("covered by match")
            }
            "add" => get(0)?.add(get(1)?),
            "mul" => get(0)?.mul(get(1)?),
            "maxpool" | "avgpool" => {
                let x = get(0)?;
                // padded windows mix in implicit zeros (maxpool: an
                // all-padding window outputs 0; avgpool: the divisor counts
                // padding), so the hull must include 0 when pad > 0
                if n.attr_usize("pad")? > 0 {
                    x.with(0.0)
                } else {
                    x
                }
            }
            // convex combinations / element shuffles stay within the hull
            "gap" | "tokmean" => get(0)?,
            "upsample2x" | "flatten" | "reshape" | "to_tokens" => get(0)?,
            "concat" => get(0)?.hull(get(1)?),
            "layernorm" => {
                let (g, b) = nc
                    .ln
                    .as_ref()
                    .with_context(|| format!("analysis: no ln params for {}", n.name))?;
                get(0)?; // producer must exist even though the bound ignores it
                layernorm_interval(n.attr_usize("d")?, g, b)
            }
            "attention" => {
                let x = get(0)?;
                let at = nc
                    .attn
                    .as_ref()
                    .with_context(|| format!("analysis: no attention ctx for {}", n.name))?;
                clip = at.in_quant.clip(x);
                let v_in = at.in_quant.apply(x);
                let v = at.v.apply(v_in).widen(0.0, cfg.sum_rel * at.v.mag(v_in));
                // softmax context rows are convex combinations of v rows
                // (weights ≥ 0, summing to 1 up to round-off)
                let ctxt = v.widen(cfg.sum_rel, ABS_SLOP);
                clip = clip.max(at.o_quant.clip(ctxt));
                let o_in = at.o_quant.apply(ctxt);
                at.o.apply(o_in).widen(0.0, cfg.sum_rel * at.o.mag(o_in))
            }
            "aq" => {
                let x = get(0)?;
                if let Some(g) = &nc.quant {
                    clip = g.clip_excess(x);
                    g.quantize(x)
                } else if nc.dyn_quant {
                    dyn_quantize(x)
                } else {
                    x
                }
            }
            other => bail!("analysis: unknown node kind {other:?}"),
        };
        if n.kind != "input" {
            iv = iv.widen(cfg.sum_rel + cfg.narrow_rel, ABS_SLOP);
            if let Some(t) = cfg.inf_threshold {
                if iv.hi >= t {
                    iv.hi = f64::INFINITY;
                }
                if iv.lo <= -t {
                    iv.lo = f64::NEG_INFINITY;
                }
            }
        }
        out.insert(n.name.clone(), NodeReport { out: iv, clip });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn iv(lo: f64, hi: f64) -> Interval {
        Interval::new(lo, hi)
    }

    #[test]
    fn interval_mul_covers_sign_corners() {
        let a = iv(-2.0, 3.0).mul(iv(-1.0, 4.0));
        assert_eq!(a, iv(-8.0, 12.0));
        assert_eq!(iv(0.0, 0.0).mul(Interval::full()), Interval::full());
    }

    #[test]
    fn quant_grid_transfer_bounds_the_lut() {
        // scale 0.1, zp 50: grid spans [-5.0, 20.5]
        let g = QuantGrid::new(0.1, 50);
        let q = g.quantize(iv(-100.0, 100.0));
        assert!(q.lo >= g.lo() - 1e-9 && q.hi <= g.hi() + 1e-9);
        assert!(g.clip_excess(iv(-100.0, 100.0)) > 1.0);
        assert_eq!(g.clip_excess(iv(-1.0, 1.0)), 0.0);
        // every representable value round-trips inside the transfer
        for q8 in [0i32, 1, 128, 255] {
            let v = (q8 - 50) as f64 * 0.1;
            assert!(g.quantize(iv(v, v)).contains(v));
        }
    }

    #[test]
    fn dyn_quantize_is_one_step_wide() {
        let x = iv(-1.0, 3.0);
        let q = dyn_quantize(x);
        let step = 4.0 / 255.0;
        assert!(q.lo <= x.lo && q.lo >= x.lo - 2.0 * step);
        assert!(q.hi >= x.hi && q.hi <= x.hi + 2.0 * step);
    }

    #[test]
    fn affine_rows_exact_on_known_matrix() {
        // rows: [1, -2], [3, 4]; bias [10, -10]; x in [-1, 2]
        let a = AffineRows::from_weights(&[1.0, -2.0, 3.0, 4.0], 2, Some(&[10.0, -10.0]));
        let y = a.apply(iv(-1.0, 2.0));
        // row0: [1*(-1) + (-2)*2, 1*2 + (-2)*(-1)] + 10 = [5, 14]
        // row1: [7*(-1), 7*2] - 10 = [-17, 4]
        assert_eq!(y, iv(-17.0, 14.0));
        assert!(a.mag(iv(-1.0, 2.0)) >= 7.0 * 2.0 + 10.0);
    }

    #[test]
    fn act_transfers_contain_dense_samples() {
        for kind in ["relu", "relu6", "hswish", "hsigmoid", "sigmoid", "silu", "gelu"] {
            for (lo, hi) in [(-6.0, 6.0), (-2.0, -0.5), (-0.3, 0.4), (1.0, 9.0), (-9.0, -3.5)] {
                let y = act_interval(kind, iv(lo, hi)).unwrap();
                let mut v = lo;
                while v <= hi {
                    let f = match kind {
                        "relu" => v.max(0.0),
                        "relu6" => v.clamp(0.0, 6.0),
                        "hswish" => v * (v + 3.0).clamp(0.0, 6.0) / 6.0,
                        "hsigmoid" => (v + 3.0).clamp(0.0, 6.0) / 6.0,
                        "sigmoid" => 1.0 / (1.0 + (-v).exp()),
                        "silu" => v / (1.0 + (-v).exp()),
                        "gelu" => {
                            let c = (2.0f64 / std::f64::consts::PI).sqrt();
                            0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
                        }
                        _ => unreachable!(),
                    };
                    assert!(
                        y.contains(f) || (f - y.lo).abs() < 1e-9 || (f - y.hi).abs() < 1e-9,
                        "{kind}: f({v}) = {f} outside {y:?}"
                    );
                    v += 0.01;
                }
            }
        }
    }

    #[test]
    fn layernorm_bound_contains_extremal_vector() {
        // d=4, one element carries all deviation: z = sqrt(d-1) = sqrt(3)
        let d = 4usize;
        let x = [10.0f64, 0.0, 0.0, 0.0];
        let mean = x.iter().sum::<f64>() / d as f64;
        let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / d as f64;
        let zmax = x.iter().map(|v| (v - mean) / var.sqrt()).fold(0.0f64, |a, b| a.max(b.abs()));
        let b = layernorm_interval(d, &[2.0, 1.0], &[0.5, -0.5]);
        assert!(b.hi >= 2.0 * zmax + 0.5 - 1e-9, "{b:?} vs zmax {zmax}");
        assert!(b.lo <= -2.0 * zmax - 0.5 + 1e-9);
    }

    #[test]
    fn acc_bounds_match_brute_force_row() {
        // one row of weights: [3, -5, 7], zx in [0, 255]
        let w = [3i64, -5, 7];
        let pos: i64 = w.iter().filter(|&&v| v > 0).sum();
        let neg: i64 = w.iter().filter(|&&v| v < 0).sum();
        let rs: i64 = w.iter().sum();
        let b = acc_bounds(&[pos], &[neg], &[rs], 0, 255);
        // brute force over a coarse lattice of xq values
        for x0 in [0i64, 100, 255] {
            for x1 in [0i64, 100, 255] {
                for x2 in [0i64, 100, 255] {
                    for zx in [0i64, 128, 255] {
                        let acc = 3 * x0 - 5 * x1 + 7 * x2;
                        let corr = acc - zx * rs;
                        assert!(corr >= b.lo && corr <= b.hi, "{corr} outside {b:?}");
                        assert!(acc.abs() <= b.max_abs && corr.abs() <= b.max_abs);
                    }
                }
            }
        }
    }

    #[test]
    fn acc_bounds_contain_every_partial_sum_in_any_order() {
        // The SIMD-tier contract: split a row's K terms across lanes in any
        // order, sum any subset — every intermediate stays inside the raw
        // bound, because each term's interval contains 0.
        let mut rng = crate::testutil::Rng::new(0x51AD_5EED);
        for _ in 0..50 {
            let k = 1 + rng.below(40);
            let w: Vec<i64> = (0..k).map(|_| rng.below(255) as i64 - 127).collect();
            let xq: Vec<i64> = (0..k).map(|_| rng.below(256) as i64).collect();
            let pos: i64 = w.iter().filter(|&&v| v > 0).sum();
            let neg: i64 = w.iter().filter(|&&v| v < 0).sum();
            let rs: i64 = w.iter().sum();
            let b = acc_bounds(&[pos], &[neg], &[rs], 0, 255);
            // random shuffled order via index draws without replacement
            let mut idx: Vec<usize> = (0..k).collect();
            for i in (1..k).rev() {
                idx.swap(i, rng.below(i + 1));
            }
            let mut partial = 0i64;
            for &i in &idx {
                partial += w[i] * xq[i];
                assert!(
                    partial >= b.lo.min(0) && partial <= b.hi.max(0),
                    "partial {partial} escapes raw bound [{}, {}] at k={k}",
                    b.lo,
                    b.hi
                );
                assert!(partial.abs() <= b.max_abs, "partial exceeds max_abs");
            }
        }
    }

    #[test]
    fn grid_bound_dominates_payload_bound() {
        let g8 = acc_bounds_grid(100, 8);
        let g4 = acc_bounds_grid(100, 4);
        assert!(g8.max_abs > g4.max_abs);
        assert!(headroom_bits(g8) > 0.0, "K=100 must be overflow-free at int8");
        // payload bounds can never exceed the grid worst case
        let b = acc_bounds(&[128 * 100], &[-128 * 100], &[0], 0, 255);
        assert!(b.max_abs <= g8.max_abs);
    }

    #[test]
    fn propagate_toy_graph_is_sane() {
        let g = Graph::parse(
            "qir t v1\noutputs r1\n\
             node input image inputs=- shape=1,4,4\n\
             node conv2d c1 inputs=image shape=2,4,4 bias=0 cin=1 cout=2 groups=1 kh=1 kw=1 pad=0 stride=1\n\
             node relu r1 inputs=c1 shape=2,4,4\n",
        )
        .unwrap();
        let mut ctx = BTreeMap::new();
        ctx.insert(
            "c1".to_string(),
            NodeCtx {
                affine: Some(AffineRows::from_weights(&[2.0, -1.0], 2, None)),
                ..Default::default()
            },
        );
        let cfg = PropagateCfg { input: Interval::new(-1.0, 1.0), ..Default::default() };
        let r = propagate(&g, &ctx, &cfg).unwrap();
        let c1 = r["c1"].out;
        assert!(c1.lo <= -2.0 && c1.hi >= 2.0 && c1.hi < 2.1, "{c1:?}");
        let r1 = r["r1"].out;
        assert!(r1.lo <= 0.0 && r1.lo > -0.01 && r1.hi >= 2.0, "{r1:?}");
    }
}
