//! QIR: the deployment graph IR, parsed from `.qir` text emitted by
//! `python/compile/ir.py`. This is what the simulated vendor compilers
//! (rust/src/backends) consume — a standard, ONNX-like op set with no custom
//! operators, exactly as the paper exports to its NPU toolchains.

pub mod analysis;
pub mod passes;

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One IR node. `attrs` are string-typed in the text format and accessed via
/// typed getters.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: String,
    pub name: String,
    pub inputs: Vec<String>,
    /// Shape excluding the batch dimension.
    pub shape: Vec<usize>,
    pub attrs: BTreeMap<String, String>,
}

impl Node {
    pub fn attr_usize(&self, key: &str) -> Result<usize> {
        self.attrs
            .get(key)
            .with_context(|| format!("node {}: missing attr {key}", self.name))?
            .parse()
            .with_context(|| format!("node {}: attr {key} not usize", self.name))
    }

    pub fn attr_bool(&self, key: &str) -> bool {
        matches!(self.attrs.get(key).map(|s| s.as_str()), Some("1") | Some("true"))
    }
}

#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub outputs: Vec<String>,
    index: HashMap<String, usize>,
}

impl Graph {
    pub fn parse(text: &str) -> Result<Graph> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().context("empty .qir")?;
        let hp: Vec<&str> = header.split_whitespace().collect();
        if hp.len() != 3 || hp[0] != "qir" || hp[2] != "v1" {
            bail!("bad .qir header: {header:?}");
        }
        let name = hp[1].to_string();
        let mut outputs = Vec::new();
        let mut nodes = Vec::new();
        let mut index = HashMap::new();
        for line in lines {
            let parts: Vec<&str> = line.split_whitespace().collect();
            match parts[0] {
                "outputs" => {
                    outputs = parts[1].split(',').map(|s| s.to_string()).collect();
                }
                "node" => {
                    if parts.len() < 5 {
                        bail!("malformed node line: {line:?}");
                    }
                    let kind = parts[1].to_string();
                    let nname = parts[2].to_string();
                    let mut inputs = Vec::new();
                    let mut shape = Vec::new();
                    let mut attrs = BTreeMap::new();
                    for kv in &parts[3..] {
                        let (k, v) = kv
                            .split_once('=')
                            .with_context(|| format!("bad attr {kv:?} in {line:?}"))?;
                        match k {
                            "inputs" => {
                                if v != "-" {
                                    inputs = v.split(',').map(|s| s.to_string()).collect();
                                }
                            }
                            "shape" => {
                                shape = v
                                    .split(',')
                                    .filter(|s| !s.is_empty())
                                    .map(|s| s.parse::<usize>().map_err(Into::into))
                                    .collect::<Result<Vec<_>>>()?;
                            }
                            _ => {
                                attrs.insert(k.to_string(), v.to_string());
                            }
                        }
                    }
                    index.insert(nname.clone(), nodes.len());
                    nodes.push(Node { kind, name: nname, inputs, shape, attrs });
                }
                other => bail!("unknown .qir line kind {other:?}"),
            }
        }
        if outputs.is_empty() {
            if let Some(last) = nodes.last() {
                outputs = vec![last.name.clone()];
            }
        }
        let g = Graph { name, nodes, outputs, index };
        g.validate()?;
        Ok(g)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Graph> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("read {:?}", path.as_ref()))?;
        Graph::parse(&text)
    }

    pub fn node(&self, name: &str) -> Option<&Node> {
        self.index.get(name).map(|&i| &self.nodes[i])
    }

    /// Every input reference must point at an already-defined node
    /// (the list is topologically ordered by construction).
    pub fn validate(&self) -> Result<()> {
        let mut seen: HashMap<&str, ()> = HashMap::new();
        for n in &self.nodes {
            for i in &n.inputs {
                if !seen.contains_key(i.as_str()) {
                    bail!("node {} references undefined input {}", n.name, i);
                }
            }
            seen.insert(&n.name, ());
        }
        for o in &self.outputs {
            if !seen.contains_key(o.as_str()) {
                bail!("graph output {o} undefined");
            }
        }
        Ok(())
    }

    /// Names of weight-bearing nodes (quantization targets).
    pub fn weight_nodes(&self) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| matches!(n.kind.as_str(), "conv2d" | "linear" | "attention"))
            .collect()
    }

    /// Per-node consumer counts (for liveness / arena reuse in the engine).
    pub fn consumer_counts(&self) -> HashMap<String, usize> {
        let mut counts: HashMap<String, usize> = HashMap::new();
        for n in &self.nodes {
            for i in &n.inputs {
                *counts.entry(i.clone()).or_insert(0) += 1;
            }
        }
        for o in &self.outputs {
            *counts.entry(o.clone()).or_insert(0) += 1;
        }
        counts
    }

    /// Total MACs per batch element for compute-bearing ops, used by the
    /// roofline performance model.
    pub fn node_macs(&self, n: &Node) -> u64 {
        match n.kind.as_str() {
            "conv2d" => {
                let (cout, ho, wo) = (n.shape[0], n.shape[1], n.shape[2]);
                let cin = n.attr_usize("cin").unwrap_or(1);
                let g = n.attr_usize("groups").unwrap_or(1);
                let kh = n.attr_usize("kh").unwrap_or(1);
                let kw = n.attr_usize("kw").unwrap_or(1);
                (cout * ho * wo * (cin / g) * kh * kw) as u64
            }
            "linear" => {
                let din = n.attr_usize("din").unwrap_or(1);
                let dout = n.attr_usize("dout").unwrap_or(1);
                let lead: usize = n.shape[..n.shape.len().saturating_sub(1)].iter().product();
                (lead.max(1) * din * dout) as u64
            }
            "attention" => {
                let d = n.attr_usize("d").unwrap_or(1);
                let t = n.shape[0];
                // 4 projections + 2 attention matmuls
                (4 * t * d * d + 2 * t * t * d) as u64
            }
            _ => 0,
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| self.node_macs(n)).sum()
    }

    /// Bytes of activation traffic per batch element (rough: out tensor f32).
    pub fn node_out_bytes(&self, n: &Node) -> u64 {
        4 * n.shape.iter().product::<usize>() as u64
    }

    /// Weight elements a node streams per pass (0 for weightless ops).
    /// The perf model multiplies this by the deployment's bytes-per-weight —
    /// the term sub-byte (INT4) packing halves.
    pub fn node_weight_elems(&self, n: &Node) -> u64 {
        match n.kind.as_str() {
            "conv2d" => {
                let cout = n.attr_usize("cout").unwrap_or(n.shape[0]);
                let cin = n.attr_usize("cin").unwrap_or(1);
                let g = n.attr_usize("groups").unwrap_or(1);
                let kh = n.attr_usize("kh").unwrap_or(1);
                let kw = n.attr_usize("kw").unwrap_or(1);
                (cout * (cin / g.max(1)) * kh * kw) as u64
            }
            "linear" => {
                let din = n.attr_usize("din").unwrap_or(1);
                let dout = n.attr_usize("dout").unwrap_or(1);
                (din * dout) as u64
            }
            "attention" => {
                let d = n.attr_usize("d").unwrap_or(1);
                (4 * d * d) as u64
            }
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DEMO: &str = "qir demo v1\noutputs head\n\
        node input image inputs=- shape=3,8,8\n\
        node conv2d c1 inputs=image shape=4,8,8 bias=0 cin=3 cout=4 groups=1 kh=3 kw=3 pad=1 stride=1\n\
        node relu r1 inputs=c1 shape=4,8,8\n\
        node gap g1 inputs=r1 shape=4,1,1\n\
        node flatten f1 inputs=g1 shape=4\n\
        node linear head inputs=f1 shape=10 bias=1 din=4 dout=10\n";

    #[test]
    fn parse_demo() {
        let g = Graph::parse(DEMO).unwrap();
        assert_eq!(g.name, "demo");
        assert_eq!(g.nodes.len(), 6);
        assert_eq!(g.outputs, vec!["head"]);
        assert_eq!(g.node("c1").unwrap().attr_usize("cout").unwrap(), 4);
        assert_eq!(g.weight_nodes().len(), 2);
    }

    #[test]
    fn macs_accounting() {
        let g = Graph::parse(DEMO).unwrap();
        let c1 = g.node("c1").unwrap();
        assert_eq!(g.node_macs(c1), (4 * 8 * 8 * 3 * 3 * 3) as u64);
        let head = g.node("head").unwrap();
        assert_eq!(g.node_macs(head), 40);
    }

    #[test]
    fn undefined_input_rejected() {
        let bad = "qir x v1\noutputs a\nnode relu a inputs=ghost shape=1\n";
        assert!(Graph::parse(bad).is_err());
    }
}
