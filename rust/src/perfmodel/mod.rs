//! Roofline latency / power / energy models for the edge-device fleet
//! (paper Tables 5, 6, 10; Figs 3, 7, 11).
//!
//! Per-op time = max(compute_time, memory_time) + fixed per-op overhead;
//! add-in cards pay PCIe transfer for input/output, unsupported ops fall back
//! to the host with a synchronization penalty. Power = idle + (peak - idle) *
//! sustained utilization. Absolute numbers are *modelled*, not measured — the
//! shapes (who wins, by what factor) are what we reproduce; see DESIGN.md §2.

use crate::qir::Graph;

/// How activation ranges are obtained at inference time (paper Table 4
/// "Act. scaling @ inference") — an axis of the perf model because on-the-fly
/// range computation has its own per-node cost.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ActScaling {
    /// Compile-time ranges (calibration or embedded QAT scales) baked into
    /// the deployment; zero runtime overhead.
    #[default]
    Static,
    /// Per-tensor (lo, hi) recomputed from the live batch at every
    /// quantization point; costs an extra activation read (the range scan)
    /// plus a reduction/sync per node on integer deployments.
    Dynamic,
}

impl ActScaling {
    /// Human-readable cell label ("static" / "dynamic").
    pub fn label(self) -> &'static str {
        match self {
            ActScaling::Static => "static",
            ActScaling::Dynamic => "dynamic",
        }
    }
}

/// Numeric precision of a compiled deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// W4/A8: nibble-packed weights, u8 static activations.
    Int4,
    Int8,
    Bf16,
    Fp16,
    Fp32,
}

impl Precision {
    pub fn label(self) -> &'static str {
        match self {
            Precision::Int4 => "INT4",
            Precision::Int8 => "INT8",
            Precision::Bf16 => "BF16",
            Precision::Fp16 => "FP16",
            Precision::Fp32 => "FP32",
        }
    }

    /// Bytes per *activation* element in flight at this deployment
    /// precision. INT4 deployments keep u8 activations (W4/A8) — only the
    /// weights go sub-byte.
    pub fn bytes(self) -> f64 {
        match self {
            Precision::Int4 | Precision::Int8 => 1.0,
            Precision::Bf16 | Precision::Fp16 => 2.0,
            Precision::Fp32 => 4.0,
        }
    }

    /// Bytes per *weight* element streamed from memory. This is where the
    /// sub-byte win lives: INT4 halves weight traffic vs INT8, and the
    /// W8/ABF16 hybrid keeps i8 weights under bf16 activations.
    pub fn weight_bytes(self) -> f64 {
        match self {
            Precision::Int4 => 0.5,
            Precision::Int8 | Precision::Bf16 => 1.0,
            Precision::Fp16 => 2.0,
            Precision::Fp32 => 4.0,
        }
    }
}

/// Device capability sheet (paper Table 6 + A.1/A.2 descriptions).
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub form_factor: &'static str,
    pub link: &'static str,
    /// Peak TOPS per precision; 0.0 = unsupported on this device.
    /// Sub-byte (INT4) MAC arrays; 0.0 = no native int4 kernels.
    pub tops_int4: f64,
    pub tops_int8: f64,
    pub tflops_bf16: f64,
    pub tflops_fp16: f64,
    pub tflops_fp32: f64,
    /// Sustained fraction of peak the compiler's kernels reach.
    pub efficiency: f64,
    pub mem_bw_gbs: f64,
    /// PCIe/USB transfer bandwidth for add-in cards; None = unified memory.
    pub pcie_gbs: Option<f64>,
    pub idle_w: f64,
    pub peak_w: f64,
    pub price_eur: f64,
    /// Fixed per-op scheduling overhead (us). SoC runtimes are leaner than
    /// host-dispatched add-in cards.
    pub op_overhead_us: f64,
    /// Penalty for a host-fallback subgraph (ms) — sync + copies.
    pub fallback_ms: f64,
}

impl DeviceSpec {
    pub fn peak_ops(&self, p: Precision) -> f64 {
        match p {
            Precision::Int4 => self.tops_int4 * 1e12,
            Precision::Int8 => self.tops_int8 * 1e12,
            Precision::Bf16 => self.tflops_bf16 * 1e12,
            Precision::Fp16 => self.tflops_fp16 * 1e12,
            Precision::Fp32 => self.tflops_fp32 * 1e12,
        }
    }

    pub fn supports(&self, p: Precision) -> bool {
        self.peak_ops(p) > 0.0
    }
}

/// Modelled execution report for one compiled graph at one precision.
#[derive(Clone, Debug)]
pub struct PerfReport {
    pub latency_ms: f64,
    pub fps: f64,
    pub avg_power_w: f64,
    pub peak_power_w: f64,
    pub energy_mj_per_inf: f64,
    pub utilization: f64,
    pub fallback_ops: usize,
}

/// Estimate one inference (batch elements amortize per-op overhead) under
/// static activation scaling. See [`estimate_scaled`] for the dynamic-scaling
/// variant.
///
/// `runtime_boost`: TensorRT-style compiled runtimes fuse + autotune,
/// modelled as a multiplier (>1) on sustained efficiency; naive CUDA-kernel
/// dispatch is 1.0 (paper Fig 3 "filled vs unfilled markers").
pub fn estimate(
    graph: &Graph,
    dev: &DeviceSpec,
    prec: Precision,
    batch: usize,
    runtime_boost: f64,
    unsupported: &dyn Fn(&str) -> bool,
) -> PerfReport {
    estimate_scaled(graph, dev, prec, ActScaling::Static, batch, runtime_boost, unsupported)
}

/// [`estimate`] with the activation-scaling axis exposed. Under
/// [`ActScaling::Dynamic`] on an integer deployment, every on-device node
/// pays a **dynamic-scaling overhead term**: the range reduction re-reads
/// the node's output activation at memory bandwidth, and the reduced
/// (lo, hi) must be synchronized with the requantization stage before it can
/// start — modelled as half an op dispatch. Float-activation precisions have
/// no requantization points, so the term is zero there.
#[allow(clippy::too_many_arguments)]
pub fn estimate_scaled(
    graph: &Graph,
    dev: &DeviceSpec,
    prec: Precision,
    scaling: ActScaling,
    batch: usize,
    runtime_boost: f64,
    unsupported: &dyn Fn(&str) -> bool,
) -> PerfReport {
    estimate_audited(graph, dev, prec, scaling, batch, runtime_boost, unsupported, &|_| false)
}

/// [`estimate_scaled`] with the static auditor's findings folded in:
/// `flagged` receives each node *name* and returns true for layers the plan
/// auditor marked as saturation / accumulator-headroom risks
/// (`engine::verify` — low headroom, requant clipping, scale inflation).
/// A flagged integer layer pays a **headroom mitigation term**: the runtime
/// splits its accumulation (or inserts an extra rescale pass) to keep the
/// i32 accumulator in range, modelled like the dynamic-scaling term as one
/// extra output-activation pass at memory bandwidth plus half an op
/// dispatch for the rescale stage. Float deployments have no integer
/// accumulators, so the term is zero there.
#[allow(clippy::too_many_arguments)]
pub fn estimate_audited(
    graph: &Graph,
    dev: &DeviceSpec,
    prec: Precision,
    scaling: ActScaling,
    batch: usize,
    runtime_boost: f64,
    unsupported: &dyn Fn(&str) -> bool,
    flagged: &dyn Fn(&str) -> bool,
) -> PerfReport {
    let peak = dev.peak_ops(prec).max(1e9);
    let integer_prec = matches!(prec, Precision::Int4 | Precision::Int8);
    let dynamic_act = scaling == ActScaling::Dynamic && integer_prec;
    let eff = (dev.efficiency * runtime_boost).min(0.95);
    let mut compute_s = 0.0f64;
    let mut busy_s = 0.0f64;
    let mut fallback_ops = 0usize;
    let bytes_per = prec.bytes();
    let w_bytes_per = prec.weight_bytes();
    for n in &graph.nodes {
        let macs = graph.node_macs(n) as f64 * batch as f64;
        // activation traffic scales with batch; weight traffic is streamed
        // once per pass whatever the batch (this is the term sub-byte
        // weights halve — the INT4 memory-bandwidth win)
        let bytes = graph.node_out_bytes(n) as f64 / 4.0 * bytes_per * batch as f64
            + graph.node_weight_elems(n) as f64 * w_bytes_per;
        if unsupported(&n.kind) {
            fallback_ops += 1;
            // runs on host fp32 at a fraction of device speed + sync penalty
            let host_time = macs * 2.0 / (50e9) + dev.fallback_ms / 1e3;
            busy_s += host_time;
            continue;
        }
        let ct = macs * 2.0 / (peak * eff);
        let mt = bytes / (dev.mem_bw_gbs * 1e9);
        compute_s += ct;
        // compiled runtimes (TensorRT) fuse ops: fewer launches -> less overhead
        busy_s += ct.max(mt) + dev.op_overhead_us / runtime_boost / 1e6;
        if dynamic_act {
            // per-node dynamic-scaling overhead: re-read the output
            // activation for the range scan + half a dispatch to sync the
            // reduced (lo, hi) into the requantization stage
            let act_bytes = graph.node_out_bytes(n) as f64 / 4.0 * bytes_per * batch as f64;
            busy_s += act_bytes / (dev.mem_bw_gbs * 1e9)
                + 0.5 * dev.op_overhead_us / runtime_boost / 1e6;
        }
        if integer_prec && flagged(&n.name) {
            // headroom mitigation for auditor-flagged layers: one extra
            // pass over the layer output (split accumulation / rescale)
            // plus half a dispatch for the inserted stage
            let act_bytes = graph.node_out_bytes(n) as f64 / 4.0 * bytes_per * batch as f64;
            busy_s += act_bytes / (dev.mem_bw_gbs * 1e9)
                + 0.5 * dev.op_overhead_us / runtime_boost / 1e6;
        }
    }
    // add-in cards: PCIe in/out per inference (inputs ship at the deployment
    // precision — INT8 engines take quantized u8 frames from the host)
    if let Some(pcie) = dev.pcie_gbs {
        let in_bytes = graph
            .nodes
            .first()
            .map(|n| graph.node_out_bytes(n) as f64 / 4.0 * bytes_per * batch as f64)
            .unwrap_or(0.0);
        let out_bytes: f64 = graph
            .outputs
            .iter()
            .filter_map(|o| graph.node(o))
            .map(|n| graph.node_out_bytes(n) as f64 * batch as f64)
            .sum();
        busy_s += (in_bytes + out_bytes) / (pcie * 1e9);
    }
    let latency_s = busy_s.max(1e-9);
    let util = (compute_s / latency_s).clamp(0.02, 1.0);
    let avg_power = dev.idle_w + (dev.peak_w - dev.idle_w) * util;
    let peak_power = dev.idle_w + (dev.peak_w - dev.idle_w) * util.sqrt();
    let fps = batch as f64 / latency_s;
    PerfReport {
        latency_ms: latency_s * 1e3,
        fps,
        avg_power_w: avg_power,
        peak_power_w: peak_power,
        energy_mj_per_inf: avg_power * latency_s / batch as f64 * 1e3,
        utilization: util,
        fallback_ops,
    }
}

/// Throughput multiplier the host engine's kernel tier (`engine::simd`)
/// contributes over the scalar tier, by deployment precision. Analytic, not
/// measured: the AVX2 integer path retires 16 u8×i8 MACs per
/// `_mm256_madd_epi16` step against the scalar kernel's 1, but epilogue,
/// packing and memory traffic keep the realizable win near half the lane
/// count; the f32 panels only vectorize 4-wide across panel lanes. NEON is
/// 128-bit, so half the AVX2 ratios. Deliberately NOT folded into
/// `estimate_audited`'s committed host-fallback constants — those tables
/// must stay machine-independent; this term is for live what-if queries
/// against the tier the local plan actually resolved.
pub fn tier_boost(tier: crate::engine::KernelTier, p: Precision) -> f64 {
    use crate::engine::KernelTier;
    match (tier, p) {
        (KernelTier::Scalar, _) => 1.0,
        (KernelTier::Avx2, Precision::Int4 | Precision::Int8) => 8.0,
        (KernelTier::Avx2, _) => 4.0,
        (KernelTier::Neon, Precision::Int4 | Precision::Int8) => 4.0,
        (KernelTier::Neon, _) => 2.0,
    }
}

/// Tiled inference cost for large images (paper Fig 7 / Table 10: 512x512
/// tiles, 50% overlap => stride 256).
pub fn tiles_for(image_px: usize, tile: usize, overlap_frac: f64) -> usize {
    let stride = ((tile as f64) * (1.0 - overlap_frac)) as usize;
    let per_axis = if image_px <= tile { 1 } else { (image_px - tile).div_ceil(stride) + 1 };
    per_axis * per_axis
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qir::Graph;

    fn toy_graph() -> Graph {
        Graph::parse(
            "qir t v1\noutputs head\n\
             node input image inputs=- shape=3,32,32\n\
             node conv2d c1 inputs=image shape=16,32,32 bias=0 cin=3 cout=16 groups=1 kh=3 kw=3 pad=1 stride=1\n\
             node relu r1 inputs=c1 shape=16,32,32\n\
             node gap g1 inputs=r1 shape=16,1,1\n\
             node flatten f1 inputs=g1 shape=16\n\
             node linear head inputs=f1 shape=10 bias=1 din=16 dout=10\n",
        )
        .unwrap()
    }

    fn dev() -> DeviceSpec {
        DeviceSpec {
            name: "test",
            form_factor: "M.2",
            link: "PCIe",
            tops_int4: 52.0,
            tops_int8: 26.0,
            tflops_bf16: 0.0,
            tflops_fp16: 2.0,
            tflops_fp32: 1.0,
            efficiency: 0.4,
            mem_bw_gbs: 20.0,
            pcie_gbs: Some(2.0),
            idle_w: 1.0,
            peak_w: 5.0,
            price_eur: 150.0,
            op_overhead_us: 10.0,
            fallback_ms: 2.0,
        }
    }

    #[test]
    fn tier_boost_is_monotone_and_scalar_neutral() {
        use crate::engine::KernelTier;
        for p in
            [Precision::Int4, Precision::Int8, Precision::Bf16, Precision::Fp16, Precision::Fp32]
        {
            assert_eq!(tier_boost(KernelTier::Scalar, p), 1.0, "{p:?}");
            for t in [KernelTier::Avx2, KernelTier::Neon] {
                assert!(tier_boost(t, p) > 1.0, "{t:?} {p:?} must beat scalar");
                assert!(tier_boost(t, p) <= 16.0, "{t:?} {p:?} exceeds lane count");
            }
            // 256-bit lanes cannot be slower than 128-bit ones
            assert!(tier_boost(KernelTier::Avx2, p) >= tier_boost(KernelTier::Neon, p));
        }
        // the integer paths vectorize wider than the f32 panels
        assert!(
            tier_boost(KernelTier::Avx2, Precision::Int8)
                > tier_boost(KernelTier::Avx2, Precision::Fp32)
        );
    }

    #[test]
    fn int8_faster_than_fp32() {
        let g = toy_graph();
        let d = dev();
        let r8 = estimate(&g, &d, Precision::Int8, 1, 1.0, &|_| false);
        let r32 = estimate(&g, &d, Precision::Fp32, 1, 1.0, &|_| false);
        assert!(r8.fps > r32.fps, "{} vs {}", r8.fps, r32.fps);
        assert!(r8.energy_mj_per_inf < r32.energy_mj_per_inf);
    }

    #[test]
    fn int4_beats_int8_on_supporting_device() {
        // double MAC rate + half the weight traffic: the INT4 deployment of
        // the same graph must model faster and cheaper per inference
        let g = toy_graph();
        let d = dev();
        let r4 = estimate(&g, &d, Precision::Int4, 1, 1.0, &|_| false);
        let r8 = estimate(&g, &d, Precision::Int8, 1, 1.0, &|_| false);
        assert!(r4.fps >= r8.fps, "{} vs {}", r4.fps, r8.fps);
        assert!(r4.energy_mj_per_inf <= r8.energy_mj_per_inf);
        // a device without int4 MAC arrays models it as (slow) 1 GOPS floor
        let mut no4 = dev();
        no4.tops_int4 = 0.0;
        assert!(!no4.supports(Precision::Int4));
    }

    #[test]
    fn dynamic_scaling_costs_latency_on_integer_deployments() {
        let g = toy_graph();
        let d = dev();
        for p in [Precision::Int8, Precision::Int4] {
            let st = estimate_scaled(&g, &d, p, ActScaling::Static, 1, 1.0, &|_| false);
            let dy = estimate_scaled(&g, &d, p, ActScaling::Dynamic, 1, 1.0, &|_| false);
            assert!(
                dy.latency_ms > st.latency_ms,
                "{p:?}: dynamic must pay the range-scan term ({} vs {})",
                dy.latency_ms,
                st.latency_ms
            );
            assert!(dy.energy_mj_per_inf >= st.energy_mj_per_inf);
        }
        // static path through estimate() is the estimate_scaled(Static) path
        let st = estimate(&g, &d, Precision::Int8, 1, 1.0, &|_| false);
        let st2 = estimate_scaled(&g, &d, Precision::Int8, ActScaling::Static, 1, 1.0, &|_| false);
        assert_eq!(st.latency_ms, st2.latency_ms);
    }

    #[test]
    fn dynamic_scaling_is_free_on_float_deployments() {
        // no integer requantization points -> no range scans to pay for
        let g = toy_graph();
        let d = dev();
        for p in [Precision::Fp16, Precision::Fp32] {
            let st = estimate_scaled(&g, &d, p, ActScaling::Static, 1, 1.0, &|_| false);
            let dy = estimate_scaled(&g, &d, p, ActScaling::Dynamic, 1, 1.0, &|_| false);
            assert_eq!(st.latency_ms, dy.latency_ms, "{p:?}");
        }
    }

    #[test]
    fn audited_headroom_term_costs_latency_only_when_flagged() {
        let g = toy_graph();
        let d = dev();
        for p in [Precision::Int8, Precision::Int4] {
            let clean = estimate_scaled(&g, &d, p, ActScaling::Static, 1, 1.0, &|_| false);
            let none = estimate_audited(
                &g,
                &d,
                p,
                ActScaling::Static,
                1,
                1.0,
                &|_| false,
                &|_| false,
            );
            assert_eq!(clean.latency_ms, none.latency_ms, "{p:?}: no flags == estimate_scaled");
            let flagged = estimate_audited(
                &g,
                &d,
                p,
                ActScaling::Static,
                1,
                1.0,
                &|_| false,
                &|name| name == "c1",
            );
            assert!(
                flagged.latency_ms > none.latency_ms,
                "{p:?}: flagged layer must pay the mitigation term ({} vs {})",
                flagged.latency_ms,
                none.latency_ms
            );
        }
        // float deployments carry no integer accumulators -> term is free
        let clean =
            estimate_scaled(&g, &d, Precision::Fp16, ActScaling::Static, 1, 1.0, &|_| false);
        let flagged = estimate_audited(
            &g,
            &d,
            Precision::Fp16,
            ActScaling::Static,
            1,
            1.0,
            &|_| false,
            &|_| true,
        );
        assert_eq!(clean.latency_ms, flagged.latency_ms);
    }

    #[test]
    fn runtime_boost_helps() {
        let g = toy_graph();
        let d = dev();
        let naive = estimate(&g, &d, Precision::Fp16, 1, 1.0, &|_| false);
        let trt = estimate(&g, &d, Precision::Fp16, 1, 2.0, &|_| false);
        assert!(trt.fps > naive.fps);
    }

    #[test]
    fn fallback_hurts_latency() {
        let g = toy_graph();
        let d = dev();
        let clean = estimate(&g, &d, Precision::Int8, 1, 1.0, &|_| false);
        let fallback = estimate(&g, &d, Precision::Int8, 1, 1.0, &|k| k == "linear");
        assert!(fallback.latency_ms > clean.latency_ms + 1.0);
        assert_eq!(fallback.fallback_ops, 1);
    }

    #[test]
    fn power_between_idle_and_peak() {
        let g = toy_graph();
        let d = dev();
        for p in [Precision::Int8, Precision::Fp16, Precision::Fp32] {
            let r = estimate(&g, &d, p, 8, 1.0, &|_| false);
            assert!(r.avg_power_w >= d.idle_w && r.avg_power_w <= d.peak_w);
            assert!(r.peak_power_w >= r.avg_power_w);
        }
    }

    #[test]
    fn tile_math_matches_paper() {
        // paper Table 10: 2k x 2k image, 512 tiles, 50% overlap -> 50 tiles
        // ceil((2000-512)/256)+1 = 7 per axis -> 49 (paper says ~50)
        let t = tiles_for(2000, 512, 0.5);
        assert!((45..=56).contains(&t), "{t}");
        assert_eq!(tiles_for(512, 512, 0.5), 1);
        assert_eq!(tiles_for(1024, 512, 0.5), 9);
    }
}
