//! Hand-rolled HTTP/1.1 wire layer for the cluster tier (std-only — the
//! offline vendor set has no tokio/axum/hyper).
//!
//! One protocol everywhere: the router's public front door, node-internal
//! forwarding, registration/heartbeat, and `/metrics`/`/state` scraping all
//! speak the same minimal HTTP/1.1 subset, so the parser here is exercised
//! by every cluster interaction (and adversarially in
//! `rust/tests/http_wire.rs`).
//!
//! The parser is **total**: any byte stream yields either a well-formed
//! [`HttpRequest`] or a typed [`WireError`] carrying the status the server
//! should answer with (400 malformed / 431 oversized headers / 413 oversized
//! body) — never a panic and never an unbounded read. Limits:
//!
//! * request line <= [`MAX_REQUEST_LINE`] bytes (431)
//! * <= [`MAX_HEADERS`] headers, each line <= [`MAX_HEADER_LINE`] bytes (431)
//! * body (`Content-Length`) <= [`MAX_BODY`] bytes (413)
//!
//! Pipelining falls out of the design: [`read_request`] consumes exactly one
//! request from a `BufRead`, so a keep-alive loop reads back-to-back
//! requests off one connection. Tensors travel as a little-endian binary
//! body ([`encode_tensor`] / [`decode_tensor`]) — no JSON on the data path.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Longest accepted `METHOD SP PATH SP VERSION` line, bytes (431 beyond).
pub const MAX_REQUEST_LINE: usize = 4096;
/// Longest accepted single header line, bytes (431 beyond).
pub const MAX_HEADER_LINE: usize = 4096;
/// Maximum accepted header count per request (431 beyond).
pub const MAX_HEADERS: usize = 64;
/// Maximum accepted `Content-Length` in bytes (413 beyond).
pub const MAX_BODY: usize = 64 << 20;

/// Why a request could not be parsed, carrying the HTTP status a server
/// should answer before closing the connection.
#[derive(Debug)]
pub enum WireError {
    /// Malformed request (bad request line, bad header syntax, truncated
    /// stream mid-request, invalid Content-Length) — answer 400.
    Malformed(String),
    /// Request line or header section beyond the fixed limits — answer 431.
    HeadersTooLarge(String),
    /// Declared body beyond [`MAX_BODY`] — answer 413.
    BodyTooLarge(usize),
    /// Transport error (timeout, reset). No answer is possible; close.
    Io(std::io::Error),
}

impl WireError {
    /// HTTP status this parse failure should be answered with (0 = none:
    /// transport is gone).
    pub fn status(&self) -> u16 {
        match self {
            WireError::Malformed(_) => 400,
            WireError::HeadersTooLarge(_) => 431,
            WireError::BodyTooLarge(_) => 413,
            WireError::Io(_) => 0,
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Malformed(m) => write!(f, "malformed request: {m}"),
            WireError::HeadersTooLarge(m) => write!(f, "headers too large: {m}"),
            WireError::BodyTooLarge(n) => write!(f, "declared body of {n} bytes exceeds limit"),
            WireError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

/// One parsed HTTP/1.1 request. Header names are lower-cased at parse time;
/// the query string is split into raw `k=v` pairs (no percent-decoding — the
/// cluster's identifiers never need it).
#[derive(Debug)]
pub struct HttpRequest {
    /// Verb as sent (`GET`, `POST`, ...), upper-cased token.
    pub method: String,
    /// Path without the query string, e.g. `/infer`.
    pub path: String,
    /// Raw query parameters in order of appearance (later keys win in
    /// [`HttpRequest::query`]).
    pub query_pairs: Vec<(String, String)>,
    /// Headers, names lower-cased, values trimmed.
    pub headers: BTreeMap<String, String>,
    /// Request body (empty when no `Content-Length`).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Last value of query parameter `name`, if present.
    pub fn query(&self, name: &str) -> Option<&str> {
        self.query_pairs.iter().rev().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Header value by lower-case name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    /// Did the client ask to keep the connection open? HTTP/1.1 defaults to
    /// keep-alive unless `Connection: close` is sent.
    pub fn keep_alive(&self) -> bool {
        !self.header("connection").is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Read one CRLF- (or bare-LF-) terminated line, erroring past `limit`
/// bytes. `Ok(None)` = clean EOF before any byte of this line.
fn read_limited_line(
    r: &mut impl BufRead,
    limit: usize,
    what: &str,
) -> Result<Option<String>, WireError> {
    let mut line: Vec<u8> = Vec::with_capacity(128);
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(WireError::Malformed(format!("connection closed mid-{what}")));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return match String::from_utf8(line) {
                        Ok(s) => Ok(Some(s)),
                        Err(_) => Err(WireError::Malformed(format!("non-UTF8 {what}"))),
                    };
                }
                line.push(byte[0]);
                if line.len() > limit {
                    return Err(WireError::HeadersTooLarge(format!(
                        "{what} exceeds {limit} bytes"
                    )));
                }
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
}

/// Split `/path?a=1&b=2` into the path and its raw query pairs.
fn split_target(target: &str) -> (String, Vec<(String, String)>) {
    match target.split_once('?') {
        None => (target.to_string(), Vec::new()),
        Some((path, qs)) => {
            let pairs = qs
                .split('&')
                .filter(|p| !p.is_empty())
                .map(|p| match p.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (p.to_string(), String::new()),
                })
                .collect();
            (path.to_string(), pairs)
        }
    }
}

/// Parse exactly one HTTP/1.1 request off `r`. `Ok(None)` = the peer closed
/// cleanly before sending anything (normal end of a keep-alive connection).
/// Every malformed, oversized, or truncated input comes back as a typed
/// [`WireError`] — this function never panics and never reads past the
/// declared body.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<HttpRequest>, WireError> {
    let Some(request_line) = read_limited_line(r, MAX_REQUEST_LINE, "request line")? else {
        return Ok(None);
    };
    let mut parts = request_line.split(' ');
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if !m.is_empty() && !t.is_empty() => (m, t, v),
        _ => {
            return Err(WireError::Malformed(format!(
                "bad request line {request_line:?} (want `METHOD SP TARGET SP VERSION`)"
            )))
        }
    };
    if !method.bytes().all(|b| b.is_ascii_alphanumeric()) {
        return Err(WireError::Malformed(format!("bad method token {method:?}")));
    }
    if !version.starts_with("HTTP/1.") {
        return Err(WireError::Malformed(format!("unsupported version {version:?}")));
    }
    if !target.starts_with('/') {
        return Err(WireError::Malformed(format!("target {target:?} must be origin-form")));
    }
    let mut headers = BTreeMap::new();
    loop {
        let Some(line) = read_limited_line(r, MAX_HEADER_LINE, "header")? else {
            return Err(WireError::Malformed("connection closed inside headers".into()));
        };
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(WireError::HeadersTooLarge(format!(">{MAX_HEADERS} headers")));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(WireError::Malformed(format!("header line {line:?} has no colon")));
        };
        if name.is_empty() || name.contains(' ') {
            return Err(WireError::Malformed(format!("bad header name {name:?}")));
        }
        headers.insert(name.to_ascii_lowercase(), value.trim().to_string());
    }
    let body = match headers.get("content-length") {
        None => Vec::new(),
        Some(v) => {
            let n: usize = v
                .parse()
                .map_err(|_| WireError::Malformed(format!("bad Content-Length {v:?}")))?;
            if n > MAX_BODY {
                return Err(WireError::BodyTooLarge(n));
            }
            let mut body = vec![0u8; n];
            r.read_exact(&mut body).map_err(|e| {
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    WireError::Malformed(format!("connection closed inside {n}-byte body"))
                } else {
                    WireError::Io(e)
                }
            })?;
            body
        }
    };
    let (path, query_pairs) = split_target(target);
    Ok(Some(HttpRequest {
        method: method.to_ascii_uppercase(),
        path,
        query_pairs,
        headers,
        body,
    }))
}

/// Canonical reason phrase for the statuses the cluster emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one HTTP/1.1 response (status + extra headers + body). Always sends
/// `Content-Length`; `Connection: close` is sent when `keep_alive` is false.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        reason(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    if !keep_alive {
        head.push_str("Connection: close\r\n");
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// A parsed HTTP response (client side of [`http_call`]).
#[derive(Debug)]
pub struct HttpResponse {
    /// Status code from the status line.
    pub status: u16,
    /// Headers, names lower-cased.
    pub headers: BTreeMap<String, String>,
    /// Response body (sized by `Content-Length`).
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// Header value by lower-case name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(name).map(|s| s.as_str())
    }

    /// Body as UTF-8 (lossy) — convenient for text endpoints.
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Parse one HTTP/1.1 response off `r` (status line, headers,
/// `Content-Length` body). Same limits as the request parser.
pub fn read_http_response(r: &mut impl BufRead) -> Result<HttpResponse> {
    let status_line = read_limited_line(r, MAX_REQUEST_LINE, "status line")
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .ok_or_else(|| anyhow::anyhow!("peer closed before a status line"))?;
    let mut parts = status_line.split(' ');
    let (version, code) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    if !version.starts_with("HTTP/1.") {
        bail!("bad response status line {status_line:?}");
    }
    let status: u16 = code.parse().map_err(|_| anyhow::anyhow!("bad status code {code:?}"))?;
    let mut headers = BTreeMap::new();
    loop {
        let line = read_limited_line(r, MAX_HEADER_LINE, "response header")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .ok_or_else(|| anyhow::anyhow!("peer closed inside response headers"))?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            bail!("response carries more than {MAX_HEADERS} headers");
        }
        let Some((name, value)) = line.split_once(':') else {
            bail!("bad response header line {line:?}");
        };
        headers.insert(name.to_ascii_lowercase(), value.trim().to_string());
    }
    let body = match headers.get("content-length") {
        None => Vec::new(),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| anyhow::anyhow!("bad Content-Length {v:?}"))?;
            if n > MAX_BODY {
                bail!("response body of {n} bytes exceeds limit");
            }
            let mut body = vec![0u8; n];
            r.read_exact(&mut body)?;
            body
        }
    };
    Ok(HttpResponse { status, headers, body })
}

/// One-shot HTTP call over a fresh connection: connect (with timeout), send
/// `method target` plus headers/body, read the response, close. The cluster
/// uses one-shot connections internally (`Connection: close`), keeping node
/// drain deterministic — no idle keep-alive connections to wait out.
pub fn http_call(
    addr: SocketAddr,
    method: &str,
    target: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    timeout: Duration,
) -> Result<HttpResponse> {
    let stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;
    let mut head = format!("{method} {target} HTTP/1.1\r\nHost: {addr}\r\n");
    for (k, v) in extra_headers {
        head.push_str(k);
        head.push_str(": ");
        head.push_str(v);
        head.push_str("\r\n");
    }
    head.push_str(&format!("Content-Length: {}\r\nConnection: close\r\n\r\n", body.len()));
    let mut w = &stream;
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()?;
    let mut reader = BufReader::new(&stream);
    read_http_response(&mut reader)
}

// ---------------------------------------------------------------------------
// Tensor body codec
// ---------------------------------------------------------------------------

/// Most dimensions a wire tensor may carry.
pub const MAX_TENSOR_DIMS: usize = 8;
/// Most elements a wire tensor may carry (64M floats = 256 MiB).
pub const MAX_TENSOR_ELEMS: usize = 1 << 26;

/// Encode a tensor as a little-endian binary body:
/// `ndim: u32 | dims: u32 * ndim | data: f32 * prod(dims)`.
pub fn encode_tensor(t: &Tensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + 4 * t.shape.len() + 4 * t.data.len());
    out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
    for &d in &t.shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode [`encode_tensor`]'s format, validating dims, element count, and
/// exact body length. Total: any byte slice yields a tensor or an error.
pub fn decode_tensor(bytes: &[u8]) -> Result<Tensor> {
    let take_u32 = |at: usize| -> Result<u32> {
        let end = at.checked_add(4).filter(|&e| e <= bytes.len());
        let end = end.ok_or_else(|| anyhow::anyhow!("tensor body truncated at byte {at}"))?;
        Ok(u32::from_le_bytes(bytes[at..end].try_into().expect("4-byte slice")))
    };
    let ndim = take_u32(0)? as usize;
    if ndim == 0 || ndim > MAX_TENSOR_DIMS {
        bail!("tensor ndim {ndim} outside 1..={MAX_TENSOR_DIMS}");
    }
    let mut shape = Vec::with_capacity(ndim);
    let mut elems: usize = 1;
    for i in 0..ndim {
        let d = take_u32(4 + 4 * i)? as usize;
        if d == 0 {
            bail!("tensor dimension {i} is zero");
        }
        elems = elems
            .checked_mul(d)
            .filter(|&e| e <= MAX_TENSOR_ELEMS)
            .ok_or_else(|| anyhow::anyhow!("tensor element count exceeds {MAX_TENSOR_ELEMS}"))?;
        shape.push(d);
    }
    let data_at = 4 + 4 * ndim;
    let want = data_at + 4 * elems;
    if bytes.len() != want {
        bail!("tensor body is {} bytes, shape {shape:?} needs exactly {want}", bytes.len());
    }
    let data = bytes[data_at..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4-byte chunk")))
        .collect();
    Ok(Tensor::new(shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(bytes: &[u8]) -> Result<Option<HttpRequest>, WireError> {
        read_request(&mut Cursor::new(bytes.to_vec()))
    }

    #[test]
    fn parses_a_minimal_get() {
        let req = parse(b"GET /state HTTP/1.1\r\nHost: x\r\n\r\n").unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/state");
        assert!(req.query_pairs.is_empty());
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(req.keep_alive());
    }

    #[test]
    fn parses_query_and_body() {
        let req = parse(b"POST /infer?deployment=npu&key=k7 HTTP/1.1\r\nContent-Length: 3\r\nConnection: close\r\n\r\nabc")
            .unwrap()
            .unwrap();
        assert_eq!(req.path, "/infer");
        assert_eq!(req.query("deployment"), Some("npu"));
        assert_eq!(req.query("key"), Some("k7"));
        assert_eq!(req.body, b"abc");
        assert!(!req.keep_alive());
    }

    #[test]
    fn bare_lf_lines_are_accepted() {
        let req = parse(b"GET / HTTP/1.1\nHost: x\n\n").unwrap().unwrap();
        assert_eq!(req.path, "/");
    }

    #[test]
    fn clean_eof_is_none_truncated_is_malformed() {
        assert!(parse(b"").unwrap().is_none(), "clean EOF before any byte");
        for partial in [&b"GET /x HT"[..], b"GET /x HTTP/1.1\r\nHost: x", b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc"] {
            let err = parse(partial).unwrap_err();
            assert_eq!(err.status(), 400, "{err}");
        }
    }

    #[test]
    fn oversized_inputs_get_431_and_413() {
        let long_target = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_REQUEST_LINE + 10));
        assert_eq!(parse(long_target.as_bytes()).unwrap_err().status(), 431);
        let big_header =
            format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "b".repeat(MAX_HEADER_LINE + 10));
        assert_eq!(parse(big_header.as_bytes()).unwrap_err().status(), 431);
        let many: String = (0..MAX_HEADERS + 1).map(|i| format!("X-{i}: v\r\n")).collect();
        let req = format!("GET / HTTP/1.1\r\n{many}\r\n");
        assert_eq!(parse(req.as_bytes()).unwrap_err().status(), 431);
        let huge = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert_eq!(parse(huge.as_bytes()).unwrap_err().status(), 413);
    }

    #[test]
    fn pipelined_requests_parse_back_to_back() {
        let two = b"GET /a HTTP/1.1\r\n\r\nPOST /b HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi";
        let mut cur = Cursor::new(two.to_vec());
        let a = read_request(&mut cur).unwrap().unwrap();
        let b = read_request(&mut cur).unwrap().unwrap();
        assert_eq!(a.path, "/a");
        assert_eq!(b.path, "/b");
        assert_eq!(b.body, b"hi");
        assert!(read_request(&mut cur).unwrap().is_none(), "then clean EOF");
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 200, "text/plain", &[("X-Node", "n0")], b"hello", false)
            .unwrap();
        let resp = read_http_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.header("x-node"), Some("n0"));
        assert_eq!(resp.text(), "hello");
    }

    #[test]
    fn tensor_codec_roundtrips_and_rejects_garbage() {
        let t = Tensor::new(vec![2, 3], vec![1.0, -2.5, 0.0, 3.25, f32::MIN, f32::MAX]);
        let enc = encode_tensor(&t);
        let back = decode_tensor(&enc).unwrap();
        assert_eq!(back.shape, t.shape);
        assert_eq!(back.data, t.data);
        assert!(decode_tensor(&[]).is_err());
        assert!(decode_tensor(&enc[..enc.len() - 1]).is_err(), "short body");
        assert!(decode_tensor(&[&enc[..], &[0u8]].concat()).is_err(), "long body");
        let mut zero_dim = enc.clone();
        zero_dim[4..8].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_tensor(&zero_dim).is_err(), "zero dim");
        let mut huge = enc;
        huge[0..4].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode_tensor(&huge).is_err(), "ndim over limit");
    }
}
