//! Layer-3 coordinator: training orchestration (curriculum, epoch loop over
//! the AOT train-step executable, reverse-pruning triggers, checkpointing),
//! evaluation, and the batching inference server.

pub mod schedule;
pub mod server;
pub mod state;
pub mod trainer;

pub use schedule::{cosine_lr, Curriculum};
pub use server::{
    BatchModel, BatchPolicy, EngineModel, Request, Response, Server, ServerConfig,
    ServerDeployment, ServerStats, SubmitError,
};
pub use state::{CallExtras, TrainState};
pub use trainer::{EpochLog, TrainConfig, Trainer};

pub mod experiment;
