//! Layer-3 coordinator: training orchestration (curriculum, epoch loop over
//! the AOT train-step executable, reverse-pruning triggers, checkpointing),
//! evaluation, the batching inference server, and the sharded multi-node
//! cluster tier (consistent-hash router + HTTP nodes over `std::net`).

pub mod cluster;
pub mod faults;
pub mod qtrain;
pub mod ring;
pub mod schedule;
pub mod server;
pub mod state;
pub mod trainer;
pub mod wire;

pub use cluster::{
    infer, scrape_metrics, ClusterNode, InferReply, Membership, NodeConfig, Router, RouterConfig,
    RouterStats,
};
pub use faults::{Brownout, BrownoutMode, FaultPlan, FaultyModel};
pub use qtrain::{NativeTrainer, QtConfig, QtEpochLog, QtReport, RunControls};
pub use ring::{stable_hash, HashRing};
pub use schedule::{cosine_lr, Curriculum};
pub use server::{
    is_transient, latency_percentile, transient_error, BatchModel, BatchPolicy, BreakerPolicy,
    EngineModel, Outcome, Priority, Request, Response, RetryPolicy, Server, ServerConfig,
    ServerDeployment, ServerStats, SubmitError, TRANSIENT_MARKER,
};
pub use state::{CallExtras, TrainState};
pub use trainer::{EpochAccum, EpochLog, TrainConfig, Trainer};

pub mod experiment;
