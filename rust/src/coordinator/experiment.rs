//! Shared experiment harness used by examples/ and benches/: artifact
//! loading, training runs with validation, cross-backend deployment +
//! metric collection (the machinery behind every paper table/figure).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::backends::{backend_by_name, BackendSpec, CheckpointView, PtqOptions, RangeSource};
use crate::ckpt::Checkpoint;
use crate::coordinator::ring::HashRing;
use crate::coordinator::server::{EngineModel, ServerDeployment};
use crate::coordinator::state::TrainState;
use crate::coordinator::trainer::{EpochLog, TrainConfig, Trainer};
use crate::data::{gen_cls_batch, gen_seg_batch, Batch, ClsSpec, SegSpec};
use crate::engine::fp32_model;
use crate::metrics;
use crate::perfmodel::{ActScaling, Precision};
use crate::qir::Graph;
use crate::runtime::{Manifest, Runtime};
use crate::tensor::Tensor;

/// Locate artifacts/ from any run context (repo root or target/ subdirs).
pub fn artifacts_dir() -> Result<PathBuf> {
    for cand in [
        PathBuf::from("artifacts"),
        PathBuf::from("../artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if cand.join("kernels.manifest").exists() {
            return Ok(cand);
        }
    }
    anyhow::bail!("artifacts/ not found — run `make artifacts` first")
}

/// Graph used for the roofline perf model: prefers the paper-scale variant
/// (`{model}_paper.qir`, 224^2/512^2 inputs — Figs 3/7/11, Table 10) and
/// falls back to the trainable slim graph.
pub fn perf_graph(dir: &Path, model: &str) -> Result<Graph> {
    let paper = dir.join(format!("{model}_paper.qir"));
    if paper.exists() {
        return Graph::load(paper);
    }
    Graph::load(dir.join(format!("{model}.qir")))
}

/// Everything exported for one model.
pub struct ModelArtifacts {
    pub manifest: Manifest,
    pub graph: Graph,
    pub init: Checkpoint,
}

pub fn load_model(dir: &Path, model: &str) -> Result<ModelArtifacts> {
    let manifest = Manifest::load(dir.join(format!("{model}.manifest")))?;
    let graph = Graph::load(manifest.file_path("qir")?)?;
    let init = Checkpoint::load(manifest.file_path("ckpt")?)?;
    Ok(ModelArtifacts { manifest, graph, init })
}

/// Task data plumbing for training runs.
#[derive(Clone, Copy, Debug)]
pub enum Task {
    Cls(ClsSpec),
    Seg(SegSpec),
}

impl Task {
    pub fn batch(&self, n: usize, seed: u64) -> Batch {
        match self {
            Task::Cls(s) => gen_cls_batch(*s, n, seed),
            Task::Seg(s) => gen_seg_batch(*s, n, seed),
        }
    }
}

/// Train a model through the Rust coordinator with per-epoch validation.
/// Returns the trainer (holding the final state) and the epoch logs — the
/// training-dynamics curves of Figs 4, 5, 8, 10.
pub fn train_with_validation<'rt>(
    rt: &'rt Runtime,
    dir: &Path,
    model: &str,
    cfg: TrainConfig,
    task: Task,
    val_batches: usize,
    verbose: bool,
) -> Result<(Trainer<'rt>, Vec<EpochLog>)> {
    let man = Manifest::load(dir.join(format!("{model}.manifest")))?;
    let mut tr = Trainer::new(rt, man, cfg.clone())?;
    let bs = tr.batch_size();
    let seed = cfg.seed;
    let make = move |epoch: usize, step: usize| {
        task.batch(bs, seed ^ ((epoch as u64) << 24) ^ (step as u64 + 1))
    };
    // held-out validation batches (seeds disjoint from training)
    let eval_bs = tr
        .fns
        .manifest()
        .fns
        .get("forward")
        .map(|f| f.args.iter().find(|s| s.role == "data").map(|s| s.shape[0]).unwrap_or(bs))
        .unwrap_or(bs);
    let val: Vec<Batch> =
        (0..val_batches).map(|i| task.batch(eval_bs, 0xEA7_0000 + i as u64)).collect();

    let mut logs: Vec<EpochLog> = Vec::new();
    let epochs = cfg.epochs;
    for e in 0..epochs {
        let lam = if cfg.quant_trim { cfg.curriculum.lam(e) } else { 0.0 };
        let mut pruned = false;
        if cfg.quant_trim && cfg.curriculum.prune_now(e) {
            if let Some(rp) = cfg.reverse_prune_fn.clone() {
                tr.reverse_prune(&rp)?;
                pruned = true;
            }
        }
        let mut acc = crate::coordinator::trainer::EpochAccum::default();
        let total_steps = cfg.epochs * cfg.steps_per_epoch;
        for s in 0..cfg.steps_per_epoch {
            let g = e * cfg.steps_per_epoch + s;
            let lr = crate::coordinator::schedule::cosine_lr(
                cfg.base_lr,
                g,
                total_steps,
                total_steps / 20 + 1,
            );
            let b = make(e, s);
            let (l, m) = tr.train_step(&b, lam as f32, lr as f32)?;
            acc.push(l, m);
        }
        let (vl, vm) = if !val.is_empty() {
            let (l, a) = tr.evaluate(&val)?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };
        let (loss, metric, nonfinite_steps) = acc.summary();
        let log = EpochLog {
            epoch: e,
            lam,
            loss,
            metric,
            nonfinite_steps,
            pruned,
            val_loss: vl,
            val_metric: vm,
        };
        if verbose {
            println!(
                "epoch {:>3}  lam {:.3}  loss {:.4}  acc {:.3}  val_acc {}  {}",
                log.epoch,
                log.lam,
                log.loss,
                log.metric,
                log.val_metric.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into()),
                if log.pruned { "[pruned]" } else { "" }
            );
        }
        logs.push(log);
    }
    Ok((tr, logs))
}

/// On-device metric row for Tables 1-2 (and the SNR of Table 3).
#[derive(Clone, Debug)]
pub struct DeployMetrics {
    /// Vendor backend that compiled the deployment.
    pub backend: &'static str,
    /// Effective deployment precision (INT4 requests on backends without
    /// sub-byte kernels compile — and report — as INT8).
    pub precision: Precision,
    /// Precision the experiment asked for.
    pub requested: Precision,
    /// Effective activation scaling (dynamic requests on backends without
    /// runtime range support compile — and report — as static).
    pub act_scaling: ActScaling,
    /// Activation scaling the experiment asked for.
    pub requested_scaling: ActScaling,
    /// Top-1 accuracy on the eval batches.
    pub top1: f64,
    /// Top-5 accuracy on the eval batches.
    pub top5: f64,
    /// MSE between device and FP32-reference logits.
    pub logit_mse: f64,
    /// Brier score of the device softmax.
    pub brier: f64,
    /// Expected calibration error (15 bins).
    pub ece: f64,
    /// Output SNR of the device logits vs the FP32 reference.
    pub snr_db: f64,
    /// Modelled batch-1 throughput on the simulated device.
    pub fps_modelled: f64,
    /// Number of graph ops that fell back to the host.
    pub fallback_ops: usize,
}

impl DeployMetrics {
    /// "INT4" / "INT8" / … or "INT4→INT8" when the backend fell back.
    pub fn precision_label(&self) -> String {
        if self.requested == self.precision {
            self.precision.label().to_string()
        } else {
            format!("{}→{}", self.requested.label(), self.precision.label())
        }
    }

    /// "static" / "dynamic", or "dyn→static" when a dynamic-scaling request
    /// fell back to compile-time ranges.
    pub fn scaling_label(&self) -> String {
        if self.requested_scaling == self.act_scaling {
            self.act_scaling.label().to_string()
        } else {
            "dyn→static".to_string()
        }
    }
}

/// Deploy a trained checkpoint on one backend and evaluate against the FP32
/// reference logits (the "ONNX FP32" parenthetical values in Tables 1-2).
/// Static activation scaling; see [`deploy_and_eval_scaled`].
#[allow(clippy::too_many_arguments)]
pub fn deploy_and_eval(
    backend: &BackendSpec,
    graph: &Graph,
    state: &TrainState,
    precision: Precision,
    range_source: RangeSource,
    ptq: PtqOptions,
    calib: &[Tensor],
    eval_batches: &[Batch],
) -> Result<DeployMetrics> {
    deploy_and_eval_scaled(
        backend,
        graph,
        state,
        precision,
        ActScaling::Static,
        range_source,
        ptq,
        calib,
        eval_batches,
    )
}

/// [`deploy_and_eval`] with the activation-scaling axis exposed — the
/// machinery behind the paper's static-vs-dynamic comparison columns.
#[allow(clippy::too_many_arguments)]
pub fn deploy_and_eval_scaled(
    backend: &BackendSpec,
    graph: &Graph,
    state: &TrainState,
    precision: Precision,
    scaling: ActScaling,
    range_source: RangeSource,
    ptq: PtqOptions,
    calib: &[Tensor],
    eval_batches: &[Batch],
) -> Result<DeployMetrics> {
    let params: BTreeMap<String, Tensor> = state.params.clone();
    let bn: BTreeMap<String, Tensor> = state.bn.clone();
    let qstate: BTreeMap<String, Tensor> = state.qstate.clone();
    let view = CheckpointView { graph, params: &params, bn: &bn, qstate: &qstate };
    let dep = backend.compile_scaled(view, precision, scaling, range_source, calib, ptq)?;

    // FP32 reference on the same eval set
    let reference = fp32_model(graph.clone(), params.clone(), bn.clone());

    let mut all_dev: Vec<f32> = Vec::new();
    let mut all_ref: Vec<f32> = Vec::new();
    let mut labels: Vec<i32> = Vec::new();
    let mut cdim = 1;
    for b in eval_batches {
        let dl = dep.model.run(&b.images)?.remove(0);
        let rl = reference.run(&b.images)?.remove(0);
        cdim = dl.shape[1];
        all_dev.extend_from_slice(&dl.data);
        all_ref.extend_from_slice(&rl.data);
        labels.extend_from_slice(&b.labels);
    }
    let dev = Tensor::new(vec![labels.len(), cdim], all_dev);
    let refl = Tensor::new(vec![labels.len(), cdim], all_ref);
    let (top1, top5) = metrics::topk_accuracy(&dev, &labels);
    Ok(DeployMetrics {
        backend: backend.name,
        precision: dep.precision,
        requested: precision,
        act_scaling: dep.act_scaling,
        requested_scaling: scaling,
        top1,
        top5,
        logit_mse: metrics::logit_mse(&dev, &refl),
        brier: metrics::brier(&dev, &labels),
        ece: metrics::ece(&dev, &labels, 15),
        snr_db: metrics::snr_db(&refl.data, &dev.data),
        fps_modelled: dep.perf_b1.fps,
        fallback_ops: dep.perf_b1.fallback_ops,
    })
}

/// One server fronting several simulated NPUs: compile the checkpoint on
/// each named backend (at its default precision unless overridden, with
/// static or dynamic activation scaling per entry) and wrap every deployment
/// for the batching server, keyed by backend name. A backend listed more
/// than once (e.g. `hardware_d` at INT8 *and* INT4, or at static *and*
/// dynamic scaling — a mixed fleet) gets `@PREC`-suffixed deployment names
/// (plus `@dyn` for dynamic-scaling entries) so the router can address each
/// variant separately.
///
/// With `service_floor` set, each deployment is paced per **actual** batch
/// size: an n-request batch pays the roofline perf model's device latency at
/// batch n (but at least `floor · n / max_batch`, so the floor scales with
/// executed work too). The Rust engine computes exact logits faster than the
/// edge NPUs it simulates, so un-paced serving sweeps would measure host CPU
/// speed instead of the fleet's scheduling behaviour; `service_floor` is the
/// minimum full-batch service time.
pub fn compile_serving_fleet(
    graph: &Graph,
    params: &BTreeMap<String, Tensor>,
    bn: &BTreeMap<String, Tensor>,
    backends: &[(&str, Option<Precision>, ActScaling)],
    calib: &[Tensor],
    max_batch: usize,
    service_floor: Option<Duration>,
) -> Result<Vec<ServerDeployment>> {
    let qstate: BTreeMap<String, Tensor> = BTreeMap::new();
    let mut fleet = Vec::with_capacity(backends.len());
    // (backend name, effective precision, effective scaling) per deployment,
    // kept parallel to `fleet` for the fallback wiring below
    let mut spec = Vec::with_capacity(backends.len());
    for &(name, precision, scaling) in backends {
        let be = backend_by_name(name).with_context(|| format!("unknown backend {name:?}"))?;
        let precision = precision.unwrap_or_else(|| be.default_precision());
        let view = CheckpointView { graph, params, bn, qstate: &qstate };
        let dep = be
            .compile_scaled(view, precision, scaling, RangeSource::Calibration, calib, PtqOptions::default())
            .with_context(|| format!("compiling serving deployment {name}"))?;
        // suffix with the REQUESTED precision/scaling: unique per spec entry
        // even when an INT4 or dynamic request falls back (labelling with
        // the effective values would collide with the backend's plain entry
        // and the server would refuse the duplicate name)
        let duplicated = backends.iter().filter(|(n, _, _)| *n == name).count() > 1;
        let dep_name = if duplicated {
            let dyn_suffix = if scaling == ActScaling::Dynamic { "@dyn" } else { "" };
            format!("{name}@{}{dyn_suffix}", precision.label())
        } else {
            name.to_string()
        };
        // pace at the precision AND scaling the deployment actually runs at
        // (a fallback executes — and must be paced — as what it fell back to,
        // and a dynamic deployment pays the modelled range-scan overhead)
        let effective = dep.precision;
        let effective_scaling = dep.act_scaling;
        let model = Arc::new(dep.model);
        let engine = match service_floor {
            Some(floor) => {
                let floors: Vec<Duration> = (1..=max_batch)
                    .map(|n| {
                        let modelled_s =
                            be.perf_scaled(graph, effective, effective_scaling, n).latency_ms / 1e3;
                        let min_s = floor.as_secs_f64() * n as f64 / max_batch as f64;
                        Duration::from_secs_f64(modelled_s.max(min_s))
                    })
                    .collect();
                EngineModel::paced(model, max_batch, floors)
            }
            None => EngineModel::new(model, max_batch),
        };
        spec.push((name, effective, effective_scaling));
        fleet.push(ServerDeployment {
            name: dep_name,
            model: Arc::new(engine),
            fallbacks: Vec::new(),
        });
    }
    // Graceful-degradation wiring: each deployment's fallbacks are its
    // same-backend siblings, preferring the precision-shedding targets the
    // breaker should degrade to — INT4 first, then dynamic-scaling variants,
    // then anything else on that backend. A single-entry backend gets no
    // fallbacks (breaker-open traffic fails fast instead).
    for i in 0..fleet.len() {
        let mut sibs: Vec<usize> =
            (0..fleet.len()).filter(|&j| j != i && spec[j].0 == spec[i].0).collect();
        sibs.sort_by_key(|&j| {
            let int4_rank = usize::from(spec[j].1 != Precision::Int4);
            let dyn_rank = usize::from(spec[j].2 != ActScaling::Dynamic);
            (int4_rank, dyn_rank, j)
        });
        let names: Vec<String> = sibs.into_iter().map(|j| fleet[j].name.clone()).collect();
        fleet[i].fallbacks = names;
    }
    Ok(fleet)
}

/// Shard a compiled serving fleet across cluster nodes: each deployment is
/// placed on `replication` distinct nodes, chosen by a consistent-hash ring
/// over `node_ids` keyed by the deployment name (128 vnodes — the balanced
/// regime, see `rust/tests/hash_ring.rs`). Returns one deployment list per
/// node, parallel to `node_ids`, ready for
/// [`crate::coordinator::ClusterNode::start`].
///
/// Placement is deterministic: the same `(fleet, node_ids, replication)`
/// always yields the same shards, so replicas of a *static-precision*
/// deployment are bit-exact siblings and router failover is invisible to
/// accuracy (asserted in `rust/tests/cluster.rs`). Fallback wiring from
/// [`compile_serving_fleet`] is pruned per node to the siblings actually
/// co-located there — [`crate::coordinator::Server`] rejects dangling
/// fallback names at startup.
///
/// The models behind the deployments are shared (`Arc`), not recompiled:
/// in-process multi-node tests and benches pay one compile per fleet entry
/// regardless of the replication factor.
pub fn place_fleet_on_nodes(
    fleet: &[ServerDeployment],
    node_ids: &[String],
    replication: usize,
) -> Result<Vec<Vec<ServerDeployment>>> {
    anyhow::ensure!(!node_ids.is_empty(), "placement needs at least one node");
    anyhow::ensure!(replication >= 1, "replication factor must be >= 1");
    let mut ring = HashRing::new(128);
    for id in node_ids {
        ring.add_node(id);
    }
    anyhow::ensure!(ring.len() == node_ids.len(), "node ids must be unique");
    let mut shards: Vec<Vec<ServerDeployment>> = node_ids.iter().map(|_| Vec::new()).collect();
    for dep in fleet {
        for owner in ring.replicas(&dep.name, replication) {
            let slot =
                node_ids.iter().position(|id| id.as_str() == owner).expect("owner is a node id");
            shards[slot].push(ServerDeployment {
                name: dep.name.clone(),
                model: Arc::clone(&dep.model),
                fallbacks: dep.fallbacks.clone(),
            });
        }
    }
    // prune fallbacks to co-located siblings (the server validates names)
    for shard in &mut shards {
        let local: Vec<String> = shard.iter().map(|d| d.name.clone()).collect();
        for dep in shard.iter_mut() {
            dep.fallbacks.retain(|f| local.contains(f));
        }
    }
    Ok(shards)
}

/// A `TrainState` wrapping a synthetic seeded model (testutil::synth):
/// lets the deployment-matrix machinery run with no exported artifacts, no
/// PJRT runtime and no training — the CI smoke path.
pub fn synthetic_state(sm: &crate::testutil::synth::SynthModel) -> TrainState {
    TrainState {
        params: sm.params.clone(),
        bn: sm.bn.clone(),
        ..TrainState::default()
    }
}

/// Reference (FP32) metrics on the same eval set — the parenthetical columns.
pub fn reference_metrics(
    graph: &Graph,
    state: &TrainState,
    eval_batches: &[Batch],
) -> Result<(f64, f64, f64, f64)> {
    let reference = fp32_model(graph.clone(), state.params.clone(), state.bn.clone());
    let mut all: Vec<f32> = Vec::new();
    let mut labels: Vec<i32> = Vec::new();
    let mut cdim = 0;
    for b in eval_batches {
        let rl = reference.run(&b.images)?.remove(0);
        cdim = rl.shape[1];
        all.extend_from_slice(&rl.data);
        labels.extend_from_slice(&b.labels);
    }
    let t = Tensor::new(vec![labels.len(), cdim], all);
    let (t1, t5) = metrics::topk_accuracy(&t, &labels);
    Ok((t1, t5, metrics::brier(&t, &labels), metrics::ece(&t, &labels, 15)))
}
