//! Native Quant-Trim training — the paper's loop, closed in pure Rust.
//!
//! `coordinator/trainer.rs` drives exported PJRT artifacts; this module is
//! its artifact-free twin: an f32 forward/backward over QIR CNN graphs
//! (everything `testutil::synth` emits) with straight-through-estimator
//! fake quantization on the progressive [`Curriculum`] lambda schedule and
//! epoch-boundary reverse pruning, porting the semantics of
//! `python/compile/quant.py`, `train.py`, and `kernels/{fake_quant,
//! reverse_prune}.py`. Training runs from `cargo test` alone.
//!
//! Around the loop sits a robustness supervisor:
//! - every epoch ends with an atomic, checksummed checkpoint
//!   ([`Checkpoint::save`]: temp + fsync + rename) plus a resume manifest,
//!   so a `kill -9` at ANY step resumes to a bit-identical final
//!   checkpoint (seeded data order + fixed sequential accumulation);
//! - a non-finite loss or gradient never touches optimizer state: the step
//!   is refused, the trainer rolls back to the last good epoch boundary,
//!   and lambda/LR are backed off multiplicatively before retrying;
//! - a scale-inflation watchdog compiles the in-training weights through a
//!   real backend each epoch and runs the static plan auditor's interval
//!   pass; when `SCALE_INFLATION` fires it triggers an early reverse-prune
//!   — the paper's outlier story, closed-loop.
//!
//! Determinism contract: given the same config, data seed, and fault
//! history, every f32 in `TrainState` is bit-identical across runs,
//! interruptions included. All reductions run in fixed sequential order
//! and all state lives in `BTreeMap`s (sorted iteration).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::backends::{backend_by_name, CheckpointView, PtqOptions, RangeSource};
use crate::ckpt::{write_atomic, Checkpoint};
use crate::data::{epoch_seeds, gen_cls_batch, Batch, ClsSpec};
use crate::engine::verify::SCALE_INFLATION;
use crate::metrics::nan_safe_argmax;
use crate::perfmodel::{ActScaling, Precision};
use crate::qir::{Graph, Node};
use crate::tensor::{empirical_quantile, subsample, Tensor};

use super::schedule::{cosine_lr, Curriculum};
use super::state::TrainState;

// Quantization grid + EMA constants (python/compile/kernels/ref.py).
const EPS: f32 = 1e-6;
const QMIN_W: f32 = -128.0;
const QMAX_W: f32 = 127.0;
const QMAX_A: f32 = 255.0;
/// Weight-quantile order statistic (quant.py `p_hi`).
pub const P_HI: f64 = 0.999;
/// Reverse-prune tensor-quantile subsample cap (ref.py `S_MAX_W`).
const S_MAX_W: usize = 100_000;

// AdamW (python/compile/train.py).
const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

// BatchNorm train mode (python/compile/jax_exec.py; eps matches the
// engine's inference-side folding).
const BN_MOM: f32 = 0.1;
const BN_EPS: f32 = 1e-5;

const MANIFEST_NAME: &str = "MANIFEST";
const MANIFEST_HEADER: &str = "qtrain-manifest v1";

/// Native training configuration.
#[derive(Clone, Debug)]
pub struct QtConfig {
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub batch_size: usize,
    pub base_lr: f64,
    pub weight_decay: f32,
    pub curriculum: Curriculum,
    /// false = plain f32 baseline (no fake quant, no pruning).
    pub quant_trim: bool,
    pub seed: u64,
    pub data: ClsSpec,
    /// Abort training after this many non-finite rollbacks.
    pub max_rollbacks: usize,
    /// Multiplier applied to both lambda and LR on each rollback.
    pub backoff: f64,
    /// Run the per-epoch scale-inflation watchdog (audit interval pass).
    pub watchdog: bool,
}

impl QtConfig {
    /// Small-but-real Quant-Trim run on the tiny synthetic task; the
    /// curriculum is the paper's CIFAR column compressed to `epochs`.
    pub fn tiny(epochs: usize, steps_per_epoch: usize) -> Self {
        QtConfig {
            epochs,
            steps_per_epoch,
            batch_size: 4,
            base_lr: 3e-3,
            weight_decay: 0.01,
            curriculum: Curriculum::cifar().scaled_to(epochs, 100),
            quant_trim: true,
            seed: 0xDA7A,
            data: ClsSpec::tiny(),
            max_rollbacks: 8,
            backoff: 0.5,
            watchdog: true,
        }
    }

    fn total_steps(&self) -> usize {
        self.epochs * self.steps_per_epoch
    }
}

/// Per-epoch training log.
#[derive(Clone, Debug)]
pub struct QtEpochLog {
    pub epoch: usize,
    /// Effective lambda (curriculum value times rollback backoff).
    pub lam: f64,
    /// Mean loss over finite steps of the final (successful) epoch attempt.
    pub loss: f64,
    /// Mean train accuracy over finite steps.
    pub acc: f64,
    /// Steps whose loss/grads were non-finite across all attempts of this
    /// epoch (each one triggered a rollback).
    pub nonfinite_steps: usize,
    /// Scheduled reverse-prune fired at this epoch's start.
    pub pruned: bool,
    /// Watchdog-triggered early reverse-prune fired at this epoch's end.
    pub watchdog_pruned: bool,
}

/// Result of a completed (or aborted) training run.
#[derive(Debug)]
pub struct QtReport {
    pub logs: Vec<QtEpochLog>,
    pub rollbacks: usize,
    pub watchdog_prunes: usize,
    /// Path of the last epoch's checkpoint (None if aborted before the
    /// first epoch completed).
    pub final_checkpoint: Option<PathBuf>,
    /// True when the run stopped via `RunControls::abort_after_steps`.
    pub aborted: bool,
}

/// Test/fault-injection controls for one `train` call. `Default` runs
/// training straight through.
#[derive(Default)]
pub struct RunControls<'a> {
    /// Called before each step with (epoch, step); returning true poisons
    /// that step's loss with NaN (simulating a numeric fault).
    pub fault: Option<&'a mut dyn FnMut(usize, usize) -> bool>,
    /// Stop abruptly after this many executed steps — no checkpoint, no
    /// cleanup — simulating `kill -9` mid-epoch.
    pub abort_after_steps: Option<usize>,
}

enum StepOutcome {
    Ok { loss: f32, acc: f32 },
    NonFinite,
}

/// Everything one step computes before any state is committed, so a
/// non-finite result can be discarded without corrupting the trainer.
pub struct StepEval {
    pub loss: f32,
    pub acc: f32,
    pub grads: BTreeMap<String, Tensor>,
    pub new_bn: BTreeMap<String, Tensor>,
    pub new_qstate: BTreeMap<String, Tensor>,
}

/// Pure-Rust Quant-Trim trainer + robustness supervisor.
pub struct NativeTrainer {
    pub graph: Graph,
    pub state: TrainState,
    pub cfg: QtConfig,
    lam_scale: f64,
    lr_scale: f64,
    rollbacks: usize,
    watchdog_prunes: usize,
    start_epoch: usize,
    /// In-memory twin of the last on-disk checkpoint (initial state before
    /// the first epoch completes) — the rollback target.
    last_good: Option<Box<TrainState>>,
}

impl NativeTrainer {
    pub fn new(
        graph: Graph,
        params: BTreeMap<String, Tensor>,
        bn: BTreeMap<String, Tensor>,
        cfg: QtConfig,
    ) -> Self {
        let qstate = if cfg.quant_trim {
            init_qstate(&graph, &params, P_HI, cfg.curriculum.p_clip)
        } else {
            BTreeMap::new()
        };
        let mut state = TrainState::default();
        for (k, t) in &params {
            state.opt_m.insert(k.clone(), Tensor::zeros(&t.shape));
            state.opt_v.insert(k.clone(), Tensor::zeros(&t.shape));
        }
        state.params = params;
        state.bn = bn;
        state.qstate = qstate;
        NativeTrainer {
            graph,
            state,
            cfg,
            lam_scale: 1.0,
            lr_scale: 1.0,
            rollbacks: 0,
            watchdog_prunes: 0,
            start_epoch: 0,
            last_good: None,
        }
    }

    /// Resume from `dir`'s manifest. Returns `None` when no training has
    /// checkpointed there yet. A corrupt latest checkpoint (detected by the
    /// file checksum) falls back to the newest earlier epoch that loads.
    pub fn resume(graph: Graph, cfg: QtConfig, dir: &Path) -> Result<Option<Self>> {
        let mpath = dir.join(MANIFEST_NAME);
        if !mpath.exists() {
            return Ok(None);
        }
        let text = std::fs::read_to_string(&mpath).with_context(|| format!("read {mpath:?}"))?;
        let (epoch, file) = parse_manifest(&text)?;
        let mut candidates = vec![dir.join(&file)];
        for e in (0..epoch).rev() {
            candidates.push(dir.join(ckpt_name(e)));
        }
        let mut last_err = None;
        for path in &candidates {
            match Checkpoint::load(path) {
                Ok(ck) => {
                    let state = TrainState::from_checkpoint(&ck);
                    let meta = |key: &str, default: f32| {
                        ck.get(key).and_then(|t| t.data.first().copied()).unwrap_or(default)
                    };
                    let ck_epoch = meta("meta/epoch", 0.0) as usize;
                    let mut tr = NativeTrainer {
                        graph,
                        state,
                        cfg,
                        lam_scale: meta("meta/lam_scale", 1.0) as f64,
                        lr_scale: meta("meta/lr_scale", 1.0) as f64,
                        rollbacks: meta("meta/rollbacks", 0.0) as usize,
                        watchdog_prunes: meta("meta/watchdog_prunes", 0.0) as usize,
                        start_epoch: ck_epoch + 1,
                        last_good: None,
                    };
                    tr.last_good = Some(Box::new(tr.state.clone()));
                    return Ok(Some(tr));
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow::anyhow!("no checkpoint candidates"))
            .context(format!("manifest at {mpath:?} points at no loadable checkpoint")))
    }

    /// Resume from `dir` if a manifest exists there, else start fresh.
    pub fn resume_or_new(
        graph: Graph,
        params: BTreeMap<String, Tensor>,
        bn: BTreeMap<String, Tensor>,
        cfg: QtConfig,
        dir: &Path,
    ) -> Result<Self> {
        match Self::resume(graph.clone(), cfg.clone(), dir)? {
            Some(t) => Ok(t),
            None => Ok(Self::new(graph, params, bn, cfg)),
        }
    }

    pub fn rollbacks(&self) -> usize {
        self.rollbacks
    }

    pub fn watchdog_prunes(&self) -> usize {
        self.watchdog_prunes
    }

    /// Run (or continue) training, checkpointing into `dir` each epoch.
    pub fn train(&mut self, dir: &Path, mut controls: RunControls<'_>) -> Result<QtReport> {
        std::fs::create_dir_all(dir).with_context(|| format!("create {dir:?}"))?;
        if self.last_good.is_none() {
            self.last_good = Some(Box::new(self.state.clone()));
        }
        let total_steps = self.cfg.total_steps();
        let warmup = total_steps / 20 + 1;
        let mut logs: Vec<QtEpochLog> = Vec::new();
        let mut executed = 0usize;
        let mut epoch = self.start_epoch;
        let mut carry_nonfinite = 0usize;
        let mut final_ckpt = (self.start_epoch > 0).then(|| dir.join(ckpt_name(self.start_epoch - 1)));
        'epoch: while epoch < self.cfg.epochs {
            let lam = if self.cfg.quant_trim {
                self.cfg.curriculum.lam(epoch) * self.lam_scale
            } else {
                0.0
            };
            let pruned = self.cfg.quant_trim && self.cfg.curriculum.prune_now(epoch);
            if pruned {
                reverse_prune(
                    &self.graph,
                    &mut self.state,
                    self.cfg.curriculum.p_clip,
                    self.cfg.curriculum.beta,
                );
            }
            let seeds = epoch_seeds(self.cfg.seed, epoch, self.cfg.steps_per_epoch);
            let mut ep_loss = 0f64;
            let mut ep_acc = 0f64;
            let mut ok_steps = 0usize;
            for (step, &seed) in seeds.iter().enumerate() {
                if let Some(k) = controls.abort_after_steps {
                    if executed >= k {
                        return Ok(QtReport {
                            logs,
                            rollbacks: self.rollbacks,
                            watchdog_prunes: self.watchdog_prunes,
                            final_checkpoint: final_ckpt,
                            aborted: true,
                        });
                    }
                }
                let global = epoch * self.cfg.steps_per_epoch + step;
                let lr = cosine_lr(self.cfg.base_lr, global, total_steps, warmup) * self.lr_scale;
                let batch = gen_cls_batch(self.cfg.data, self.cfg.batch_size, seed);
                let poison = controls.fault.as_mut().is_some_and(|f| f(epoch, step));
                executed += 1;
                match self.train_step(&batch, lam as f32, lr as f32, poison)? {
                    StepOutcome::Ok { loss, acc } => {
                        ep_loss += loss as f64;
                        ep_acc += acc as f64;
                        ok_steps += 1;
                    }
                    StepOutcome::NonFinite => {
                        // Refuse the step, restore the last epoch boundary,
                        // back off lambda and LR, and retry this epoch.
                        carry_nonfinite += 1;
                        self.rollbacks += 1;
                        if self.rollbacks > self.cfg.max_rollbacks {
                            bail!(
                                "training diverged: {} non-finite rollbacks (max {})",
                                self.rollbacks,
                                self.cfg.max_rollbacks
                            );
                        }
                        let good = self.last_good.as_ref().expect("set at train start");
                        self.state = (**good).clone();
                        self.lam_scale *= self.cfg.backoff;
                        self.lr_scale *= self.cfg.backoff;
                        continue 'epoch;
                    }
                }
            }
            let mut watchdog_pruned = false;
            if self.cfg.watchdog && self.cfg.quant_trim && self.scale_inflation_fires() {
                reverse_prune(
                    &self.graph,
                    &mut self.state,
                    self.cfg.curriculum.p_clip,
                    self.cfg.curriculum.beta,
                );
                self.watchdog_prunes += 1;
                watchdog_pruned = true;
            }
            let path = self.save_epoch(dir, epoch)?;
            self.last_good = Some(Box::new(self.state.clone()));
            final_ckpt = Some(path);
            logs.push(QtEpochLog {
                epoch,
                lam,
                loss: ep_loss / ok_steps.max(1) as f64,
                acc: ep_acc / ok_steps.max(1) as f64,
                nonfinite_steps: carry_nonfinite,
                pruned,
                watchdog_pruned,
            });
            carry_nonfinite = 0;
            epoch += 1;
        }
        Ok(QtReport {
            logs,
            rollbacks: self.rollbacks,
            watchdog_prunes: self.watchdog_prunes,
            final_checkpoint: final_ckpt,
            aborted: false,
        })
    }

    /// Held-out evaluation through the real deployment path: the current
    /// state is compiled to an fp32 `CompiledModel` and run on seeded
    /// validation batches. Returns (mean loss, top-1 accuracy).
    pub fn evaluate(&self, batches: usize) -> Result<(f64, f64)> {
        let model = crate::engine::fp32_model(
            self.graph.clone(),
            self.state.params.clone(),
            self.state.bn.clone(),
        );
        let mut loss = 0f64;
        let mut hits = 0usize;
        let mut total = 0usize;
        for b in 0..batches {
            let batch =
                gen_cls_batch(self.cfg.data, self.cfg.batch_size, 0xEA7_0000 + b as u64);
            let out = model.run(&batch.images)?;
            let (l, _, _) = softmax_xent(&out[0], &batch.labels);
            loss += l as f64;
            let k = out[0].shape[1];
            for (i, &label) in batch.labels.iter().enumerate() {
                let row = &out[0].data[i * k..(i + 1) * k];
                if nan_safe_argmax(row) == Some(label as usize) {
                    hits += 1;
                }
                total += 1;
            }
        }
        Ok((loss / batches.max(1) as f64, hits as f64 / total.max(1) as f64))
    }

    fn train_step(
        &mut self,
        batch: &Batch,
        lam: f32,
        lr: f32,
        poison: bool,
    ) -> Result<StepOutcome> {
        let ev = self.loss_and_grads(batch, lam)?;
        let loss = if poison { f32::NAN } else { ev.loss };
        let finite = loss.is_finite()
            && ev
                .grads
                .values()
                .all(|g| g.data.iter().all(|v| v.is_finite()));
        if !finite {
            return Ok(StepOutcome::NonFinite);
        }
        self.adamw(&ev.grads, lr);
        for (k, t) in ev.new_bn {
            self.state.bn.insert(k, t);
        }
        for (k, t) in ev.new_qstate {
            self.state.qstate.insert(k, t);
        }
        Ok(StepOutcome::Ok { loss, acc: ev.acc })
    }

    /// One full forward/backward without committing anything: loss, top-1
    /// accuracy, parameter gradients, and the would-be BN/qstate updates.
    pub fn loss_and_grads(&self, batch: &Batch, lam: f32) -> Result<StepEval> {
        let tape = self.forward(&batch.images, lam)?;
        let out_name = &self.graph.outputs[0];
        let logits = tape
            .acts
            .get(out_name)
            .with_context(|| format!("forward produced no output {out_name}"))?;
        let (loss, acc, dlogits) = softmax_xent(logits, &batch.labels);
        let grads = self.backward(&tape, dlogits)?;
        Ok(StepEval { loss, acc, grads, new_bn: tape.new_bn, new_qstate: tape.new_qstate })
    }

    // -- forward ----------------------------------------------------------

    fn forward(&self, x: &Tensor, lam: f32) -> Result<Tape> {
        let mut tape = Tape::default();
        let n = x.shape[0];
        let mu = self.cfg.curriculum.mu as f32;
        for node in &self.graph.nodes {
            let out = match node.kind.as_str() {
                "input" => {
                    if x.shape[1..] != node.shape[..] {
                        bail!(
                            "input shape {:?} does not match graph input {:?}",
                            &x.shape[1..],
                            node.shape
                        );
                    }
                    x.clone()
                }
                "conv2d" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let w = self.param(node, "w")?;
                    let b = self.state.params.get(&format!("{}.b", node.name));
                    let w_eff = if self.cfg.quant_trim {
                        fake_quant_weight(&node.name, w, lam, mu, &self.state.qstate, &mut tape.new_qstate)
                    } else {
                        w.clone()
                    };
                    let out = conv2d_fwd(
                        xin,
                        &w_eff,
                        b,
                        node.attr_usize("stride")?,
                        node.attr_usize("pad")?,
                        node.attr_usize("groups")?,
                        &node.shape,
                    );
                    tape.w_eff.insert(node.name.clone(), w_eff);
                    out
                }
                "linear" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let w = self.param(node, "w")?;
                    let b = self.state.params.get(&format!("{}.b", node.name));
                    let w_eff = if self.cfg.quant_trim {
                        fake_quant_weight(&node.name, w, lam, mu, &self.state.qstate, &mut tape.new_qstate)
                    } else {
                        w.clone()
                    };
                    let out = linear_fwd(xin, &w_eff, b);
                    tape.w_eff.insert(node.name.clone(), w_eff);
                    out
                }
                "bn" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let gamma = self.param(node, "gamma")?;
                    let beta = self.param(node, "beta")?;
                    let (out, mean, inv) = bn_fwd_train(xin, gamma, beta);
                    // Running stats: new = (1-mom)*old + mom*batch (biased
                    // batch variance, matching the jax twin).
                    let var: Vec<f32> = inv
                        .iter()
                        .map(|&iv| (1.0 / (iv * iv)) - BN_EPS)
                        .collect();
                    for (suffix, batch_v) in [("mean", &mean), ("var", &var)] {
                        let key = format!("{}.{suffix}", node.name);
                        let old = self
                            .state
                            .bn
                            .get(&key)
                            .with_context(|| format!("bn state missing {key}"))?;
                        let merged: Vec<f32> = old
                            .data
                            .iter()
                            .zip(batch_v.iter())
                            .map(|(&o, &bv)| (1.0 - BN_MOM) * o + BN_MOM * bv)
                            .collect();
                        tape.new_bn.insert(key, Tensor::new(old.shape.clone(), merged));
                    }
                    tape.bn_stats.insert(node.name.clone(), (mean, inv));
                    out
                }
                "aq" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    if self.cfg.quant_trim {
                        fake_quant_act(&node.name, xin, lam, mu, &self.state.qstate, &mut tape.new_qstate)
                    } else {
                        xin.clone()
                    }
                }
                "relu" | "relu6" | "hswish" | "hsigmoid" | "silu" | "gelu" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    xin.map(act_fn(&node.kind))
                }
                "maxpool" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let (out, idx) = maxpool_fwd(
                        xin,
                        node.attr_usize("k")?,
                        node.attr_usize("stride")?,
                        node.attr_usize("pad")?,
                        &node.shape,
                    );
                    tape.pool_idx.insert(node.name.clone(), idx);
                    out
                }
                "avgpool" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    avgpool_fwd(
                        xin,
                        node.attr_usize("k")?,
                        node.attr_usize("stride")?,
                        node.attr_usize("pad")?,
                        &node.shape,
                    )
                }
                "gap" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    gap_fwd(xin)
                }
                "add" => {
                    let a = taped(&tape.acts, &node.inputs[0])?;
                    let bt = taped(&tape.acts, &node.inputs[1])?;
                    if a.shape != bt.shape {
                        bail!("add {}: shape mismatch {:?} vs {:?}", node.name, a.shape, bt.shape);
                    }
                    let data = a.data.iter().zip(bt.data.iter()).map(|(&u, &v)| u + v).collect();
                    Tensor::new(a.shape.clone(), data)
                }
                "mul" => {
                    let a = taped(&tape.acts, &node.inputs[0])?;
                    let bt = taped(&tape.acts, &node.inputs[1])?;
                    mul_fwd(a, bt, &node.name)?
                }
                "flatten" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let flat: usize = xin.shape[1..].iter().product();
                    xin.clone().reshaped(&[n, flat])
                }
                other => bail!("native trainer does not support op `{other}` (node {})", node.name),
            };
            tape.acts.insert(node.name.clone(), out);
        }
        Ok(tape)
    }

    // -- backward ---------------------------------------------------------

    fn backward(&self, tape: &Tape, dlogits: Tensor) -> Result<BTreeMap<String, Tensor>> {
        let mut gacts: BTreeMap<String, Tensor> = BTreeMap::new();
        gacts.insert(self.graph.outputs[0].clone(), dlogits);
        let mut gparams: BTreeMap<String, Tensor> = BTreeMap::new();
        // Nodes are topo-ordered, so the reverse pass sees every consumer's
        // contribution before reaching the producer.
        for node in self.graph.nodes.iter().rev() {
            let Some(dy) = gacts.remove(&node.name) else { continue };
            match node.kind.as_str() {
                "input" => {}
                "conv2d" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let w_eff = tape
                        .w_eff
                        .get(&node.name)
                        .with_context(|| format!("no blended weight taped for {}", node.name))?;
                    let has_bias = self.state.params.contains_key(&format!("{}.b", node.name));
                    let (dx, dw, db) = conv2d_bwd(
                        xin,
                        w_eff,
                        &dy,
                        node.attr_usize("stride")?,
                        node.attr_usize("pad")?,
                        node.attr_usize("groups")?,
                    );
                    // STE: dL/dw equals dL/dw_eff — the fake-quant blend
                    // backpropagates as identity.
                    gparams.insert(format!("{}.w", node.name), dw);
                    if has_bias {
                        gparams.insert(format!("{}.b", node.name), db);
                    }
                    accum(&mut gacts, &node.inputs[0], dx);
                }
                "linear" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let w_eff = tape
                        .w_eff
                        .get(&node.name)
                        .with_context(|| format!("no blended weight taped for {}", node.name))?;
                    let has_bias = self.state.params.contains_key(&format!("{}.b", node.name));
                    let (dx, dw, db) = linear_bwd(xin, w_eff, &dy);
                    gparams.insert(format!("{}.w", node.name), dw);
                    if has_bias {
                        gparams.insert(format!("{}.b", node.name), db);
                    }
                    accum(&mut gacts, &node.inputs[0], dx);
                }
                "bn" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let gamma = self.param(node, "gamma")?;
                    let (mean, inv) = tape
                        .bn_stats
                        .get(&node.name)
                        .with_context(|| format!("no bn stats taped for {}", node.name))?;
                    let (dx, dgamma, dbeta) = bn_bwd_train(xin, gamma, mean, inv, &dy);
                    gparams.insert(format!("{}.gamma", node.name), dgamma);
                    gparams.insert(format!("{}.beta", node.name), dbeta);
                    accum(&mut gacts, &node.inputs[0], dx);
                }
                // Straight-through estimator: the fake-quant blend is
                // identity for gradients.
                "aq" => accum(&mut gacts, &node.inputs[0], dy),
                "relu" | "relu6" | "hswish" | "hsigmoid" | "silu" | "gelu" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let d = act_grad(&node.kind);
                    let data = xin.data.iter().zip(dy.data.iter()).map(|(&x, &g)| g * d(x)).collect();
                    accum(&mut gacts, &node.inputs[0], Tensor::new(xin.shape.clone(), data));
                }
                "maxpool" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let idx = tape
                        .pool_idx
                        .get(&node.name)
                        .with_context(|| format!("no pool indices taped for {}", node.name))?;
                    let mut dx = Tensor::zeros(&xin.shape);
                    for (o, &src) in idx.iter().enumerate() {
                        dx.data[src] += dy.data[o];
                    }
                    accum(&mut gacts, &node.inputs[0], dx);
                }
                "avgpool" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let dx = avgpool_bwd(
                        xin,
                        &dy,
                        node.attr_usize("k")?,
                        node.attr_usize("stride")?,
                        node.attr_usize("pad")?,
                    );
                    accum(&mut gacts, &node.inputs[0], dx);
                }
                "gap" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    let (h, w) = (xin.shape[2], xin.shape[3]);
                    let scale = 1.0 / (h * w) as f32;
                    let mut dx = Tensor::zeros(&xin.shape);
                    let (nb, c) = (xin.shape[0], xin.shape[1]);
                    for ni in 0..nb {
                        for ci in 0..c {
                            let g = dy.data[ni * c + ci] * scale;
                            let base = (ni * c + ci) * h * w;
                            for i in 0..h * w {
                                dx.data[base + i] = g;
                            }
                        }
                    }
                    accum(&mut gacts, &node.inputs[0], dx);
                }
                "add" => {
                    accum(&mut gacts, &node.inputs[0], dy.clone());
                    accum(&mut gacts, &node.inputs[1], dy);
                }
                "mul" => {
                    let a = taped(&tape.acts, &node.inputs[0])?;
                    let bt = taped(&tape.acts, &node.inputs[1])?;
                    let (da, db) = mul_bwd(a, bt, &dy, &node.name)?;
                    accum(&mut gacts, &node.inputs[0], da);
                    accum(&mut gacts, &node.inputs[1], db);
                }
                "flatten" => {
                    let xin = taped(&tape.acts, &node.inputs[0])?;
                    accum(&mut gacts, &node.inputs[0], dy.reshaped(&xin.shape));
                }
                other => bail!("native trainer does not support op `{other}` in backward"),
            }
        }
        Ok(gparams)
    }

    // -- optimizer / supervisor internals ---------------------------------

    /// AdamW exactly as `train.py::_adamw`: bias-corrected moments, decoupled
    /// weight decay on every parameter, step incremented first.
    fn adamw(&mut self, grads: &BTreeMap<String, Tensor>, lr: f32) {
        self.state.step += 1.0;
        let t = self.state.step;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        let wd = self.cfg.weight_decay;
        for (k, g) in grads {
            let Some(p) = self.state.params.get_mut(k) else { continue };
            let m = self
                .state
                .opt_m
                .entry(k.clone())
                .or_insert_with(|| Tensor::zeros(&p.shape));
            let v = self
                .state
                .opt_v
                .entry(k.clone())
                .or_insert_with(|| Tensor::zeros(&p.shape));
            for i in 0..p.data.len() {
                let gi = g.data[i];
                m.data[i] = ADAM_B1 * m.data[i] + (1.0 - ADAM_B1) * gi;
                v.data[i] = ADAM_B2 * v.data[i] + (1.0 - ADAM_B2) * gi * gi;
                let upd = (m.data[i] / bc1) / ((v.data[i] / bc2).sqrt() + ADAM_EPS);
                p.data[i] -= lr * (upd + wd * p.data[i]);
            }
        }
    }

    /// Compile the in-training weights through a real per-channel backend
    /// and run the static auditor's interval pass; true when the
    /// `SCALE_INFLATION` finding fires. Compile failures (e.g. an op a
    /// backend refuses) are treated as "no signal", never as faults.
    fn scale_inflation_fires(&self) -> bool {
        for be_name in ["hardware_b", "hardware_d"] {
            let Some(be) = backend_by_name(be_name) else { continue };
            let view = CheckpointView {
                graph: &self.graph,
                params: &self.state.params,
                bn: &self.state.bn,
                qstate: &self.state.qstate,
            };
            let Ok(dep) = be.compile_scaled(
                view,
                Precision::Int8,
                ActScaling::Static,
                RangeSource::QatScales,
                &[],
                PtqOptions::default(),
            ) else {
                continue;
            };
            let Ok(report) = dep.audit(None) else { continue };
            return report.findings.iter().any(|f| f.code == SCALE_INFLATION);
        }
        false
    }

    fn save_epoch(&self, dir: &Path, epoch: usize) -> Result<PathBuf> {
        let mut ck = self.state.to_checkpoint_full();
        ck.insert("meta/epoch", Tensor::scalar(epoch as f32));
        ck.insert("meta/lam_scale", Tensor::scalar(self.lam_scale as f32));
        ck.insert("meta/lr_scale", Tensor::scalar(self.lr_scale as f32));
        ck.insert("meta/rollbacks", Tensor::scalar(self.rollbacks as f32));
        ck.insert("meta/watchdog_prunes", Tensor::scalar(self.watchdog_prunes as f32));
        let name = ckpt_name(epoch);
        let path = dir.join(&name);
        ck.save(&path)?;
        // The manifest is written only after the checkpoint is durable, so
        // a crash between the two leaves the previous epoch resumable.
        let manifest = format!("{MANIFEST_HEADER}\nepoch {epoch}\nfile {name}\n");
        write_atomic(dir.join(MANIFEST_NAME), manifest.as_bytes())?;
        Ok(path)
    }

    fn param(&self, node: &Node, suffix: &str) -> Result<&Tensor> {
        self.state
            .params
            .get(&format!("{}.{suffix}", node.name))
            .with_context(|| format!("missing param {}.{suffix}", node.name))
    }
}

fn ckpt_name(epoch: usize) -> String {
    format!("ckpt_e{epoch:04}.qtckpt")
}

fn parse_manifest(text: &str) -> Result<(usize, String)> {
    let mut lines = text.lines();
    if lines.next().map(str::trim) != Some(MANIFEST_HEADER) {
        bail!("unrecognized manifest header");
    }
    let mut epoch = None;
    let mut file = None;
    for line in lines {
        match line.split_once(' ') {
            Some(("epoch", v)) => epoch = Some(v.trim().parse::<usize>().context("manifest epoch")?),
            Some(("file", v)) => file = Some(v.trim().to_string()),
            _ => {}
        }
    }
    Ok((
        epoch.context("manifest missing epoch")?,
        file.context("manifest missing file")?,
    ))
}

// ---------------------------------------------------------------------------
// tape
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Tape {
    /// Node name -> forward activation (batch dim included).
    acts: BTreeMap<String, Tensor>,
    /// Weight node name -> blended (fake-quantized) weight used in forward.
    w_eff: BTreeMap<String, Tensor>,
    /// BN node name -> (batch mean, 1/sqrt(var+eps)) per channel.
    bn_stats: BTreeMap<String, (Vec<f32>, Vec<f32>)>,
    /// Maxpool node name -> per-output flat argmax index into the input.
    pool_idx: BTreeMap<String, Vec<usize>>,
    new_bn: BTreeMap<String, Tensor>,
    new_qstate: BTreeMap<String, Tensor>,
}

/// Field-level activation lookup (keeps borrows of the other tape fields
/// available while an activation reference is live).
fn taped<'a>(acts: &'a BTreeMap<String, Tensor>, name: &str) -> Result<&'a Tensor> {
    acts.get(name)
        .with_context(|| format!("activation {name} not on tape (topo order violated?)"))
}

fn accum(gacts: &mut BTreeMap<String, Tensor>, name: &str, t: Tensor) {
    match gacts.get_mut(name) {
        Some(acc) => {
            for (a, b) in acc.data.iter_mut().zip(t.data.iter()) {
                *a += b;
            }
        }
        None => {
            gacts.insert(name.to_string(), t);
        }
    }
}

// ---------------------------------------------------------------------------
// fake quantization (python/compile/quant.py train mode, exact port)
// ---------------------------------------------------------------------------

/// Per-output-channel symmetric weight fake quant with EMA'd quantile
/// ranges; the EMA is updated every step and the *updated* value scales this
/// step (quant.py semantics). Returns the STE blend `w + lam*(wq - w)`.
fn fake_quant_weight(
    name: &str,
    w: &Tensor,
    lam: f32,
    mu: f32,
    qstate: &BTreeMap<String, Tensor>,
    new_qstate: &mut BTreeMap<String, Tensor>,
) -> Tensor {
    let cout = w.shape[0];
    let row = w.data.len() / cout.max(1);
    let key = format!("{name}.m");
    let prev = qstate.get(&key);
    let mut m_ema = vec![0f32; cout];
    for oc in 0..cout {
        let abs: Vec<f32> = w.data[oc * row..(oc + 1) * row].iter().map(|v| v.abs()).collect();
        let m = empirical_quantile(&abs, P_HI);
        let p = prev.and_then(|t| t.data.get(oc).copied()).unwrap_or(m);
        m_ema[oc] = (1.0 - mu) * p + mu * m;
    }
    new_qstate.insert(key, Tensor::new(vec![cout], m_ema.clone()));
    let mut out = Vec::with_capacity(w.data.len());
    for oc in 0..cout {
        let s = m_ema[oc].max(EPS) / QMAX_W;
        for &v in &w.data[oc * row..(oc + 1) * row] {
            let wq = (v / s).round_ties_even().clamp(QMIN_W, QMAX_W) * s;
            out.push(v + lam * (wq - v));
        }
    }
    Tensor::new(w.shape.clone(), out)
}

/// Asymmetric u8 activation fake quant at `aq` nodes: exact batch min/max
/// (stop-grad), EMA'd into qstate, updated EMA used this step.
fn fake_quant_act(
    name: &str,
    x: &Tensor,
    lam: f32,
    mu: f32,
    qstate: &BTreeMap<String, Tensor>,
    new_qstate: &mut BTreeMap<String, Tensor>,
) -> Tensor {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in &x.data {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let scalar = |q: &BTreeMap<String, Tensor>, key: &str, d: f32| {
        q.get(key).and_then(|t| t.data.first().copied()).unwrap_or(d)
    };
    let lo_key = format!("{name}.lo");
    let hi_key = format!("{name}.hi");
    let lo_e = (1.0 - mu) * scalar(qstate, &lo_key, lo) + mu * lo;
    let hi_e = (1.0 - mu) * scalar(qstate, &hi_key, hi) + mu * hi;
    new_qstate.insert(lo_key, Tensor::scalar(lo_e));
    new_qstate.insert(hi_key, Tensor::scalar(hi_e));
    let s = (hi_e - lo_e).max(EPS) / QMAX_A;
    let z = (-lo_e / s).round_ties_even().clamp(0.0, QMAX_A);
    let data = x
        .data
        .iter()
        .map(|&v| {
            let q = ((v / s).round_ties_even() + z).clamp(0.0, QMAX_A);
            let xq = (q - z) * s;
            v + lam * (xq - v)
        })
        .collect();
    Tensor::new(x.shape.clone(), data)
}

/// Initialize Quant-Trim statistics from the float weights, matching
/// `train.py::init_qstate`: per-output-channel `p_hi` quantile of |w| plus a
/// scalar `p_clip` tensor quantile (`tau`) for every conv/linear node, and
/// `(lo, hi) = (0, 6)` priors for every `aq` node.
pub fn init_qstate(
    graph: &Graph,
    params: &BTreeMap<String, Tensor>,
    p_hi: f64,
    p_clip: f64,
) -> BTreeMap<String, Tensor> {
    let mut q = BTreeMap::new();
    for node in &graph.nodes {
        match node.kind.as_str() {
            "conv2d" | "linear" => {
                let Some(w) = params.get(&format!("{}.w", node.name)) else { continue };
                let cout = w.shape[0];
                let row = w.data.len() / cout.max(1);
                let m: Vec<f32> = (0..cout)
                    .map(|oc| {
                        let abs: Vec<f32> =
                            w.data[oc * row..(oc + 1) * row].iter().map(|v| v.abs()).collect();
                        empirical_quantile(&abs, p_hi)
                    })
                    .collect();
                q.insert(format!("{}.m", node.name), Tensor::new(vec![cout], m));
                q.insert(
                    format!("{}.tau", node.name),
                    Tensor::scalar(tensor_quantile_abs(&w.data, p_clip)),
                );
            }
            "aq" => {
                q.insert(format!("{}.lo", node.name), Tensor::scalar(0.0));
                q.insert(format!("{}.hi", node.name), Tensor::scalar(6.0));
            }
            _ => {}
        }
    }
    q
}

/// `ref.py::tensor_quantile` of |w|: strided subsample capped at `S_MAX_W`,
/// then the order-statistic quantile.
fn tensor_quantile_abs(data: &[f32], p: f64) -> f32 {
    let abs: Vec<f32> = data.iter().map(|v| v.abs()).collect();
    let sub = subsample(&abs, S_MAX_W);
    empirical_quantile(&sub, p)
}

/// Reverse pruning (`kernels/reverse_prune.py`): for every conv/linear node,
/// EMA the clip threshold `tau` toward the current `p_clip` quantile of |w|
/// and clip the weights into `[-tau, tau]` — outliers are pulled back in
/// rather than the grid stretched to cover them.
pub fn reverse_prune(graph: &Graph, state: &mut TrainState, p_clip: f64, beta: f64) {
    let beta = beta as f32;
    for node in &graph.nodes {
        if node.kind != "conv2d" && node.kind != "linear" {
            continue;
        }
        let wk = format!("{}.w", node.name);
        let tk = format!("{}.tau", node.name);
        let Some(w) = state.params.get_mut(&wk) else { continue };
        let that = tensor_quantile_abs(&w.data, p_clip);
        let tau = state
            .qstate
            .get(&tk)
            .and_then(|t| t.data.first().copied())
            .unwrap_or(that);
        let tnew = (1.0 - beta) * tau + beta * that;
        for v in &mut w.data {
            *v = v.clamp(-tnew, tnew);
        }
        state.qstate.insert(tk, Tensor::scalar(tnew));
    }
}

// ---------------------------------------------------------------------------
// loss
// ---------------------------------------------------------------------------

/// Softmax cross-entropy (mean over batch) + top-1 accuracy + dlogits.
/// NaN-safe: rows whose logits are all NaN count as misses, never panic.
pub fn softmax_xent(logits: &Tensor, labels: &[i32]) -> (f32, f32, Tensor) {
    let n = logits.shape[0];
    let k = logits.shape[1];
    let mut dl = Tensor::zeros(&logits.shape);
    let mut loss = 0f32;
    let mut hits = 0usize;
    for (i, &label) in labels.iter().enumerate().take(n) {
        let row = &logits.data[i * k..(i + 1) * k];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0f32;
        for &v in row {
            sum += (v - mx).exp();
        }
        let logz = mx + sum.ln();
        loss += logz - row[label as usize];
        for j in 0..k {
            let p = (row[j] - mx).exp() / sum;
            let onehot = if j == label as usize { 1.0 } else { 0.0 };
            dl.data[i * k + j] = (p - onehot) / n as f32;
        }
        if nan_safe_argmax(row) == Some(label as usize) {
            hits += 1;
        }
    }
    (loss / n as f32, hits as f32 / n as f32, dl)
}

// ---------------------------------------------------------------------------
// op kernels (forward + backward)
// ---------------------------------------------------------------------------

fn conv2d_fwd(
    x: &Tensor,
    w: &Tensor,
    b: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    oshape: &[usize],
) -> Tensor {
    let (n, cin, ih, iw) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, oh, ow) = (oshape[0], oshape[1], oshape[2]);
    let cin_g = cin / groups;
    let cout_g = cout / groups;
    let (kh, kw) = (w.shape[2], w.shape[3]);
    let mut out = Tensor::zeros(&[n, cout, oh, ow]);
    for ni in 0..n {
        for oc in 0..cout {
            let base_ic = (oc / cout_g) * cin_g;
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = b.map_or(0.0, |t| t.data[oc]);
                    for ic in 0..cin_g {
                        let xc = base_ic + ic;
                        for ky in 0..kh {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= ih as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix >= iw as isize {
                                    continue;
                                }
                                acc += x.data[((ni * cin + xc) * ih + iy as usize) * iw
                                    + ix as usize]
                                    * w.data[((oc * cin_g + ic) * kh + ky) * kw + kx];
                            }
                        }
                    }
                    out.data[((ni * cout + oc) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    out
}

fn conv2d_bwd(
    x: &Tensor,
    w: &Tensor,
    dy: &Tensor,
    stride: usize,
    pad: usize,
    groups: usize,
) -> (Tensor, Tensor, Tensor) {
    let (n, cin, ih, iw) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, oh, ow) = (dy.shape[1], dy.shape[2], dy.shape[3]);
    let cin_g = cin / groups;
    let cout_g = cout / groups;
    let (kh, kw) = (w.shape[2], w.shape[3]);
    let mut dx = Tensor::zeros(&x.shape);
    let mut dw = Tensor::zeros(&w.shape);
    let mut db = Tensor::zeros(&[cout]);
    for ni in 0..n {
        for oc in 0..cout {
            let base_ic = (oc / cout_g) * cin_g;
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dy.data[((ni * cout + oc) * oh + oy) * ow + ox];
                    db.data[oc] += g;
                    for ic in 0..cin_g {
                        let xc = base_ic + ic;
                        for ky in 0..kh {
                            let iy = (oy * stride + ky) as isize - pad as isize;
                            if iy < 0 || iy >= ih as isize {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = (ox * stride + kx) as isize - pad as isize;
                                if ix < 0 || ix >= iw as isize {
                                    continue;
                                }
                                let xi = ((ni * cin + xc) * ih + iy as usize) * iw + ix as usize;
                                let wi = ((oc * cin_g + ic) * kh + ky) * kw + kx;
                                dx.data[xi] += g * w.data[wi];
                                dw.data[wi] += g * x.data[xi];
                            }
                        }
                    }
                }
            }
        }
    }
    (dx, dw, db)
}

fn linear_fwd(x: &Tensor, w: &Tensor, b: Option<&Tensor>) -> Tensor {
    let (n, din) = (x.shape[0], x.shape[1]);
    let dout = w.shape[0];
    let mut out = Tensor::zeros(&[n, dout]);
    for ni in 0..n {
        for o in 0..dout {
            let mut acc = b.map_or(0.0, |t| t.data[o]);
            for i in 0..din {
                acc += x.data[ni * din + i] * w.data[o * din + i];
            }
            out.data[ni * dout + o] = acc;
        }
    }
    out
}

fn linear_bwd(x: &Tensor, w: &Tensor, dy: &Tensor) -> (Tensor, Tensor, Tensor) {
    let (n, din) = (x.shape[0], x.shape[1]);
    let dout = w.shape[0];
    let mut dx = Tensor::zeros(&x.shape);
    let mut dw = Tensor::zeros(&w.shape);
    let mut db = Tensor::zeros(&[dout]);
    for ni in 0..n {
        for o in 0..dout {
            let g = dy.data[ni * dout + o];
            db.data[o] += g;
            for i in 0..din {
                dx.data[ni * din + i] += g * w.data[o * din + i];
                dw.data[o * din + i] += g * x.data[ni * din + i];
            }
        }
    }
    (dx, dw, db)
}

/// Train-mode BN: normalize with the *batch* statistics (biased variance over
/// N, H, W per channel). Returns (y, batch_mean, 1/sqrt(var+eps)).
fn bn_fwd_train(x: &Tensor, gamma: &Tensor, beta: &Tensor) -> (Tensor, Vec<f32>, Vec<f32>) {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let count = (n * h * w) as f32;
    let hw = h * w;
    let mut mean = vec![0f32; c];
    let mut inv = vec![0f32; c];
    let mut out = Tensor::zeros(&x.shape);
    for ci in 0..c {
        let mut sum = 0f32;
        for ni in 0..n {
            let base = (ni * c + ci) * hw;
            for i in 0..hw {
                sum += x.data[base + i];
            }
        }
        let mu = sum / count;
        let mut var = 0f32;
        for ni in 0..n {
            let base = (ni * c + ci) * hw;
            for i in 0..hw {
                let d = x.data[base + i] - mu;
                var += d * d;
            }
        }
        var /= count;
        let iv = 1.0 / (var + BN_EPS).sqrt();
        mean[ci] = mu;
        inv[ci] = iv;
        let (ga, be) = (gamma.data[ci], beta.data[ci]);
        for ni in 0..n {
            let base = (ni * c + ci) * hw;
            for i in 0..hw {
                out.data[base + i] = ga * (x.data[base + i] - mu) * iv + be;
            }
        }
    }
    (out, mean, inv)
}

/// Train-mode BN backward, including the gradient paths through the batch
/// mean and variance:
/// `dx = (gamma*inv) * (dy - mean(dy) - xhat * mean(dy*xhat))` per channel.
fn bn_bwd_train(
    x: &Tensor,
    gamma: &Tensor,
    mean: &[f32],
    inv: &[f32],
    dy: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let count = (n * h * w) as f32;
    let hw = h * w;
    let mut dx = Tensor::zeros(&x.shape);
    let mut dgamma = Tensor::zeros(&[c]);
    let mut dbeta = Tensor::zeros(&[c]);
    for ci in 0..c {
        let (mu, iv) = (mean[ci], inv[ci]);
        let mut sum_dy = 0f32;
        let mut sum_dy_xhat = 0f32;
        for ni in 0..n {
            let base = (ni * c + ci) * hw;
            for i in 0..hw {
                let xhat = (x.data[base + i] - mu) * iv;
                let g = dy.data[base + i];
                sum_dy += g;
                sum_dy_xhat += g * xhat;
            }
        }
        dgamma.data[ci] = sum_dy_xhat;
        dbeta.data[ci] = sum_dy;
        let mdy = sum_dy / count;
        let mdyx = sum_dy_xhat / count;
        let ga_iv = gamma.data[ci] * iv;
        for ni in 0..n {
            let base = (ni * c + ci) * hw;
            for i in 0..hw {
                let xhat = (x.data[base + i] - mu) * iv;
                dx.data[base + i] = ga_iv * (dy.data[base + i] - mdy - xhat * mdyx);
            }
        }
    }
    (dx, dgamma, dbeta)
}

fn maxpool_fwd(
    x: &Tensor,
    k: usize,
    stride: usize,
    pad: usize,
    oshape: &[usize],
) -> (Tensor, Vec<usize>) {
    let (n, c, ih, iw) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (oshape[1], oshape[2]);
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    let mut idx = vec![0usize; n * c * oh * ow];
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut best = f32::NEG_INFINITY;
                    let mut best_i = usize::MAX;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= ih as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= iw as isize {
                                continue;
                            }
                            let xi = ((ni * c + ci) * ih + iy as usize) * iw + ix as usize;
                            let v = x.data[xi];
                            if best_i == usize::MAX || v > best {
                                best = v;
                                best_i = xi;
                            }
                        }
                    }
                    let o = ((ni * c + ci) * oh + oy) * ow + ox;
                    out.data[o] = best;
                    idx[o] = best_i;
                }
            }
        }
    }
    (out, idx)
}

/// Average pool matching the jax twin: padded cells contribute 0 to the sum
/// and the divisor is always `k*k`.
fn avgpool_fwd(x: &Tensor, k: usize, stride: usize, pad: usize, oshape: &[usize]) -> Tensor {
    let (n, c, ih, iw) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (oshape[1], oshape[2]);
    let norm = 1.0 / (k * k) as f32;
    let mut out = Tensor::zeros(&[n, c, oh, ow]);
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut sum = 0f32;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= ih as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= iw as isize {
                                continue;
                            }
                            sum += x.data[((ni * c + ci) * ih + iy as usize) * iw + ix as usize];
                        }
                    }
                    out.data[((ni * c + ci) * oh + oy) * ow + ox] = sum * norm;
                }
            }
        }
    }
    out
}

fn avgpool_bwd(x: &Tensor, dy: &Tensor, k: usize, stride: usize, pad: usize) -> Tensor {
    let (n, c, ih, iw) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (oh, ow) = (dy.shape[2], dy.shape[3]);
    let norm = 1.0 / (k * k) as f32;
    let mut dx = Tensor::zeros(&x.shape);
    for ni in 0..n {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dy.data[((ni * c + ci) * oh + oy) * ow + ox] * norm;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= ih as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= iw as isize {
                                continue;
                            }
                            dx.data[((ni * c + ci) * ih + iy as usize) * iw + ix as usize] += g;
                        }
                    }
                }
            }
        }
    }
    dx
}

fn gap_fwd(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let hw = h * w;
    let mut out = Tensor::zeros(&[n, c, 1, 1]);
    for ni in 0..n {
        for ci in 0..c {
            let base = (ni * c + ci) * hw;
            let mut sum = 0f32;
            for i in 0..hw {
                sum += x.data[base + i];
            }
            out.data[ni * c + ci] = sum / hw as f32;
        }
    }
    out
}

/// Elementwise mul with the SE-gate broadcast: either both operands share a
/// shape, or the second is `(C,1,1)` against the first's `(C,H,W)`.
fn mul_fwd(a: &Tensor, b: &Tensor, name: &str) -> Result<Tensor> {
    if a.shape == b.shape {
        let data = a.data.iter().zip(b.data.iter()).map(|(&u, &v)| u * v).collect();
        return Ok(Tensor::new(a.shape.clone(), data));
    }
    if a.shape.len() == 4 && b.shape.len() == 4 && a.shape[..2] == b.shape[..2] && b.shape[2] == 1 && b.shape[3] == 1 {
        let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
        let hw = h * w;
        let mut out = Tensor::zeros(&a.shape);
        for ni in 0..n {
            for ci in 0..c {
                let g = b.data[ni * c + ci];
                let base = (ni * c + ci) * hw;
                for i in 0..hw {
                    out.data[base + i] = a.data[base + i] * g;
                }
            }
        }
        return Ok(out);
    }
    bail!("mul {name}: unsupported broadcast {:?} x {:?}", a.shape, b.shape)
}

fn mul_bwd(a: &Tensor, b: &Tensor, dy: &Tensor, name: &str) -> Result<(Tensor, Tensor)> {
    if a.shape == b.shape {
        let da = dy.data.iter().zip(b.data.iter()).map(|(&g, &v)| g * v).collect();
        let db = dy.data.iter().zip(a.data.iter()).map(|(&g, &v)| g * v).collect();
        return Ok((Tensor::new(a.shape.clone(), da), Tensor::new(b.shape.clone(), db)));
    }
    if a.shape.len() == 4 && b.shape.len() == 4 && a.shape[..2] == b.shape[..2] && b.shape[2] == 1 && b.shape[3] == 1 {
        let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
        let hw = h * w;
        let mut da = Tensor::zeros(&a.shape);
        let mut db = Tensor::zeros(&b.shape);
        for ni in 0..n {
            for ci in 0..c {
                let g = b.data[ni * c + ci];
                let base = (ni * c + ci) * hw;
                let mut acc = 0f32;
                for i in 0..hw {
                    da.data[base + i] = dy.data[base + i] * g;
                    acc += dy.data[base + i] * a.data[base + i];
                }
                db.data[ni * c + ci] = acc;
            }
        }
        return Ok((da, db));
    }
    bail!("mul {name}: unsupported broadcast {:?} x {:?} in backward", a.shape, b.shape)
}

// Activation functions + derivatives (formulas match python/compile/jax_exec.py).

fn act_fn(kind: &str) -> fn(f32) -> f32 {
    match kind {
        "relu" => |x| x.max(0.0),
        "relu6" => |x| x.clamp(0.0, 6.0),
        "hswish" => |x| x * (x + 3.0).clamp(0.0, 6.0) / 6.0,
        "hsigmoid" => |x| (x + 3.0).clamp(0.0, 6.0) / 6.0,
        "silu" => |x| x / (1.0 + (-x).exp()),
        "gelu" => |x| {
            let c = 0.797_884_56_f32; // sqrt(2/pi)
            0.5 * x * (1.0 + (c * (x + 0.044_715 * x * x * x)).tanh())
        },
        _ => unreachable!("act_fn called for non-activation kind"),
    }
}

fn act_grad(kind: &str) -> fn(f32) -> f32 {
    match kind {
        "relu" => |x| if x > 0.0 { 1.0 } else { 0.0 },
        "relu6" => |x| if x > 0.0 && x < 6.0 { 1.0 } else { 0.0 },
        "hswish" => |x| {
            if x <= -3.0 {
                0.0
            } else if x < 3.0 {
                (2.0 * x + 3.0) / 6.0
            } else {
                1.0
            }
        },
        "hsigmoid" => |x| if x > -3.0 && x < 3.0 { 1.0 / 6.0 } else { 0.0 },
        "silu" => |x| {
            let s = 1.0 / (1.0 + (-x).exp());
            s * (1.0 + x * (1.0 - s))
        },
        "gelu" => |x| {
            let c = 0.797_884_56_f32;
            let u = c * (x + 0.044_715 * x * x * x);
            let t = u.tanh();
            0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * c * (1.0 + 3.0 * 0.044_715 * x * x)
        },
        _ => unreachable!("act_grad called for non-activation kind"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_xent_uniform_logits_and_grad_rows_sum_to_zero() {
        let n = 3;
        let k = 10;
        let logits = Tensor::zeros(&[n, k]);
        let labels = [0i32, 3, 7];
        let (loss, _, dl) = softmax_xent(&logits, &labels);
        assert!((loss - (k as f32).ln()).abs() < 1e-5, "uniform logits -> ln(k), got {loss}");
        for i in 0..n {
            let row_sum: f32 = dl.data[i * k..(i + 1) * k].iter().sum();
            assert!(row_sum.abs() < 1e-6, "softmax grad row must sum to zero, got {row_sum}");
            // the label entry carries (p - 1)/n, every other entry p/n > 0
            assert!(dl.data[i * k + labels[i] as usize] < 0.0);
        }
    }

    #[test]
    fn softmax_xent_confident_correct_logits_have_low_loss_full_acc() {
        let mut logits = Tensor::zeros(&[2, 4]);
        logits.data[0] = 10.0; // sample 0 -> class 0
        logits.data[4 + 2] = 10.0; // sample 1 -> class 2
        let (loss, acc, _) = softmax_xent(&logits, &[0, 2]);
        assert!(loss < 1e-3);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn manifest_roundtrip_and_rejection() {
        let name = ckpt_name(7);
        assert_eq!(name, "ckpt_e0007.qtckpt");
        let text = format!("{MANIFEST_HEADER}\nepoch 7\nfile {name}\n");
        let (epoch, file) = parse_manifest(&text).expect("well-formed manifest parses");
        assert_eq!(epoch, 7);
        assert_eq!(file, name);
        assert!(parse_manifest("not a manifest\nepoch 1\nfile x\n").is_err());
        assert!(parse_manifest(&format!("{MANIFEST_HEADER}\nfile only.qtckpt\n")).is_err());
        assert!(parse_manifest(&format!("{MANIFEST_HEADER}\nepoch 3\n")).is_err());
    }

    #[test]
    fn init_qstate_covers_every_quantized_node() {
        let sm = crate::testutil::synth::resnet_like(8, 8);
        let q = init_qstate(&sm.graph, &sm.params, P_HI, 0.9);
        for node in &sm.graph.nodes {
            match node.kind.as_str() {
                "conv2d" | "linear" => {
                    let m = q.get(&format!("{}.m", node.name)).expect("per-channel m");
                    assert_eq!(m.len(), sm.params[&format!("{}.w", node.name)].shape[0]);
                    assert!(m.data.iter().all(|v| *v > 0.0 && v.is_finite()));
                    assert!(q.contains_key(&format!("{}.tau", node.name)));
                }
                "aq" => {
                    assert!(q.contains_key(&format!("{}.lo", node.name)));
                    assert!(q.contains_key(&format!("{}.hi", node.name)));
                }
                _ => {}
            }
        }
    }

    #[test]
    fn reverse_prune_clamps_outliers_to_tau() {
        let sm = crate::testutil::synth::resnet_like(8, 8);
        let mut state = TrainState {
            params: sm.params.clone(),
            qstate: init_qstate(&sm.graph, &sm.params, P_HI, 0.9),
            ..Default::default()
        };
        let w = state.params.get_mut("c2.w").unwrap();
        w.data[0] = 50.0; // plant an outlier far past any weight quantile
        reverse_prune(&sm.graph, &mut state, 0.9, 0.5);
        let tau = state.qstate["c2.tau"].data[0];
        assert!(tau.is_finite() && tau > 0.0);
        let w = &state.params["c2.w"];
        let max = w.data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        assert!(max <= tau + 1e-6, "weights must be clipped into [-tau, tau]");
        assert!(max < 50.0, "the planted outlier must be pulled back in");
    }

    #[test]
    fn fake_quant_weight_is_identity_at_lambda_zero_and_on_grid_at_one() {
        let w = Tensor::new(vec![2, 4], vec![0.5, -0.25, 0.1, 0.9, -1.5, 0.7, 0.0, 0.3]);
        let q = BTreeMap::new();
        let mut nq = BTreeMap::new();
        let id = fake_quant_weight("t", &w, 0.0, 1e-2, &q, &mut nq);
        assert_eq!(id.data, w.data, "lambda 0 must pass weights through untouched");
        let mut nq2 = BTreeMap::new();
        let fq = fake_quant_weight("t", &w, 1.0, 1e-2, &q, &mut nq2);
        let m = &nq2["t.m"];
        for oc in 0..2 {
            let s = m.data[oc].max(EPS) / QMAX_W;
            for &v in &fq.data[oc * 4..(oc + 1) * 4] {
                let steps = v / s;
                assert!(
                    (steps - steps.round()).abs() < 1e-3,
                    "lambda 1 output must land on the quant grid (got {v}, scale {s})"
                );
            }
        }
    }

    #[test]
    fn fake_quant_act_clamps_to_u8_grid_at_lambda_one() {
        let x = Tensor::new(vec![1, 4], vec![-2.0, 0.0, 3.0, 9.0]);
        let q = BTreeMap::new();
        let mut nq = BTreeMap::new();
        let out = fake_quant_act("a", &x, 1.0, 1.0, &q, &mut nq);
        let lo = nq["a.lo"].data[0];
        let hi = nq["a.hi"].data[0];
        assert_eq!((lo, hi), (-2.0, 9.0), "mu=1 EMA adopts the batch range");
        for &v in &out.data {
            assert!(v >= lo - 0.1 && v <= hi + 0.1, "quantized activation escapes the range: {v}");
        }
    }
}
