//! Quant-Trim curriculum (paper §3.3) — Rust twin of
//! `python/compile/schedule.py`. Golden values are pinned in both test
//! suites so the two implementations cannot drift.

/// Curriculum hyperparameters (paper Tables 7-8).
#[derive(Clone, Copy, Debug)]
pub struct Curriculum {
    /// Warmup end (epochs): lambda = 0 before this.
    pub e_w: usize,
    /// Ramp end.
    pub e_f: usize,
    /// Epochs from e_f until lambda reaches 1.
    pub horizon: usize,
    /// Final blend cap (~0.8 for transformers, Table 8).
    pub lam_max: f64,
    /// Reverse-pruning clip quantile.
    pub p_clip: f64,
    /// Reverse-prune every K epochs after warmup.
    pub prune_every: usize,
    /// tau EMA momentum.
    pub beta: f64,
    /// Quantile EMA momentum (per step).
    pub mu: f64,
}

impl Curriculum {
    /// Paper Table 7, CIFAR-100 column.
    pub fn cifar() -> Self {
        Curriculum {
            e_w: 10,
            e_f: 50,
            horizon: 20,
            lam_max: 1.0,
            p_clip: 0.90,
            prune_every: 5,
            beta: 0.5,
            mu: 1e-2,
        }
    }

    /// Paper Table 7, segmentation column.
    pub fn seg() -> Self {
        Curriculum { e_w: 15, e_f: 30, horizon: 20, lam_max: 1.0, p_clip: 0.95, prune_every: 5, beta: 0.5, mu: 1e-3 }
    }

    /// Paper Table 8, transformer column.
    pub fn transformer() -> Self {
        Curriculum {
            e_w: 10,
            e_f: 50,
            horizon: 20,
            lam_max: 0.8,
            p_clip: 0.97,
            prune_every: 15,
            beta: 0.5,
            mu: 1e-3,
        }
    }

    /// Compressed curriculum for short runs: scales epoch breakpoints to a
    /// target epoch budget while keeping the shape.
    pub fn scaled_to(&self, total_epochs: usize, reference_total: usize) -> Curriculum {
        let f = total_epochs as f64 / reference_total as f64;
        let s = |v: usize| ((v as f64 * f).round() as usize).max(1);
        Curriculum {
            e_w: s(self.e_w),
            e_f: s(self.e_f).max(s(self.e_w) + 1),
            horizon: s(self.horizon),
            ..*self
        }
    }

    /// Blend coefficient at epoch t (paper eq. in §3.3).
    pub fn lam(&self, t: usize) -> f64 {
        let v = if t < self.e_w {
            0.0
        } else if t < self.e_f {
            let frac = (t - self.e_w) as f64 / (self.e_f - self.e_w) as f64;
            (frac.powi(4) * 0.5).min(0.5)
        } else {
            let frac = ((t - self.e_f) as f64 / self.horizon as f64).min(1.0);
            0.5 + frac * frac * 0.5
        };
        v.min(self.lam_max)
    }

    /// Reverse pruning fires at warmup end and every K epochs after
    /// (Algorithm 1, line 3).
    pub fn prune_now(&self, t: usize) -> bool {
        t >= self.e_w && (t - self.e_w) % self.prune_every == 0
    }
}

/// Cosine LR schedule with linear warmup over the first `warmup` steps.
pub fn cosine_lr(base_lr: f64, step: usize, total_steps: usize, warmup: usize) -> f64 {
    if step < warmup {
        return base_lr * (step + 1) as f64 / warmup as f64;
    }
    let frac = (step - warmup) as f64 / (total_steps.saturating_sub(warmup)).max(1) as f64;
    base_lr * 0.5 * (1.0 + (std::f64::consts::PI * frac.min(1.0)).cos())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Golden values — identical assertions exist in
    /// python/tests/test_schedule.py.
    #[test]
    fn golden_lambda_values() {
        let c = Curriculum::cifar(); // e_w=10 e_f=50 h=20
        assert_eq!(c.lam(0), 0.0);
        assert_eq!(c.lam(9), 0.0);
        assert_eq!(c.lam(10), 0.0); // ramp start
        // t=30: frac=0.5 -> 0.5^4*0.5 = 0.03125
        assert!((c.lam(30) - 0.03125).abs() < 1e-12);
        // t=45: frac=0.875 -> 0.875^4*0.5 = 0.2930908203125
        assert!((c.lam(45) - 0.293_090_820_312_5).abs() < 1e-12);
        // t=50: start of quadratic phase -> 0.5
        assert!((c.lam(50) - 0.5).abs() < 1e-12);
        // t=60: frac=0.5 -> 0.5 + 0.125 = 0.625
        assert!((c.lam(60) - 0.625).abs() < 1e-12);
        // t=70 and beyond: 1.0
        assert_eq!(c.lam(70), 1.0);
        assert_eq!(c.lam(1000), 1.0);
    }

    #[test]
    fn lambda_monotone_nondecreasing() {
        let c = Curriculum::cifar();
        let mut prev = -1.0;
        for t in 0..120 {
            let v = c.lam(t);
            assert!(v >= prev, "lambda decreased at t={t}");
            assert!((0.0..=1.0).contains(&v));
            prev = v;
        }
    }

    #[test]
    fn transformer_cap_applies() {
        let c = Curriculum::transformer();
        assert!((c.lam(1000) - 0.8).abs() < 1e-12);
    }

    #[test]
    fn prune_schedule() {
        let c = Curriculum::cifar(); // e_w=10, K=5
        assert!(!c.prune_now(9));
        assert!(c.prune_now(10));
        assert!(!c.prune_now(12));
        assert!(c.prune_now(15));
        assert!(c.prune_now(20));
    }

    #[test]
    fn scaled_curriculum_keeps_shape() {
        let c = Curriculum::cifar().scaled_to(20, 100);
        assert_eq!(c.e_w, 2);
        assert_eq!(c.e_f, 10);
        assert_eq!(c.horizon, 4);
        assert_eq!(c.lam(0), 0.0);
        assert!(c.lam(19) > 0.9);
    }

    #[test]
    fn cosine_lr_shape() {
        let base = 3e-4;
        assert!(cosine_lr(base, 0, 100, 10) < base * 0.2);
        assert!((cosine_lr(base, 10, 100, 10) - base).abs() < 1e-9);
        assert!(cosine_lr(base, 99, 100, 10) < base * 0.01);
    }

    /// Random curriculum with valid shape: e_w < e_f, horizon/prune_every
    /// >= 1, lam_max in (0, 1].
    fn random_curriculum(rng: &mut crate::testutil::Rng) -> Curriculum {
        let e_w = rng.below(30);
        Curriculum {
            e_w,
            e_f: e_w + 1 + rng.below(60),
            horizon: 1 + rng.below(30),
            lam_max: 0.05 + 0.95 * f64::from(rng.uniform()),
            p_clip: 0.9,
            prune_every: 1 + rng.below(10),
            beta: 0.5,
            mu: 1e-2,
        }
    }

    /// PROPERTY (satellite): lambda is monotone non-decreasing and stays in
    /// [0, lam_max] for every valid curriculum, not just the paper presets.
    #[test]
    fn prop_lambda_monotone_and_bounded() {
        crate::testutil::prop_check(
            "lam monotone+bounded",
            200,
            |rng| random_curriculum(rng),
            |c| {
                let mut prev = 0.0f64;
                for t in 0..(c.e_f + c.horizon + 20) {
                    let v = c.lam(t);
                    if v < prev || !(0.0..=c.lam_max).contains(&v) {
                        return false;
                    }
                    prev = v;
                }
                true
            },
        );
    }

    /// PROPERTY (satellite): prune_now fires exactly on {e_w, e_w+K,
    /// e_w+2K, ...} and never before warmup.
    #[test]
    fn prop_prune_fires_exactly_configured_epochs() {
        crate::testutil::prop_check(
            "prune epochs exact",
            200,
            |rng| random_curriculum(rng),
            |c| {
                (0..(c.e_f + 3 * c.prune_every)).all(|t| {
                    let expected = t >= c.e_w && (t - c.e_w) % c.prune_every == 0;
                    c.prune_now(t) == expected
                })
            },
        );
    }

    /// PROPERTY (satellite): cosine_lr is never negative and never exceeds
    /// base_lr, across warmup edge cases (0 warmup, warmup == total,
    /// warmup > total, 1-step schedules).
    #[test]
    fn prop_cosine_lr_bounded() {
        crate::testutil::prop_check(
            "cosine_lr in [0, base]",
            300,
            |rng| {
                let total = 1 + rng.below(400);
                // deliberately includes warmup == 0, == total, and > total
                let warmup = rng.below(total + 3);
                let base = 10f64.powf(-4.0 + 3.0 * f64::from(rng.uniform()));
                (base, total, warmup)
            },
            |&(base, total, warmup)| {
                (0..total + 5).all(|s| {
                    let lr = cosine_lr(base, s, total, warmup);
                    lr >= 0.0 && lr <= base + 1e-15
                })
            },
        );
    }

    /// Warmup edge cases pinned exactly: zero-warmup starts at base_lr;
    /// the last warmup step reaches base_lr exactly; a one-step schedule
    /// never divides by zero.
    #[test]
    fn cosine_lr_warmup_edges() {
        let base = 1e-3;
        assert!((cosine_lr(base, 0, 100, 0) - base).abs() < 1e-15);
        assert!((cosine_lr(base, 9, 100, 10) - base).abs() < 1e-15);
        let lr = cosine_lr(base, 0, 1, 1);
        assert!(lr > 0.0 && lr <= base);
    }
}
