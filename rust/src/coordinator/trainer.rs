//! The training orchestrator: drives the AOT HLO train step from Rust,
//! applies the Quant-Trim curriculum (lambda schedule + reverse-pruning
//! triggers), evaluates through the FP32 forward, and writes checkpoints.
//! Python never runs here — all compute is the PJRT executables.

use anyhow::{Context, Result};

use crate::coordinator::schedule::{cosine_lr, Curriculum};
use crate::coordinator::state::{CallExtras, TrainState};
use crate::data::Batch;
use crate::runtime::{FnCache, Manifest, Runtime};
use crate::tensor::Tensor;

/// One epoch's summary.
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub lam: f64,
    /// Mean loss over the epoch's *finite* steps (see `nonfinite_steps`).
    pub loss: f64,
    /// Mean metric over the epoch's *finite* steps.
    pub metric: f64,
    /// Steps whose loss or metric came back non-finite. They are excluded
    /// from the means instead of silently poisoning them.
    pub nonfinite_steps: usize,
    pub pruned: bool,
    pub val_loss: Option<f64>,
    pub val_metric: Option<f64>,
}

/// Accumulates per-step (loss, metric) pairs into epoch means, excluding
/// non-finite steps rather than letting one NaN absorb the whole average.
#[derive(Default)]
pub struct EpochAccum {
    loss: f64,
    metric: f64,
    finite: usize,
    nonfinite: usize,
}

impl EpochAccum {
    pub fn push(&mut self, loss: f32, metric: f32) {
        if loss.is_finite() && metric.is_finite() {
            self.loss += loss as f64;
            self.metric += metric as f64;
            self.finite += 1;
        } else {
            self.nonfinite += 1;
        }
    }

    /// (mean loss, mean metric, nonfinite step count). An epoch with zero
    /// finite steps reports NaN means — visible, not silently zero.
    pub fn summary(&self) -> (f64, f64, usize) {
        if self.finite == 0 {
            (f64::NAN, f64::NAN, self.nonfinite)
        } else {
            (self.loss / self.finite as f64, self.metric / self.finite as f64, self.nonfinite)
        }
    }
}

/// Training configuration for a run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub steps_per_epoch: usize,
    pub base_lr: f64,
    pub curriculum: Curriculum,
    /// false => MAP baseline: fp32 train step, no reverse pruning.
    pub quant_trim: bool,
    /// Reverse-pruning artifact to use (e.g. "reverse_prune_90"); None
    /// disables pruning (ablation config 2 "QAT only").
    pub reverse_prune_fn: Option<String>,
    pub seed: u64,
}

impl TrainConfig {
    pub fn quant_trim(epochs: usize, steps: usize, cur: Curriculum) -> Self {
        TrainConfig {
            epochs,
            steps_per_epoch: steps,
            base_lr: 3e-4,
            curriculum: cur,
            quant_trim: true,
            reverse_prune_fn: Some(format!("reverse_prune_{}", (cur.p_clip * 100.0).round() as u32)),
            seed: 0xDA7A,
        }
    }

    pub fn map_baseline(epochs: usize, steps: usize, cur: Curriculum) -> Self {
        TrainConfig {
            epochs,
            steps_per_epoch: steps,
            base_lr: 3e-4,
            curriculum: cur,
            quant_trim: false,
            reverse_prune_fn: None,
            seed: 0xDA7A,
        }
    }
}

/// Batch supplier: (epoch, step) -> Batch. Deterministic generators in
/// `data::` implement this.
pub type BatchFn<'a> = dyn Fn(usize, usize) -> Batch + 'a;

pub struct Trainer<'rt> {
    pub fns: FnCache<'rt>,
    pub state: TrainState,
    pub cfg: TrainConfig,
    batch_size: usize,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, man: Manifest, cfg: TrainConfig) -> Result<Self> {
        let ck_path = man.file_path("ckpt")?;
        let ck = crate::ckpt::Checkpoint::load(ck_path)?;
        let state = TrainState::from_checkpoint(&ck);
        let step_fn = if cfg.quant_trim { "train_step" } else { "train_step_fp32" };
        let batch_size = man.fns[step_fn]
            .args
            .iter()
            .find(|s| s.role == "data")
            .context("train step has no data arg")?
            .shape[0];
        Ok(Trainer { fns: FnCache::new(rt, man), state, cfg, batch_size })
    }

    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    fn step_fn_name(&self) -> &'static str {
        if self.cfg.quant_trim {
            "train_step"
        } else {
            "train_step_fp32"
        }
    }

    /// Run the full curriculum. `make_batch(epoch, step)` supplies data;
    /// `on_epoch` observes progress (logging, curve capture).
    pub fn train(
        &mut self,
        make_batch: &BatchFn<'_>,
        mut on_epoch: impl FnMut(&EpochLog),
    ) -> Result<Vec<EpochLog>> {
        let total_steps = self.cfg.epochs * self.cfg.steps_per_epoch;
        let mut logs = Vec::new();
        for epoch in 0..self.cfg.epochs {
            let lam = if self.cfg.quant_trim { self.cfg.curriculum.lam(epoch) } else { 0.0 };
            // reverse pruning fires at epoch boundaries (Algorithm 1 line 3)
            let mut pruned = false;
            if self.cfg.quant_trim && self.cfg.curriculum.prune_now(epoch) {
                if let Some(rp) = self.cfg.reverse_prune_fn.clone() {
                    self.reverse_prune(&rp)?;
                    pruned = true;
                }
            }
            let mut acc = EpochAccum::default();
            for s in 0..self.cfg.steps_per_epoch {
                let global = epoch * self.cfg.steps_per_epoch + s;
                let lr = cosine_lr(self.cfg.base_lr, global, total_steps, total_steps / 20 + 1);
                let batch = make_batch(epoch, s);
                let (loss, metric) = self.train_step(&batch, lam as f32, lr as f32)?;
                acc.push(loss, metric);
            }
            let (loss, metric, nonfinite_steps) = acc.summary();
            let log = EpochLog {
                epoch,
                lam,
                loss,
                metric,
                nonfinite_steps,
                pruned,
                val_loss: None,
                val_metric: None,
            };
            on_epoch(&log);
            logs.push(log);
        }
        Ok(logs)
    }

    pub fn train_step(&mut self, batch: &Batch, lam: f32, lr: f32) -> Result<(f32, f32)> {
        let name = self.step_fn_name();
        let spec = self.fns.manifest().fns[name].clone();
        let extras = CallExtras {
            data: Some(&batch.images),
            labels: Some(&batch.labels),
            lam,
            lr,
            teacher: None,
        };
        let args = self.state.marshal(&spec, &extras)?;
        let outs = self.fns.get(name)?.call(&args)?;
        let (loss, metric) = self.state.absorb(&spec, &outs)?;
        Ok((loss.unwrap_or(f32::NAN), metric.unwrap_or(f32::NAN)))
    }

    /// Distillation step (NanoSAM2): same flow with teacher state as input.
    pub fn distill_step(
        &mut self,
        teacher: &TrainState,
        images: &Tensor,
        lam: f32,
        lr: f32,
    ) -> Result<(f32, f32)> {
        let spec = self.fns.manifest().fns["distill_step"].clone();
        let extras = CallExtras {
            data: Some(images),
            labels: None,
            lam,
            lr,
            teacher: Some(teacher),
        };
        let args = self.state.marshal(&spec, &extras)?;
        let outs = self.fns.get("distill_step")?.call(&args)?;
        let (loss, metric) = self.state.absorb(&spec, &outs)?;
        Ok((loss.unwrap_or(f32::NAN), metric.unwrap_or(f32::NAN)))
    }

    /// Apply one reverse-pruning pass through the exported HLO (Pallas clip
    /// kernel inside).
    pub fn reverse_prune(&mut self, fn_name: &str) -> Result<()> {
        let spec = self.fns.manifest().fns[fn_name].clone();
        let extras = CallExtras::default();
        let args = self.state.marshal(&spec, &extras)?;
        let outs = self.fns.get(fn_name)?.call(&args)?;
        self.state.absorb(&spec, &outs)?;
        Ok(())
    }

    /// FP32 eval forward on a batch; returns logits.
    pub fn forward(&mut self, images: &Tensor) -> Result<Tensor> {
        let spec = self.fns.manifest().fns["forward"].clone();
        let extras = CallExtras { data: Some(images), ..Default::default() };
        let args = self.state.marshal(&spec, &extras)?;
        let outs = self.fns.get("forward")?.call(&args)?;
        crate::runtime::literal_to_tensor(&outs[0], &spec.rets[0].shape)
    }

    /// Device-simulated (full fake-quant, Pallas kernels) forward.
    pub fn device_forward(&mut self, images: &Tensor) -> Result<Tensor> {
        let spec = self.fns.manifest().fns["device_forward"].clone();
        let extras = CallExtras { data: Some(images), ..Default::default() };
        let args = self.state.marshal(&spec, &extras)?;
        let outs = self.fns.get("device_forward")?.call(&args)?;
        crate::runtime::literal_to_tensor(&outs[0], &spec.rets[0].shape)
    }

    /// Evaluate classification accuracy + loss over batches.
    pub fn evaluate(&mut self, batches: &[Batch]) -> Result<(f64, f64)> {
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut loss = 0.0f64;
        for b in batches {
            let logits = self.forward(&b.images)?;
            let n = logits.shape[0];
            let c = logits.shape[1];
            for i in 0..n {
                let row = &logits.data[i * c..(i + 1) * c];
                let y = b.labels[i] as usize;
                let p = crate::metrics::softmax_row(row);
                loss -= (p[y].max(1e-12)).ln() as f64;
                // NaN logits must degrade to a miss, not a panic: an
                // all-NaN row has no argmax and counts as wrong.
                if crate::metrics::nan_safe_argmax(row) == Some(y) {
                    correct += 1;
                }
                total += 1;
            }
        }
        Ok((loss / total as f64, correct as f64 / total as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::EpochAccum;

    #[test]
    fn epoch_accum_excludes_nonfinite_steps_from_means() {
        let mut acc = EpochAccum::default();
        acc.push(2.0, 0.5);
        acc.push(f32::NAN, 0.5);
        acc.push(4.0, 1.0);
        acc.push(1.0, f32::INFINITY);
        let (loss, metric, bad) = acc.summary();
        assert_eq!(bad, 2);
        assert!((loss - 3.0).abs() < 1e-12);
        assert!((metric - 0.75).abs() < 1e-12);
    }

    #[test]
    fn epoch_accum_all_nonfinite_reports_nan_not_zero() {
        let mut acc = EpochAccum::default();
        acc.push(f32::NAN, f32::NAN);
        let (loss, metric, bad) = acc.summary();
        assert_eq!(bad, 1);
        assert!(loss.is_nan() && metric.is_nan());
    }

    #[test]
    fn epoch_accum_empty_epoch_is_visible() {
        let (loss, _, bad) = EpochAccum::default().summary();
        assert_eq!(bad, 0);
        assert!(loss.is_nan());
    }
}
