//! Sharded multi-node serving cluster over `std::net` — the scale tier that
//! turns one in-process [`Server`] fleet into many router-attached nodes.
//!
//! Topology:
//!
//! ```text
//!   clients ──HTTP──▶ Router ──consistent-hash(key)──▶ ClusterNode (1..N)
//!                       │        failover to replica        │
//!                       │  membership: register/heartbeat   │  wraps Server
//!                       ◀──────────/heartbeat───────────────┘  (PR 2/6)
//! ```
//!
//! * **[`ClusterNode`]** wraps the existing [`Server`] *unchanged* behind a
//!   minimal hand-rolled HTTP/1.1 front door ([`super::wire`]): `POST
//!   /infer` (binary tensor body), `GET /metrics` (every [`ServerStats`]
//!   counter via [`ServerStats::export`]), `GET /state`, `GET /healthz`.
//!   SLO lanes, dynamic batching, retries, per-deployment breakers, and
//!   chaos injection all compose with sharding because the node *is* a
//!   [`Server`].
//! * **[`Router`]** owns a consistent-hash ring ([`super::ring`]) over the
//!   registered nodes and forwards each `/infer` to the key's primary,
//!   failing over in ring order to the next replica when the primary's
//!   router-side circuit breaker (the PR 6 [`BreakerPolicy`] machinery) is
//!   open, the node was evicted, or the forward itself fails. Membership is
//!   registration + heartbeat + timeout-based eviction, implemented in the
//!   pure [`Membership`] struct (explicit `now` arguments — mock-clock
//!   testable with zero sleeps, see `rust/tests/cluster.rs`).
//! * **Replication**: a deployment lives on R nodes (placement via
//!   [`crate::coordinator::experiment::place_fleet_on_nodes`]); the router's
//!   replica walk only counts nodes that actually *host* the requested
//!   deployment, so failover always lands on a serving sibling.
//!
//! Everything is `std::net::TcpListener`/`TcpStream` + threads: the vendor
//! set is offline (no tokio/axum). All cluster-internal connections are
//! one-shot (`Connection: close`), which keeps node drain deterministic —
//! shutdown never waits on an idle keep-alive peer beyond the read timeout.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::ring::HashRing;
use super::server::{
    Breaker, BreakerPolicy, EngineModel, Outcome, Priority, Request, Server, ServerDeployment,
    ServerStats, SubmitError,
};
use super::wire::{
    decode_tensor, encode_tensor, http_call, read_request, write_response, HttpRequest,
    HttpResponse,
};
use crate::tensor::Tensor;

/// Metric-name prefix for node `/metrics` lines (`pallas_served 12` ...).
pub const NODE_METRICS_PREFIX: &str = "pallas";
/// Metric-name prefix for router `/metrics` lines.
pub const ROUTER_METRICS_PREFIX: &str = "pallas_router";

// ---------------------------------------------------------------------------
// Membership (pure: every transition takes an explicit `now`)
// ---------------------------------------------------------------------------

/// What the router knows about one registered node.
#[derive(Clone, Debug)]
pub struct MemberInfo {
    /// Node's HTTP listener address (always loopback in tests/benches).
    pub addr: SocketAddr,
    /// Deployments this node hosts, by name.
    pub deployments: BTreeSet<String>,
    /// Instant of the last heartbeat (or registration).
    pub last_heartbeat: Instant,
    /// Instant the node (re-)registered.
    pub joined: Instant,
}

/// Cluster membership + placement: registration, heartbeats, timeout-based
/// eviction, and the consistent-hash ring over the live nodes.
///
/// Pure state machine — every transition takes `now: Instant` explicitly
/// (the same mock-clock pattern the PR 6 breaker uses), so the full
/// registration -> heartbeat -> eviction lifecycle is testable with
/// synthetic instants and zero sleeps. The [`Router`] drives it with real
/// time.
pub struct Membership {
    members: BTreeMap<String, MemberInfo>,
    ring: HashRing,
    /// Bumped on every membership change (register/leave/evict) — lets
    /// `/state` consumers detect topology changes cheaply.
    epoch: u64,
}

impl Membership {
    /// Empty membership over a ring with `vnodes` virtual nodes per node.
    pub fn new(vnodes: usize) -> Membership {
        Membership { members: BTreeMap::new(), ring: HashRing::new(vnodes), epoch: 0 }
    }

    /// Register (or re-register) a node. Re-registration refreshes the
    /// address, deployment set, and heartbeat. Returns `true` if the node
    /// was new to the ring.
    pub fn register(
        &mut self,
        id: &str,
        addr: SocketAddr,
        deployments: impl IntoIterator<Item = String>,
        now: Instant,
    ) -> bool {
        let info = MemberInfo {
            addr,
            deployments: deployments.into_iter().collect(),
            last_heartbeat: now,
            joined: now,
        };
        let new = self.members.insert(id.to_string(), info).is_none();
        if new {
            self.ring.add_node(id);
        }
        self.epoch += 1;
        new
    }

    /// Record a heartbeat. Returns `false` for an unknown (never-registered
    /// or already-evicted) node — the node should re-register.
    pub fn heartbeat(&mut self, id: &str, now: Instant) -> bool {
        match self.members.get_mut(id) {
            Some(m) => {
                m.last_heartbeat = now;
                true
            }
            None => false,
        }
    }

    /// Voluntary leave: remove the node from the ring immediately. Returns
    /// `false` if the node wasn't a member.
    pub fn leave(&mut self, id: &str) -> bool {
        let existed = self.members.remove(id).is_some();
        if existed {
            self.ring.remove_node(id);
            self.epoch += 1;
        }
        existed
    }

    /// Evict every node whose last heartbeat is older than `timeout`,
    /// returning the evicted ids (sorted, since members iterate sorted).
    pub fn evict_stale(&mut self, timeout: Duration, now: Instant) -> Vec<String> {
        let stale: Vec<String> = self
            .members
            .iter()
            .filter(|(_, m)| now.saturating_duration_since(m.last_heartbeat) > timeout)
            .map(|(id, _)| id.clone())
            .collect();
        for id in &stale {
            self.members.remove(id);
            self.ring.remove_node(id);
            self.epoch += 1;
        }
        stale
    }

    /// The first `r` live nodes in ring order from `key` that host
    /// `deployment` (any node when `deployment` is `None`) — primary first.
    /// Walking the *full* ring order before filtering means replication
    /// degrades gracefully: if the key's primary doesn't host the model, its
    /// successor that does becomes the effective primary.
    pub fn replicas_for(
        &self,
        key: &str,
        deployment: Option<&str>,
        r: usize,
    ) -> Vec<(String, SocketAddr)> {
        self.ring
            .replicas(key, self.ring.len())
            .into_iter()
            .filter_map(|id| {
                let m = self.members.get(id)?;
                match deployment {
                    Some(d) if !m.deployments.contains(d) => None,
                    _ => Some((id.to_string(), m.addr)),
                }
            })
            .take(r)
            .collect()
    }

    /// Number of live members.
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True when no node is registered.
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Is this node currently a member?
    pub fn contains(&self, id: &str) -> bool {
        self.members.contains_key(id)
    }

    /// Live members, sorted by id.
    pub fn members(&self) -> impl Iterator<Item = (&str, &MemberInfo)> {
        self.members.iter().map(|(id, m)| (id.as_str(), m))
    }

    /// Membership epoch: bumps on every register/leave/evict.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

// ---------------------------------------------------------------------------
// Connection plumbing shared by node and router
// ---------------------------------------------------------------------------

/// A [`Server`] that can be shut down while connection handlers still hold
/// references: `submit` goes through a read lock, shutdown takes the server
/// out under the write lock (subsequent submits get `ShutDown`).
struct ServerCell {
    inner: RwLock<Option<Server>>,
}

impl ServerCell {
    fn new(server: Server) -> ServerCell {
        ServerCell { inner: RwLock::new(Some(server)) }
    }

    fn submit(&self, req: Request) -> Result<(), SubmitError> {
        match &*self.inner.read().unwrap() {
            Some(s) => s.submit(req),
            None => Err(SubmitError::ShutDown(req)),
        }
    }

    fn stats_snapshot(&self) -> Option<ServerStats> {
        self.inner.read().unwrap().as_ref().map(|s| s.stats_snapshot())
    }

    fn queue_len(&self) -> usize {
        self.inner.read().unwrap().as_ref().map(|s| s.queue_len()).unwrap_or(0)
    }

    fn take(&self) -> Option<Server> {
        self.inner.write().unwrap().take()
    }

    /// Delegate an audit-gated model hot-swap to the wrapped server (read
    /// lock: swaps don't block concurrent submits on the cell).
    fn swap_model(
        &self,
        deployment: &str,
        candidate: EngineModel,
    ) -> Result<crate::engine::verify::AuditReport> {
        match &*self.inner.read().unwrap() {
            Some(s) => s.swap_model(deployment, candidate),
            None => bail!("node is shut down"),
        }
    }
}

/// Accept loop + per-connection handler threads with joinable shutdown.
/// Handlers run `serve` per parsed request until the connection closes, the
/// stop flag rises, or the client pipelines past `Connection: close`.
struct Acceptor {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Acceptor {
    /// Bind `127.0.0.1:0` (or a caller-given address) and start accepting.
    fn start<F>(bind: &str, read_timeout: Duration, thread_name: &str, serve: F) -> Result<Acceptor>
    where
        F: Fn(&HttpRequest) -> (u16, &'static str, Vec<(String, String)>, Vec<u8>)
            + Send
            + Sync
            + 'static,
    {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let serve = Arc::new(serve);
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("{thread_name}-accept"))
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let stop = stop.clone();
                        let serve = serve.clone();
                        let h = std::thread::Builder::new()
                            .name("cluster-conn".into())
                            .spawn(move || handle_connection(stream, read_timeout, &stop, &*serve))
                            .expect("spawn connection handler");
                        conns.lock().unwrap().push(h);
                    }
                })
                .with_context(|| format!("spawning {thread_name} accept loop"))?
        };
        Ok(Acceptor { addr, stop, accept: Some(accept), conns })
    }

    /// Stop accepting and join every connection handler. Idempotent.
    fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept() call with a throwaway connection to ourselves
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        loop {
            // pop under the lock, join outside it
            let handle = self.conns.lock().unwrap().pop();
            let Some(h) = handle else { break };
            let _ = h.join();
        }
    }
}

impl Drop for Acceptor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Keep-alive connection loop: parse -> serve -> answer, until close. Parse
/// failures are answered with their [`super::wire::WireError::status`]
/// (400/431/413) and
/// the connection closes; transport errors just close — never a panic, and
/// the read timeout bounds how long a silent peer can hold the handler.
fn handle_connection<F>(stream: TcpStream, read_timeout: Duration, stop: &AtomicBool, serve: &F)
where
    F: Fn(&HttpRequest) -> (u16, &'static str, Vec<(String, String)>, Vec<u8>),
{
    let _ = stream.set_read_timeout(Some(read_timeout));
    let _ = stream.set_write_timeout(Some(read_timeout));
    let _ = stream.set_nodelay(true);
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut write_half = &stream;
    loop {
        match read_request(&mut reader) {
            Ok(None) => return, // clean EOF
            Ok(Some(req)) => {
                let keep = req.keep_alive() && !stop.load(Ordering::SeqCst);
                let (status, ctype, headers, body) = serve(&req);
                let hdrs: Vec<(&str, &str)> =
                    headers.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
                if write_response(&mut write_half, status, ctype, &hdrs, &body, keep).is_err() {
                    return;
                }
                if !keep {
                    return;
                }
            }
            Err(e) => {
                let status = e.status();
                if status != 0 {
                    let msg = e.to_string();
                    let _ = write_response(
                        &mut write_half,
                        status,
                        "text/plain",
                        &[],
                        msg.as_bytes(),
                        false,
                    );
                }
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// ClusterNode
// ---------------------------------------------------------------------------

/// Sizing and timing knobs for one [`ClusterNode`].
#[derive(Clone, Debug)]
pub struct NodeConfig {
    /// Configuration of the wrapped [`Server`] (workers, queue, SLO lanes,
    /// retries, breakers — all of PR 2/6 composes under the cluster).
    pub server: super::server::ServerConfig,
    /// How long `/infer` waits on the server's reply channel before
    /// answering 500 (the server contract says every accepted request is
    /// answered, so this only fires if the node is truly wedged).
    pub request_timeout: Duration,
    /// Per-connection socket read timeout: bounds how long a silent or
    /// half-open peer can hold a handler thread (and therefore drain).
    pub read_timeout: Duration,
    /// Heartbeat period when attached to a router.
    pub heartbeat_every: Duration,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig {
            server: super::server::ServerConfig::default(),
            request_timeout: Duration::from_secs(30),
            read_timeout: Duration::from_secs(2),
            heartbeat_every: Duration::from_millis(100),
        }
    }
}

/// One serving node: the existing [`Server`] (unchanged) behind an HTTP
/// front door on a loopback/LAN `TcpListener`, optionally attached to a
/// [`Router`] via register + heartbeat. See the module docs for endpoints.
pub struct ClusterNode {
    id: String,
    addr: SocketAddr,
    deployments: Vec<String>,
    server: Arc<ServerCell>,
    acceptor: Acceptor,
    heartbeat: Option<std::thread::JoinHandle<()>>,
    hb_stop: Arc<AtomicBool>,
    router: Option<SocketAddr>,
    heartbeat_every: Duration,
}

impl ClusterNode {
    /// Start a node: spin up the wrapped [`Server`] over `deployments`, bind
    /// an ephemeral loopback port, and — when `router` is given — register
    /// there and heartbeat every [`NodeConfig::heartbeat_every`].
    pub fn start(
        id: impl Into<String>,
        deployments: Vec<ServerDeployment>,
        cfg: NodeConfig,
        router: Option<SocketAddr>,
    ) -> Result<ClusterNode> {
        let id = id.into();
        let names: Vec<String> = deployments.iter().map(|d| d.name.clone()).collect();
        let server = Arc::new(ServerCell::new(Server::start(deployments, cfg.server.clone())?));
        let acceptor = {
            let server = server.clone();
            let id = id.clone();
            let names = names.clone();
            let request_timeout = cfg.request_timeout;
            Acceptor::start("127.0.0.1:0", cfg.read_timeout, "cluster-node", move |req| {
                serve_node_request(req, &server, &id, &names, request_timeout)
            })?
        };
        let addr = acceptor.addr;
        let hb_stop = Arc::new(AtomicBool::new(false));
        let heartbeat = match router {
            None => None,
            Some(router_addr) => {
                let stop = hb_stop.clone();
                let id = id.clone();
                let names = names.clone();
                let every = cfg.heartbeat_every;
                Some(
                    std::thread::Builder::new()
                        .name("cluster-node-heartbeat".into())
                        .spawn(move || heartbeat_loop(router_addr, &id, addr, &names, every, &stop))
                        .context("spawning heartbeat thread")?,
                )
            }
        };
        Ok(ClusterNode {
            id,
            addr,
            deployments: names,
            server,
            acceptor,
            heartbeat,
            hb_stop,
            router,
            heartbeat_every: cfg.heartbeat_every,
        })
    }

    /// This node's HTTP listener address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// This node's cluster id.
    pub fn id(&self) -> &str {
        &self.id
    }

    /// Names of the deployments this node hosts.
    pub fn deployments(&self) -> &[String] {
        &self.deployments
    }

    /// Live stats snapshot of the wrapped server (None once shut down).
    pub fn stats_snapshot(&self) -> Option<ServerStats> {
        self.server.stats_snapshot()
    }

    /// Hot-swap one hosted deployment's model under live traffic, gated on
    /// a clean audit ([`Server::swap_model`] semantics: an ERROR finding
    /// refuses the swap and the incumbent keeps serving).
    pub fn swap_model(
        &self,
        deployment: &str,
        candidate: EngineModel,
    ) -> Result<crate::engine::verify::AuditReport> {
        self.server.swap_model(deployment, candidate)
    }

    /// Graceful leave + drain: deregister from the router (new traffic
    /// reroutes to replicas), stop accepting connections, finish in-flight
    /// requests, drain the wrapped server, and return its final stats.
    /// In-flight forwards that race the listener teardown fail over at the
    /// router — zero *accepted* requests are lost either way.
    pub fn shutdown(mut self) -> ServerStats {
        // 1. tell the router first so new routes avoid this node
        self.hb_stop.store(true, Ordering::SeqCst);
        if let Some(router) = self.router {
            let _ = http_call(
                router,
                "POST",
                &format!("/leave?id={}", self.id),
                &[],
                b"",
                Duration::from_secs(2),
            );
        }
        if let Some(h) = self.heartbeat.take() {
            let _ = h.join();
        }
        // 2. stop accepting and finish every in-flight connection
        self.acceptor.shutdown();
        // 3. drain the wrapped server (its shutdown answers everything it
        //    accepted) and hand the stats up
        match self.server.take() {
            Some(server) => server.shutdown(),
            None => ServerStats::default(),
        }
    }

    /// Heartbeat period this node was started with (diagnostics).
    pub fn heartbeat_every(&self) -> Duration {
        self.heartbeat_every
    }
}

/// Register with the router (retrying — the router may come up after the
/// node), then heartbeat every `every` until stopped, re-registering if the
/// router forgot us (eviction during a long GC pause, router restart).
fn heartbeat_loop(
    router: SocketAddr,
    id: &str,
    addr: SocketAddr,
    deployments: &[String],
    every: Duration,
    stop: &AtomicBool,
) {
    let register_target =
        format!("/register?id={id}&addr={addr}&deployments={}", deployments.join(","));
    let timeout = Duration::from_secs(2);
    let mut registered = false;
    while !stop.load(Ordering::SeqCst) {
        if !registered {
            registered = http_call(router, "POST", &register_target, &[], b"", timeout)
                .is_ok_and(|r| r.status == 200);
        } else {
            // a rejected heartbeat means the router no longer knows us;
            // fall back to re-registration on the next tick
            registered = http_call(router, "POST", &format!("/heartbeat?id={id}"), &[], b"", timeout)
                .is_ok_and(|r| r.status == 200);
        }
        // sleep in short slices so shutdown never waits a full period
        let deadline = Instant::now() + every;
        while !stop.load(Ordering::SeqCst) && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5).min(every));
        }
    }
}

type ServeReply = (u16, &'static str, Vec<(String, String)>, Vec<u8>);

fn text_reply(status: u16, msg: impl Into<String>) -> ServeReply {
    (status, "text/plain", Vec::new(), msg.into().into_bytes())
}

/// Node-side request dispatch (`/infer`, `/metrics`, `/state`, `/healthz`).
fn serve_node_request(
    req: &HttpRequest,
    server: &ServerCell,
    id: &str,
    deployments: &[String],
    request_timeout: Duration,
) -> ServeReply {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/infer") => serve_node_infer(req, server, id, request_timeout),
        ("GET", "/metrics") => match server.stats_snapshot() {
            Some(stats) => (
                200,
                "text/plain",
                Vec::new(),
                stats.render_metrics(NODE_METRICS_PREFIX).into_bytes(),
            ),
            None => text_reply(503, "node draining"),
        },
        ("GET", "/state") => {
            let deps: Vec<String> = deployments.iter().map(|d| format!("\"{d}\"")).collect();
            let body = format!(
                "{{\"id\": \"{id}\", \"deployments\": [{}], \"queue_len\": {}, \"draining\": {}}}\n",
                deps.join(", "),
                server.queue_len(),
                server.stats_snapshot().is_none(),
            );
            (200, "application/json", Vec::new(), body.into_bytes())
        }
        ("GET", "/healthz") => text_reply(200, "ok"),
        ("POST" | "GET", _) => text_reply(404, format!("no such endpoint {}", req.path)),
        _ => text_reply(405, format!("method {} not supported", req.method)),
    }
}

/// `POST /infer?deployment=NAME` with a binary tensor body: submit to the
/// wrapped server, wait for its response, and translate the [`Outcome`] to
/// HTTP (Served -> 200 + logits body, Failed -> 502, Expired -> 504;
/// submit-side backpressure -> 429, draining -> 503).
fn serve_node_infer(
    req: &HttpRequest,
    server: &ServerCell,
    id: &str,
    request_timeout: Duration,
) -> ServeReply {
    let image = match decode_tensor(&req.body) {
        Ok(t) => t,
        Err(e) => return text_reply(400, format!("bad tensor body: {e}")),
    };
    let deadline = req
        .header("x-deadline-ms")
        .and_then(|v| v.parse::<u64>().ok())
        .map(|ms| Instant::now() + Duration::from_millis(ms));
    let priority = match req.header("x-priority") {
        Some("low") => Priority::Low,
        Some("high") => Priority::High,
        _ => Priority::Normal,
    };
    let (tx, rx) = mpsc::channel();
    let request = Request {
        image,
        deployment: req.query("deployment").map(|s| s.to_string()),
        reply: tx,
        submitted: Instant::now(),
        deadline,
        priority,
    };
    if let Err(e) = server.submit(request) {
        return match e {
            SubmitError::QueueFull(_) => text_reply(429, "ingress queue full"),
            SubmitError::Shed(_) => text_reply(429, "low-priority request shed under overload"),
            SubmitError::ShutDown(_) => text_reply(503, "node draining"),
        };
    }
    let resp = match rx.recv_timeout(request_timeout) {
        Ok(r) => r,
        Err(_) => return text_reply(500, "node wedged: no response within request timeout"),
    };
    let mut headers = vec![
        ("X-Node".to_string(), id.to_string()),
        ("X-Deployment".to_string(), resp.deployment.clone()),
        ("X-Degraded".to_string(), if resp.degraded { "1" } else { "0" }.to_string()),
        ("X-Batch-Size".to_string(), resp.batch_size.to_string()),
        ("X-Retries".to_string(), resp.retries.to_string()),
    ];
    match (&resp.outcome, &resp.result) {
        (Outcome::Served, Ok(logits)) => {
            let body = encode_tensor(&Tensor::new(vec![logits.len()], logits.clone()));
            headers.push(("X-Outcome".to_string(), "served".to_string()));
            (200, "application/octet-stream", headers, body)
        }
        (Outcome::Expired, _) => {
            headers.push(("X-Outcome".to_string(), "expired".to_string()));
            (504, "text/plain", headers, b"deadline expired before execution".to_vec())
        }
        (Outcome::Failed, Err(msg)) => {
            headers.push(("X-Outcome".to_string(), "failed".to_string()));
            (502, "text/plain", headers, msg.clone().into_bytes())
        }
        // unreachable by the server contract (Served always carries logits,
        // Failed always carries an error), but the parser must stay total
        _ => (500, "text/plain", headers, b"inconsistent server response".to_vec()),
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// Routing, membership, and failover knobs for one [`Router`].
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Replica walk length: a request may fail over across up to this many
    /// hosting nodes (primary included).
    pub replication: usize,
    /// Virtual nodes per physical node on the ring (>=128 keeps the key
    /// share balanced; see `rust/tests/hash_ring.rs`).
    pub vnodes: usize,
    /// A node whose last heartbeat is older than this is evicted.
    pub heartbeat_timeout: Duration,
    /// Eviction sweep period.
    pub sweep_every: Duration,
    /// Router-side per-node circuit breaker (PR 6 semantics: consecutive
    /// forward failures trip it open; cooldown then half-open probe).
    pub breaker: BreakerPolicy,
    /// Timeout for one forwarded `/infer` (connect + node-side execution).
    pub forward_timeout: Duration,
    /// Per-connection socket read timeout on the front door.
    pub read_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            replication: 2,
            vnodes: 128,
            heartbeat_timeout: Duration::from_secs(1),
            sweep_every: Duration::from_millis(100),
            breaker: BreakerPolicy::default(),
            forward_timeout: Duration::from_secs(30),
            read_timeout: Duration::from_secs(2),
        }
    }
}

/// Router counters, live in shared atomics (scraped by `/metrics`, snapshot
/// at shutdown).
#[derive(Default)]
struct RouterCounters {
    routed: AtomicUsize,
    forwarded_ok: AtomicUsize,
    failovers: AtomicUsize,
    forward_errors: AtomicUsize,
    no_replica: AtomicUsize,
    registered: AtomicUsize,
    heartbeats: AtomicUsize,
    left: AtomicUsize,
    evicted: AtomicUsize,
    bad_requests: AtomicUsize,
}

/// Snapshot of the router's counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// `/infer` requests the router accepted for routing.
    pub routed: usize,
    /// Forwards that came back 200 from a node.
    pub forwarded_ok: usize,
    /// Times the router moved past a replica (breaker-open skip, transport
    /// failure, or a node-side 5xx/429 answer).
    pub failovers: usize,
    /// Forwards that failed at the transport (connect/timeout/reset).
    pub forward_errors: usize,
    /// `/infer` requests with no live hosting replica (answered 503).
    pub no_replica: usize,
    /// Successful `/register` calls.
    pub registered: usize,
    /// Accepted heartbeats.
    pub heartbeats: usize,
    /// Voluntary `/leave` departures.
    pub left: usize,
    /// Nodes evicted by heartbeat timeout.
    pub evicted: usize,
    /// Requests answered 4xx at the front door (parse/validation failures).
    pub bad_requests: usize,
}

impl RouterStats {
    /// Every router counter as `(name, value)` pairs — same exhaustive-
    /// destructuring discipline as [`ServerStats::export`], so a new counter
    /// cannot be silently dropped from `/metrics`.
    pub fn export(&self) -> Vec<(&'static str, f64)> {
        let RouterStats {
            routed,
            forwarded_ok,
            failovers,
            forward_errors,
            no_replica,
            registered,
            heartbeats,
            left,
            evicted,
            bad_requests,
        } = self;
        vec![
            ("routed", *routed as f64),
            ("forwarded_ok", *forwarded_ok as f64),
            ("failovers", *failovers as f64),
            ("forward_errors", *forward_errors as f64),
            ("no_replica", *no_replica as f64),
            ("registered", *registered as f64),
            ("heartbeats", *heartbeats as f64),
            ("left", *left as f64),
            ("evicted", *evicted as f64),
            ("bad_requests", *bad_requests as f64),
        ]
    }

    /// Plain-text exposition (`<prefix>_<name> <value>` lines).
    pub fn render_metrics(&self, prefix: &str) -> String {
        let mut out = String::new();
        for (name, value) in self.export() {
            out.push_str(&format!("{prefix}_{name} {value}\n"));
        }
        out
    }
}

impl RouterCounters {
    fn snapshot(&self) -> RouterStats {
        let ld = Ordering::Relaxed;
        RouterStats {
            routed: self.routed.load(ld),
            forwarded_ok: self.forwarded_ok.load(ld),
            failovers: self.failovers.load(ld),
            forward_errors: self.forward_errors.load(ld),
            no_replica: self.no_replica.load(ld),
            registered: self.registered.load(ld),
            heartbeats: self.heartbeats.load(ld),
            left: self.left.load(ld),
            evicted: self.evicted.load(ld),
            bad_requests: self.bad_requests.load(ld),
        }
    }

    fn bump(&self, c: &AtomicUsize) {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// Everything the router's request handlers share.
struct RouterCore {
    cfg: RouterConfig,
    membership: Mutex<Membership>,
    /// Router-side breaker per node id. Entries persist across
    /// eviction/re-registration so a flapping node re-joins with its
    /// history.
    breakers: Mutex<HashMap<String, Arc<Breaker>>>,
    counters: RouterCounters,
}

impl RouterCore {
    fn breaker_for(&self, id: &str) -> Arc<Breaker> {
        self.breakers
            .lock()
            .unwrap()
            .entry(id.to_string())
            .or_insert_with(|| Arc::new(Breaker::new(self.cfg.breaker)))
            .clone()
    }
}

/// The cluster front door: consistent-hash request routing with replica
/// failover, plus the membership endpoints. See the module docs.
pub struct Router {
    core: Arc<RouterCore>,
    acceptor: Acceptor,
    sweep_stop: Arc<AtomicBool>,
    sweeper: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Bind the front door on an ephemeral loopback port and start the
    /// eviction sweeper.
    pub fn start(cfg: RouterConfig) -> Result<Router> {
        let core = Arc::new(RouterCore {
            membership: Mutex::new(Membership::new(cfg.vnodes)),
            breakers: Mutex::new(HashMap::new()),
            counters: RouterCounters::default(),
            cfg: cfg.clone(),
        });
        let acceptor = {
            let core = core.clone();
            Acceptor::start("127.0.0.1:0", cfg.read_timeout, "cluster-router", move |req| {
                serve_router_request(req, &core)
            })?
        };
        let sweep_stop = Arc::new(AtomicBool::new(false));
        let sweeper = {
            let core = core.clone();
            let stop = sweep_stop.clone();
            std::thread::Builder::new()
                .name("cluster-router-sweep".into())
                .spawn(move || {
                    while !stop.load(Ordering::SeqCst) {
                        std::thread::sleep(core.cfg.sweep_every);
                        let evicted = core
                            .membership
                            .lock()
                            .unwrap()
                            .evict_stale(core.cfg.heartbeat_timeout, Instant::now());
                        for _ in &evicted {
                            core.counters.bump(&core.counters.evicted);
                        }
                    }
                })
                .context("spawning eviction sweeper")?
        };
        Ok(Router { core, acceptor, sweep_stop, sweeper: Some(sweeper) })
    }

    /// The front door's address (hand this to clients and nodes).
    pub fn addr(&self) -> SocketAddr {
        self.acceptor.addr
    }

    /// Register a node directly (tests and in-process wiring; the HTTP
    /// `/register` endpoint is the same transition).
    pub fn admit(&self, id: &str, addr: SocketAddr, deployments: &[String]) {
        self.core.membership.lock().unwrap().register(
            id,
            addr,
            deployments.iter().cloned(),
            Instant::now(),
        );
        self.core.counters.bump(&self.core.counters.registered);
    }

    /// Live membership size (diagnostics).
    pub fn members(&self) -> usize {
        self.core.membership.lock().unwrap().len()
    }

    /// Current membership epoch (bumps on register/leave/evict).
    pub fn epoch(&self) -> u64 {
        self.core.membership.lock().unwrap().epoch()
    }

    /// Live counter snapshot.
    pub fn stats(&self) -> RouterStats {
        self.core.counters.snapshot()
    }

    /// Stop the sweeper and the front door (in-flight forwards complete),
    /// returning the final counters.
    pub fn shutdown(mut self) -> RouterStats {
        self.sweep_stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
        self.acceptor.shutdown();
        self.core.counters.snapshot()
    }
}

/// Router-side request dispatch.
fn serve_router_request(req: &HttpRequest, core: &RouterCore) -> ServeReply {
    let counters = &core.counters;
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/infer") => serve_router_infer(req, core),
        ("POST", "/register") => {
            let (Some(id), Some(addr)) = (req.query("id"), req.query("addr")) else {
                counters.bump(&counters.bad_requests);
                return text_reply(400, "register needs id= and addr=");
            };
            let Ok(addr) = addr.parse::<SocketAddr>() else {
                counters.bump(&counters.bad_requests);
                return text_reply(400, format!("bad addr {:?}", addr));
            };
            let deployments = req
                .query("deployments")
                .unwrap_or("")
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.to_string());
            core.membership.lock().unwrap().register(id, addr, deployments, Instant::now());
            counters.bump(&counters.registered);
            text_reply(200, "registered")
        }
        ("POST", "/heartbeat") => {
            let Some(id) = req.query("id") else {
                counters.bump(&counters.bad_requests);
                return text_reply(400, "heartbeat needs id=");
            };
            if core.membership.lock().unwrap().heartbeat(id, Instant::now()) {
                counters.bump(&counters.heartbeats);
                text_reply(200, "ok")
            } else {
                text_reply(404, "unknown node; re-register")
            }
        }
        ("POST", "/leave") => {
            let Some(id) = req.query("id") else {
                counters.bump(&counters.bad_requests);
                return text_reply(400, "leave needs id=");
            };
            if core.membership.lock().unwrap().leave(id) {
                counters.bump(&counters.left);
                text_reply(200, "left")
            } else {
                text_reply(404, "unknown node")
            }
        }
        ("GET", "/metrics") => (
            200,
            "text/plain",
            Vec::new(),
            core.counters.snapshot().render_metrics(ROUTER_METRICS_PREFIX).into_bytes(),
        ),
        ("GET", "/state") => (200, "application/json", Vec::new(), router_state_json(core)),
        ("GET", "/healthz") => text_reply(200, "ok"),
        ("POST" | "GET", _) => text_reply(404, format!("no such endpoint {}", req.path)),
        _ => text_reply(405, format!("method {} not supported", req.method)),
    }
}

/// `/state`: membership, per-node breaker state, and routing config as JSON.
fn router_state_json(core: &RouterCore) -> Vec<u8> {
    let now = Instant::now();
    let membership = core.membership.lock().unwrap();
    let mut members = Vec::new();
    for (id, m) in membership.members() {
        let deps: Vec<String> = m.deployments.iter().map(|d| format!("\"{d}\"")).collect();
        let breaker = core.breaker_for(id).state_label(now);
        members.push(format!(
            "    {{\"id\": \"{id}\", \"addr\": \"{}\", \"deployments\": [{}], \
             \"heartbeat_age_ms\": {:.1}, \"breaker\": \"{breaker}\"}}",
            m.addr,
            deps.join(", "),
            now.saturating_duration_since(m.last_heartbeat).as_secs_f64() * 1e3,
        ));
    }
    format!(
        "{{\n  \"epoch\": {},\n  \"nodes\": {},\n  \"replication\": {},\n  \"vnodes\": {},\n  \"members\": [\n{}\n  ]\n}}\n",
        membership.epoch(),
        membership.len(),
        core.cfg.replication,
        core.cfg.vnodes,
        members.join(",\n"),
    )
    .into_bytes()
}

/// Headers worth relaying from a node's `/infer` answer to the client.
const RELAY_HEADERS: [&str; 5] =
    ["x-node", "x-deployment", "x-degraded", "x-batch-size", "x-outcome"];

/// `POST /infer?deployment=D&key=K`: walk the key's replica set in ring
/// order, skipping nodes whose router-side breaker is open, forwarding to
/// the first candidate; a transport failure or a node-side 5xx/429 records
/// a breaker failure and fails over to the next replica. The sharding key
/// defaults to a stable hash of the body, so keyless clients still spread.
fn serve_router_infer(req: &HttpRequest, core: &RouterCore) -> ServeReply {
    let counters = &core.counters;
    counters.bump(&counters.routed);
    let deployment = req.query("deployment");
    let key = match req.query("key") {
        Some(k) => k.to_string(),
        None => format!("body-{:016x}", super::ring::stable_hash(&req.body)),
    };
    let candidates = {
        let membership = core.membership.lock().unwrap();
        membership.replicas_for(&key, deployment, core.cfg.replication)
    };
    if candidates.is_empty() {
        counters.bump(&counters.no_replica);
        return text_reply(
            503,
            match deployment {
                Some(d) => format!("no live node hosts deployment {d:?}"),
                None => "no live nodes".to_string(),
            },
        );
    }
    let mut target = format!("/infer?key={key}");
    if let Some(d) = deployment {
        target.push_str(&format!("&deployment={d}"));
    }
    let fwd_headers: Vec<(&str, &str)> = req
        .headers
        .iter()
        .filter(|(k, _)| k.as_str() == "x-deadline-ms" || k.as_str() == "x-priority")
        .map(|(k, v)| (k.as_str(), v.as_str()))
        .collect();
    let mut hops = 0u32;
    let mut last_failure: Option<ServeReply> = None;
    for (id, addr) in &candidates {
        let breaker = core.breaker_for(id);
        if !breaker.allows(Instant::now()) {
            counters.bump(&counters.failovers);
            hops += 1;
            continue;
        }
        let forwarded = http_call(
            *addr,
            "POST",
            &target,
            &fwd_headers,
            &req.body,
            core.cfg.forward_timeout,
        );
        match forwarded {
            Ok(resp) if resp.status == 200 => {
                breaker.record(true, Instant::now());
                counters.bump(&counters.forwarded_ok);
                return relay(resp, hops);
            }
            // 4xx from the node is the client's fault (bad tensor, unknown
            // deployment on a hosting node, oversized body): relay verbatim,
            // no breaker penalty, no failover — every replica would agree.
            Ok(resp) if resp.status < 500 && resp.status != 429 => {
                breaker.record(true, Instant::now());
                return relay(resp, hops);
            }
            // node-side overload (429) or failure (5xx): penalize + fail over
            Ok(resp) => {
                breaker.record(false, Instant::now());
                counters.bump(&counters.failovers);
                hops += 1;
                last_failure = Some(relay(resp, hops));
            }
            Err(_) => {
                breaker.record(false, Instant::now());
                counters.bump(&counters.forward_errors);
                counters.bump(&counters.failovers);
                hops += 1;
                last_failure =
                    Some(text_reply(502, format!("forward to node {id:?} ({addr}) failed")));
            }
        }
    }
    last_failure.unwrap_or_else(|| {
        text_reply(503, "all replicas skipped by open circuit breakers".to_string())
    })
}

/// Relay a node response to the client, preserving the diagnostic headers
/// and stamping the failover count.
fn relay(resp: HttpResponse, hops: u32) -> ServeReply {
    let mut headers: Vec<(String, String)> = Vec::new();
    for name in RELAY_HEADERS {
        if let Some(v) = resp.header(name) {
            headers.push((name.to_string(), v.to_string()));
        }
    }
    headers.push(("X-Failovers".to_string(), hops.to_string()));
    let ctype = if resp.status == 200 { "application/octet-stream" } else { "text/plain" };
    (resp.status, ctype, headers, resp.body)
}

// ---------------------------------------------------------------------------
// Client helper
// ---------------------------------------------------------------------------

/// One `/infer` answer as seen by a cluster client.
#[derive(Debug)]
pub struct InferReply {
    /// HTTP status (200 = served).
    pub status: u16,
    /// Decoded logits on success.
    pub logits: Option<Tensor>,
    /// Node that executed (X-Node).
    pub node: Option<String>,
    /// Server deployment that executed (X-Deployment).
    pub deployment: Option<String>,
    /// The node's server served this via a fallback sibling.
    pub degraded: bool,
    /// Replicas the router skipped/failed over before this answer.
    pub failovers: u32,
    /// Error text for non-200 answers.
    pub error: Option<String>,
}

impl InferReply {
    /// True when the request was served with logits.
    pub fn is_served(&self) -> bool {
        self.status == 200 && self.logits.is_some()
    }
}

/// Send one image to a cluster front door (router or node) and decode the
/// answer. `key` drives consistent-hash placement (defaults to a body hash
/// at the router); `deadline_ms` becomes the node-side SLO deadline.
pub fn infer(
    addr: SocketAddr,
    deployment: Option<&str>,
    key: Option<&str>,
    image: &Tensor,
    deadline_ms: Option<u64>,
    timeout: Duration,
) -> Result<InferReply> {
    let mut target = String::from("/infer");
    let mut sep = '?';
    if let Some(d) = deployment {
        target.push_str(&format!("{sep}deployment={d}"));
        sep = '&';
    }
    if let Some(k) = key {
        target.push_str(&format!("{sep}key={k}"));
    }
    let deadline_hdr = deadline_ms.map(|ms| ms.to_string());
    let mut headers: Vec<(&str, &str)> = Vec::new();
    if let Some(ms) = &deadline_hdr {
        headers.push(("X-Deadline-Ms", ms));
    }
    let resp = http_call(addr, "POST", &target, &headers, &encode_tensor(image), timeout)?;
    let logits = if resp.status == 200 { decode_tensor(&resp.body).ok() } else { None };
    Ok(InferReply {
        status: resp.status,
        node: resp.header("x-node").map(|s| s.to_string()),
        deployment: resp.header("x-deployment").map(|s| s.to_string()),
        degraded: resp.header("x-degraded") == Some("1"),
        failovers: resp
            .header("x-failovers")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0),
        error: if resp.status == 200 { None } else { Some(resp.text()) },
        logits,
    })
}

/// Fetch and parse a `/metrics` endpoint into `name -> value` pairs
/// (inverse of [`ServerStats::render_metrics`] — used by the counter-export
/// regression test and ops tooling).
pub fn scrape_metrics(addr: SocketAddr, timeout: Duration) -> Result<BTreeMap<String, f64>> {
    let resp = http_call(addr, "GET", "/metrics", &[], b"", timeout)?;
    anyhow::ensure!(resp.status == 200, "/metrics answered {}", resp.status);
    let mut out = BTreeMap::new();
    for line in resp.text().lines() {
        if let Some((name, value)) = line.rsplit_once(' ') {
            if let Ok(v) = value.parse::<f64>() {
                out.insert(name.to_string(), v);
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addr(port: u16) -> SocketAddr {
        format!("127.0.0.1:{port}").parse().unwrap()
    }

    #[test]
    fn membership_register_heartbeat_evict_with_mock_clock() {
        let t0 = Instant::now();
        let t = |ms: u64| t0 + Duration::from_millis(ms);
        let mut m = Membership::new(64);
        assert!(m.register("a", addr(9001), ["m".to_string()], t(0)));
        assert!(m.register("b", addr(9002), ["m".to_string()], t(0)));
        assert!(!m.register("a", addr(9001), ["m".to_string()], t(10)), "re-register not new");
        assert_eq!(m.len(), 2);
        // b heartbeats, a goes silent
        assert!(m.heartbeat("b", t(500)));
        assert!(!m.heartbeat("ghost", t(500)));
        let evicted = m.evict_stale(Duration::from_millis(400), t(600));
        assert_eq!(evicted, vec!["a".to_string()], "a's last beat was t0");
        assert!(m.contains("b") && !m.contains("a"));
        // an evicted node's heartbeat is refused until it re-registers
        assert!(!m.heartbeat("a", t(700)));
        assert!(m.register("a", addr(9001), ["m".to_string()], t(700)));
        assert!(m.heartbeat("a", t(800)));
    }

    #[test]
    fn replicas_filter_by_hosted_deployment() {
        let now = Instant::now();
        let mut m = Membership::new(64);
        m.register("a", addr(9001), ["x".to_string()], now);
        m.register("b", addr(9002), ["y".to_string()], now);
        m.register("c", addr(9003), ["x".to_string(), "y".to_string()], now);
        for key in ["k1", "k2", "k3", "k4"] {
            let xs = m.replicas_for(key, Some("x"), 3);
            assert_eq!(xs.len(), 2, "only a and c host x");
            assert!(xs.iter().all(|(id, _)| id == "a" || id == "c"));
            let any = m.replicas_for(key, None, 3);
            assert_eq!(any.len(), 3);
        }
        assert!(m.replicas_for("k", Some("zzz"), 2).is_empty());
    }

    #[test]
    fn epoch_bumps_on_every_membership_change() {
        let now = Instant::now();
        let mut m = Membership::new(16);
        let e0 = m.epoch();
        m.register("a", addr(9001), Vec::new(), now);
        assert!(m.epoch() > e0);
        let e1 = m.epoch();
        m.leave("a");
        assert!(m.epoch() > e1);
    }
}
