//! Batching inference server: the serving half of the coordinator.
//!
//! A router thread collects requests into dynamic batches (size- or
//! deadline-triggered, vLLM-router style), a worker executes the compiled
//! forward, responses fan back out over per-request channels. Built on std
//! threads + mpsc (no tokio in the vendored crate set); the request path is
//! pure Rust + PJRT.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::tensor::Tensor;

/// One inference request: a single image (C, H, W) + reply channel.
pub struct Request {
    pub image: Tensor,
    pub reply: Sender<Response>,
    pub submitted: Instant,
}

/// Response: logits + timing breakdown.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub batch_size: usize,
    pub total_ms: f64,
}

/// Dynamic batcher policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// The model side of the server: anything that maps a batched image tensor
/// (N, C, H, W) to logits (N, K). Implemented by PJRT executables and by the
/// simulated backends.
pub trait BatchModel: Send {
    fn run_batch(&mut self, images: &Tensor) -> Result<Tensor>;
    fn max_batch(&self) -> usize;
}

/// Server statistics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
}

/// Spawn the router+worker; returns the request sender and a join handle
/// that yields stats once the sender is dropped.
pub fn serve(
    mut model: Box<dyn BatchModel>,
    policy: BatchPolicy,
) -> (Sender<Request>, std::thread::JoinHandle<ServerStats>) {
    let (tx, rx): (Sender<Request>, Receiver<Request>) = mpsc::channel();
    let handle = std::thread::spawn(move || {
        let mut latencies: Vec<f64> = Vec::new();
        let mut served = 0usize;
        let mut batches = 0usize;
        let started = Instant::now();
        let max_batch = policy.max_batch.min(model.max_batch());
        loop {
            // block for the first request
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => break, // all senders dropped: shut down
            };
            let mut batch = vec![first];
            let deadline = Instant::now() + policy.max_wait;
            // gather until full or deadline
            while batch.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => batch.push(r),
                    Err(_) => break,
                }
            }
            let exec_start = Instant::now();
            let n = batch.len();
            let (c, h, w) = {
                let s = &batch[0].image.shape;
                (s[0], s[1], s[2])
            };
            let mut images = Tensor::zeros(&[max_batch, c, h, w]);
            for (i, r) in batch.iter().enumerate() {
                let sz = c * h * w;
                images.data[i * sz..(i + 1) * sz].copy_from_slice(&r.image.data);
            }
            let logits = match model.run_batch(&images) {
                Ok(l) => l,
                Err(_) => continue,
            };
            let k = logits.shape[1];
            let done = Instant::now();
            for (i, r) in batch.into_iter().enumerate() {
                let total_ms = done.duration_since(r.submitted).as_secs_f64() * 1e3;
                latencies.push(total_ms);
                let _ = r.reply.send(Response {
                    logits: logits.data[i * k..(i + 1) * k].to_vec(),
                    queue_ms: exec_start.duration_since(r.submitted).as_secs_f64() * 1e3,
                    batch_size: n,
                    total_ms,
                });
            }
            served += n;
            batches += 1;
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if latencies.is_empty() {
                return 0.0;
            }
            latencies[((latencies.len() as f64 * p) as usize).min(latencies.len() - 1)]
        };
        ServerStats {
            served,
            batches,
            mean_batch: if batches == 0 { 0.0 } else { served as f64 / batches as f64 },
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            throughput_rps: served as f64 / started.elapsed().as_secs_f64().max(1e-9),
        }
    });
    (tx, handle)
}

/// A BatchModel over the Rust integer engine (simulated NPU deployment).
pub struct EngineModel {
    pub model: Arc<Mutex<crate::engine::CompiledModel>>,
    pub batch: usize,
}

impl BatchModel for EngineModel {
    fn run_batch(&mut self, images: &Tensor) -> Result<Tensor> {
        let m = self.model.lock().unwrap();
        let outs = m.run(images)?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn max_batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy model: logits = [sum(pixels), -sum(pixels)].
    struct Toy;

    impl BatchModel for Toy {
        fn run_batch(&mut self, images: &Tensor) -> Result<Tensor> {
            let n = images.shape[0];
            let sz: usize = images.shape[1..].iter().product();
            let mut out = Tensor::zeros(&[n, 2]);
            for i in 0..n {
                let s: f32 = images.data[i * sz..(i + 1) * sz].iter().sum();
                out.data[i * 2] = s;
                out.data[i * 2 + 1] = -s;
            }
            Ok(out)
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn serves_and_batches() {
        let (tx, handle) =
            serve(Box::new(Toy), BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) });
        let mut replies = Vec::new();
        for i in 0..16 {
            let (rtx, rrx) = mpsc::channel();
            let img = Tensor::full(&[1, 2, 2], i as f32);
            tx.send(Request { image: img, reply: rtx, submitted: Instant::now() }).unwrap();
            replies.push((i, rrx));
        }
        drop(tx);
        for (i, rrx) in replies {
            let resp = rrx.recv().unwrap();
            assert_eq!(resp.logits[0], (i * 4) as f32);
            assert_eq!(resp.logits[1], -(i as f32) * 4.0);
        }
        let stats = handle.join().unwrap();
        assert_eq!(stats.served, 16);
        assert!(stats.batches <= 16);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn deadline_fires_on_partial_batch() {
        let (tx, handle) =
            serve(Box::new(Toy), BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) });
        let (rtx, rrx) = mpsc::channel();
        tx.send(Request {
            image: Tensor::full(&[1, 2, 2], 1.0),
            reply: rtx,
            submitted: Instant::now(),
        })
        .unwrap();
        let resp = rrx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(resp.batch_size, 1);
        drop(tx);
        handle.join().unwrap();
    }
}
