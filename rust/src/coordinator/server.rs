//! Concurrent batching inference server: the serving half of the coordinator.
//!
//! A router thread pulls requests off a **bounded** ingress queue (submit
//! returns `QueueFull` instead of growing without bound), groups them into
//! per-deployment dynamic batches (size- or deadline-triggered, vLLM-router
//! style), and hands each batch to a pool of **N worker threads** over a
//! bounded work queue. Workers share the compiled deployments lock-free —
//! `CompiledModel` is frozen after planning and `Send + Sync` (asserted at
//! compile time in `engine`), so an `Arc` is all the synchronisation the
//! model needs. Batches execute at their **actual** size (a 1-request batch
//! pays 1-request compute, not `max_batch` — the per-op-overhead effect the
//! paper's Table 4 / Fig 3 quantify), and every accepted request receives
//! exactly one [`Response`] — model errors come back as an error response
//! instead of an abandoned reply channel.
//!
//! One server can front **several named deployments** (simulated NPUs at
//! different precisions, built from `backends::all_backends()` compiles);
//! the router maps each request to the deployment it names. Built on std
//! threads + mpsc (no tokio in the vendored crate set); the request path is
//! pure Rust + PJRT.
//!
//! # Failure semantics
//!
//! The request lifecycle is fault-tolerant end to end; callers may rely on:
//!
//! * **Every accepted request gets exactly one [`Response`]** — served,
//!   failed, or expired ([`Outcome`]). Reply channels are never abandoned,
//!   including on model errors, model panics, breaker rejections, router
//!   death, and shutdown.
//! * **Deadlines** ([`Request::deadline`]) are enforced *before* execution:
//!   an expired request is shed with [`Outcome::Expired`] (no model compute
//!   is spent on it) at routing time and again at the worker just before the
//!   batch runs. With [`BatchPolicy::slo_margin`] set, a pending batch is
//!   flushed early when its most urgent request comes within the margin of
//!   its deadline (the SLO lane).
//! * **Admission control**: when [`ServerConfig::shed_watermark`] is set and
//!   the ingress queue is at/above it, [`Priority::Low`] requests are shed
//!   at `submit` with [`SubmitError::Shed`] (handed back, never enqueued).
//! * **Transient model errors** (messages carrying [`TRANSIENT_MARKER`],
//!   e.g. from [`crate::coordinator::faults`]) are retried with capped
//!   exponential backoff per [`RetryPolicy`], preferring a healthy fallback
//!   sibling ([`ServerDeployment::fallbacks`]) over the failing deployment.
//! * **Panic containment**: a panicking `run_batch` (including parallel
//!   kernel-chunk panics re-raised by `engine::pool`) is caught; the batch
//!   gets error responses, the panic is counted in
//!   [`ServerStats::worker_panics`], and the worker thread is *recycled* —
//!   it replies, then replaces itself with a fresh thread
//!   ([`ServerStats::workers_restarted`]) in case the panic poisoned
//!   thread-local state. `shutdown()` completes with accurate stats either
//!   way: counters live in shared atomics, not in thread-join results.
//! * **Circuit breaker + graceful precision degradation**: per-deployment,
//!   [`BreakerPolicy::trip_after`] consecutive batch failures trip the
//!   breaker open ([`ServerStats::breaker_trips`]); while open, traffic is
//!   routed to the first healthy fallback sibling — typically the same
//!   checkpoint at INT4 or with dynamic scaling (see
//!   `experiment::compile_serving_fleet`, which wires these automatically).
//!   Degraded responses carry [`Response::degraded`] and name the sibling in
//!   [`Response::deployment`]; a static-scaling sibling answers bit-exactly
//!   what a directly-deployed copy would. After
//!   [`BreakerPolicy::cooldown`] the breaker half-opens, probes the primary,
//!   and closes again on success (degradation reverses itself).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::tensor::{empirical_quantile, Tensor};

/// Marker that classifies a model error as *transient* (retryable): the
/// retry loop re-runs batches whose error message contains it, everything
/// else fails fast. [`transient_error`] builds conforming errors; the fault
/// injector ([`crate::coordinator::faults`]) uses it for injected flakes and
/// brownouts. (String-based because the vendored `anyhow` shim carries a
/// flattened message chain, not a downcastable payload.)
pub const TRANSIENT_MARKER: &str = "(transient)";

/// Build a retryable model error (see [`TRANSIENT_MARKER`]).
pub fn transient_error(msg: impl std::fmt::Display) -> anyhow::Error {
    anyhow!("{TRANSIENT_MARKER} {msg}")
}

/// Does this error self-classify as transient/retryable?
pub fn is_transient(err: &anyhow::Error) -> bool {
    err.to_string().contains(TRANSIENT_MARKER)
}

/// Request priority for admission control: when the ingress queue crosses
/// [`ServerConfig::shed_watermark`], `Low` requests are shed at `submit`
/// while `Normal`/`High` traffic still queues (until the queue is full).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

/// One inference request: a single image (C, H, W) + reply channel.
pub struct Request {
    pub image: Tensor,
    /// Named deployment to route to; `None` = the server's default (first)
    /// deployment.
    pub deployment: Option<String>,
    pub reply: Sender<Response>,
    pub submitted: Instant,
    /// SLO deadline: past it the request is shed *before* execution with an
    /// [`Outcome::Expired`] response. `None` = no deadline.
    pub deadline: Option<Instant>,
    /// Admission-control lane (see [`Priority`]).
    pub priority: Priority,
}

impl Request {
    /// A deadline-free, normal-priority request (the pre-SLO default).
    pub fn new(image: Tensor, deployment: Option<String>, reply: Sender<Response>) -> Request {
        Request {
            image,
            deployment,
            reply,
            submitted: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
        }
    }
}

/// How a request left the server (every accepted request leaves exactly one
/// way).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Answered with logits.
    Served,
    /// Answered with a model/routing error.
    Failed,
    /// Deadline passed before execution; shed without spending model compute.
    Expired,
}

/// Response: logits (or the error that prevented them) + timing breakdown.
/// Every request accepted by [`Server::submit`] receives exactly one.
#[derive(Clone, Debug)]
pub struct Response {
    /// Per-request logits on success, the model/routing error otherwise.
    pub result: Result<Vec<f32>, String>,
    /// Terminal state of the request (served / failed / expired).
    pub outcome: Outcome,
    /// Deployment that handled (or rejected) the request. Under breaker
    /// degradation this is the *fallback sibling* that actually executed.
    pub deployment: String,
    /// The request was served by a fallback sibling (breaker-open rerouting
    /// or a retry that switched deployments), not the deployment it named.
    pub degraded: bool,
    /// Batch re-executions this request's batch needed before this response.
    pub retries: u32,
    pub queue_ms: f64,
    /// Actual executed batch size (0 for requests rejected by the router).
    pub batch_size: usize,
    pub total_ms: f64,
}

impl Response {
    /// Logits, if the request succeeded.
    pub fn logits(&self) -> Option<&[f32]> {
        self.result.as_deref().ok()
    }
}

/// Dynamic batcher policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// SLO lane: flush a pending batch early when the most urgent request in
    /// it comes within this margin of its [`Request::deadline`] (instead of
    /// waiting out `max_wait` and executing past the deadline). `None` =
    /// deadline-agnostic flush.
    pub slo_margin: Option<Duration>,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5), slo_margin: None }
    }
}

/// Retry policy for transient model errors (see [`TRANSIENT_MARKER`]):
/// capped exponential backoff, preferring a healthy fallback sibling.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Maximum batch re-executions after the first failed attempt.
    pub max_retries: u32,
    /// Backoff before retry k (1-based) is `base_backoff * 2^(k-1)`, capped.
    pub base_backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 2,
            base_backoff: Duration::from_micros(500),
            max_backoff: Duration::from_millis(20),
        }
    }
}

impl RetryPolicy {
    fn backoff(&self, attempt: u32) -> Duration {
        let exp = self.base_backoff.saturating_mul(1u32 << attempt.saturating_sub(1).min(16));
        exp.min(self.max_backoff)
    }
}

/// Per-deployment circuit-breaker policy: `trip_after` *consecutive* batch
/// failures (errors, panics) open the breaker; while open the router sends
/// the deployment's traffic to its fallback siblings. After `cooldown` the
/// breaker half-opens and probes the primary — success closes it again.
#[derive(Clone, Copy, Debug)]
pub struct BreakerPolicy {
    pub trip_after: u32,
    pub cooldown: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy { trip_after: 5, cooldown: Duration::from_millis(250) }
    }
}

#[derive(Clone, Copy, Debug)]
enum BreakerState {
    Closed { fails: u32 },
    Open { until: Instant },
    HalfOpen,
}

/// Consecutive-failure circuit breaker (interior mutability: the router
/// consults it while workers record outcomes). Also reused per-node by the
/// cluster router (`coordinator::cluster`) to gate forwarding.
pub(crate) struct Breaker {
    policy: BreakerPolicy,
    state: Mutex<BreakerState>,
}

impl Breaker {
    pub(crate) fn new(policy: BreakerPolicy) -> Breaker {
        Breaker { policy, state: Mutex::new(BreakerState::Closed { fails: 0 }) }
    }

    /// May traffic be routed to this deployment right now? An open breaker
    /// whose cooldown elapsed transitions to half-open and admits a probe.
    pub(crate) fn allows(&self, now: Instant) -> bool {
        let mut st = self.state.lock().unwrap();
        match *st {
            BreakerState::Closed { .. } | BreakerState::HalfOpen => true,
            BreakerState::Open { until } => {
                if now >= until {
                    *st = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Human-readable state for diagnostics (the cluster's `/state`):
    /// "closed", "open", or "half-open". Read-only — unlike [`Breaker::allows`]
    /// it does not perform the open -> half-open transition.
    pub(crate) fn state_label(&self, now: Instant) -> &'static str {
        match *self.state.lock().unwrap() {
            BreakerState::Closed { .. } => "closed",
            BreakerState::HalfOpen => "half-open",
            BreakerState::Open { until } => {
                if now >= until {
                    "half-open"
                } else {
                    "open"
                }
            }
        }
    }

    /// Record a batch outcome. Returns `true` iff this record tripped the
    /// breaker open (closed->open on the threshold, or a failed half-open
    /// probe re-opening it).
    pub(crate) fn record(&self, ok: bool, now: Instant) -> bool {
        let mut st = self.state.lock().unwrap();
        if ok {
            *st = BreakerState::Closed { fails: 0 };
            return false;
        }
        match *st {
            BreakerState::Closed { fails } => {
                let fails = fails + 1;
                if fails >= self.policy.trip_after {
                    *st = BreakerState::Open { until: now + self.policy.cooldown };
                    true
                } else {
                    *st = BreakerState::Closed { fails };
                    false
                }
            }
            BreakerState::HalfOpen => {
                *st = BreakerState::Open { until: now + self.policy.cooldown };
                true
            }
            BreakerState::Open { .. } => false,
        }
    }
}

/// The model side of the server: anything that maps a batched image tensor
/// (N, C, H, W) to logits (N, K). Implemented by PJRT executables and by the
/// simulated backends.
///
/// `run_batch` takes `&self`: implementations must be internally immutable
/// (or synchronise internally) so the worker pool can share one instance
/// lock-free via `Arc`. [`crate::engine::CompiledModel`] satisfies this by
/// construction — frozen after planning, `Send + Sync`.
pub trait BatchModel: Send + Sync {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor>;
    fn max_batch(&self) -> usize;

    /// Per-request input shape (batch dim excluded), when statically known.
    /// The router rejects mismatched requests up front so one bad request
    /// cannot poison a whole batch.
    fn input_shape(&self) -> Option<Vec<usize>> {
        None
    }
}

/// Server statistics, aggregated at shutdown. Counters live in shared
/// atomics while the server runs, so nothing is lost when a worker thread
/// panics and is replaced. Invariant: `served + errors + expired` = every
/// request the server accepted — none go unanswered.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Requests answered with logits.
    pub served: usize,
    /// Requests answered with an error response (model failure, unknown
    /// deployment, shape mismatch, exhausted retries, contained panic).
    pub errors: usize,
    /// Requests shed with [`Outcome::Expired`] before execution.
    pub expired: usize,
    /// Requests refused at `submit` with `QueueFull` (backpressure).
    pub rejected: usize,
    /// Low-priority requests shed at `submit` by admission control
    /// ([`ServerConfig::shed_watermark`]).
    pub shed: usize,
    /// Requests answered only after >= 1 batch retry.
    pub retried: usize,
    /// Requests served by a fallback sibling instead of the deployment they
    /// named (breaker-open rerouting or retry switching).
    pub degraded: usize,
    /// Circuit-breaker open transitions across all deployments.
    pub breaker_trips: usize,
    /// Model panics caught and converted to error responses.
    pub worker_panics: usize,
    /// Worker threads recycled after a contained panic.
    pub workers_restarted: usize,
    /// Router thread panics survived (requests drained with errors).
    pub router_panics: usize,
    /// Served responses that finished past their request deadline.
    pub slo_misses: usize,
    /// Audit-gated checkpoint hot-swaps applied via [`Server::swap_model`]
    /// (refused candidates don't count).
    pub model_swaps: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub throughput_rps: f64,
}

impl ServerStats {
    /// Every request the server accepted (each got exactly one response).
    pub fn accepted(&self) -> usize {
        self.served + self.errors + self.expired
    }

    /// Fraction of accepted requests that missed their SLO: expired before
    /// execution, or served past their deadline. 0 when nothing was accepted.
    pub fn slo_violation_rate(&self) -> f64 {
        let n = self.accepted();
        if n == 0 {
            0.0
        } else {
            (self.expired + self.slo_misses) as f64 / n as f64
        }
    }

    /// Every stat as a `(name, value)` pair — the single serialization point
    /// behind the cluster's `/metrics` endpoint and anything else that
    /// exports counters. The **exhaustive destructuring** is the fix for the
    /// dropped-counter class of bug: adding a `ServerStats` field without
    /// exporting it fails to *compile* here instead of silently vanishing
    /// from `/metrics` (regression-tested against a seeded chaos replay in
    /// `rust/tests/cluster.rs`). Derived values (`accepted`,
    /// `slo_violation_rate`) are exported too, so scrapers need no
    /// server-side arithmetic.
    pub fn export(&self) -> Vec<(&'static str, f64)> {
        let ServerStats {
            served,
            errors,
            expired,
            rejected,
            shed,
            retried,
            degraded,
            breaker_trips,
            worker_panics,
            workers_restarted,
            router_panics,
            slo_misses,
            model_swaps,
            batches,
            mean_batch,
            p50_ms,
            p95_ms,
            p99_ms,
            throughput_rps,
        } = self;
        vec![
            ("served", *served as f64),
            ("errors", *errors as f64),
            ("expired", *expired as f64),
            ("rejected", *rejected as f64),
            ("shed", *shed as f64),
            ("retried", *retried as f64),
            ("degraded", *degraded as f64),
            ("breaker_trips", *breaker_trips as f64),
            ("worker_panics", *worker_panics as f64),
            ("workers_restarted", *workers_restarted as f64),
            ("router_panics", *router_panics as f64),
            ("slo_misses", *slo_misses as f64),
            ("model_swaps", *model_swaps as f64),
            ("batches", *batches as f64),
            ("mean_batch", *mean_batch),
            ("p50_ms", *p50_ms),
            ("p95_ms", *p95_ms),
            ("p99_ms", *p99_ms),
            ("throughput_rps", *throughput_rps),
            ("accepted", self.accepted() as f64),
            ("slo_violation_rate", self.slo_violation_rate()),
        ]
    }

    /// Plain-text exposition of [`ServerStats::export`] — one
    /// `<prefix>_<name> <value>` line per stat (Prometheus-style flat
    /// gauges), served by the cluster's `/metrics` endpoint.
    pub fn render_metrics(&self, prefix: &str) -> String {
        let mut out = String::new();
        for (name, value) in self.export() {
            out.push_str(&format!("{prefix}_{name} {value}\n"));
        }
        out
    }
}

/// Nearest-rank (ceil) latency percentile, aligned with
/// [`crate::tensor::empirical_quantile`] (x_(ceil(p·n))). The old private
/// truncating-rank closure returned the *max* for p50 of 2 samples.
pub fn latency_percentile(samples_ms: &[f64], p: f64) -> f64 {
    if samples_ms.is_empty() {
        return 0.0;
    }
    let as_f32: Vec<f32> = samples_ms.iter().map(|&v| v as f32).collect();
    empirical_quantile(&as_f32, p) as f64
}

// ---------------------------------------------------------------------------
// Bounded MPMC queue: Mutex<VecDeque> + Condvar. Used for the ingress queue
// (non-blocking try_push => backpressure to clients) and the router->worker
// batch queue (blocking push => backpressure from busy workers up the pipe).
// ---------------------------------------------------------------------------

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

pub(crate) enum PushRejected<T> {
    Full(T),
    Closed(T),
}

pub(crate) enum Popped<T> {
    Item(T),
    TimedOut,
    Closed,
}

pub(crate) struct BoundedQueue<T> {
    cap: usize,
    state: Mutex<QueueState<T>>,
    cv: Condvar,
}

impl<T> BoundedQueue<T> {
    pub(crate) fn new(cap: usize) -> Self {
        BoundedQueue {
            cap: cap.max(1),
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Non-blocking push; hands the value back on a full or closed queue.
    pub(crate) fn try_push(&self, v: T) -> Result<(), PushRejected<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushRejected::Closed(v));
        }
        if st.items.len() >= self.cap {
            return Err(PushRejected::Full(v));
        }
        st.items.push_back(v);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking push: waits for space. `Err(v)` only if the queue closed.
    pub(crate) fn push(&self, v: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(v);
            }
            if st.items.len() < self.cap {
                st.items.push_back(v);
                drop(st);
                self.cv.notify_all();
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Blocking pop. `None` only once the queue is closed AND drained, so a
    /// closed queue still delivers everything already accepted (graceful
    /// shutdown needs exactly this).
    pub(crate) fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.cv.notify_all();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Pop with a timeout (same closed-means-drained contract as `pop`).
    pub(crate) fn pop_timeout(&self, dur: Duration) -> Popped<T> {
        let deadline = Instant::now() + dur;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.cv.notify_all();
                return Popped::Item(v);
            }
            if st.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::TimedOut;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    pub(crate) fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    pub(crate) fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// A named deployment behind the server: one compiled model (one simulated
/// NPU at one precision).
pub struct ServerDeployment {
    pub name: String,
    pub model: Arc<dyn BatchModel>,
    /// Sibling deployments (by name) able to serve this deployment's
    /// traffic when it fails — retry targets and breaker-open fallbacks, in
    /// preference order. `experiment::compile_serving_fleet` wires these to
    /// the same backend's INT4 / dynamic-scaling variants automatically.
    pub fallbacks: Vec<String>,
}

impl ServerDeployment {
    pub fn new(name: impl Into<String>, model: impl BatchModel + 'static) -> Self {
        ServerDeployment { name: name.into(), model: Arc::new(model), fallbacks: Vec::new() }
    }

    /// Builder: set the fallback siblings (preference order).
    pub fn with_fallbacks(mut self, fallbacks: Vec<String>) -> Self {
        self.fallbacks = fallbacks;
        self
    }
}

/// Server sizing knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Worker threads executing batches (shared across all deployments).
    pub workers: usize,
    /// Ingress queue capacity; beyond it `submit` returns `QueueFull`.
    pub queue_depth: usize,
    pub policy: BatchPolicy,
    /// Retry policy for transient model errors.
    pub retry: RetryPolicy,
    /// Per-deployment circuit-breaker policy.
    pub breaker: BreakerPolicy,
    /// Admission-control watermark: at/above this ingress depth, `Low`
    /// priority submissions are shed with [`SubmitError::Shed`]. `None`
    /// disables shedding (only `QueueFull` pushes back).
    pub shed_watermark: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: 2,
            queue_depth: 256,
            policy: BatchPolicy::default(),
            retry: RetryPolicy::default(),
            breaker: BreakerPolicy::default(),
            shed_watermark: None,
        }
    }
}

/// Why `submit` refused a request. Every variant hands the request back so
/// the caller can retry, downgrade, or drop it (backpressure, not data
/// loss).
pub enum SubmitError {
    /// Bounded ingress queue at capacity.
    QueueFull(Request),
    /// Low-priority request shed by admission control (queue depth crossed
    /// [`ServerConfig::shed_watermark`]).
    Shed(Request),
    /// The server is shutting down.
    ShutDown(Request),
}

impl SubmitError {
    pub fn into_request(self) -> Request {
        match self {
            SubmitError::QueueFull(r) | SubmitError::Shed(r) | SubmitError::ShutDown(r) => r,
        }
    }

    pub fn is_queue_full(&self) -> bool {
        matches!(self, SubmitError::QueueFull(_))
    }

    pub fn is_shed(&self) -> bool {
        matches!(self, SubmitError::Shed(_))
    }
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull(_) => "SubmitError::QueueFull",
            SubmitError::Shed(_) => "SubmitError::Shed",
            SubmitError::ShutDown(_) => "SubmitError::ShutDown",
        })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull(_) => "server ingress queue full",
            SubmitError::Shed(_) => "low-priority request shed under overload",
            SubmitError::ShutDown(_) => "server shutting down",
        })
    }
}

/// The hot-swappable part of a deployment: the model plus everything derived
/// from it. Swapped atomically under the entry's `RwLock` by
/// [`Server::swap_model`]; the router and workers take short read locks and
/// clone the `Arc` out, so in-flight batches finish on the plan they started
/// with while new submissions route to the replacement.
struct ModelSlot {
    model: Arc<dyn BatchModel>,
    /// Effective batch bound: min(policy.max_batch, model.max_batch()).
    max_batch: usize,
    input_shape: Option<Vec<usize>>,
}

struct DeployEntry {
    slot: RwLock<ModelSlot>,
    breaker: Breaker,
    fallbacks: Vec<String>,
}

impl DeployEntry {
    /// Snapshot the current serving model (short read lock; never held
    /// across model execution).
    fn model(&self) -> Arc<dyn BatchModel> {
        self.slot.read().unwrap().model.clone()
    }
}

struct Deployments {
    map: HashMap<String, DeployEntry>,
}

struct WorkBatch {
    /// Deployment that will *execute* the batch (under breaker degradation,
    /// a fallback sibling of the one the requests named).
    deployment: String,
    requests: Vec<Request>,
}

/// Latency sample cap: beyond it the sample set is decimated 2:1 and the
/// record stride doubles, so a long-lived server keeps O(1) memory (an
/// evenly-strided subsample still estimates p50/p95/p99 faithfully) instead
/// of one f64 per request served since startup.
const LATENCY_SAMPLE_CAP: usize = 1 << 16;

#[derive(Default)]
struct LatencyReservoir {
    samples_ms: Vec<f64>,
    stride: usize,
    seen: usize,
}

impl LatencyReservoir {
    fn record(&mut self, ms: f64) {
        if self.stride == 0 {
            self.stride = 1;
        }
        self.seen += 1;
        if self.seen % self.stride != 0 {
            return;
        }
        if self.samples_ms.len() >= LATENCY_SAMPLE_CAP {
            let mut keep = false;
            self.samples_ms.retain(|_| {
                keep = !keep;
                keep
            });
            self.stride *= 2;
        }
        self.samples_ms.push(ms);
    }
}

/// Live counters shared by the router, every worker (including respawned
/// ones), and `submit`. Shared atomics — not per-thread state returned
/// through `join()` — so a panicking worker can never take its drained
/// stats down with it.
#[derive(Default)]
struct SharedStats {
    served: AtomicUsize,
    errors: AtomicUsize,
    expired: AtomicUsize,
    rejected: AtomicUsize,
    shed: AtomicUsize,
    retried: AtomicUsize,
    degraded: AtomicUsize,
    breaker_trips: AtomicUsize,
    worker_panics: AtomicUsize,
    workers_restarted: AtomicUsize,
    router_panics: AtomicUsize,
    slo_misses: AtomicUsize,
    batches: AtomicUsize,
    batched_requests: AtomicUsize,
    model_swaps: AtomicUsize,
    latencies: Mutex<LatencyReservoir>,
}

impl SharedStats {
    fn bump(&self, c: &AtomicUsize) {
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Aggregate the live atomics into a [`ServerStats`] — the ONE
    /// aggregation path, shared by `shutdown()` and the live
    /// [`Server::stats_snapshot`] so the `/metrics` view can never diverge
    /// from the shutdown view by reading a different set of counters.
    fn aggregate(&self, started: Instant) -> ServerStats {
        let ld = Ordering::Relaxed;
        let latencies = {
            let r = self.latencies.lock().unwrap();
            r.samples_ms.clone()
        };
        let batches = self.batches.load(ld);
        let mut stats = ServerStats {
            served: self.served.load(ld),
            errors: self.errors.load(ld),
            expired: self.expired.load(ld),
            rejected: self.rejected.load(ld),
            shed: self.shed.load(ld),
            retried: self.retried.load(ld),
            degraded: self.degraded.load(ld),
            breaker_trips: self.breaker_trips.load(ld),
            worker_panics: self.worker_panics.load(ld),
            workers_restarted: self.workers_restarted.load(ld),
            router_panics: self.router_panics.load(ld),
            slo_misses: self.slo_misses.load(ld),
            model_swaps: self.model_swaps.load(ld),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                self.batched_requests.load(ld) as f64 / batches as f64
            },
            ..ServerStats::default()
        };
        stats.p50_ms = latency_percentile(&latencies, 0.50);
        stats.p95_ms = latency_percentile(&latencies, 0.95);
        stats.p99_ms = latency_percentile(&latencies, 0.99);
        stats.throughput_rps = stats.served as f64 / started.elapsed().as_secs_f64().max(1e-9);
        stats
    }
}

/// The concurrent batching server. Start with [`Server::start`] (multiple
/// deployments) or [`Server::single`], feed it with [`Server::submit`] /
/// [`Server::submit_image`], stop with [`Server::shutdown`] — which drains
/// everything already accepted before returning the aggregated stats.
pub struct Server {
    ingress: Arc<BoundedQueue<Request>>,
    router: Option<std::thread::JoinHandle<()>>,
    /// Live worker threads. A worker that recycles itself after a contained
    /// panic registers its replacement here before exiting, so `shutdown`
    /// always joins the current generation (loop-until-empty).
    workers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    stats: Arc<SharedStats>,
    /// Deployment table shared with the router and workers — kept here so
    /// [`Server::swap_model`] can hot-swap a slot under live traffic.
    deps: Arc<Deployments>,
    /// `cfg.policy.max_batch` at start time; a swapped-in model recomputes
    /// its effective batch bound against this.
    policy_max_batch: usize,
    shed_watermark: Option<usize>,
    started: Instant,
}

impl Server {
    /// Spawn the router + worker pool over a set of named deployments. The
    /// first deployment is the default route for requests that name none.
    pub fn start(deployments: Vec<ServerDeployment>, cfg: ServerConfig) -> Result<Server> {
        ensure!(!deployments.is_empty(), "server needs at least one deployment");
        ensure!(cfg.workers >= 1, "server needs at least one worker");
        ensure!(cfg.policy.max_batch >= 1, "batch policy max_batch must be >= 1");
        // Warm the engine's persistent kernel pool before traffic arrives:
        // all N batch workers submit row-chunk GEMM work to this ONE shared
        // team (sized once from available_parallelism) instead of each
        // spawning transient per-call thread sets — N workers no longer
        // oversubscribe the host N×8, and the first request doesn't pay
        // worker spawns.
        crate::engine::pool::global();
        let default_name = deployments[0].name.clone();
        let names: Vec<String> = deployments.iter().map(|d| d.name.clone()).collect();
        let mut map = HashMap::new();
        for d in deployments {
            let ServerDeployment { name, model, fallbacks } = d;
            ensure!(model.max_batch() >= 1, "deployment {name:?}: max_batch must be >= 1");
            for f in &fallbacks {
                ensure!(
                    names.contains(f) && f != &name,
                    "deployment {name:?}: fallback {f:?} is not another deployment of this server"
                );
            }
            let entry = DeployEntry {
                slot: RwLock::new(ModelSlot {
                    max_batch: cfg.policy.max_batch.min(model.max_batch()),
                    input_shape: model.input_shape(),
                    model,
                }),
                breaker: Breaker::new(cfg.breaker),
                fallbacks,
            };
            if map.insert(name.clone(), entry).is_some() {
                bail!("duplicate deployment name {name:?}");
            }
        }
        let deps = Arc::new(Deployments { map });
        let stats = Arc::new(SharedStats::default());
        let ingress: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(cfg.queue_depth));
        // Small work queue: enough to keep every worker busy while the
        // router batches the next wave, small enough that backpressure from
        // slow workers reaches the ingress queue (and then the clients).
        let work: Arc<BoundedQueue<WorkBatch>> =
            Arc::new(BoundedQueue::new((cfg.workers * 2).max(2)));

        let registry: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::with_capacity(cfg.workers)));
        let ctx = WorkerCtx {
            work: work.clone(),
            deps: deps.clone(),
            stats: stats.clone(),
            registry: registry.clone(),
            retry: cfg.retry,
            default_name: Arc::new(default_name.clone()),
        };
        {
            let mut reg = registry.lock().unwrap();
            for i in 0..cfg.workers {
                let ctx = ctx.clone();
                let h = std::thread::Builder::new()
                    .name(format!("server-worker-{i}"))
                    .spawn(move || worker_main(ctx))
                    .expect("spawn server worker");
                reg.push(h);
            }
        }
        let router = {
            let ingress = ingress.clone();
            let work = work.clone();
            let stats = stats.clone();
            let policy = cfg.policy;
            std::thread::Builder::new()
                .name("server-router".into())
                .spawn(move || {
                    // `pending` lives OUTSIDE the containment boundary so a
                    // router panic cannot drop in-flight reply channels
                    let mut pending: HashMap<String, PendingBatch> = HashMap::new();
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        router_loop(
                            &ingress,
                            &work,
                            &deps,
                            policy,
                            &default_name,
                            &stats,
                            &mut pending,
                        )
                    }));
                    if run.is_err() {
                        // Contain a router panic: stop accepting, answer
                        // everything pending or queued with an error response
                        // (reply channels must never be abandoned), and let
                        // the workers drain what was already batched.
                        stats.bump(&stats.router_panics);
                        ingress.close();
                        for (_, batch) in pending.drain() {
                            for req in batch.requests {
                                stats.bump(&stats.errors);
                                reject_request(req, "router", "router thread panicked".to_string());
                            }
                        }
                        while let Some(req) = ingress.pop() {
                            stats.bump(&stats.errors);
                            reject_request(req, "router", "router thread panicked".to_string());
                        }
                    }
                    // idempotent: the normal router path already closed it
                    work.close();
                })
                .expect("spawn server router")
        };
        Ok(Server {
            ingress,
            router: Some(router),
            workers: registry,
            stats,
            deps,
            policy_max_batch: cfg.policy.max_batch,
            shed_watermark: cfg.shed_watermark,
            started: Instant::now(),
        })
    }

    /// Audit-gated zero-downtime checkpoint hot-swap.
    ///
    /// The candidate's compiled plan is audited first
    /// ([`crate::engine::CompiledModel::audit`]); any ERROR-severity finding
    /// refuses the swap with the report in the error and the incumbent
    /// keeps serving untouched. On success the deployment's model slot is
    /// replaced atomically: batches already executing (workers clone the
    /// `Arc` out before running) complete on the old plan, while every
    /// subsequent batch routes to the new one — no accepted request is
    /// dropped either way. A candidate whose statically-declared input
    /// shape differs from the incumbent's is also refused, since requests
    /// validated against the old shape could otherwise land on a model that
    /// can't take them.
    ///
    /// Returns the (error-free) audit report of the installed candidate.
    pub fn swap_model(
        &self,
        deployment: &str,
        candidate: EngineModel,
    ) -> Result<crate::engine::verify::AuditReport> {
        let entry = self
            .deps
            .map
            .get(deployment)
            .ok_or_else(|| anyhow!("swap_model: unknown deployment {deployment:?}"))?;
        ensure!(candidate.batch >= 1, "swap_model: candidate max_batch must be >= 1");
        let report = candidate.model.audit(None)?;
        if report.has_errors() {
            let errs: Vec<String> = report
                .findings
                .iter()
                .filter(|f| f.severity == crate::engine::verify::Severity::Error)
                .map(|f| format!("{} @ {}: {}", f.code, f.node, f.message))
                .collect();
            bail!(
                "swap refused for {deployment:?}: candidate audit has {} ERROR finding(s): {}",
                errs.len(),
                errs.join("; ")
            );
        }
        let new_shape = candidate.input_shape();
        let new_slot = ModelSlot {
            max_batch: self.policy_max_batch.min(candidate.batch),
            input_shape: new_shape.clone(),
            model: Arc::new(candidate),
        };
        let mut slot = entry.slot.write().unwrap();
        if let (Some(old), Some(new)) = (&slot.input_shape, &new_shape) {
            ensure!(
                old == new,
                "swap refused for {deployment:?}: input shape changes {old:?} -> {new:?}"
            );
        }
        *slot = new_slot;
        drop(slot);
        self.stats.bump(&self.stats.model_swaps);
        Ok(report)
    }

    /// Single-deployment convenience (the deployment is named `"default"`).
    pub fn single(model: impl BatchModel + 'static, cfg: ServerConfig) -> Result<Server> {
        Server::start(vec![ServerDeployment::new("default", model)], cfg)
    }

    /// Enqueue a request. Non-blocking: a full ingress queue surfaces as
    /// `QueueFull`, and a low-priority request over the shed watermark as
    /// `Shed` (each with the request handed back) instead of unbounded
    /// buffering — the caller decides whether to retry, shed, or block.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        if let Some(w) = self.shed_watermark {
            if req.priority == Priority::Low && self.ingress.len() >= w {
                self.stats.bump(&self.stats.shed);
                return Err(SubmitError::Shed(req));
            }
        }
        match self.ingress.try_push(req) {
            Ok(()) => Ok(()),
            Err(PushRejected::Full(r)) => {
                self.stats.bump(&self.stats.rejected);
                Err(SubmitError::QueueFull(r))
            }
            Err(PushRejected::Closed(r)) => Err(SubmitError::ShutDown(r)),
        }
    }

    /// Submit one image and get the reply channel back.
    pub fn submit_image(
        &self,
        image: Tensor,
        deployment: Option<&str>,
    ) -> Result<Receiver<Response>, SubmitError> {
        self.submit_image_with(image, deployment, None, Priority::Normal)
    }

    /// [`Server::submit_image`] with the SLO knobs exposed: an absolute
    /// deadline (expired requests are shed before execution) and a priority
    /// lane for admission control.
    pub fn submit_image_with(
        &self,
        image: Tensor,
        deployment: Option<&str>,
        deadline: Option<Instant>,
        priority: Priority,
    ) -> Result<Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request {
            image,
            deployment: deployment.map(|s| s.to_string()),
            reply: tx,
            submitted: Instant::now(),
            deadline,
            priority,
        })?;
        Ok(rx)
    }

    /// Current ingress queue depth (diagnostics / load shedding).
    pub fn queue_len(&self) -> usize {
        self.ingress.len()
    }

    /// Live snapshot of the aggregated stats while the server runs — the
    /// exact same aggregation `shutdown()` performs (one shared code path),
    /// so a `/metrics` scrape between batches agrees field-for-field with
    /// the stats a subsequent shutdown would report (modulo
    /// `throughput_rps`, whose elapsed-time denominator keeps growing).
    pub fn stats_snapshot(&self) -> ServerStats {
        self.stats.aggregate(self.started)
    }

    /// Graceful shutdown: stop accepting, drain every accepted request
    /// through the workers (partial batches included), then aggregate stats.
    ///
    /// Panic-tolerant: a panicked router or worker thread is *recorded*
    /// (`router_panics` / `worker_panics`), not propagated — the stats of
    /// every healthy thread survive because counters live in shared atomics,
    /// not in join results.
    pub fn shutdown(mut self) -> ServerStats {
        self.ingress.close();
        if let Some(h) = self.router.take() {
            if h.join().is_err() {
                // double panic in the router containment itself; count it
                self.stats.bump(&self.stats.router_panics);
            }
        }
        // Join the worker generation(s): a worker that recycles itself
        // registers its replacement before exiting, so looping until the
        // registry is empty observes every live thread.
        loop {
            let handle = self.workers.lock().unwrap().pop();
            match handle {
                Some(h) => {
                    if h.join().is_err() {
                        // escaped the containment in worker_main; count it —
                        // its served/error counts are already in the shared
                        // atomics, nothing is lost
                        self.stats.bump(&self.stats.worker_panics);
                    }
                }
                None => break,
            }
        }
        self.stats.aggregate(self.started)
    }
}

impl Drop for Server {
    /// Dropping without `shutdown()` still closes the ingress so the router
    /// and workers wind down instead of blocking forever.
    fn drop(&mut self) {
        self.ingress.close();
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

struct PendingBatch {
    requests: Vec<Request>,
    deadline: Instant,
}

/// Reply immediately with a routing error (unknown deployment / bad shape /
/// breaker open with no healthy fallback). The reply channel is never
/// abandoned — this is an error *response*.
fn reject_request(req: Request, deployment: &str, msg: String) {
    let now = Instant::now();
    let ms = now.duration_since(req.submitted).as_secs_f64() * 1e3;
    let _ = req.reply.send(Response {
        result: Err(msg),
        outcome: Outcome::Failed,
        deployment: deployment.to_string(),
        degraded: false,
        retries: 0,
        queue_ms: ms,
        batch_size: 0,
        total_ms: ms,
    });
}

/// Shed a deadline-expired request before execution ([`Outcome::Expired`]).
fn expire_request(req: Request, deployment: &str, stats: &SharedStats) {
    stats.bump(&stats.expired);
    let now = Instant::now();
    let ms = now.duration_since(req.submitted).as_secs_f64() * 1e3;
    let _ = req.reply.send(Response {
        result: Err("deadline expired before execution".to_string()),
        outcome: Outcome::Expired,
        deployment: deployment.to_string(),
        degraded: false,
        retries: 0,
        queue_ms: ms,
        batch_size: 0,
        total_ms: ms,
    });
}

/// Route one request into a deployment's pending batch (flushing the batch
/// when full). Deadline-expired requests are shed here; a tripped breaker
/// reroutes to the first healthy fallback sibling (graceful degradation).
fn route_request(
    req: Request,
    pending: &mut HashMap<String, PendingBatch>,
    deps: &Deployments,
    work: &BoundedQueue<WorkBatch>,
    policy: BatchPolicy,
    default_name: &str,
    stats: &SharedStats,
) {
    let requested = req.deployment.clone().unwrap_or_else(|| default_name.to_string());
    let Some(primary) = deps.map.get(&requested) else {
        let known: Vec<&str> = deps.map.keys().map(|k| k.as_str()).collect();
        stats.bump(&stats.errors);
        let msg = format!("unknown deployment {requested:?} (have {known:?})");
        reject_request(req, &requested, msg);
        return;
    };
    let now = Instant::now();
    // SLO shedding: don't spend queue space or compute on a request that is
    // already past its deadline
    if req.deadline.is_some_and(|d| now >= d) {
        expire_request(req, &requested, stats);
        return;
    }
    // Breaker-aware target selection: an open breaker reroutes to the first
    // healthy fallback sibling (degraded-precision serving). With no healthy
    // fallback, fail fast — protecting the browning-out backend is the point.
    let (name, dep) = if primary.breaker.allows(now) {
        (requested.clone(), primary)
    } else {
        match primary
            .fallbacks
            .iter()
            .find_map(|f| deps.map.get(f).filter(|d| d.breaker.allows(now)).map(|d| (f.clone(), d)))
        {
            Some(t) => t,
            None => {
                stats.bump(&stats.errors);
                reject_request(
                    req,
                    &requested,
                    format!("circuit breaker open for {requested:?} and no healthy fallback"),
                );
                return;
            }
        }
    };
    // snapshot the swappable slot once per request: shape screening and the
    // batch bound must agree on ONE model generation even mid-hot-swap
    let (input_shape, max_batch) = {
        let slot = dep.slot.read().unwrap();
        (slot.input_shape.clone(), slot.max_batch)
    };
    // shape screening: a statically declared input shape wins; otherwise a
    // request must at least match the batch it would join
    if let Some(expected) = &input_shape {
        if &req.image.shape != expected {
            let msg = format!(
                "deployment {name}: request shape {:?} != expected input shape {expected:?}",
                req.image.shape
            );
            stats.bump(&stats.errors);
            reject_request(req, &name, msg);
            return;
        }
    } else if let Some(p) = pending.get(&name) {
        if p.requests[0].image.shape != req.image.shape {
            let msg = format!(
                "deployment {name}: request shape {:?} does not match in-flight batch shape {:?}",
                req.image.shape, p.requests[0].image.shape
            );
            stats.bump(&stats.errors);
            reject_request(req, &name, msg);
            return;
        }
    }
    let entry = pending.entry(name.clone()).or_insert_with(|| PendingBatch {
        requests: Vec::new(),
        deadline: now + policy.max_wait,
    });
    // SLO lane: a deadline-carrying request pulls the batch flush forward so
    // it ships `slo_margin` before the most urgent deadline in the batch
    if let (Some(margin), Some(dl)) = (policy.slo_margin, req.deadline) {
        let target = dl.checked_sub(margin).unwrap_or(now).max(now);
        entry.deadline = entry.deadline.min(target);
    }
    entry.requests.push(req);
    if entry.requests.len() >= max_batch {
        let batch = pending.remove(&name).expect("pending batch just filled");
        let _ = work.push(WorkBatch { deployment: name, requests: batch.requests });
    }
}

#[allow(clippy::too_many_arguments)]
fn router_loop(
    ingress: &BoundedQueue<Request>,
    work: &BoundedQueue<WorkBatch>,
    deps: &Deployments,
    policy: BatchPolicy,
    default_name: &str,
    stats: &SharedStats,
    pending: &mut HashMap<String, PendingBatch>,
) {
    loop {
        let next_deadline = pending.values().map(|p| p.deadline).min();
        let popped = match next_deadline {
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    Popped::TimedOut
                } else {
                    ingress.pop_timeout(deadline - now)
                }
            }
            None => match ingress.pop() {
                Some(r) => Popped::Item(r),
                None => Popped::Closed,
            },
        };
        let mut closed = false;
        match popped {
            Popped::Item(req) => {
                route_request(req, pending, deps, work, policy, default_name, stats);
            }
            Popped::TimedOut => {}
            Popped::Closed => closed = true,
        }
        // flush deadline-expired partial batches (max_wait or SLO lane)
        let now = Instant::now();
        let expired: Vec<String> = pending
            .iter()
            .filter(|(_, p)| p.deadline <= now)
            .map(|(name, _)| name.clone())
            .collect();
        for name in expired {
            if let Some(batch) = pending.remove(&name) {
                let _ = work.push(WorkBatch { deployment: name, requests: batch.requests });
            }
        }
        if closed {
            break;
        }
    }
    // graceful shutdown: the ingress is closed AND drained (pop's contract);
    // flush every remaining partial batch so in-flight requests complete
    for (name, batch) in pending.drain() {
        let _ = work.push(WorkBatch { deployment: name, requests: batch.requests });
    }
    work.close();
}

// ---------------------------------------------------------------------------
// Workers (supervised: a contained panic recycles the thread)
// ---------------------------------------------------------------------------

/// Everything a worker thread needs — clonable so a worker can spawn its own
/// replacement after containing a model panic.
#[derive(Clone)]
struct WorkerCtx {
    work: Arc<BoundedQueue<WorkBatch>>,
    deps: Arc<Deployments>,
    stats: Arc<SharedStats>,
    registry: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    retry: RetryPolicy,
    default_name: Arc<String>,
}

#[derive(PartialEq, Eq)]
enum BatchExit {
    Clean,
    /// The model panicked under this batch. The batch was still answered
    /// (error responses), but the thread recycles itself — the panic may
    /// have poisoned thread-local state (scratch arenas, allocator caches).
    Panicked,
}

fn worker_main(ctx: WorkerCtx) {
    while let Some(batch) = ctx.work.pop() {
        if run_one_batch(&ctx, batch) == BatchExit::Panicked {
            ctx.stats.bump(&ctx.stats.workers_restarted);
            let replacement = ctx.clone();
            let h = std::thread::Builder::new()
                .name("server-worker-respawn".into())
                .spawn(move || worker_main(replacement))
                .expect("respawn server worker");
            // register before exiting: shutdown's join loop must observe the
            // replacement no later than this thread's own exit
            ctx.registry.lock().unwrap().push(h);
            return;
        }
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Execute one batch with deadline shedding, retries, breaker accounting and
/// panic containment. Every request in the batch is answered on every path.
fn run_one_batch(ctx: &WorkerCtx, batch: WorkBatch) -> BatchExit {
    let WorkBatch { deployment: batch_name, requests } = batch;
    let stats = &*ctx.stats;
    let Some(first_entry) = ctx.deps.map.get(&batch_name) else {
        // unreachable: the router only enqueues validated names
        for req in requests {
            stats.bump(&stats.errors);
            reject_request(req, &batch_name, "deployment vanished".to_string());
        }
        return BatchExit::Clean;
    };
    // shed expired requests one final time, right before execution
    let now = Instant::now();
    let mut live: Vec<Request> = Vec::with_capacity(requests.len());
    for req in requests {
        if req.deadline.is_some_and(|d| now >= d) {
            expire_request(req, &batch_name, stats);
        } else {
            live.push(req);
        }
    }
    if live.is_empty() {
        return BatchExit::Clean;
    }
    let n = live.len();
    let per_shape = live[0].image.shape.clone();
    let sz: usize = per_shape.iter().product();
    // the batch tensor is exactly (n, ...): no zero-padding to max_batch,
    // so a partial batch pays partial compute
    let mut batch_shape = Vec::with_capacity(per_shape.len() + 1);
    batch_shape.push(n);
    batch_shape.extend_from_slice(&per_shape);
    let mut images = Tensor::zeros(&batch_shape);
    for (i, r) in live.iter().enumerate() {
        images.data[i * sz..(i + 1) * sz].copy_from_slice(&r.image.data);
    }
    let mut serving_name = batch_name;
    let mut serving = first_entry;
    let mut attempt: u32 = 0;
    loop {
        let exec_start = Instant::now();
        // Clone the model Arc out of the swappable slot BEFORE executing: a
        // concurrent `swap_model` replaces the slot for future batches while
        // this one finishes on the plan it started with.
        let model = serving.model();
        // Containment boundary: a panicking model (or a kernel-chunk panic
        // re-raised by engine::pool) becomes an error response, not a dead
        // worker with abandoned reply channels.
        let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.run_batch(&images)
        }));
        let done = Instant::now();
        match run {
            Ok(result) => {
                let result = result.and_then(|logits| {
                    ensure!(
                        !logits.shape.is_empty() && logits.shape[0] == n,
                        "deployment {serving_name}: model returned logits {:?} for a batch of {n}",
                        logits.shape
                    );
                    Ok(logits)
                });
                match result {
                    Ok(logits) => {
                        serving.breaker.record(true, done);
                        reply_batch(
                            ctx,
                            &serving_name,
                            live,
                            Ok(logits),
                            exec_start,
                            done,
                            attempt,
                            n,
                        );
                        return BatchExit::Clean;
                    }
                    Err(e) => {
                        if serving.breaker.record(false, done) {
                            stats.bump(&stats.breaker_trips);
                        }
                        if is_transient(&e) && attempt < ctx.retry.max_retries {
                            attempt += 1;
                            std::thread::sleep(ctx.retry.backoff(attempt));
                            // prefer a healthy replica/sibling over hammering
                            // the deployment that just failed
                            if let Some((name, dep)) = pick_fallback(ctx, &serving_name) {
                                serving_name = name;
                                serving = dep;
                            }
                            continue;
                        }
                        reply_batch(
                            ctx,
                            &serving_name,
                            live,
                            Err(e.to_string()),
                            exec_start,
                            done,
                            attempt,
                            n,
                        );
                        return BatchExit::Clean;
                    }
                }
            }
            Err(payload) => {
                let msg = panic_message(payload);
                if serving.breaker.record(false, done) {
                    stats.bump(&stats.breaker_trips);
                }
                stats.bump(&stats.worker_panics);
                reply_batch(
                    ctx,
                    &serving_name,
                    live,
                    Err(format!("worker panic contained: {msg}")),
                    exec_start,
                    done,
                    attempt,
                    n,
                );
                return BatchExit::Panicked;
            }
        }
    }
}

/// First fallback sibling of `current` whose breaker admits traffic.
fn pick_fallback<'d>(ctx: &'d WorkerCtx, current: &str) -> Option<(String, &'d DeployEntry)> {
    let entry = ctx.deps.map.get(current)?;
    let now = Instant::now();
    entry
        .fallbacks
        .iter()
        .filter(|f| f.as_str() != current)
        .find_map(|f| ctx.deps.map.get(f).filter(|d| d.breaker.allows(now)).map(|d| (f.clone(), d)))
}

/// Answer every request in an executed batch (success or failure), updating
/// the shared counters: served/errors, retried, degraded, SLO misses.
#[allow(clippy::too_many_arguments)]
fn reply_batch(
    ctx: &WorkerCtx,
    serving_name: &str,
    requests: Vec<Request>,
    result: Result<Tensor, String>,
    exec_start: Instant,
    done: Instant,
    retries: u32,
    n: usize,
) {
    let stats = &*ctx.stats;
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.batched_requests.fetch_add(n, Ordering::Relaxed);
    let k = result.as_ref().map(|l| l.data.len() / n).unwrap_or(0);
    for (i, r) in requests.into_iter().enumerate() {
        let requested = r.deployment.as_deref().unwrap_or(&ctx.default_name);
        let degraded = requested != serving_name;
        let total_ms = done.duration_since(r.submitted).as_secs_f64() * 1e3;
        let queue_ms = exec_start.duration_since(r.submitted).as_secs_f64() * 1e3;
        let (per_req, outcome) = match &result {
            Ok(logits) => (Ok(logits.data[i * k..(i + 1) * k].to_vec()), Outcome::Served),
            Err(msg) => (Err(msg.clone()), Outcome::Failed),
        };
        match outcome {
            Outcome::Served => {
                stats.bump(&stats.served);
                stats.latencies.lock().unwrap().record(total_ms);
                if r.deadline.is_some_and(|d| done > d) {
                    stats.bump(&stats.slo_misses);
                }
            }
            _ => stats.bump(&stats.errors),
        }
        if retries > 0 {
            stats.bump(&stats.retried);
        }
        if degraded {
            stats.bump(&stats.degraded);
        }
        let _ = r.reply.send(Response {
            result: per_req,
            outcome,
            deployment: serving_name.to_string(),
            degraded,
            retries,
            queue_ms,
            batch_size: n,
            total_ms,
        });
    }
}

// ---------------------------------------------------------------------------
// Engine-backed deployment
// ---------------------------------------------------------------------------

/// A BatchModel over the Rust integer engine (one simulated NPU deployment).
///
/// Shares the compiled model lock-free: `CompiledModel::run` is `&self` over
/// a `OnceLock`'d plan (the engine asserts `CompiledModel: Send + Sync` at
/// compile time), so N workers run the same deployment concurrently with no
/// mutex — the old `Arc<Mutex<CompiledModel>>` serialised the whole fleet.
/// Steady-state execution is allocation-free per worker: `run` reuses a
/// per-thread `ExecScratch` arena, and parallel GEMM chunks go to the
/// process-wide persistent `engine::pool` shared by every worker.
pub struct EngineModel {
    pub model: Arc<crate::engine::CompiledModel>,
    pub batch: usize,
    /// Minimum wall-clock service time per batch, indexed by **actual**
    /// batch size (entry `n-1` paces an n-request batch; the last entry is
    /// reused beyond). Empty = unpaced. The engine computes exact logits
    /// faster than the simulated NPU it stands in for, so serving
    /// experiments pace each batch to the perf model's device latency —
    /// otherwise a "fleet" bench measures host CPU speed. Pacing scales
    /// with the executed size: a partial batch pays partial device time,
    /// matching the actual-size execution contract.
    pub service_floors: Vec<Duration>,
}

impl EngineModel {
    pub fn new(model: Arc<crate::engine::CompiledModel>, batch: usize) -> Self {
        EngineModel { model, batch, service_floors: Vec::new() }
    }

    /// Engine model paced to simulated device service times per batch size
    /// (`floors[n-1]` for an n-request batch).
    pub fn paced(
        model: Arc<crate::engine::CompiledModel>,
        batch: usize,
        floors: Vec<Duration>,
    ) -> Self {
        EngineModel { model, batch, service_floors: floors }
    }
}

impl BatchModel for EngineModel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let t0 = Instant::now();
        let mut outs = self.model.run(images)?;
        ensure!(!outs.is_empty(), "engine model produced no outputs");
        let out = outs.remove(0);
        if !self.service_floors.is_empty() {
            let n = images.shape.first().copied().unwrap_or(1).max(1);
            let floor = self.service_floors[(n - 1).min(self.service_floors.len() - 1)];
            let elapsed = t0.elapsed();
            if elapsed < floor {
                std::thread::sleep(floor - elapsed);
            }
        }
        Ok(out)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn input_shape(&self) -> Option<Vec<usize>> {
        self.model.input_shape()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy model: logits = [sum(pixels), -sum(pixels)].
    struct Toy;

    impl BatchModel for Toy {
        fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
            let n = images.shape[0];
            let sz: usize = images.shape[1..].iter().product();
            let mut out = Tensor::zeros(&[n, 2]);
            for i in 0..n {
                let s: f32 = images.data[i * sz..(i + 1) * sz].iter().sum();
                out.data[i * 2] = s;
                out.data[i * 2 + 1] = -s;
            }
            Ok(out)
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    fn recv_ok(rx: &Receiver<Response>) -> Response {
        rx.recv_timeout(Duration::from_secs(10)).expect("response must arrive")
    }

    #[test]
    fn serves_and_batches() {
        let server = Server::single(
            Toy,
            ServerConfig {
                workers: 2,
                queue_depth: 64,
                policy: BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(2),
                    slo_margin: None,
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut replies = Vec::new();
        for i in 0..16 {
            let img = Tensor::full(&[1, 2, 2], i as f32);
            let rx = server.submit_image(img, None).unwrap();
            replies.push((i, rx));
        }
        for (i, rx) in &replies {
            let resp = recv_ok(rx);
            let logits = resp.result.expect("toy model never fails");
            assert_eq!(logits[0], (i * 4) as f32);
            assert_eq!(logits[1], -(*i as f32) * 4.0);
            assert_eq!(resp.deployment, "default");
            assert_eq!(resp.outcome, Outcome::Served);
            assert!(!resp.degraded);
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 16);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.expired, 0);
        assert_eq!(stats.worker_panics, 0);
        assert!(stats.batches <= 16);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn deadline_fires_on_partial_batch() {
        let server = Server::single(
            Toy,
            ServerConfig {
                workers: 1,
                queue_depth: 8,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(1),
                    slo_margin: None,
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let rx = server.submit_image(Tensor::full(&[1, 2, 2], 1.0), None).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(resp.batch_size, 1);
        server.shutdown();
    }

    #[test]
    fn unknown_deployment_gets_error_response() {
        let server = Server::single(Toy, ServerConfig::default()).unwrap();
        let rx = server.submit_image(Tensor::full(&[1, 2, 2], 1.0), Some("no-such-npu")).unwrap();
        let resp = recv_ok(&rx);
        let err = resp.result.expect_err("unknown deployment must be an error response");
        assert!(err.contains("unknown deployment"), "{err}");
        assert_eq!(resp.outcome, Outcome::Failed);
        let stats = server.shutdown();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn expired_request_is_shed_before_execution() {
        let server = Server::single(Toy, ServerConfig::default()).unwrap();
        let past = Instant::now() - Duration::from_millis(5);
        let rx = server
            .submit_image_with(Tensor::full(&[1, 2, 2], 1.0), None, Some(past), Priority::Normal)
            .unwrap();
        let resp = recv_ok(&rx);
        assert_eq!(resp.outcome, Outcome::Expired);
        assert_eq!(resp.batch_size, 0, "expired requests must not reach execution");
        assert!(resp.result.is_err());
        let stats = server.shutdown();
        assert_eq!(stats.expired, 1);
        assert_eq!(stats.served, 0);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.accepted(), 1);
        assert_eq!(stats.slo_violation_rate(), 1.0);
    }

    #[test]
    fn slo_margin_flushes_batch_before_deadline() {
        // max_wait is far longer than the deadline: only the SLO lane can
        // ship this partial batch in time
        let server = Server::single(
            Toy,
            ServerConfig {
                workers: 1,
                queue_depth: 8,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_secs(30),
                    slo_margin: Some(Duration::from_millis(40)),
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let deadline = Instant::now() + Duration::from_millis(80);
        let rx = server
            .submit_image_with(
                Tensor::full(&[1, 2, 2], 2.0),
                None,
                Some(deadline),
                Priority::Normal,
            )
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(2)).expect("SLO lane must flush early");
        assert_eq!(resp.outcome, Outcome::Served);
        let stats = server.shutdown();
        assert_eq!(stats.served, 1);
        assert_eq!(stats.slo_misses, 0, "flushed within the SLO margin");
    }

    #[test]
    fn low_priority_shed_at_watermark() {
        // worker is slow, queue fills: low-priority submissions over the
        // watermark come back as Shed (not QueueFull), high priority queues
        struct Stall;
        impl BatchModel for Stall {
            fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
                std::thread::sleep(Duration::from_millis(30));
                Ok(Tensor::zeros(&[images.shape[0], 1]))
            }
            fn max_batch(&self) -> usize {
                1
            }
        }
        let server = Server::single(
            Stall,
            ServerConfig {
                workers: 1,
                queue_depth: 64,
                policy: BatchPolicy {
                    max_batch: 1,
                    max_wait: Duration::from_millis(1),
                    slo_margin: None,
                },
                shed_watermark: Some(2),
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut shed = 0usize;
        let mut accepted = Vec::new();
        for i in 0..24 {
            let pri = if i % 2 == 0 { Priority::Low } else { Priority::High };
            match server.submit_image_with(Tensor::full(&[1, 2, 2], i as f32), None, None, pri) {
                Ok(rx) => accepted.push(rx),
                Err(e) => {
                    assert!(e.is_shed(), "only admission-control sheds expected: {e}");
                    shed += 1;
                }
            }
        }
        assert!(shed > 0, "low-priority traffic over the watermark must be shed");
        for rx in &accepted {
            recv_ok(rx);
        }
        let stats = server.shutdown();
        assert_eq!(stats.shed, shed);
        assert_eq!(stats.served, accepted.len());
    }

    /// Always answers with a batch dimension of 1, whatever it was given —
    /// the shape of bug the old zero-padded `max_batch` execution hid.
    struct WrongBatchDim;

    impl BatchModel for WrongBatchDim {
        fn run_batch(&self, _images: &Tensor) -> Result<Tensor> {
            Ok(Tensor::zeros(&[1, 2]))
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn wrong_batch_dimension_is_an_error_response() {
        let server = Server::single(
            WrongBatchDim,
            ServerConfig {
                workers: 1,
                queue_depth: 8,
                // max_batch 2 + generous deadline: the two requests below are
                // guaranteed to execute as one batch of 2
                policy: BatchPolicy {
                    max_batch: 2,
                    max_wait: Duration::from_millis(500),
                    slo_margin: None,
                },
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..2).map(|_| server.submit_image(Tensor::zeros(&[1, 2, 2]), None).unwrap()).collect();
        for rx in &rxs {
            let resp = recv_ok(rx);
            let err = resp.result.expect_err("batch-dim mismatch must be an error response");
            assert!(err.contains("returned logits"), "{err}");
        }
        let stats = server.shutdown();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn stats_percentiles_use_ceil_rank() {
        // the old truncating rank returned the max for p50 of 2 samples
        assert_eq!(latency_percentile(&[1.0, 2.0], 0.50), 1.0);
        assert_eq!(latency_percentile(&[1.0, 2.0], 0.95), 2.0);
        let ten: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(latency_percentile(&ten, 0.50), 5.0);
        assert_eq!(latency_percentile(&ten, 0.90), 9.0);
        assert_eq!(latency_percentile(&ten, 0.95), 10.0);
        assert_eq!(latency_percentile(&[], 0.50), 0.0);
    }

    #[test]
    fn bounded_queue_closed_means_drained() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.try_push(1).map_err(|_| ()).unwrap();
        q.try_push(2).map_err(|_| ()).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err(PushRejected::Closed(3))));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_full_backpressure() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        q.try_push(1).map_err(|_| ()).unwrap();
        q.try_push(2).map_err(|_| ()).unwrap();
        assert!(matches!(q.try_push(3), Err(PushRejected::Full(3))));
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 2);
        q.close();
    }

    #[test]
    fn bounded_queue_pop_timeout_contract() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Popped::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(5), "timeout must actually wait");
        q.try_push(7).map_err(|_| ()).unwrap();
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Popped::Item(7)));
        q.try_push(8).map_err(|_| ()).unwrap();
        q.close();
        // closed-means-drained: buffered items still come out, then Closed
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Popped::Item(8)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Popped::Closed));
    }

    #[test]
    fn bounded_queue_pop_timeout_under_racing_pushers() {
        // the router's exact loop shape: one consumer popping with short
        // timeouts while several producers push in bursts with gaps longer
        // than the timeout — every item must arrive exactly once, with
        // TimedOut wakeups in the gaps and Closed only after the drain
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(64));
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let (mut got, mut timeouts) = (Vec::new(), 0usize);
                loop {
                    match q.pop_timeout(Duration::from_millis(1)) {
                        Popped::Item(v) => got.push(v),
                        Popped::TimedOut => timeouts += 1,
                        Popped::Closed => break,
                    }
                }
                (got, timeouts)
            })
        };
        std::thread::scope(|s| {
            for p in 0..4u32 {
                let q = &q;
                s.spawn(move || {
                    for i in 0..50u32 {
                        if i % 8 == 0 {
                            std::thread::sleep(Duration::from_millis(3));
                        }
                        q.push(p * 100 + i).map_err(|_| ()).unwrap();
                    }
                });
            }
        });
        q.close();
        let (mut got, timeouts) = consumer.join().unwrap();
        got.sort_unstable();
        let mut want: Vec<u32> = (0..4).flat_map(|p| (0..50).map(move |i| p * 100 + i)).collect();
        want.sort_unstable();
        assert_eq!(got, want, "every pushed item pops exactly once");
        assert!(timeouts > 0, "1 ms pops against 3 ms production gaps must time out");
    }

    #[test]
    fn breaker_trips_half_opens_and_closes() {
        let b = Breaker::new(BreakerPolicy { trip_after: 3, cooldown: Duration::from_millis(10) });
        let t0 = Instant::now();
        assert!(b.allows(t0));
        assert!(!b.record(false, t0));
        assert!(!b.record(false, t0));
        assert!(b.record(false, t0), "third consecutive failure must trip");
        assert!(!b.allows(t0), "open breaker rejects before cooldown");
        let later = t0 + Duration::from_millis(11);
        assert!(b.allows(later), "cooldown elapsed: half-open probe admitted");
        assert!(!b.record(true, later), "a successful probe is not a trip");
        assert!(b.allows(later + Duration::from_millis(1)), "probe success closed the breaker");
        // failed probe re-opens
        b.record(false, later);
        b.record(false, later);
        b.record(false, later);
        assert!(!b.allows(later));
        let again = later + Duration::from_millis(11);
        assert!(b.allows(again));
        assert!(b.record(false, again), "failed half-open probe re-trips");
        assert!(!b.allows(again));
    }

    #[test]
    fn transient_marker_classifies() {
        assert!(is_transient(&transient_error("backend flake")));
        assert!(!is_transient(&anyhow!("shape mismatch")));
    }

    #[test]
    fn retry_backoff_is_capped_exponential() {
        let r = RetryPolicy {
            max_retries: 8,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(5),
        };
        assert_eq!(r.backoff(1), Duration::from_millis(1));
        assert_eq!(r.backoff(2), Duration::from_millis(2));
        assert_eq!(r.backoff(3), Duration::from_millis(4));
        assert_eq!(r.backoff(4), Duration::from_millis(5), "capped at max_backoff");
        assert_eq!(r.backoff(30), Duration::from_millis(5));
    }
}
