//! Concurrent batching inference server: the serving half of the coordinator.
//!
//! A router thread pulls requests off a **bounded** ingress queue (submit
//! returns `QueueFull` instead of growing without bound), groups them into
//! per-deployment dynamic batches (size- or deadline-triggered, vLLM-router
//! style), and hands each batch to a pool of **N worker threads** over a
//! bounded work queue. Workers share the compiled deployments lock-free —
//! `CompiledModel` is frozen after planning and `Send + Sync` (asserted at
//! compile time in `engine`), so an `Arc` is all the synchronisation the
//! model needs. Batches execute at their **actual** size (a 1-request batch
//! pays 1-request compute, not `max_batch` — the per-op-overhead effect the
//! paper's Table 4 / Fig 3 quantify), and every accepted request receives
//! exactly one [`Response`] — model errors come back as an error response
//! instead of an abandoned reply channel.
//!
//! One server can front **several named deployments** (simulated NPUs at
//! different precisions, built from `backends::all_backends()` compiles);
//! the router maps each request to the deployment it names. Built on std
//! threads + mpsc (no tokio in the vendored crate set); the request path is
//! pure Rust + PJRT.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::tensor::{empirical_quantile, Tensor};

/// One inference request: a single image (C, H, W) + reply channel.
pub struct Request {
    pub image: Tensor,
    /// Named deployment to route to; `None` = the server's default (first)
    /// deployment.
    pub deployment: Option<String>,
    pub reply: Sender<Response>,
    pub submitted: Instant,
}

/// Response: logits (or the error that prevented them) + timing breakdown.
/// Every request accepted by [`Server::submit`] receives exactly one.
#[derive(Clone, Debug)]
pub struct Response {
    /// Per-request logits on success, the model/routing error otherwise.
    pub result: Result<Vec<f32>, String>,
    /// Deployment that handled (or rejected) the request.
    pub deployment: String,
    pub queue_ms: f64,
    /// Actual executed batch size (0 for requests rejected by the router).
    pub batch_size: usize,
    pub total_ms: f64,
}

impl Response {
    /// Logits, if the request succeeded.
    pub fn logits(&self) -> Option<&[f32]> {
        self.result.as_deref().ok()
    }
}

/// Dynamic batcher policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }
    }
}

/// The model side of the server: anything that maps a batched image tensor
/// (N, C, H, W) to logits (N, K). Implemented by PJRT executables and by the
/// simulated backends.
///
/// `run_batch` takes `&self`: implementations must be internally immutable
/// (or synchronise internally) so the worker pool can share one instance
/// lock-free via `Arc`. [`crate::engine::CompiledModel`] satisfies this by
/// construction — frozen after planning, `Send + Sync`.
pub trait BatchModel: Send + Sync {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor>;
    fn max_batch(&self) -> usize;

    /// Per-request input shape (batch dim excluded), when statically known.
    /// The router rejects mismatched requests up front so one bad request
    /// cannot poison a whole batch.
    fn input_shape(&self) -> Option<Vec<usize>> {
        None
    }
}

/// Server statistics, aggregated across workers at shutdown.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Requests answered with logits.
    pub served: usize,
    /// Requests answered with an error response (model failure, unknown
    /// deployment, shape mismatch). `served + errors` = every request the
    /// server accepted — none are dropped.
    pub errors: usize,
    /// Requests refused at `submit` with `QueueFull` (backpressure).
    pub rejected: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub throughput_rps: f64,
}

/// Nearest-rank (ceil) latency percentile, aligned with
/// [`crate::tensor::empirical_quantile`] (x_(ceil(p·n))). The old private
/// truncating-rank closure returned the *max* for p50 of 2 samples.
pub fn latency_percentile(samples_ms: &[f64], p: f64) -> f64 {
    if samples_ms.is_empty() {
        return 0.0;
    }
    let as_f32: Vec<f32> = samples_ms.iter().map(|&v| v as f32).collect();
    empirical_quantile(&as_f32, p) as f64
}

// ---------------------------------------------------------------------------
// Bounded MPMC queue: Mutex<VecDeque> + Condvar. Used for the ingress queue
// (non-blocking try_push => backpressure to clients) and the router->worker
// batch queue (blocking push => backpressure from busy workers up the pipe).
// ---------------------------------------------------------------------------

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

enum PushRejected<T> {
    Full(T),
    Closed(T),
}

enum Popped<T> {
    Item(T),
    TimedOut,
    Closed,
}

struct BoundedQueue<T> {
    cap: usize,
    state: Mutex<QueueState<T>>,
    cv: Condvar,
}

impl<T> BoundedQueue<T> {
    fn new(cap: usize) -> Self {
        BoundedQueue {
            cap: cap.max(1),
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Non-blocking push; hands the value back on a full or closed queue.
    fn try_push(&self, v: T) -> Result<(), PushRejected<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushRejected::Closed(v));
        }
        if st.items.len() >= self.cap {
            return Err(PushRejected::Full(v));
        }
        st.items.push_back(v);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    /// Blocking push: waits for space. `Err(v)` only if the queue closed.
    fn push(&self, v: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(v);
            }
            if st.items.len() < self.cap {
                st.items.push_back(v);
                drop(st);
                self.cv.notify_all();
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Blocking pop. `None` only once the queue is closed AND drained, so a
    /// closed queue still delivers everything already accepted (graceful
    /// shutdown needs exactly this).
    fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.cv.notify_all();
                return Some(v);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Pop with a timeout (same closed-means-drained contract as `pop`).
    fn pop_timeout(&self, dur: Duration) -> Popped<T> {
        let deadline = Instant::now() + dur;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                drop(st);
                self.cv.notify_all();
                return Popped::Item(v);
            }
            if st.closed {
                return Popped::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Popped::TimedOut;
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// A named deployment behind the server: one compiled model (one simulated
/// NPU at one precision).
pub struct ServerDeployment {
    pub name: String,
    pub model: Arc<dyn BatchModel>,
}

impl ServerDeployment {
    pub fn new(name: impl Into<String>, model: impl BatchModel + 'static) -> Self {
        ServerDeployment { name: name.into(), model: Arc::new(model) }
    }
}

/// Server sizing knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Worker threads executing batches (shared across all deployments).
    pub workers: usize,
    /// Ingress queue capacity; beyond it `submit` returns `QueueFull`.
    pub queue_depth: usize,
    pub policy: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { workers: 2, queue_depth: 256, policy: BatchPolicy::default() }
    }
}

/// Why `submit` refused a request. Both variants hand the request back so
/// the caller can retry (backpressure, not data loss).
pub enum SubmitError {
    /// Bounded ingress queue at capacity.
    QueueFull(Request),
    /// The server is shutting down.
    ShutDown(Request),
}

impl SubmitError {
    pub fn into_request(self) -> Request {
        match self {
            SubmitError::QueueFull(r) | SubmitError::ShutDown(r) => r,
        }
    }

    pub fn is_queue_full(&self) -> bool {
        matches!(self, SubmitError::QueueFull(_))
    }
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull(_) => "SubmitError::QueueFull",
            SubmitError::ShutDown(_) => "SubmitError::ShutDown",
        })
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SubmitError::QueueFull(_) => "server ingress queue full",
            SubmitError::ShutDown(_) => "server shutting down",
        })
    }
}

struct DeployEntry {
    model: Arc<dyn BatchModel>,
    /// Effective batch bound: min(policy.max_batch, model.max_batch()).
    max_batch: usize,
    input_shape: Option<Vec<usize>>,
}

struct Deployments {
    map: HashMap<String, DeployEntry>,
}

struct WorkBatch {
    deployment: String,
    requests: Vec<Request>,
}

/// Per-worker latency sample cap: beyond it the sample set is decimated 2:1
/// and the record stride doubles, so a long-lived server keeps O(1) memory
/// (an evenly-strided subsample still estimates p50/p95 faithfully) instead
/// of one f64 per request served since startup.
const LATENCY_SAMPLE_CAP: usize = 1 << 16;

struct WorkerStats {
    latencies_ms: Vec<f64>,
    lat_stride: usize,
    lat_seen: usize,
    served: usize,
    errors: usize,
    batches: usize,
    batched_requests: usize,
}

impl Default for WorkerStats {
    fn default() -> Self {
        WorkerStats {
            latencies_ms: Vec::new(),
            lat_stride: 1,
            lat_seen: 0,
            served: 0,
            errors: 0,
            batches: 0,
            batched_requests: 0,
        }
    }
}

impl WorkerStats {
    fn record_latency(&mut self, ms: f64) {
        self.lat_seen += 1;
        if self.lat_seen % self.lat_stride != 0 {
            return;
        }
        if self.latencies_ms.len() >= LATENCY_SAMPLE_CAP {
            let mut keep = false;
            self.latencies_ms.retain(|_| {
                keep = !keep;
                keep
            });
            self.lat_stride *= 2;
        }
        self.latencies_ms.push(ms);
    }
}

/// The concurrent batching server. Start with [`Server::start`] (multiple
/// deployments) or [`Server::single`], feed it with [`Server::submit`] /
/// [`Server::submit_image`], stop with [`Server::shutdown`] — which drains
/// everything already accepted before returning the aggregated stats.
pub struct Server {
    ingress: Arc<BoundedQueue<Request>>,
    router: Option<std::thread::JoinHandle<usize>>,
    workers: Vec<std::thread::JoinHandle<WorkerStats>>,
    rejected: Arc<AtomicUsize>,
    started: Instant,
}

impl Server {
    /// Spawn the router + worker pool over a set of named deployments. The
    /// first deployment is the default route for requests that name none.
    pub fn start(deployments: Vec<ServerDeployment>, cfg: ServerConfig) -> Result<Server> {
        ensure!(!deployments.is_empty(), "server needs at least one deployment");
        ensure!(cfg.workers >= 1, "server needs at least one worker");
        ensure!(cfg.policy.max_batch >= 1, "batch policy max_batch must be >= 1");
        // Warm the engine's persistent kernel pool before traffic arrives:
        // all N batch workers submit row-chunk GEMM work to this ONE shared
        // team (sized once from available_parallelism) instead of each
        // spawning transient per-call thread sets — N workers no longer
        // oversubscribe the host N×8, and the first request doesn't pay
        // worker spawns.
        crate::engine::pool::global();
        let default_name = deployments[0].name.clone();
        let mut map = HashMap::new();
        for d in deployments {
            let ServerDeployment { name, model } = d;
            ensure!(model.max_batch() >= 1, "deployment {name:?}: max_batch must be >= 1");
            let entry = DeployEntry {
                max_batch: cfg.policy.max_batch.min(model.max_batch()),
                input_shape: model.input_shape(),
                model,
            };
            if map.insert(name.clone(), entry).is_some() {
                bail!("duplicate deployment name {name:?}");
            }
        }
        let deps = Arc::new(Deployments { map });
        let ingress: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(cfg.queue_depth));
        // Small work queue: enough to keep every worker busy while the
        // router batches the next wave, small enough that backpressure from
        // slow workers reaches the ingress queue (and then the clients).
        let work: Arc<BoundedQueue<WorkBatch>> = Arc::new(BoundedQueue::new((cfg.workers * 2).max(2)));

        let workers = (0..cfg.workers)
            .map(|_| {
                let work = work.clone();
                let deps = deps.clone();
                std::thread::spawn(move || worker_loop(&work, &deps))
            })
            .collect();
        let router = {
            let ingress = ingress.clone();
            std::thread::spawn(move || router_loop(&ingress, &work, &deps, cfg.policy, &default_name))
        };
        Ok(Server {
            ingress,
            router: Some(router),
            workers,
            rejected: Arc::new(AtomicUsize::new(0)),
            started: Instant::now(),
        })
    }

    /// Single-deployment convenience (the deployment is named `"default"`).
    pub fn single(model: impl BatchModel + 'static, cfg: ServerConfig) -> Result<Server> {
        Server::start(vec![ServerDeployment::new("default", model)], cfg)
    }

    /// Enqueue a request. Non-blocking: a full ingress queue surfaces as
    /// `QueueFull` (with the request handed back) instead of unbounded
    /// buffering — the caller decides whether to retry, shed, or block.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        match self.ingress.try_push(req) {
            Ok(()) => Ok(()),
            Err(PushRejected::Full(r)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::QueueFull(r))
            }
            Err(PushRejected::Closed(r)) => Err(SubmitError::ShutDown(r)),
        }
    }

    /// Submit one image and get the reply channel back.
    pub fn submit_image(
        &self,
        image: Tensor,
        deployment: Option<&str>,
    ) -> Result<Receiver<Response>, SubmitError> {
        let (tx, rx) = mpsc::channel();
        self.submit(Request {
            image,
            deployment: deployment.map(|s| s.to_string()),
            reply: tx,
            submitted: Instant::now(),
        })?;
        Ok(rx)
    }

    /// Current ingress queue depth (diagnostics / load shedding).
    pub fn queue_len(&self) -> usize {
        self.ingress.len()
    }

    /// Graceful shutdown: stop accepting, drain every accepted request
    /// through the workers (partial batches included), then aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        self.ingress.close();
        let router_errors = self
            .router
            .take()
            .map(|h| h.join().expect("server router thread panicked"))
            .unwrap_or(0);
        let mut latencies: Vec<f64> = Vec::new();
        let mut stats = ServerStats { errors: router_errors, ..ServerStats::default() };
        for h in std::mem::take(&mut self.workers) {
            let ws = h.join().expect("server worker thread panicked");
            latencies.extend(ws.latencies_ms);
            stats.served += ws.served;
            stats.errors += ws.errors;
            stats.batches += ws.batches;
            stats.mean_batch += ws.batched_requests as f64;
        }
        stats.rejected = self.rejected.load(Ordering::Relaxed);
        stats.mean_batch =
            if stats.batches == 0 { 0.0 } else { stats.mean_batch / stats.batches as f64 };
        stats.p50_ms = latency_percentile(&latencies, 0.50);
        stats.p95_ms = latency_percentile(&latencies, 0.95);
        stats.throughput_rps =
            stats.served as f64 / self.started.elapsed().as_secs_f64().max(1e-9);
        stats
    }
}

impl Drop for Server {
    /// Dropping without `shutdown()` still closes the ingress so the router
    /// and workers wind down instead of blocking forever.
    fn drop(&mut self) {
        self.ingress.close();
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

struct PendingBatch {
    requests: Vec<Request>,
    deadline: Instant,
}

/// Reply immediately with a routing error (unknown deployment / bad shape).
/// The reply channel is never abandoned — this is an error *response*.
fn reject_request(req: Request, deployment: &str, msg: String) {
    let now = Instant::now();
    let ms = now.duration_since(req.submitted).as_secs_f64() * 1e3;
    let _ = req.reply.send(Response {
        result: Err(msg),
        deployment: deployment.to_string(),
        queue_ms: ms,
        batch_size: 0,
        total_ms: ms,
    });
}

/// Route one request into its deployment's pending batch (flushing the batch
/// when full). Returns 1 if the request was rejected with an error response.
fn route_request(
    req: Request,
    pending: &mut HashMap<String, PendingBatch>,
    deps: &Deployments,
    work: &BoundedQueue<WorkBatch>,
    policy: BatchPolicy,
    default_name: &str,
) -> usize {
    let name = req.deployment.clone().unwrap_or_else(|| default_name.to_string());
    let Some(dep) = deps.map.get(&name) else {
        let known: Vec<&str> = deps.map.keys().map(|k| k.as_str()).collect();
        reject_request(req, &name, format!("unknown deployment {name:?} (have {known:?})"));
        return 1;
    };
    // shape screening: a statically declared input shape wins; otherwise a
    // request must at least match the batch it would join
    if let Some(expected) = &dep.input_shape {
        if &req.image.shape != expected {
            let msg = format!(
                "deployment {name}: request shape {:?} != expected input shape {expected:?}",
                req.image.shape
            );
            reject_request(req, &name, msg);
            return 1;
        }
    } else if let Some(p) = pending.get(&name) {
        if p.requests[0].image.shape != req.image.shape {
            let msg = format!(
                "deployment {name}: request shape {:?} does not match in-flight batch shape {:?}",
                req.image.shape, p.requests[0].image.shape
            );
            reject_request(req, &name, msg);
            return 1;
        }
    }
    let entry = pending.entry(name.clone()).or_insert_with(|| PendingBatch {
        requests: Vec::new(),
        deadline: Instant::now() + policy.max_wait,
    });
    entry.requests.push(req);
    if entry.requests.len() >= dep.max_batch {
        let batch = pending.remove(&name).expect("pending batch just filled");
        let _ = work.push(WorkBatch { deployment: name, requests: batch.requests });
    }
    0
}

fn router_loop(
    ingress: &BoundedQueue<Request>,
    work: &BoundedQueue<WorkBatch>,
    deps: &Deployments,
    policy: BatchPolicy,
    default_name: &str,
) -> usize {
    let mut pending: HashMap<String, PendingBatch> = HashMap::new();
    let mut rejected_invalid = 0usize;
    loop {
        let next_deadline = pending.values().map(|p| p.deadline).min();
        let popped = match next_deadline {
            Some(deadline) => {
                let now = Instant::now();
                if deadline <= now {
                    Popped::TimedOut
                } else {
                    ingress.pop_timeout(deadline - now)
                }
            }
            None => match ingress.pop() {
                Some(r) => Popped::Item(r),
                None => Popped::Closed,
            },
        };
        let mut closed = false;
        match popped {
            Popped::Item(req) => {
                rejected_invalid +=
                    route_request(req, &mut pending, deps, work, policy, default_name);
            }
            Popped::TimedOut => {}
            Popped::Closed => closed = true,
        }
        // flush deadline-expired partial batches
        let now = Instant::now();
        let expired: Vec<String> = pending
            .iter()
            .filter(|(_, p)| p.deadline <= now)
            .map(|(name, _)| name.clone())
            .collect();
        for name in expired {
            if let Some(batch) = pending.remove(&name) {
                let _ = work.push(WorkBatch { deployment: name, requests: batch.requests });
            }
        }
        if closed {
            break;
        }
    }
    // graceful shutdown: the ingress is closed AND drained (pop's contract);
    // flush every remaining partial batch so in-flight requests complete
    for (name, batch) in pending.drain() {
        let _ = work.push(WorkBatch { deployment: name, requests: batch.requests });
    }
    work.close();
    rejected_invalid
}

// ---------------------------------------------------------------------------
// Workers
// ---------------------------------------------------------------------------

fn worker_loop(work: &BoundedQueue<WorkBatch>, deps: &Deployments) -> WorkerStats {
    let mut stats = WorkerStats::default();
    while let Some(batch) = work.pop() {
        match deps.map.get(&batch.deployment) {
            Some(dep) => run_one_batch(dep.model.as_ref(), &batch.deployment, batch.requests, &mut stats),
            None => {
                // unreachable: the router only enqueues validated names
                for req in batch.requests {
                    stats.errors += 1;
                    reject_request(req, &batch.deployment, "deployment vanished".to_string());
                }
            }
        }
    }
    stats
}

fn run_one_batch(
    model: &dyn BatchModel,
    deployment: &str,
    requests: Vec<Request>,
    stats: &mut WorkerStats,
) {
    let n = requests.len();
    let per_shape = requests[0].image.shape.clone();
    let sz: usize = per_shape.iter().product();
    // the batch tensor is exactly (n, ...): no zero-padding to max_batch,
    // so a partial batch pays partial compute
    let mut batch_shape = Vec::with_capacity(per_shape.len() + 1);
    batch_shape.push(n);
    batch_shape.extend_from_slice(&per_shape);
    let mut images = Tensor::zeros(&batch_shape);
    for (i, r) in requests.iter().enumerate() {
        images.data[i * sz..(i + 1) * sz].copy_from_slice(&r.image.data);
    }
    let exec_start = Instant::now();
    let result = model.run_batch(&images).and_then(|logits| {
        ensure!(
            !logits.shape.is_empty() && logits.shape[0] == n,
            "deployment {deployment}: model returned logits {:?} for a batch of {n}",
            logits.shape
        );
        Ok(logits)
    });
    let done = Instant::now();
    stats.batches += 1;
    stats.batched_requests += n;
    match result {
        Ok(logits) => {
            let k = logits.data.len() / n;
            for (i, r) in requests.into_iter().enumerate() {
                let total_ms = done.duration_since(r.submitted).as_secs_f64() * 1e3;
                stats.record_latency(total_ms);
                stats.served += 1;
                let _ = r.reply.send(Response {
                    result: Ok(logits.data[i * k..(i + 1) * k].to_vec()),
                    deployment: deployment.to_string(),
                    queue_ms: exec_start.duration_since(r.submitted).as_secs_f64() * 1e3,
                    batch_size: n,
                    total_ms,
                });
            }
        }
        Err(e) => {
            // the model failed: every request in the batch gets an error
            // response — reply channels are never silently dropped
            let msg = e.to_string();
            for r in requests {
                let total_ms = done.duration_since(r.submitted).as_secs_f64() * 1e3;
                stats.errors += 1;
                let _ = r.reply.send(Response {
                    result: Err(msg.clone()),
                    deployment: deployment.to_string(),
                    queue_ms: exec_start.duration_since(r.submitted).as_secs_f64() * 1e3,
                    batch_size: n,
                    total_ms,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Engine-backed deployment
// ---------------------------------------------------------------------------

/// A BatchModel over the Rust integer engine (one simulated NPU deployment).
///
/// Shares the compiled model lock-free: `CompiledModel::run` is `&self` over
/// a `OnceLock`'d plan (the engine asserts `CompiledModel: Send + Sync` at
/// compile time), so N workers run the same deployment concurrently with no
/// mutex — the old `Arc<Mutex<CompiledModel>>` serialised the whole fleet.
/// Steady-state execution is allocation-free per worker: `run` reuses a
/// per-thread `ExecScratch` arena, and parallel GEMM chunks go to the
/// process-wide persistent `engine::pool` shared by every worker.
pub struct EngineModel {
    pub model: Arc<crate::engine::CompiledModel>,
    pub batch: usize,
    /// Minimum wall-clock service time per batch, indexed by **actual**
    /// batch size (entry `n-1` paces an n-request batch; the last entry is
    /// reused beyond). Empty = unpaced. The engine computes exact logits
    /// faster than the simulated NPU it stands in for, so serving
    /// experiments pace each batch to the perf model's device latency —
    /// otherwise a "fleet" bench measures host CPU speed. Pacing scales
    /// with the executed size: a partial batch pays partial device time,
    /// matching the actual-size execution contract.
    pub service_floors: Vec<Duration>,
}

impl EngineModel {
    pub fn new(model: Arc<crate::engine::CompiledModel>, batch: usize) -> Self {
        EngineModel { model, batch, service_floors: Vec::new() }
    }

    /// Engine model paced to simulated device service times per batch size
    /// (`floors[n-1]` for an n-request batch).
    pub fn paced(
        model: Arc<crate::engine::CompiledModel>,
        batch: usize,
        floors: Vec<Duration>,
    ) -> Self {
        EngineModel { model, batch, service_floors: floors }
    }
}

impl BatchModel for EngineModel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let t0 = Instant::now();
        let mut outs = self.model.run(images)?;
        ensure!(!outs.is_empty(), "engine model produced no outputs");
        let out = outs.remove(0);
        if !self.service_floors.is_empty() {
            let n = images.shape.first().copied().unwrap_or(1).max(1);
            let floor = self.service_floors[(n - 1).min(self.service_floors.len() - 1)];
            let elapsed = t0.elapsed();
            if elapsed < floor {
                std::thread::sleep(floor - elapsed);
            }
        }
        Ok(out)
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn input_shape(&self) -> Option<Vec<usize>> {
        self.model.input_shape()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy model: logits = [sum(pixels), -sum(pixels)].
    struct Toy;

    impl BatchModel for Toy {
        fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
            let n = images.shape[0];
            let sz: usize = images.shape[1..].iter().product();
            let mut out = Tensor::zeros(&[n, 2]);
            for i in 0..n {
                let s: f32 = images.data[i * sz..(i + 1) * sz].iter().sum();
                out.data[i * 2] = s;
                out.data[i * 2 + 1] = -s;
            }
            Ok(out)
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    fn recv_ok(rx: &Receiver<Response>) -> Response {
        rx.recv_timeout(Duration::from_secs(10)).expect("response must arrive")
    }

    #[test]
    fn serves_and_batches() {
        let server = Server::single(
            Toy,
            ServerConfig {
                workers: 2,
                queue_depth: 64,
                policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) },
            },
        )
        .unwrap();
        let mut replies = Vec::new();
        for i in 0..16 {
            let img = Tensor::full(&[1, 2, 2], i as f32);
            let rx = server.submit_image(img, None).unwrap();
            replies.push((i, rx));
        }
        for (i, rx) in &replies {
            let resp = recv_ok(rx);
            let logits = resp.result.expect("toy model never fails");
            assert_eq!(logits[0], (i * 4) as f32);
            assert_eq!(logits[1], -(*i as f32) * 4.0);
            assert_eq!(resp.deployment, "default");
        }
        let stats = server.shutdown();
        assert_eq!(stats.served, 16);
        assert_eq!(stats.errors, 0);
        assert_eq!(stats.rejected, 0);
        assert!(stats.batches <= 16);
        assert!(stats.mean_batch >= 1.0);
    }

    #[test]
    fn deadline_fires_on_partial_batch() {
        let server = Server::single(
            Toy,
            ServerConfig {
                workers: 1,
                queue_depth: 8,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            },
        )
        .unwrap();
        let rx = server.submit_image(Tensor::full(&[1, 2, 2], 1.0), None).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(resp.batch_size, 1);
        server.shutdown();
    }

    #[test]
    fn unknown_deployment_gets_error_response() {
        let server = Server::single(Toy, ServerConfig::default()).unwrap();
        let rx = server.submit_image(Tensor::full(&[1, 2, 2], 1.0), Some("no-such-npu")).unwrap();
        let resp = recv_ok(&rx);
        let err = resp.result.expect_err("unknown deployment must be an error response");
        assert!(err.contains("unknown deployment"), "{err}");
        let stats = server.shutdown();
        assert_eq!(stats.errors, 1);
        assert_eq!(stats.served, 0);
    }

    /// Always answers with a batch dimension of 1, whatever it was given —
    /// the shape of bug the old zero-padded `max_batch` execution hid.
    struct WrongBatchDim;

    impl BatchModel for WrongBatchDim {
        fn run_batch(&self, _images: &Tensor) -> Result<Tensor> {
            Ok(Tensor::zeros(&[1, 2]))
        }
        fn max_batch(&self) -> usize {
            8
        }
    }

    #[test]
    fn wrong_batch_dimension_is_an_error_response() {
        let server = Server::single(
            WrongBatchDim,
            ServerConfig {
                workers: 1,
                queue_depth: 8,
                // max_batch 2 + generous deadline: the two requests below are
                // guaranteed to execute as one batch of 2
                policy: BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(500) },
            },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..2).map(|_| server.submit_image(Tensor::zeros(&[1, 2, 2]), None).unwrap()).collect();
        for rx in &rxs {
            let resp = recv_ok(rx);
            let err = resp.result.expect_err("batch-dim mismatch must be an error response");
            assert!(err.contains("returned logits"), "{err}");
        }
        let stats = server.shutdown();
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.served, 0);
    }

    #[test]
    fn stats_percentiles_use_ceil_rank() {
        // the old truncating rank returned the max for p50 of 2 samples
        assert_eq!(latency_percentile(&[1.0, 2.0], 0.50), 1.0);
        assert_eq!(latency_percentile(&[1.0, 2.0], 0.95), 2.0);
        let ten: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(latency_percentile(&ten, 0.50), 5.0);
        assert_eq!(latency_percentile(&ten, 0.90), 9.0);
        assert_eq!(latency_percentile(&ten, 0.95), 10.0);
        assert_eq!(latency_percentile(&[], 0.50), 0.0);
    }

    #[test]
    fn bounded_queue_closed_means_drained() {
        let q: BoundedQueue<u32> = BoundedQueue::new(4);
        q.try_push(1).map_err(|_| ()).unwrap();
        q.try_push(2).map_err(|_| ()).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err(PushRejected::Closed(3))));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn bounded_queue_full_backpressure() {
        let q: BoundedQueue<u32> = BoundedQueue::new(2);
        q.try_push(1).map_err(|_| ()).unwrap();
        q.try_push(2).map_err(|_| ()).unwrap();
        assert!(matches!(q.try_push(3), Err(PushRejected::Full(3))));
        assert_eq!(q.pop(), Some(1));
        q.try_push(3).map_err(|_| ()).unwrap();
        assert_eq!(q.len(), 2);
        q.close();
    }
}
