//! Training state + positional marshalling against the manifest.
//!
//! The exported HLO train step takes ~300 positional parameters (params, BN
//! state, quant stats, optimizer moments, batch, scalars). `TrainState` holds
//! the named tensors; `marshal` lines them up against a FnSpec's arg slots and
//! `absorb` writes the result tuple back. Nothing here knows model shapes —
//! it is all driven by the manifest.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::ckpt::Checkpoint;
use crate::runtime::{self, DType, FnSpec, Slot};
use crate::tensor::Tensor;

/// Named training state, sectioned by role.
#[derive(Clone, Debug, Default)]
pub struct TrainState {
    pub params: BTreeMap<String, Tensor>,
    pub bn: BTreeMap<String, Tensor>,
    pub qstate: BTreeMap<String, Tensor>,
    pub opt_m: BTreeMap<String, Tensor>,
    pub opt_v: BTreeMap<String, Tensor>,
    pub step: f32,
}

impl TrainState {
    /// Initialize from a `.qtckpt`. Optimizer moments and the step counter
    /// are restored when the checkpoint carries `opt_m/` / `opt_v/` /
    /// `meta/step` entries (a mid-training checkpoint from
    /// [`TrainState::to_checkpoint_full`]); otherwise they start at zero,
    /// matching an exported `.init.qtckpt`.
    pub fn from_checkpoint(ck: &Checkpoint) -> Self {
        let mut s = TrainState::default();
        for (k, t) in ck.section("param") {
            s.opt_m.insert(k.clone(), Tensor::zeros(&t.shape));
            s.opt_v.insert(k.clone(), Tensor::zeros(&t.shape));
            s.params.insert(k, t.clone());
        }
        for (k, t) in ck.section("bn") {
            s.bn.insert(k, t.clone());
        }
        for (k, t) in ck.section("qstate") {
            s.qstate.insert(k, t.clone());
        }
        for (k, t) in ck.section("opt_m") {
            s.opt_m.insert(k, t.clone());
        }
        for (k, t) in ck.section("opt_v") {
            s.opt_v.insert(k, t.clone());
        }
        if let Some(t) = ck.get("meta/step") {
            if let Some(&v) = t.data.first() {
                s.step = v;
            }
        }
        s
    }

    /// Deployment-facing checkpoint: params, BN state, and quant stats only.
    pub fn to_checkpoint(&self) -> Checkpoint {
        let mut ck = Checkpoint::new();
        for (k, t) in &self.params {
            ck.insert(format!("param/{k}"), t.clone());
        }
        for (k, t) in &self.bn {
            ck.insert(format!("bn/{k}"), t.clone());
        }
        for (k, t) in &self.qstate {
            ck.insert(format!("qstate/{k}"), t.clone());
        }
        ck
    }

    /// Resume-grade checkpoint: everything in [`to_checkpoint`] plus AdamW
    /// moments and the step counter, so a reload continues training
    /// bit-identically instead of restarting the optimizer cold.
    ///
    /// [`to_checkpoint`]: TrainState::to_checkpoint
    pub fn to_checkpoint_full(&self) -> Checkpoint {
        let mut ck = self.to_checkpoint();
        for (k, t) in &self.opt_m {
            ck.insert(format!("opt_m/{k}"), t.clone());
        }
        for (k, t) in &self.opt_v {
            ck.insert(format!("opt_v/{k}"), t.clone());
        }
        ck.insert("meta/step", Tensor::scalar(self.step));
        ck
    }

    fn lookup(&self, role: &str, key: &str) -> Option<&Tensor> {
        match role {
            "param" => self.params.get(key),
            "bn" => self.bn.get(key),
            "qstate" | "tau" => self.qstate.get(key),
            "opt_m" => self.opt_m.get(key),
            "opt_v" => self.opt_v.get(key),
            _ => None,
        }
    }

    fn store(&mut self, role: &str, key: &str, t: Tensor) {
        match role {
            "param" => {
                self.params.insert(key.to_string(), t);
            }
            "bn" => {
                self.bn.insert(key.to_string(), t);
            }
            "qstate" | "tau" => {
                self.qstate.insert(key.to_string(), t);
            }
            "opt_m" => {
                self.opt_m.insert(key.to_string(), t);
            }
            "opt_v" => {
                self.opt_v.insert(key.to_string(), t);
            }
            "step" => self.step = t.data[0],
            _ => {}
        }
    }

    /// Extra per-call inputs that aren't state: batch data, labels, scalars,
    /// teacher state.
    pub fn marshal(
        &self,
        spec: &FnSpec,
        extras: &CallExtras<'_>,
    ) -> Result<Vec<xla::Literal>> {
        let mut out = Vec::with_capacity(spec.args.len());
        for slot in &spec.args {
            out.push(self.literal_for(slot, extras)?);
        }
        Ok(out)
    }

    fn literal_for(&self, slot: &Slot, extras: &CallExtras<'_>) -> Result<xla::Literal> {
        match slot.role.as_str() {
            "param" | "bn" | "qstate" | "tau" | "opt_m" | "opt_v" => {
                let t = self
                    .lookup(&slot.role, &slot.key)
                    .with_context(|| format!("state missing {}/{}", slot.role, slot.key))?;
                if t.shape != slot.shape {
                    bail!(
                        "shape mismatch for {}/{}: state {:?} vs manifest {:?}",
                        slot.role,
                        slot.key,
                        t.shape,
                        slot.shape
                    );
                }
                runtime::tensor_to_literal(t)
            }
            "step" => runtime::tensor_to_literal(&Tensor::scalar(self.step)),
            "data" => {
                let x = extras.data.context("call needs data batch")?;
                runtime::tensor_to_literal(x)
            }
            "label" => {
                let y = extras.labels.context("call needs labels")?;
                if slot.dtype != DType::I32 {
                    bail!("labels must be i32");
                }
                runtime::i32_to_literal(y, &slot.shape)
            }
            "lam" => runtime::tensor_to_literal(&Tensor::scalar(extras.lam)),
            "lr" => runtime::tensor_to_literal(&Tensor::scalar(extras.lr)),
            "tparam" => {
                let t = extras
                    .teacher
                    .and_then(|tp| tp.params.get(&slot.key))
                    .with_context(|| format!("teacher param {} missing", slot.key))?;
                runtime::tensor_to_literal(t)
            }
            "tbn" => {
                let t = extras
                    .teacher
                    .and_then(|tp| tp.bn.get(&slot.key))
                    .with_context(|| format!("teacher bn {} missing", slot.key))?;
                runtime::tensor_to_literal(t)
            }
            other => bail!("unknown arg role {other}"),
        }
    }

    /// Write a result tuple back into the state; returns (loss, metric) if
    /// the function reports them.
    pub fn absorb(
        &mut self,
        spec: &FnSpec,
        outs: &[xla::Literal],
    ) -> Result<(Option<f32>, Option<f32>)> {
        let mut loss = None;
        let mut metric = None;
        for (slot, lit) in spec.rets.iter().zip(outs.iter()) {
            match slot.role.as_str() {
                "param" | "bn" | "qstate" | "tau" | "opt_m" | "opt_v" => {
                    let t = runtime::literal_to_tensor(lit, &slot.shape)?;
                    self.store(&slot.role, &slot.key, t);
                }
                "step" => {
                    self.step = runtime::literal_to_tensor(lit, &[])?.data[0];
                }
                "loss" => loss = Some(runtime::literal_to_tensor(lit, &[])?.data[0]),
                "metric" => metric = Some(runtime::literal_to_tensor(lit, &[])?.data[0]),
                "out" => {} // forward outputs handled by caller
                other => bail!("unknown ret role {other}"),
            }
        }
        Ok((loss, metric))
    }
}

/// Per-call inputs beyond the persistent state.
#[derive(Default)]
pub struct CallExtras<'a> {
    pub data: Option<&'a Tensor>,
    pub labels: Option<&'a [i32]>,
    pub lam: f32,
    pub lr: f32,
    pub teacher: Option<&'a TrainState>,
}
