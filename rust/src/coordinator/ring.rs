//! Consistent-hash ring with virtual nodes: the request-placement half of
//! the cluster tier.
//!
//! Each physical node is hashed onto the 64-bit ring at `vnodes` points
//! ("virtual nodes"); a key is owned by the first vnode clockwise from the
//! key's hash. Virtual nodes smooth the load: at >=128 vnodes per node the
//! per-node key share stays within a tight band around `1/N` (asserted by
//! the property suite in `rust/tests/hash_ring.rs`). Consistent hashing
//! gives the *minimal-disruption* property the rebalancing story relies on:
//!
//! * **Node join** moves only the keys the joiner now owns (~`K/N` of them);
//!   every moved key moves *to* the joiner.
//! * **Node leave** moves only the keys the leaver owned; everyone else's
//!   placement is untouched.
//!
//! Hashing is a fixed splitmix64-style avalanche over the raw bytes — fully
//! deterministic across processes and runs (no `RandomState`), so the router
//! and any observer (tests, `/state` consumers) agree on placement.

use std::collections::{BTreeMap, BTreeSet};

/// Deterministic 64-bit hash of a byte string: FNV-1a accumulation followed
/// by a splitmix64 finalizer (same avalanche the fault injector uses).
/// Stable across processes — placement must not depend on `RandomState`.
pub fn stable_hash(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    // splitmix64 finalizer: FNV alone clusters short ASCII keys
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    h ^ (h >> 31)
}

/// Consistent-hash ring: node ids placed at `vnodes` points each, keys owned
/// by the first vnode clockwise. See the module docs for the distribution
/// and minimal-disruption properties.
#[derive(Clone, Debug)]
pub struct HashRing {
    vnodes: usize,
    /// ring position -> owning node id (BTreeMap = the sorted ring).
    ring: BTreeMap<u64, String>,
    nodes: BTreeSet<String>,
}

impl HashRing {
    /// An empty ring placing each node at `vnodes` points (clamped to >= 1).
    /// 128+ vnodes keep per-node key share within the tested statistical
    /// band; fewer trade balance for a smaller ring.
    pub fn new(vnodes: usize) -> HashRing {
        HashRing { vnodes: vnodes.max(1), ring: BTreeMap::new(), nodes: BTreeSet::new() }
    }

    /// Vnodes per node this ring was built with.
    pub fn vnodes(&self) -> usize {
        self.vnodes
    }

    /// Hash positions of one node's vnodes ("node#i" for i in 0..vnodes).
    fn vnode_positions(&self, node: &str) -> impl Iterator<Item = u64> + '_ {
        let node = node.to_string();
        (0..self.vnodes).map(move |i| stable_hash(format!("{node}#{i}").as_bytes()))
    }

    /// Add a node (idempotent). Returns `true` if the node was new.
    pub fn add_node(&mut self, node: &str) -> bool {
        if !self.nodes.insert(node.to_string()) {
            return false;
        }
        for pos in self.vnode_positions(node).collect::<Vec<_>>() {
            // vnode hash collisions between different nodes are possible in
            // principle (64-bit space); first writer keeps the slot, which
            // both sides compute identically — placement stays deterministic
            self.ring.entry(pos).or_insert_with(|| node.to_string());
        }
        true
    }

    /// Remove a node and all its vnodes (idempotent). Returns `true` if the
    /// node was present.
    pub fn remove_node(&mut self, node: &str) -> bool {
        if !self.nodes.remove(node) {
            return false;
        }
        self.ring.retain(|_, owner| owner != node);
        true
    }

    /// Is this node on the ring?
    pub fn contains(&self, node: &str) -> bool {
        self.nodes.contains(node)
    }

    /// Number of physical nodes on the ring.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when no node is on the ring.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// All node ids, sorted.
    pub fn nodes(&self) -> impl Iterator<Item = &str> {
        self.nodes.iter().map(|s| s.as_str())
    }

    /// The node owning `key`: first vnode clockwise from the key's hash
    /// (wrapping). `None` on an empty ring.
    pub fn primary(&self, key: &str) -> Option<&str> {
        let h = stable_hash(key.as_bytes());
        self.ring
            .range(h..)
            .next()
            .or_else(|| self.ring.iter().next())
            .map(|(_, owner)| owner.as_str())
    }

    /// The first `r` *distinct* nodes clockwise from `key` — the replica set
    /// (primary first). Fewer than `r` nodes on the ring yields all of them.
    pub fn replicas(&self, key: &str, r: usize) -> Vec<&str> {
        let want = r.min(self.nodes.len());
        let mut out: Vec<&str> = Vec::with_capacity(want);
        if want == 0 {
            return out;
        }
        let h = stable_hash(key.as_bytes());
        for (_, owner) in self.ring.range(h..).chain(self.ring.range(..h)) {
            if !out.contains(&owner.as_str()) {
                out.push(owner.as_str());
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_ring_owns_nothing() {
        let ring = HashRing::new(128);
        assert!(ring.is_empty());
        assert_eq!(ring.primary("k"), None);
        assert!(ring.replicas("k", 3).is_empty());
    }

    #[test]
    fn add_remove_are_idempotent() {
        let mut ring = HashRing::new(16);
        assert!(ring.add_node("a"));
        assert!(!ring.add_node("a"), "second add is a no-op");
        assert_eq!(ring.len(), 1);
        assert!(ring.remove_node("a"));
        assert!(!ring.remove_node("a"), "second remove is a no-op");
        assert!(ring.is_empty());
    }

    #[test]
    fn single_node_owns_everything() {
        let mut ring = HashRing::new(128);
        ring.add_node("only");
        for k in ["a", "b", "zzz", "0"] {
            assert_eq!(ring.primary(k), Some("only"));
            assert_eq!(ring.replicas(k, 3), vec!["only"]);
        }
    }

    #[test]
    fn replicas_are_distinct_and_start_at_primary() {
        let mut ring = HashRing::new(128);
        for n in ["a", "b", "c", "d"] {
            ring.add_node(n);
        }
        for i in 0..64 {
            let key = format!("key-{i}");
            let reps = ring.replicas(&key, 3);
            assert_eq!(reps.len(), 3);
            assert_eq!(reps[0], ring.primary(&key).unwrap());
            let set: BTreeSet<&str> = reps.iter().copied().collect();
            assert_eq!(set.len(), 3, "replica set must be distinct nodes");
        }
    }

    #[test]
    fn placement_is_deterministic_across_ring_instances() {
        let build = || {
            let mut r = HashRing::new(128);
            for n in ["n0", "n1", "n2"] {
                r.add_node(n);
            }
            r
        };
        let (a, b) = (build(), build());
        for i in 0..256 {
            let key = format!("k{i}");
            assert_eq!(a.primary(&key), b.primary(&key));
            assert_eq!(a.replicas(&key, 2), b.replicas(&key, 2));
        }
    }
}
