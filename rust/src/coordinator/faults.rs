//! Deterministic fault injection for the serving tier (chaos harness).
//!
//! [`FaultyModel`] wraps any [`BatchModel`] and injects the failure modes
//! the paper's deployment story has to survive — vendor-backend latency
//! spikes, transient inference errors, hard worker panics, and sustained
//! backend brownout — on **reproducible schedules**: every decision is a
//! pure function of `(FaultPlan::seed, call index)` via a splitmix64-style
//! hash, so a fixed seed replays the exact same fault sequence. That makes
//! SLO-violation rates, breaker trips, and retry counts deterministic and
//! assertable in tests (`rust/tests/server_faults.rs`) and comparable run
//! to run in the `server_load` chaos scenarios.
//!
//! Injection order per call (first match wins): scheduled panic, brownout
//! window, seeded transient error, seeded latency spike, then delegation to
//! the wrapped model. Injected transient errors carry
//! [`TRANSIENT_MARKER`](crate::coordinator::server::TRANSIENT_MARKER), so
//! the server's retry/breaker machinery treats them exactly like a flaky
//! real backend.

use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::server::{transient_error, BatchModel, ServerDeployment};
use crate::tensor::Tensor;

/// What a brownout window does to each call inside it.
#[derive(Clone, Copy, Debug)]
pub enum BrownoutMode {
    /// Every call in the window fails with a transient error (hard
    /// brownout: the backend answers, but uselessly).
    Fail,
    /// Every call in the window is slowed by this much before delegating
    /// (soft brownout: the backend limps).
    Slow(Duration),
}

/// A sustained degradation window: calls `[from_call, from_call + calls)`
/// (0-based call index on the wrapped model) misbehave per `mode`.
#[derive(Clone, Copy, Debug)]
pub struct Brownout {
    pub from_call: usize,
    pub calls: usize,
    pub mode: BrownoutMode,
}

impl Brownout {
    fn covers(&self, call: usize) -> bool {
        call >= self.from_call && call < self.from_call + self.calls
    }
}

/// Seeded fault schedule. `Default` injects nothing — start from it and turn
/// on only the failure modes a scenario needs.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// Seed for the per-call hash; same seed = same fault sequence.
    pub seed: u64,
    /// Probability in [0, 1] that a call sleeps `spike` before delegating.
    pub spike_prob: f64,
    /// Injected latency spike duration.
    pub spike: Duration,
    /// Probability in [0, 1] that a call fails with a transient error.
    pub transient_prob: f64,
    /// Panic on every n-th call (1-based: `panic_every = 3` panics calls
    /// 2, 5, 8, ... by 0-based index). Exercises worker containment.
    pub panic_every: Option<NonZeroUsize>,
    /// Optional sustained brownout window.
    pub brownout: Option<Brownout>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            spike_prob: 0.0,
            spike: Duration::from_millis(5),
            transient_prob: 0.0,
            panic_every: None,
            brownout: None,
        }
    }
}

/// splitmix64 finalizer: avalanches `seed ^ salted-call-index` into 64
/// well-mixed bits (same mixer the engine's test RNG uses).
fn mix(seed: u64, call: u64, salt: u64) -> u64 {
    let mut z = seed ^ call.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(salt);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic uniform in [0, 1) from (seed, call, salt).
fn unit(seed: u64, call: u64, salt: u64) -> f64 {
    (mix(seed, call, salt) >> 11) as f64 / (1u64 << 53) as f64
}

const SALT_TRANSIENT: u64 = 0x7261_6e73;
const SALT_SPIKE: u64 = 0x7370_696b;

/// A [`BatchModel`] that replays a [`FaultPlan`] on top of a real model.
/// Call indices are assigned atomically, so the schedule stays deterministic
/// per-model even with several workers running batches concurrently (which
/// *batch* hits fault k can still race; single-worker setups are fully
/// deterministic end to end).
pub struct FaultyModel {
    inner: Arc<dyn BatchModel>,
    plan: FaultPlan,
    calls: AtomicUsize,
}

impl FaultyModel {
    pub fn new(inner: Arc<dyn BatchModel>, plan: FaultPlan) -> Self {
        FaultyModel { inner, plan, calls: AtomicUsize::new(0) }
    }

    /// Wrap a deployment's model in this fault plan, preserving its name and
    /// fallback wiring — drop-in chaos for a compiled fleet.
    pub fn wrap(dep: ServerDeployment, plan: FaultPlan) -> ServerDeployment {
        ServerDeployment {
            name: dep.name,
            model: Arc::new(FaultyModel::new(dep.model, plan)),
            fallbacks: dep.fallbacks,
        }
    }

    /// Calls observed so far (including ones that panicked or failed).
    pub fn calls(&self) -> usize {
        self.calls.load(Ordering::Relaxed)
    }
}

impl BatchModel for FaultyModel {
    fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
        let call = self.calls.fetch_add(1, Ordering::Relaxed);
        let plan = &self.plan;
        if let Some(n) = plan.panic_every {
            if (call + 1) % n.get() == 0 {
                panic!("injected fault: model panic on call {call}");
            }
        }
        if let Some(b) = &plan.brownout {
            if b.covers(call) {
                match b.mode {
                    BrownoutMode::Fail => {
                        return Err(transient_error(format!("injected brownout on call {call}")))
                    }
                    BrownoutMode::Slow(d) => std::thread::sleep(d),
                }
            }
        }
        if plan.transient_prob > 0.0
            && unit(plan.seed, call as u64, SALT_TRANSIENT) < plan.transient_prob
        {
            return Err(transient_error(format!("injected transient error on call {call}")));
        }
        if plan.spike_prob > 0.0 && unit(plan.seed, call as u64, SALT_SPIKE) < plan.spike_prob {
            std::thread::sleep(plan.spike);
        }
        self.inner.run_batch(images)
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn input_shape(&self) -> Option<Vec<usize>> {
        self.inner.input_shape()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::is_transient;

    struct Echo;
    impl BatchModel for Echo {
        fn run_batch(&self, images: &Tensor) -> Result<Tensor> {
            Ok(images.clone())
        }
        fn max_batch(&self) -> usize {
            4
        }
    }

    fn img() -> Tensor {
        Tensor::full(&[1, 2], 1.0)
    }

    #[test]
    fn default_plan_injects_nothing() {
        let m = FaultyModel::new(Arc::new(Echo), FaultPlan::default());
        for _ in 0..64 {
            assert!(m.run_batch(&img()).is_ok());
        }
        assert_eq!(m.calls(), 64);
    }

    #[test]
    fn transient_schedule_is_seed_deterministic() {
        let plan = FaultPlan { seed: 42, transient_prob: 0.3, ..FaultPlan::default() };
        let runs: Vec<Vec<bool>> = (0..2)
            .map(|_| {
                let m = FaultyModel::new(Arc::new(Echo), plan);
                (0..200).map(|_| m.run_batch(&img()).is_err()).collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1], "same seed must replay the same fault sequence");
        let fails = runs[0].iter().filter(|&&f| f).count();
        assert!((30..=90).contains(&fails), "p=0.3 over 200 calls, got {fails}");
        // a different seed gives a different schedule
        let other = FaultyModel::new(
            Arc::new(Echo),
            FaultPlan { seed: 43, transient_prob: 0.3, ..FaultPlan::default() },
        );
        let seq: Vec<bool> = (0..200).map(|_| other.run_batch(&img()).is_err()).collect();
        assert_ne!(seq, runs[0], "different seed must reshuffle the schedule");
    }

    #[test]
    fn injected_errors_are_transient() {
        let m = FaultyModel::new(
            Arc::new(Echo),
            FaultPlan { transient_prob: 1.0, ..FaultPlan::default() },
        );
        let err = m.run_batch(&img()).unwrap_err();
        assert!(is_transient(&err), "{err}");
    }

    #[test]
    fn panic_every_n_panics_on_schedule() {
        let m = Arc::new(FaultyModel::new(
            Arc::new(Echo),
            FaultPlan { panic_every: NonZeroUsize::new(3), ..FaultPlan::default() },
        ));
        for call in 0..9 {
            let m2 = m.clone();
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                m2.run_batch(&img()).is_ok()
            }));
            if (call + 1) % 3 == 0 {
                assert!(out.is_err(), "call {call} must panic");
            } else {
                assert!(out.unwrap(), "call {call} must succeed");
            }
        }
    }

    #[test]
    fn brownout_window_fails_then_recovers() {
        let m = FaultyModel::new(
            Arc::new(Echo),
            FaultPlan {
                brownout: Some(Brownout { from_call: 2, calls: 3, mode: BrownoutMode::Fail }),
                ..FaultPlan::default()
            },
        );
        let results: Vec<bool> = (0..8).map(|_| m.run_batch(&img()).is_ok()).collect();
        assert_eq!(results, vec![true, true, false, false, false, true, true, true]);
    }
}
