//! Static plan auditor: compile-time proofs about a deployment, reported
//! as typed [`Finding`]s instead of runtime surprises.
//!
//! Three analyses (see engine/README.md "Static guarantees"):
//!
//! 1. **Interval / overflow analysis** (`qir::analysis` + [`CompiledModel::audit`]):
//!    propagates worst-case value bounds through every node using the
//!    deployment's actual qparams, dequantized weight payloads and weight
//!    bit-width, proving per layer that no i8×i8→i32 accumulator can
//!    overflow at the graph's real K dimensions — and flagging
//!    requant-saturation and outlier-driven scale-inflation risk.
//! 2. **Plan liveness/aliasing verification** ([`ExecPlan::verify`]): a
//!    symbolic replay of the compiled instruction list that independently
//!    re-derives liveness and rejects read-after-overwrite, illegal buffer
//!    swaps, uncovered output slots and `ExecScratch` high-water-mark
//!    underestimates. Debug builds run it on every fresh plan
//!    (`ExecPlan::compile`); release deployments are audited out-of-band by
//!    `plan_audit` and the CI `audit` job.
//! 3. **Qparam sanity** ([`CompiledModel::verify`]): finite positive
//!    scales, in-range zero points, non-degenerate calibrated ranges,
//!    finite parameter payloads, payload/row-sum consistency.
//!
//! The [`Sabotage`] API deliberately corrupts a cloned plan (or qparam set)
//! one violation class at a time, so tests and CI can prove the verifier
//! actually catches each class — a verifier only trusted as far as its
//! negative tests.

use std::collections::{BTreeMap, HashMap};
use std::fmt;

use anyhow::{bail, Context, Result};

use crate::engine::plan::{ExecPlan, POp, ProjW};
use crate::engine::simd::KernelTier;
use crate::engine::{lowp, ActMode, CompiledModel};
use crate::qir::analysis::{
    acc_bounds, headroom_bits, propagate, AccBounds, AffineRows, AttnCtx, InputQuant, Interval,
    NodeCtx, NodeReport, PropagateCfg, QuantGrid,
};
use crate::qir::Graph;
use crate::tensor::quantized::{row_sums_of, EPS};
use crate::tensor::{act_scale_zp, QWeight, Tensor};

// ---------------------------------------------------------------------------
// findings
// ---------------------------------------------------------------------------

/// Severity of a [`Finding`]. `Error` means the deployment is unsound (a
/// wrong-result or overflow path is reachable) — the CI audit job and the
/// debug-build compile hook fail on any of these. `Warning` marks elevated
/// numerical risk worth a human look; `Info` is context for the report.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "INFO",
            Severity::Warning => "WARN",
            Severity::Error => "ERROR",
        })
    }
}

/// Plan/graph structure is corrupted beyond what the replay can interpret.
pub const PLAN_GRAPH_MISMATCH: &str = "PLAN_GRAPH_MISMATCH";
/// A slot / arena index points outside the plan's allocation.
pub const PLAN_SLOT_RANGE: &str = "PLAN_SLOT_RANGE";
/// A node reads a slot that no longer (or never did) hold its input value.
pub const PLAN_STALE_READ: &str = "PLAN_STALE_READ";
/// A kernel's output slot aliases one of its still-read input slots.
pub const PLAN_ALIAS: &str = "PLAN_ALIAS";
/// `in_last` claims a last use that liveness analysis refutes (the buffer
/// would be stolen while another consumer still needs it).
pub const PLAN_BAD_LIVENESS: &str = "PLAN_BAD_LIVENESS";
/// A graph output slot does not hold that output's value after the run.
pub const PLAN_OUTPUT_UNCOVERED: &str = "PLAN_OUTPUT_UNCOVERED";
/// A scratch high-water mark is below what execution can actually touch.
pub const PLAN_SCRATCH_UNDER: &str = "PLAN_SCRATCH_UNDER";
/// Swap-connected slots have unequal reservations (breaks the warm-run
/// zero-allocation contract, not correctness).
pub const PLAN_LEVELING: &str = "PLAN_LEVELING";
/// A packed weight panel was laid out for a different kernel tier than the
/// plan dispatches to. The scalar tier expects the `[k][4]` panel
/// interleave, the SIMD tiers a row-major payload — executing across the
/// mismatch silently multiplies against permuted weights.
pub const PLAN_TIER_MISMATCH: &str = "PLAN_TIER_MISMATCH";
/// A weight scale is non-finite, non-positive, or the payload metadata is
/// inconsistent.
pub const QP_WEIGHT_SCALE: &str = "QP_WEIGHT_SCALE";
/// Quantized payload row sums disagree with the stored payload.
pub const QP_PAYLOAD: &str = "QP_PAYLOAD";
/// A calibrated activation range is non-finite, inverted, or degenerate.
pub const QP_RANGE: &str = "QP_RANGE";
/// A derived activation scale is non-finite or non-positive.
pub const QP_SCALE: &str = "QP_SCALE";
/// A derived zero point is outside the u8 grid.
pub const QP_ZP: &str = "QP_ZP";
/// A float parameter tensor carries NaN/inf values.
pub const NONFINITE_PARAM: &str = "NONFINITE_PARAM";
/// An i32 accumulator bound reaches the overflow region (or has under one
/// bit of headroom).
pub const ACC_OVERFLOW: &str = "ACC_OVERFLOW";
/// The worst-case value range at a quantization point spills past the
/// static grid (requant saturation risk — the paper's clipping section).
pub const SAT_CLIP: &str = "SAT_CLIP";
/// Per-channel weight scales are wildly imbalanced (outlier-driven scale
/// inflation: the largest channel dictates the grid of the rest).
pub const SCALE_INFLATION: &str = "SCALE_INFLATION";
/// The propagated bound overflows f16 storage to ±∞.
pub const F16_OVERFLOW: &str = "F16_OVERFLOW";

/// One result of a static analysis pass.
#[derive(Clone, Debug)]
pub struct Finding {
    pub severity: Severity,
    /// Stable machine-readable code (one of the module's `pub const`s).
    pub code: &'static str,
    /// Graph node (or param key) the finding is anchored to.
    pub node: String,
    pub message: String,
}

impl Finding {
    fn new(severity: Severity, code: &'static str, node: &str, message: String) -> Finding {
        Finding { severity, code, node: node.to_string(), message }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {} at {}: {}", self.severity, self.code, self.node, self.message)
    }
}

/// True when any finding in the slice is an [`Severity::Error`].
pub fn has_errors(findings: &[Finding]) -> bool {
    findings.iter().any(|f| f.severity == Severity::Error)
}

// ---------------------------------------------------------------------------
// plan replay verifier
// ---------------------------------------------------------------------------

/// What a slot currently holds during the symbolic replay: nothing yet, or
/// the value produced by plan node `i`. Buffer swaps move contents between
/// slots exactly as `eval` does, so "the value of node i" tracks the
/// physical buffer wherever the plan parks it.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Content {
    Empty,
    Val(usize),
}

impl ExecPlan {
    /// Symbolically replay the instruction list against `graph` and return
    /// every liveness / aliasing / scratch-sizing violation. Independent of
    /// `compile`'s own bookkeeping: liveness is re-derived from graph
    /// consumer counts and scratch bounds from declared shapes, so a
    /// planner regression (or a corrupted plan) is caught even though both
    /// sides started from the same graph. Panic-free on corrupted plans —
    /// every structural precondition failure is itself a finding.
    pub fn verify(&self, graph: &Graph) -> Vec<Finding> {
        let mut fs = Vec::new();
        if self.nodes.len() != graph.nodes.len()
            || self.nodes.iter().zip(graph.nodes.iter()).any(|(p, g)| p.name != g.name)
            || self.output_slots.len() != graph.outputs.len()
        {
            fs.push(Finding::new(
                Severity::Error,
                PLAN_GRAPH_MISMATCH,
                &graph.name,
                format!(
                    "plan has {} nodes / {} outputs, graph has {} / {} (or names diverge)",
                    self.nodes.len(),
                    self.output_slots.len(),
                    graph.nodes.len(),
                    graph.outputs.len()
                ),
            ));
            return fs;
        }
        for pn in &self.nodes {
            let arity_ok = pn.in_last.len() == pn.in_slots.len();
            if !arity_ok
                || pn.out_slot >= self.slot_count
                || pn.in_slots.iter().any(|&s| s >= self.slot_count)
            {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_SLOT_RANGE,
                    &pn.name,
                    format!(
                        "slots {:?} -> {} outside 0..{} (or liveness arity mismatch)",
                        pn.in_slots, pn.out_slot, self.slot_count
                    ),
                ));
                return fs;
            }
        }
        if let Some(&s) = self.output_slots.iter().find(|&&s| s >= self.slot_count) {
            fs.push(Finding::new(
                Severity::Error,
                PLAN_SLOT_RANGE,
                &graph.name,
                format!("output slot {s} outside 0..{}", self.slot_count),
            ));
            return fs;
        }
        // every packed panel must be laid out for the tier the plan's
        // kernels will dispatch to — a foreign layout is a wrong-result path
        for (i, fp) in self.fpanels.iter().enumerate() {
            if fp.tier != self.tier {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_TIER_MISMATCH,
                    &graph.name,
                    format!(
                        "f32 panel {i} packed for tier {:?}, plan dispatches {:?}",
                        fp.tier, self.tier
                    ),
                ));
            }
        }
        for (i, qp) in self.qpanels.iter().enumerate() {
            if qp.tier != self.tier {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_TIER_MISMATCH,
                    &graph.name,
                    format!(
                        "quantized panel {i} packed for tier {:?}, plan dispatches {:?}",
                        qp.tier, self.tier
                    ),
                ));
            }
        }
        self.replay(graph, &mut fs);
        self.check_sizes(graph, &mut fs);
        fs
    }

    /// The number of inputs `eval` reads for this op (used to reject plans
    /// whose in_slots arity can't satisfy the kernel).
    fn op_arity(op: &POp) -> usize {
        match op {
            POp::Input => 0,
            POp::Add | POp::Mul | POp::Concat => 2,
            _ => 1,
        }
    }

    fn replay(&self, graph: &Graph, fs: &mut Vec<Finding>) {
        let idx_of: HashMap<&str, usize> =
            graph.nodes.iter().enumerate().map(|(i, n)| (n.name.as_str(), i)).collect();
        let mut remaining = graph.consumer_counts();
        let mut content = vec![Content::Empty; self.slot_count];
        let describe = |c: Content| -> String {
            match c {
                Content::Empty => "uninitialized memory".to_string(),
                Content::Val(i) => format!("value of {}", graph.nodes[i].name),
            }
        };
        for (idx, (pn, n)) in self.nodes.iter().zip(graph.nodes.iter()).enumerate() {
            if pn.in_slots.len() < Self::op_arity(&pn.op) {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_SLOT_RANGE,
                    &pn.name,
                    format!(
                        "op needs {} inputs, plan wires {}",
                        Self::op_arity(&pn.op),
                        pn.in_slots.len()
                    ),
                ));
                return;
            }
            // 1. every read must find the producer's live value in the slot
            for (j, &s) in pn.in_slots.iter().enumerate() {
                let Some(producer) = n.inputs.get(j) else { continue };
                let want = idx_of.get(producer.as_str()).copied();
                if want.map(Content::Val) != Some(content[s]) {
                    fs.push(Finding::new(
                        Severity::Error,
                        PLAN_STALE_READ,
                        &pn.name,
                        format!(
                            "input {j} expects the value of {producer} in slot {s}, found {}",
                            describe(content[s])
                        ),
                    ));
                }
            }
            // 2. in_last soundness, re-derived from graph consumer counts
            for (j, inp) in n.inputs.iter().enumerate() {
                let mut truly_last = false;
                if let Some(c) = remaining.get_mut(inp.as_str()) {
                    *c -= 1;
                    truly_last = *c == 0 && !graph.outputs.contains(inp);
                }
                let Some(&claimed) = pn.in_last.get(j) else { continue };
                if claimed && !truly_last {
                    fs.push(Finding::new(
                        Severity::Error,
                        PLAN_BAD_LIVENESS,
                        &pn.name,
                        format!(
                            "claims last use of {inp} (input {j}) but it is still \
                             consumed later or is a graph output"
                        ),
                    ));
                } else if !claimed && truly_last {
                    fs.push(Finding::new(
                        Severity::Info,
                        PLAN_BAD_LIVENESS,
                        &pn.name,
                        format!("misses a move opportunity on dead input {inp} (copy instead)"),
                    ));
                }
            }
            // 3. mirror eval()'s exact buffer-swap / disjoint-borrow paths
            let o = pn.out_slot;
            let mut alias = |slot: usize, role: &str| {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_ALIAS,
                    &pn.name,
                    format!("output slot {o} aliases {role} input slot {slot}"),
                ));
            };
            match &pn.op {
                POp::Input => {}
                POp::Act(_)
                | POp::Aq { .. }
                | POp::AqDyn { .. }
                | POp::AqNoop
                | POp::Flatten
                | POp::Reshape { .. } => {
                    let i = pn.in_slots[0];
                    if pn.in_last[0] {
                        content.swap(i, o);
                    } else if i == o {
                        alias(i, "pass-through");
                    }
                }
                POp::Add => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 != i1 && pn.in_last[0] {
                        content.swap(i0, o);
                        if i1 == o {
                            alias(i1, "accumulate");
                        }
                    } else if i0 != i1 && pn.in_last[1] {
                        content.swap(i1, o);
                        if i0 == o {
                            alias(i0, "accumulate");
                        }
                    } else {
                        if i0 == o {
                            alias(i0, "left");
                        }
                        if i1 == o {
                            alias(i1, "right");
                        }
                    }
                }
                POp::Mul => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 != i1 && pn.in_last[0] {
                        content.swap(i0, o);
                        if i1 == o {
                            alias(i1, "gate");
                        }
                    } else {
                        if i0 == o {
                            alias(i0, "gated");
                        }
                        if i1 == o {
                            alias(i1, "gate");
                        }
                    }
                }
                POp::Concat => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 == o {
                        alias(i0, "left");
                    }
                    if i1 == o {
                        alias(i1, "right");
                    }
                }
                // every remaining op reads input 0 through in_out1
                _ => {
                    if pn.in_slots[0] == o {
                        alias(pn.in_slots[0], "kernel");
                    }
                }
            }
            content[o] = Content::Val(idx);
        }
        // 4. each graph output's value must sit in its advertised slot
        for (k, (&s, oname)) in self.output_slots.iter().zip(graph.outputs.iter()).enumerate() {
            let want = idx_of.get(oname.as_str()).copied().map(Content::Val);
            if want != Some(content[s]) {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_OUTPUT_UNCOVERED,
                    oname,
                    format!(
                        "output {k} expects its value in slot {s}, found {}",
                        describe(content[s])
                    ),
                ));
            }
        }
    }

    /// Recompute every scratch high-water mark from the graph's declared
    /// shapes (the same quantities `infer_sizes` derives, re-derived here so
    /// a corrupted or under-maintained `ScratchSizes` is caught) and check
    /// the plan's reservations cover them.
    fn check_sizes(&self, graph: &Graph, fs: &mut Vec<Finding>) {
        if self.sizes.slot_elems.len() < self.slot_count {
            fs.push(Finding::new(
                Severity::Error,
                PLAN_SCRATCH_UNDER,
                &graph.name,
                format!(
                    "slot_elems covers {} of {} slots",
                    self.sizes.slot_elems.len(),
                    self.slot_count
                ),
            ));
            return;
        }
        let mut req = vec![0usize; self.slot_count];
        let (mut col, mut mat, mut xq, mut qkv, mut sc, mut sxw) = (0usize, 0, 0, 0, 0, 0);
        let mut max_rank = 0usize;
        let dim = |n: &crate::qir::Node, i: usize| n.shape.get(i).copied().unwrap_or(1);
        for (n, pn) in graph.nodes.iter().zip(self.nodes.iter()) {
            let elems: usize = n.shape.iter().product::<usize>().max(1);
            max_rank = max_rank.max(n.shape.len() + 1);
            req[pn.out_slot] = req[pn.out_slot].max(elems);
            match &pn.op {
                POp::ConvF32 { w, .. } => {
                    let Some(wp) = self.fpanels.get(*w) else {
                        fs.push(Finding::new(
                            Severity::Error,
                            PLAN_SLOT_RANGE,
                            &pn.name,
                            format!("f32 panel index {w} out of range"),
                        ));
                        continue;
                    };
                    let rows = dim(n, 1) * dim(n, 2);
                    col = col.max(rows * wp.cols);
                    mat = mat.max(rows * wp.cout());
                }
                POp::ConvI8 { w, .. } => {
                    let Some(pw) = self.qpanels.get(*w) else {
                        fs.push(Finding::new(
                            Severity::Error,
                            PLAN_SLOT_RANGE,
                            &pn.name,
                            format!("quantized panel index {w} out of range"),
                        ));
                        continue;
                    };
                    let rows = dim(n, 1) * dim(n, 2);
                    col = col.max(rows * pw.cols);
                    mat = mat.max(rows * pw.cout());
                    xq = xq.max(rows * pw.cols);
                    sxw = sxw.max(pw.cout());
                }
                POp::LinearI8 { w, .. } => {
                    let Some(pw) = self.qpanels.get(*w) else {
                        fs.push(Finding::new(
                            Severity::Error,
                            PLAN_SLOT_RANGE,
                            &pn.name,
                            format!("quantized panel index {w} out of range"),
                        ));
                        continue;
                    };
                    let rows = elems / pw.cout().max(1);
                    xq = xq.max(rows.max(1) * pw.cols);
                    sxw = sxw.max(pw.cout());
                }
                POp::Attention { d, proj, .. } => {
                    let t = n.shape.first().copied().unwrap_or(1);
                    qkv = qkv.max(t * *d);
                    sc = sc.max(t);
                    if proj.iter().any(|p| matches!(p.w, ProjW::I8 { .. })) {
                        xq = xq.max(t * *d);
                        sxw = sxw.max(*d);
                    }
                }
                _ => {}
            }
        }
        // level per-slot requirements across run-time buffer swaps, exactly
        // as `infer_sizes` does: after any permutation of a swap class, each
        // member slot must still cover the class maximum
        let mut parent: Vec<usize> = (0..self.slot_count).collect();
        fn root(parent: &mut [usize], mut i: usize) -> usize {
            while parent[i] != i {
                parent[i] = parent[parent[i]];
                i = parent[i];
            }
            i
        }
        let mut edges: Vec<(usize, usize)> = Vec::new();
        for pn in &self.nodes {
            match &pn.op {
                POp::Act(_)
                | POp::Aq { .. }
                | POp::AqDyn { .. }
                | POp::AqNoop
                | POp::Flatten
                | POp::Reshape { .. } => {
                    if pn.in_last[0] {
                        edges.push((pn.in_slots[0], pn.out_slot));
                    }
                }
                POp::Add => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 != i1 && pn.in_last[0] {
                        edges.push((i0, pn.out_slot));
                    } else if i0 != i1 && pn.in_last[1] {
                        edges.push((i1, pn.out_slot));
                    }
                }
                POp::Mul => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 != i1 && pn.in_last[0] {
                        edges.push((i0, pn.out_slot));
                    }
                }
                _ => {}
            }
        }
        for &(a, b) in &edges {
            let (ra, rb) = (root(&mut parent, a), root(&mut parent, b));
            if ra != rb {
                parent[ra] = rb;
            }
        }
        let mut class_max = vec![0usize; self.slot_count];
        for i in 0..self.slot_count {
            let r = root(&mut parent, i);
            class_max[r] = class_max[r].max(req[i]);
        }
        for i in 0..self.slot_count {
            let need = class_max[root(&mut parent, i)];
            if self.sizes.slot_elems[i] < need {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_SCRATCH_UNDER,
                    &graph.name,
                    format!(
                        "slot {i} reserves {} elems/sample, execution can park {need}",
                        self.sizes.slot_elems[i]
                    ),
                ));
            }
        }
        for &(a, b) in &edges {
            if self.sizes.slot_elems[a] != self.sizes.slot_elems[b] {
                fs.push(Finding::new(
                    Severity::Warning,
                    PLAN_LEVELING,
                    &graph.name,
                    format!(
                        "swap-connected slots {a}/{b} reserve {} vs {} elems — a warm \
                         run can reallocate after the swap",
                        self.sizes.slot_elems[a], self.sizes.slot_elems[b]
                    ),
                ));
            }
        }
        for (name, need, have) in [
            ("col", col, self.sizes.col),
            ("mat", mat, self.sizes.mat),
            ("xq", xq, self.sizes.xq),
            ("qkv", qkv, self.sizes.qkv),
            ("sc", sc, self.sizes.sc),
            ("sxw", sxw, self.sizes.sxw),
            ("max_rank", max_rank, self.sizes.max_rank),
        ] {
            if have < need {
                fs.push(Finding::new(
                    Severity::Error,
                    PLAN_SCRATCH_UNDER,
                    &graph.name,
                    format!("{name} high-water mark {have} below required {need}"),
                ));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// qparam sanity
// ---------------------------------------------------------------------------

/// Sanity-check every quantization parameter and float payload of a
/// deployment. Standalone over the raw maps so the [`Sabotage`] API can
/// feed corrupted copies without mutating a live model.
pub(crate) fn qparam_findings(
    qweights: &HashMap<String, QWeight>,
    act_ranges: &HashMap<String, (f32, f32)>,
    params: &BTreeMap<String, Tensor>,
    bn: &BTreeMap<String, Tensor>,
) -> Vec<Finding> {
    let mut fs = Vec::new();
    let mut wkeys: Vec<&String> = qweights.keys().collect();
    wkeys.sort();
    for key in wkeys {
        let qw = &qweights[key];
        if qw.bits != 8 && qw.bits != 4 {
            fs.push(Finding::new(
                Severity::Error,
                QP_WEIGHT_SCALE,
                key,
                format!("unsupported weight bit-width {}", qw.bits),
            ));
        }
        if let Some((c, &s)) =
            qw.scales.iter().enumerate().find(|(_, s)| !s.is_finite() || **s <= 0.0)
        {
            fs.push(Finding::new(
                Severity::Error,
                QP_WEIGHT_SCALE,
                key,
                format!("channel {c} scale {s} is not a finite positive number"),
            ));
        }
        let sums = row_sums_of(&qw.unpacked_data(), qw.cout());
        if sums != qw.row_sums {
            fs.push(Finding::new(
                Severity::Error,
                QP_PAYLOAD,
                key,
                "stored row sums disagree with the payload (zero-point correction \
                 would silently corrupt results)"
                    .to_string(),
            ));
        }
    }
    let mut rkeys: Vec<&String> = act_ranges.keys().collect();
    rkeys.sort();
    for key in rkeys {
        let (lo, hi) = act_ranges[key];
        if !lo.is_finite() || !hi.is_finite() || lo > hi {
            fs.push(Finding::new(
                Severity::Error,
                QP_RANGE,
                key,
                format!("calibrated range ({lo}, {hi}) is non-finite or inverted"),
            ));
            continue;
        }
        if hi - lo < EPS {
            fs.push(Finding::new(
                Severity::Info,
                QP_RANGE,
                key,
                format!("degenerate range ({lo}, {hi}) — widened to span zero at plan time"),
            ));
        }
        let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
        if !s.is_finite() || s <= 0.0 {
            fs.push(Finding::new(
                Severity::Error,
                QP_SCALE,
                key,
                format!("derived activation scale {s} is not a finite positive number"),
            ));
        }
        if !(0..=255).contains(&z) {
            fs.push(Finding::new(
                Severity::Error,
                QP_ZP,
                key,
                format!("derived zero point {z} outside the u8 grid"),
            ));
        }
    }
    for (label, map) in [("param", params), ("bn", bn)] {
        for (key, t) in map.iter() {
            let bad = t.data.iter().filter(|v| !v.is_finite()).count();
            if bad > 0 {
                fs.push(Finding::new(
                    Severity::Error,
                    NONFINITE_PARAM,
                    key,
                    format!("{label} tensor carries {bad} non-finite of {} values", t.data.len()),
                ));
            }
        }
    }
    fs
}

// ---------------------------------------------------------------------------
// interval audit over a compiled model
// ---------------------------------------------------------------------------

/// One integer GEMM layer's accumulator audit row (the per-layer
/// saturation-risk table of `AUDIT.txt`).
#[derive(Clone, Debug)]
pub struct LayerAudit {
    /// Graph node name (attention layers contribute one row per projection).
    pub node: String,
    /// `conv2d` / `linear` / `attention.wq` … label for the table.
    pub kind: String,
    /// Weight bit-width of the payload (8 or 4).
    pub bits: u8,
    /// Reduction length (actual K dimension of the GEMM).
    pub k: usize,
    /// Worst-case i32 accumulator bounds from the actual payload.
    pub acc: AccBounds,
    /// `log2(i32::MAX / max_abs)` — bits of headroom before overflow.
    pub headroom_bits: f64,
    /// Worst-case requant clipping excess at this node (0 = saturation-free).
    pub clip: f64,
    /// max/median per-channel weight scale (1.0 when per-tensor or < 4 ch).
    pub scale_ratio: f64,
}

/// Full static audit of one deployment: findings from all three analyses,
/// the per-layer accumulator table, and the raw per-node intervals.
#[derive(Clone, Debug)]
pub struct AuditReport {
    pub findings: Vec<Finding>,
    pub layers: Vec<LayerAudit>,
    pub reports: BTreeMap<String, NodeReport>,
}

impl AuditReport {
    pub fn has_errors(&self) -> bool {
        has_errors(&self.findings)
    }

    /// Node names flagged as numerical risks (Warning-or-worse overflow,
    /// saturation, or scale-inflation findings) — the set the perf model's
    /// `estimate_audited` charges the headroom mitigation term to.
    pub fn flagged_nodes(&self) -> std::collections::BTreeSet<String> {
        self.findings
            .iter()
            .filter(|f| {
                f.severity >= Severity::Warning
                    && matches!(f.code, ACC_OVERFLOW | SAT_CLIP | SCALE_INFLATION)
            })
            .map(|f| f.node.clone())
            .collect()
    }
}

impl CompiledModel {
    /// Run the plan replay verifier and qparam sanity checks. (In debug
    /// builds `plan()` itself already refuses to return a plan with ERROR
    /// findings, so this surfaces them as `Err` there; release builds get
    /// the findings list.)
    pub fn verify(&self) -> Result<Vec<Finding>> {
        let mut fs = qparam_findings(&self.qweights, &self.act_ranges, &self.params, &self.bn);
        fs.extend(self.plan()?.verify(&self.graph));
        Ok(fs)
    }

    /// Full static audit: plan verification, qparam sanity, and interval /
    /// accumulator-overflow analysis. `input` is the worst-case (lo, hi)
    /// range of the input tensor (e.g. the eval set's observed range);
    /// `None` uses the default normalized-image interval.
    pub fn audit(&self, input: Option<(f32, f32)>) -> Result<AuditReport> {
        let mut findings = self.verify()?;
        let (ctx, mut layers) = self.analysis_ctx()?;
        let mut cfg = PropagateCfg::default();
        if let Some((lo, hi)) = input {
            let (lo, hi) = (lo.min(hi) as f64, hi.max(lo) as f64);
            cfg.input = Interval::new(lo, hi);
        }
        match self.cfg.act_mode {
            ActMode::Bf16 => cfg.narrow_rel = lowp::BF16_REL_STEP,
            ActMode::F16 => {
                cfg.narrow_rel = lowp::F16_REL_STEP;
                cfg.inf_threshold = Some(lowp::F16_MAX_FINITE);
            }
            _ => {}
        }
        let reports = propagate(&self.graph, &ctx, &cfg)?;
        for la in &mut layers {
            la.clip = reports.get(&la.node).map(|r| r.clip).unwrap_or(0.0);
            if la.acc.max_abs > i32::MAX as i64 {
                findings.push(Finding::new(
                    Severity::Error,
                    ACC_OVERFLOW,
                    &la.node,
                    format!(
                        "{} K={} int{}: worst-case |acc| {} exceeds i32::MAX",
                        la.kind, la.k, la.bits, la.acc.max_abs
                    ),
                ));
            } else if la.headroom_bits < 1.0 {
                findings.push(Finding::new(
                    Severity::Warning,
                    ACC_OVERFLOW,
                    &la.node,
                    format!(
                        "{} K={} int{}: only {:.2} bits of accumulator headroom",
                        la.kind, la.k, la.bits, la.headroom_bits
                    ),
                ));
            }
            if la.scale_ratio > 8.0 {
                findings.push(Finding::new(
                    Severity::Warning,
                    SCALE_INFLATION,
                    &la.node,
                    format!(
                        "{}: max/median per-channel weight scale {:.1}× — outlier \
                         channels inflate the shared input grid",
                        la.kind, la.scale_ratio
                    ),
                ));
            }
        }
        for (name, r) in &reports {
            if r.clip > 0.25 {
                findings.push(Finding::new(
                    Severity::Warning,
                    SAT_CLIP,
                    name,
                    format!(
                        "worst-case range spills {:.0}% of the grid span past the \
                         static requant grid",
                        r.clip * 100.0
                    ),
                ));
            } else if r.clip > 0.02 {
                findings.push(Finding::new(
                    Severity::Info,
                    SAT_CLIP,
                    name,
                    format!("worst-case range spills {:.1}% past the requant grid", r.clip * 100.0),
                ));
            }
            if matches!(self.cfg.act_mode, ActMode::F16) && !r.out.is_finite() {
                findings.push(Finding::new(
                    Severity::Warning,
                    F16_OVERFLOW,
                    name,
                    "worst-case value bound overflows f16 storage to ±inf".to_string(),
                ));
            }
        }
        Ok(AuditReport { findings, layers, reports })
    }

    /// True when this deployment runs its conv/linear/attention GEMMs on
    /// the integer path (pre-quantized payload + integer activation grid).
    fn integer_gemm(&self, wkey: &str) -> bool {
        self.cfg.weight_mode.is_integer()
            && self.int_round().is_some()
            && self.qweights.contains_key(wkey)
    }

    /// Input quantization the analysis should model in front of a GEMM
    /// reading `producer`, mirroring the engine's own dispatch.
    fn analysis_in_quant(&self, producer: &str) -> Result<InputQuant> {
        if self.cfg.act_mode.is_dynamic() {
            return Ok(InputQuant::Dynamic);
        }
        let (s, z) = self.input_qparams(producer)?;
        Ok(InputQuant::Static(QuantGrid::new(s, z)))
    }

    /// Weight summary for the analysis: on the integer path the *payload's
    /// dequantization* (what the kernel actually multiplies by), the float
    /// param otherwise — same resolution order as `weight_tensor`.
    fn analysis_affine(&self, wkey: &str, rows: usize, bias: Option<&[f32]>) -> Result<AffineRows> {
        let w = self.weight_tensor(wkey)?;
        Ok(AffineRows::from_weights(&w.data, rows, bias))
    }

    /// Accumulator audit row for one integer GEMM.
    fn layer_audit(
        &self,
        node: &str,
        kind: &str,
        qw: &QWeight,
        producer: &str,
    ) -> Result<LayerAudit> {
        let vals = qw.unpacked_data();
        let cout = qw.cout();
        let per = qw.per_row();
        let mut pos = vec![0i64; cout];
        let mut neg = vec![0i64; cout];
        for (r, row) in vals.chunks_exact(per.max(1)).enumerate().take(cout) {
            for &v in row {
                if v > 0 {
                    pos[r] += v as i64;
                } else {
                    neg[r] += v as i64;
                }
            }
        }
        let row_sums: Vec<i64> = qw.row_sums.iter().map(|&v| v as i64).collect();
        let (zx_lo, zx_hi) = if self.cfg.act_mode.is_dynamic() {
            (0i64, 255i64)
        } else {
            let (_, z) = self.input_qparams(producer)?;
            (z as i64, z as i64)
        };
        let acc = acc_bounds(&pos, &neg, &row_sums, zx_lo, zx_hi);
        let scale_ratio = if qw.scales.len() >= 4 {
            let mut s = qw.scales.clone();
            s.sort_by(f32::total_cmp);
            let med = s[s.len() / 2];
            if med > 0.0 {
                (s[s.len() - 1] / med) as f64
            } else {
                f64::INFINITY
            }
        } else {
            1.0
        };
        Ok(LayerAudit {
            node: node.to_string(),
            kind: kind.to_string(),
            bits: qw.bits,
            k: per,
            acc,
            headroom_bits: headroom_bits(acc),
            clip: 0.0,
            scale_ratio,
        })
    }

    /// Build the per-node analysis contexts (and the integer-GEMM layer
    /// table) from this deployment's actual weights and qparams.
    fn analysis_ctx(&self) -> Result<(BTreeMap<String, NodeCtx>, Vec<LayerAudit>)> {
        let mut ctx: BTreeMap<String, NodeCtx> = BTreeMap::new();
        let mut layers = Vec::new();
        for n in &self.graph.nodes {
            match n.kind.as_str() {
                "conv2d" | "linear" => {
                    let wkey = format!("{}.w", n.name);
                    let bias_t = if n.attr_bool("bias") {
                        self.params.get(&format!("{}.b", n.name))
                    } else {
                        None
                    };
                    let bias = bias_t.map(|t| t.data.as_slice());
                    let rows = if n.kind == "conv2d" {
                        n.attr_usize("cout")?
                    } else {
                        n.attr_usize("dout")?
                    };
                    let mut nc = NodeCtx {
                        affine: Some(self.analysis_affine(&wkey, rows, bias)?),
                        ..Default::default()
                    };
                    if self.integer_gemm(&wkey) {
                        nc.in_quant = self.analysis_in_quant(&n.inputs[0])?;
                        let qw = &self.qweights[&wkey];
                        layers.push(self.layer_audit(&n.name, &n.kind, qw, &n.inputs[0])?);
                    }
                    ctx.insert(n.name.clone(), nc);
                }
                "bn" => {
                    let get = |suffix: &str, map: &BTreeMap<String, Tensor>| -> Result<Tensor> {
                        map.get(&format!("{}.{suffix}", n.name))
                            .cloned()
                            .with_context(|| format!("audit: bn {} missing {suffix}", n.name))
                    };
                    let (g, b) = (get("gamma", &self.params)?, get("beta", &self.params)?);
                    let (mean, var) = (get("mean", &self.bn)?, get("var", &self.bn)?);
                    let folded = crate::engine::ops::bn_fold_params(
                        &g.data,
                        &b.data,
                        &mean.data,
                        &var.data,
                        crate::engine::BN_EPS,
                    );
                    ctx.insert(n.name.clone(), NodeCtx { bn: Some(folded), ..Default::default() });
                }
                "layernorm" => {
                    let g = self
                        .params
                        .get(&format!("{}.gamma", n.name))
                        .with_context(|| format!("audit: ln {} missing gamma", n.name))?;
                    let b = self
                        .params
                        .get(&format!("{}.beta", n.name))
                        .with_context(|| format!("audit: ln {} missing beta", n.name))?;
                    let ln = Some((g.data.clone(), b.data.clone()));
                    ctx.insert(n.name.clone(), NodeCtx { ln, ..Default::default() });
                }
                "attention" => {
                    let d = n.attr_usize("d")?;
                    let bias = |suffix: &str| {
                        self.params.get(&format!("{}.{suffix}", n.name)).map(|t| t.data.clone())
                    };
                    let (vb, ob) = (bias("vb"), bias("ob"));
                    let vkey = format!("{}.wv", n.name);
                    let okey = format!("{}.wo", n.name);
                    let v = self.analysis_affine(&vkey, d, vb.as_deref())?;
                    let o = self.analysis_affine(&okey, d, ob.as_deref())?;
                    let mut at = AttnCtx { v, o, ..Default::default() };
                    // the engine quantizes all four projection inputs (and
                    // the context) against the *block input* grid
                    for mat in ["wq", "wk", "wv", "wo"] {
                        let wkey = format!("{}.{mat}", n.name);
                        if self.integer_gemm(&wkey) {
                            let iq = self.analysis_in_quant(&n.inputs[0])?;
                            if mat == "wo" {
                                at.o_quant = iq;
                            } else if mat == "wv" {
                                at.in_quant = iq;
                            }
                            let qw = &self.qweights[&wkey];
                            layers.push(self.layer_audit(
                                &n.name,
                                &format!("attention.{mat}"),
                                qw,
                                &n.inputs[0],
                            )?);
                        }
                    }
                    ctx.insert(n.name.clone(), NodeCtx { attn: Some(at), ..Default::default() });
                }
                "aq" => match self.cfg.act_mode {
                    ActMode::Int8 { .. } => {
                        let &(lo, hi) = self
                            .act_ranges
                            .get(&n.name)
                            .with_context(|| format!("audit: no range for aq {}", n.name))?;
                        let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
                        ctx.insert(
                            n.name.clone(),
                            NodeCtx { quant: Some(QuantGrid::new(s, z)), ..Default::default() },
                        );
                    }
                    ActMode::DynInt8 { .. } => {
                        ctx.insert(
                            n.name.clone(),
                            NodeCtx { dyn_quant: true, ..Default::default() },
                        );
                    }
                    _ => {}
                },
                _ => {}
            }
        }
        Ok((ctx, layers))
    }
}

// ---------------------------------------------------------------------------
// sabotage: negative-test corruption of a cloned plan
// ---------------------------------------------------------------------------

/// One class of plan/qparam corruption the verifier must catch. Used by the
/// negative tests and by `plan_audit --sabotage` (the CI audit job asserts
/// a nonzero exit on every class).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sabotage {
    /// Point a kernel's output slot at its own input slot.
    AliasInputOutput,
    /// Rewire an input read to a slot that never holds the value.
    StaleRead,
    /// Advertise the wrong slot as a graph output.
    UncoveredOutput,
    /// Understate a scratch high-water mark.
    ScratchUnderestimate,
    /// Claim a last-use (buffer steal) liveness refutes.
    BogusSwap,
    /// Corrupt quantization parameters (NaN range, zero weight scale).
    BadQparam,
    /// Repack one weight panel for a kernel tier the plan does not dispatch.
    TierMismatch,
}

impl Sabotage {
    pub const ALL: [Sabotage; 7] = [
        Sabotage::AliasInputOutput,
        Sabotage::StaleRead,
        Sabotage::UncoveredOutput,
        Sabotage::ScratchUnderestimate,
        Sabotage::BogusSwap,
        Sabotage::BadQparam,
        Sabotage::TierMismatch,
    ];

    /// CLI name (`plan_audit --sabotage <name>`).
    pub fn name(self) -> &'static str {
        match self {
            Sabotage::AliasInputOutput => "alias",
            Sabotage::StaleRead => "stale-read",
            Sabotage::UncoveredOutput => "uncovered-output",
            Sabotage::ScratchUnderestimate => "scratch-under",
            Sabotage::BogusSwap => "bogus-swap",
            Sabotage::BadQparam => "bad-qparam",
            Sabotage::TierMismatch => "tier-mismatch",
        }
    }

    pub fn parse(s: &str) -> Option<Sabotage> {
        Sabotage::ALL.iter().copied().find(|c| c.name() == s)
    }

    /// The finding code this corruption must surface (at ERROR severity).
    pub fn expected_code(self) -> &'static str {
        match self {
            Sabotage::AliasInputOutput => PLAN_ALIAS,
            Sabotage::StaleRead => PLAN_STALE_READ,
            Sabotage::UncoveredOutput => PLAN_OUTPUT_UNCOVERED,
            Sabotage::ScratchUnderestimate => PLAN_SCRATCH_UNDER,
            Sabotage::BogusSwap => PLAN_BAD_LIVENESS,
            Sabotage::BadQparam => QP_RANGE,
            Sabotage::TierMismatch => PLAN_TIER_MISMATCH,
        }
    }
}

impl CompiledModel {
    /// Clone this deployment's plan (or qparam set), corrupt it with one
    /// [`Sabotage`] class, and return what the verifier reports. The caller
    /// asserts `expected_code()` shows up at ERROR severity — proving the
    /// verifier catches that violation class on this very model.
    pub fn verify_sabotaged(&self, s: Sabotage) -> Result<Vec<Finding>> {
        if s == Sabotage::BadQparam {
            let mut qws = self.qweights.clone();
            let mut ranges = self.act_ranges.clone();
            ranges.insert("__sabotaged_aq".to_string(), (f32::NAN, 1.0));
            if let Some(qw) = qws.values_mut().next() {
                if let Some(s0) = qw.scales.first_mut() {
                    *s0 = 0.0;
                }
            }
            return Ok(qparam_findings(&qws, &ranges, &self.params, &self.bn));
        }
        let mut plan = self.plan()?.clone();
        if plan.slot_count < 2 {
            bail!("sabotage needs a plan with at least 2 slots");
        }
        match s {
            Sabotage::AliasInputOutput => {
                let victim = plan
                    .nodes
                    .iter_mut()
                    .find(|pn| {
                        !pn.in_slots.is_empty()
                            && !matches!(
                                pn.op,
                                POp::Input
                                    | POp::Act(_)
                                    | POp::Aq { .. }
                                    | POp::AqDyn { .. }
                                    | POp::AqNoop
                                    | POp::Flatten
                                    | POp::Reshape { .. }
                                    | POp::Add
                                    | POp::Mul
                            )
                    })
                    .context("sabotage: no aliasing-sensitive node in plan")?;
                victim.out_slot = victim.in_slots[0];
            }
            Sabotage::StaleRead => {
                let slots = plan.slot_count;
                let victim = plan
                    .nodes
                    .iter_mut()
                    .find(|pn| !pn.in_slots.is_empty())
                    .context("sabotage: no reading node in plan")?;
                victim.in_slots[0] = (victim.in_slots[0] + 1) % slots;
            }
            Sabotage::UncoveredOutput => {
                let slots = plan.slot_count;
                let o = plan.output_slots.first_mut().context("sabotage: plan has no outputs")?;
                *o = (*o + 1) % slots;
            }
            Sabotage::ScratchUnderestimate => {
                let slot = plan.nodes.last().context("sabotage: empty plan")?.out_slot;
                plan.sizes.slot_elems[slot] = 0;
            }
            Sabotage::BogusSwap => {
                let victim = plan
                    .nodes
                    .iter_mut()
                    .flat_map(|pn| pn.in_last.iter_mut())
                    .find(|last| !**last)
                    .context("sabotage: every input is already a last use")?;
                *victim = true;
            }
            Sabotage::TierMismatch => {
                // flip one panel's recorded layout to a tier the plan does
                // not dispatch (any different variant does — the check is
                // equality with the plan's resolved tier)
                let foreign = if plan.tier == KernelTier::Scalar {
                    KernelTier::Avx2
                } else {
                    KernelTier::Scalar
                };
                if let Some(qp) = plan.qpanels.first_mut() {
                    qp.tier = foreign;
                } else if let Some(fp) = plan.fpanels.first_mut() {
                    fp.tier = foreign;
                } else {
                    bail!("sabotage: plan has no packed panels");
                }
            }
            Sabotage::BadQparam => unreachable!("handled above"),
        }
        Ok(plan.verify(&self.graph))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_and_prints() {
        assert!(Severity::Error > Severity::Warning && Severity::Warning > Severity::Info);
        let f = Finding::new(Severity::Error, PLAN_ALIAS, "c1", "output aliases input".into());
        let s = format!("{f}");
        assert!(s.contains("ERROR") && s.contains("PLAN_ALIAS") && s.contains("c1"));
        assert!(has_errors(&[f]));
        assert!(!has_errors(&[Finding::new(
            Severity::Info,
            SAT_CLIP,
            "q1",
            "minor".into()
        )]));
    }

    #[test]
    fn sabotage_names_round_trip() {
        for s in Sabotage::ALL {
            assert_eq!(Sabotage::parse(s.name()), Some(s), "{s:?}");
            assert!(!s.expected_code().is_empty());
        }
        assert_eq!(Sabotage::parse("nonsense"), None);
    }

    #[test]
    fn qparam_sanity_flags_each_corruption() {
        use crate::tensor::Tensor;
        let mut qws: HashMap<String, QWeight> = HashMap::new();
        let w = Tensor::new(vec![2, 3], vec![0.5, -0.25, 0.1, 1.0, -1.0, 0.75]);
        let good = QWeight::quantize(
            &w,
            crate::tensor::QuantScheme::PerChannelSym,
            crate::tensor::RoundMode::TiesEven,
        );
        qws.insert("good.w".into(), good.clone());
        let mut ranges: HashMap<String, (f32, f32)> = HashMap::new();
        ranges.insert("ok".into(), (-1.0, 2.0));
        let clean = qparam_findings(&qws, &ranges, &BTreeMap::new(), &BTreeMap::new());
        assert!(!has_errors(&clean), "{clean:?}");

        let mut bad = good.clone();
        bad.scales[0] = f32::NAN;
        qws.insert("bad.w".into(), bad);
        let mut skewed = good;
        skewed.row_sums[0] += 1;
        qws.insert("skewed.w".into(), skewed);
        ranges.insert("nan".into(), (f32::NAN, 1.0));
        ranges.insert("inverted".into(), (2.0, -1.0));
        let mut params = BTreeMap::new();
        params.insert("p.w".to_string(), Tensor::new(vec![2], vec![1.0, f32::INFINITY]));
        let fs = qparam_findings(&qws, &ranges, &params, &BTreeMap::new());
        let codes: Vec<&str> = fs
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .map(|f| f.code)
            .collect();
        assert!(codes.contains(&QP_WEIGHT_SCALE), "{fs:?}");
        assert!(codes.contains(&QP_PAYLOAD), "{fs:?}");
        assert!(codes.contains(&QP_RANGE), "{fs:?}");
        assert!(codes.contains(&NONFINITE_PARAM), "{fs:?}");
    }
}
