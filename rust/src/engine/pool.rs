//! Persistent worker pool for the engine's parallel kernels.
//!
//! PR-1's `par_row_chunks` parallelised every large GEMM with
//! `std::thread::scope` — one thread *spawn* per row chunk per kernel call,
//! thousands of spawns per second under serving load, and N server workers
//! each spawning their own 8-thread team (N×8 transient threads on an
//! 8-core host). A vendor NPU runtime keeps one long-lived worker team; so
//! does this module:
//!
//! * **One shared pool per process** ([`global`]), sized once from
//!   `available_parallelism` (capped at 8, matching the old per-call
//!   sizing). Every executor thread — the coordinator's N serving workers
//!   included — submits row-chunk work to the *same* team instead of
//!   oversubscribing the host.
//! * **Workers park on a condvar** between kernels; a submission wakes
//!   them, an atomic cursor hands out chunk indices, and the submitting
//!   thread participates in its own task (so a 1-thread pool degrades to
//!   plain inline execution and the pool never deadlocks on itself).
//! * **Zero allocations per submission**: the task descriptor lives on the
//!   submitter's stack, the queue slot is a pre-reserved `Vec` entry, and
//!   completion is signalled through the pool's own mutex + condvar — the
//!   steady-state allocation contract of the planned executor
//!   (`tests/steady_state.rs`) covers the parallel path too.
//!
//! Determinism: chunking is a pure function of (rows, pool parallelism) and
//! every output element is accumulated independently, so results are
//! bit-identical at any worker count — asserted by the pool-determinism
//! test at 1, 2 and 8 workers.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One in-flight parallel-for, owned by the submitting thread's stack frame.
/// Lives in the pool queue only between `run`'s push and retire; `visitors`
/// (guarded by the pool mutex) keeps it pinned while any worker still holds
/// a reference.
struct Task {
    /// The chunk closure. Lifetime-erased: `run` guarantees it outlives
    /// every access by not returning until `visitors` drains to zero.
    func: &'static (dyn Fn(usize) + Sync),
    chunks: usize,
    /// Next unclaimed chunk index (may overshoot `chunks` by one per visitor).
    cursor: AtomicUsize,
    /// Workers currently inside this task. Mutated only under the pool
    /// mutex; the submitter frees the task only after observing zero.
    visitors: Cell<usize>,
    /// A chunk closure panicked on some thread; `run` re-panics on the
    /// submitter so the failure is not silently swallowed.
    panicked: AtomicBool,
}

/// Queue entry: a raw pointer to a submitter-stack `Task`. Sendness is
/// asserted manually — the visitor protocol above keeps the pointee alive
/// for as long as any thread dereferences it.
struct TaskPtr(*const Task);

// SAFETY: the pointee is a stack-pinned `Task` the submitter keeps alive
// until every worker has left it (visitor count observed at zero under the
// pool mutex), so moving the pointer to another thread never outlives or
// races the pointee; all shared fields it reaches are atomics or
// mutex-guarded.
unsafe impl Send for TaskPtr {}

struct PoolState {
    queue: Vec<TaskPtr>,
    shutdown: bool,
}

/// Long-lived pool internals shared between the handle and its workers.
struct Inner {
    state: Mutex<PoolState>,
    /// Wakes parked workers when work arrives (or at shutdown).
    work_cv: Condvar,
    /// Wakes submitters waiting for their task's visitors to drain.
    done_cv: Condvar,
}

/// A persistent team of parked worker threads executing chunked parallel
/// kernels. See the module docs for the lifecycle; almost all code should
/// use the process-wide [`global`] pool rather than constructing one.
pub struct ThreadPool {
    inner: Arc<Inner>,
    threads: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// At least one chunk of a parallel kernel panicked. The panic was contained
/// on the worker that hit it (the rest of the task still completed), and the
/// pool itself stays healthy — callers that can degrade gracefully (the
/// serving tier's supervised workers) use [`ThreadPool::try_run`] and turn
/// this into an error response instead of a dead thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkPanicked;

impl std::fmt::Display for ChunkPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("engine pool: a parallel kernel chunk panicked")
    }
}

impl std::error::Error for ChunkPanicked {}

impl ThreadPool {
    /// Build a pool with `threads` total execution lanes: `threads - 1`
    /// parked workers plus the submitting thread itself. `threads <= 1`
    /// spawns nothing and `run` executes inline.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(PoolState { queue: Vec::with_capacity(16), shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("engine-pool-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn engine pool worker")
            })
            .collect();
        ThreadPool { inner, threads, handles }
    }

    /// Total execution lanes (parked workers + the submitting thread).
    pub fn parallelism(&self) -> usize {
        self.threads
    }

    /// Execute `f(0..chunks)` across the pool, returning when every chunk
    /// has finished. The submitting thread claims chunks too, so progress
    /// never depends on a worker being free. Allocation-free in steady
    /// state. Panics (after completing the task) if any chunk panicked —
    /// use [`ThreadPool::try_run`] to observe that as a `Result` instead.
    pub fn run(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if let Err(e) = self.try_run(chunks, f) {
            panic!("{e}");
        }
    }

    /// [`ThreadPool::run`] with the panic containment *exposed*: every chunk
    /// still executes (a panicking chunk never takes its siblings or the
    /// pool down), but a chunk panic surfaces as `Err(ChunkPanicked)` on the
    /// submitter rather than a re-raised panic. This is what lets a serving
    /// worker convert a poisoned kernel into an error response and keep its
    /// thread.
    pub fn try_run(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) -> Result<(), ChunkPanicked> {
        if chunks == 0 {
            return Ok(());
        }
        if self.threads <= 1 || chunks == 1 {
            // inline path: identical containment contract to the pooled path
            let mut panicked = false;
            for i in 0..chunks {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_err() {
                    panicked = true;
                }
            }
            return if panicked { Err(ChunkPanicked) } else { Ok(()) };
        }
        // SAFETY: the 'static is a lie told only to the queue — `run` does
        // not return until the retire loop below has observed zero visitors
        // under the pool mutex, after which no thread touches `task` again.
        let func: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let task = Task {
            func,
            chunks,
            cursor: AtomicUsize::new(0),
            visitors: Cell::new(0),
            panicked: AtomicBool::new(false),
        };
        {
            let mut st = self.inner.state.lock().unwrap();
            st.queue.push(TaskPtr(&task as *const Task));
        }
        self.inner.work_cv.notify_all();
        run_chunks(&task);
        // Retire: unpublish the task, then wait for in-flight visitors. The
        // mutex hand-off also makes every worker's chunk writes visible.
        {
            let ptr = &task as *const Task;
            let mut st = self.inner.state.lock().unwrap();
            st.queue.retain(|p| p.0 != ptr);
            while task.visitors.get() > 0 {
                st = self.inner.done_cv.wait(st).unwrap();
            }
            drop(st);
        }
        if task.panicked.load(Ordering::Relaxed) {
            Err(ChunkPanicked)
        } else {
            Ok(())
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and execute chunks until the task's cursor is exhausted.
fn run_chunks(task: &Task) {
    loop {
        let i = task.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= task.chunks {
            return;
        }
        let f = task.func;
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))).is_err() {
            task.panicked.store(true, Ordering::Relaxed);
        }
    }
}

fn worker_loop(inner: &Inner) {
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        let front = st.queue.first().map(|p| p.0);
        match front {
            Some(ptr) => {
                // SAFETY: the task is in the queue, so its submitter is
                // still blocked in `run`; registering as a visitor (under
                // the lock) pins it until we deregister below.
                let task = unsafe { &*ptr };
                task.visitors.set(task.visitors.get() + 1);
                drop(st);
                run_chunks(task);
                st = inner.state.lock().unwrap();
                // the cursor is exhausted: unpublish so siblings stop
                // visiting, then deregister and wake the submitter
                st.queue.retain(|p| p.0 != ptr);
                task.visitors.set(task.visitors.get() - 1);
                if task.visitors.get() == 0 {
                    inner.done_cv.notify_all();
                }
            }
            None => {
                st = inner.work_cv.wait(st).unwrap();
            }
        }
    }
}

/// Pool sizing: one lane per available core, capped at 8 (same cap the
/// per-call spawning driver used — beyond it the row chunks get too small
/// for the graphs this engine serves).
fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
}

/// The process-wide shared pool. Created (and its workers spawned) on first
/// use; every executor thread in the process — including all of the
/// serving coordinator's workers — submits to this one team.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| ThreadPool::new(default_threads()))
}

thread_local! {
    /// Per-thread pool override installed by [`with_pool`] (tests and
    /// diagnostics); null means "use the global pool".
    static OVERRIDE: Cell<*const ThreadPool> = const { Cell::new(std::ptr::null()) };
}

/// Run `f` with `pool` substituted for the global pool on THIS thread —
/// the hook the determinism tests use to execute one model at several
/// worker counts. The override applies to kernels dispatched from the
/// calling thread only and is restored (panic-safe) on exit.
pub fn with_pool<R>(pool: &ThreadPool, f: impl FnOnce() -> R) -> R {
    struct Reset(*const ThreadPool);
    impl Drop for Reset {
        fn drop(&mut self) {
            OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let prev = OVERRIDE.with(|c| c.replace(pool as *const ThreadPool));
    let _reset = Reset(prev);
    f()
}

/// Hand the calling thread's effective pool (override or global) to `f`.
pub(crate) fn with_current<R>(f: impl FnOnce(&ThreadPool) -> R) -> R {
    let p = OVERRIDE.with(|c| c.get());
    if p.is_null() {
        f(global())
    } else {
        // SAFETY: a non-null override is installed only by `with_pool`,
        // whose pool reference outlives the closure it runs.
        f(unsafe { &*p })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn executes_every_chunk_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU32> = (0..97).map(|_| AtomicU32::new(0)).collect();
        pool.run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} ran a wrong number of times");
        }
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.parallelism(), 1);
        let seen = Mutex::new(Vec::new());
        pool.run(5, &|i| seen.lock().unwrap().push(i));
        // a 1-lane pool executes on the submitter, in order
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn concurrent_submitters_share_one_team() {
        let pool = std::sync::Arc::new(ThreadPool::new(4));
        let total = AtomicU32::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = &pool;
                let total = &total;
                s.spawn(move || {
                    for _ in 0..50 {
                        pool.run(16, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 16);
    }

    #[test]
    fn with_pool_overrides_and_restores() {
        let small = ThreadPool::new(2);
        with_current(|p| assert!(std::ptr::eq(p, global())));
        with_pool(&small, || {
            with_current(|p| assert!(std::ptr::eq(p, &small)));
        });
        with_current(|p| assert!(std::ptr::eq(p, global())));
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3);
        pool.run(8, &|_| {});
        drop(pool); // must not hang
    }

    #[test]
    fn try_run_surfaces_chunk_panic_without_killing_pool() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU32> = (0..32).map(|_| AtomicU32::new(0)).collect();
        let res = pool.try_run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            if i == 7 {
                panic!("injected chunk panic");
            }
        });
        assert_eq!(res, Err(ChunkPanicked));
        // containment, not abandonment: every sibling chunk still ran
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} did not run exactly once");
        }
        // the pool stays serviceable after a contained panic
        assert_eq!(pool.try_run(16, &|_| {}), Ok(()));
    }

    #[test]
    fn try_run_inline_path_matches_pooled_contract() {
        let pool = ThreadPool::new(1);
        let hits: Vec<AtomicU32> = (0..5).map(|_| AtomicU32::new(0)).collect();
        let res = pool.try_run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
            if i == 1 {
                panic!("injected chunk panic");
            }
        });
        assert_eq!(res, Err(ChunkPanicked));
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
        assert_eq!(pool.try_run(3, &|_| {}), Ok(()));
    }
}
