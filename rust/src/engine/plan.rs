//! Execution-plan layer: a one-time compile step that lowers a backend-
//! compiled model into a flat instruction list the engine can execute with
//! zero per-run graph interpretation overhead.
//!
//! What the plan precomputes (vs the legacy interpreter in `engine::mod`):
//!
//! * **weight resolution** — every conv/linear/attention weight, bias and
//!   QWeight is resolved once into an index into the plan's arenas; no
//!   `format!`-built string keys or `HashMap` lookups on the hot path, and
//!   Int8-weight/float-activation deployments dequantize each weight once
//!   instead of once per node per run.
//! * **quantization constants** — per-node input (scale, zero_point), the
//!   premultiplied per-channel dequant scales `sw*sx`, and a 256-entry
//!   dequant LUT per `aq` node are fixed at plan time, like a real INT8
//!   compiler stack's requantization parameters. Under dynamic activation
//!   scaling ([`ActMode::DynInt8`]) those constants cannot exist at plan
//!   time: the lowered op carries an `IQuant::Dynamic` marker instead and
//!   the executor derives (scale, zero_point) from the live input with one
//!   fused signed min/max scan (`ops::dyn_qparams`) before dispatching the
//!   same requantizing GEMM — no calibration, no `act_ranges`, no second
//!   pass over the activation data.
//! * **memory plan** — liveness-based buffer-slot assignment replaces the
//!   per-run `HashMap<String, Tensor>` + consumer-count bookkeeping; the
//!   executor runs on a flat `Vec<Tensor>` of reusable slots, and
//!   single-consumer pass-through ops (flatten/reshape/act/aq) move their
//!   input instead of cloning it.
//!
//! Kernels are the planned forms in [`ops`]: parallel tiled GEMM on both
//! precision paths with the fused bias+activation epilogue. The integer
//! ops (`ConvI8`/`LinearI8`/`ProjW::I8`) carry whatever bit-width the
//! backend quantized at — the kernels dispatch on `QWeight::bits`, so
//! `WeightMode::Int4` deployments run the nibble-packed int4 GEMM through
//! the same plan structure. The int8 and int4 paths are bit-exact with the
//! interpreter (asserted by `tests/plan_exactness.rs`); the f32 path keeps
//! the reference kernels' per-output accumulation order, so it matches
//! bit-for-bit too.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::engine::ops::{self, Act};
use crate::engine::{lowp, ActMode, CompiledModel, BN_EPS};
use crate::qir::Node;
use crate::tensor::{act_scale_zp, QWeight, RoundMode, Tensor};

/// Input-quantization constants of one integer op: fixed at plan time from
/// the producer's static range (`ActMode::Int8`), or recomputed from the
/// live input tensor on every run (`ActMode::DynInt8` — one fused signed
/// min/max scan via [`ops::dyn_qparams`], then the same requantizing
/// GEMM epilogue; only the tiny per-channel `sw*sx` premultiply is redone,
/// never a second pass over the activation data).
enum IQuant {
    Static { sx: f32, zx: i32, sxw: Vec<f32> },
    Dynamic,
}

/// One attention projection with its pre-resolved weights.
enum ProjW {
    F32(usize),
    I8 { w: usize, round: RoundMode, iq: IQuant },
}

struct AttnProj {
    w: ProjW,
    b: usize,
}

/// Lowered node: every reference is an arena index, every constant is baked.
enum POp {
    Input,
    ConvF32 {
        w: usize,
        bias: Option<usize>,
        stride: usize,
        pad: usize,
        groups: usize,
        act: Option<Act>,
    },
    ConvI8 {
        w: usize,
        bias: Option<usize>,
        stride: usize,
        pad: usize,
        groups: usize,
        act: Option<Act>,
        round: RoundMode,
        iq: IQuant,
    },
    LinearF32 { w: usize, bias: Option<usize>, din: usize, dout: usize, act: Option<Act> },
    LinearI8 {
        w: usize,
        bias: Option<usize>,
        din: usize,
        act: Option<Act>,
        round: RoundMode,
        iq: IQuant,
    },
    Bn { scale: Vec<f32>, shift: Vec<f32> },
    Act(Act),
    Add,
    Mul,
    Pool { k: usize, stride: usize, pad: usize, is_max: bool },
    Gap,
    Upsample2x,
    Concat,
    Flatten,
    Reshape { shape: Vec<usize> },
    LayerNorm { d: usize, gamma: usize, beta: usize },
    ToTokens,
    TokMean,
    Attention { d: usize, heads: usize, proj: [AttnProj; 4] },
    Aq { scale: f32, zp: i32, round: RoundMode, lut: Box<[f32; 256]> },
    /// Dynamic requantization point: range scan + requant fused per run.
    AqDyn { round: RoundMode },
    AqNoop,
}

struct PlannedNode {
    name: String,
    in_slots: Vec<usize>,
    out_slot: usize,
    /// Input 0's last consumer is this node: the executor may move the
    /// tensor out of its slot instead of cloning (pass-through ops only).
    move0: bool,
    op: POp,
}

/// A compiled execution plan: flat instruction list + weight arenas +
/// buffer-reuse memory plan. Built once per `CompiledModel`, executed per
/// request.
pub struct ExecPlan {
    act_mode: ActMode,
    nodes: Vec<PlannedNode>,
    slot_count: usize,
    output_slots: Vec<usize>,
    tensors: Vec<Tensor>,
    qweights: Vec<QWeight>,
}

impl ExecPlan {
    /// Lower a compiled model. Fails early (at deploy time, not request
    /// time) on missing params, ranges, or unknown ops.
    pub fn compile(model: &CompiledModel) -> Result<ExecPlan> {
        let graph = &model.graph;
        let mut b = Builder { tensors: Vec::new(), qweights: Vec::new() };
        let mut remaining: HashMap<String, usize> = graph.consumer_counts();
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_count = 0usize;
        let mut nodes = Vec::with_capacity(graph.nodes.len());
        for n in &graph.nodes {
            let in_slots: Vec<usize> = n
                .inputs
                .iter()
                .map(|i| {
                    slot_of
                        .get(i)
                        .copied()
                        .with_context(|| format!("plan: node {} reads unplanned input {i}", n.name))
                })
                .collect::<Result<_>>()?;
            let op = b.lower(model, n)?;
            // allocate the output slot before releasing inputs, so an output
            // never aliases a buffer the kernel still reads
            let out_slot = free.pop().unwrap_or_else(|| {
                slot_count += 1;
                slot_count - 1
            });
            slot_of.insert(n.name.clone(), out_slot);
            let mut move0 = false;
            for (idx, i) in n.inputs.iter().enumerate() {
                if let Some(c) = remaining.get_mut(i.as_str()) {
                    *c -= 1;
                    if *c == 0 && !graph.outputs.contains(i) {
                        free.push(slot_of[i.as_str()]);
                        if idx == 0 && n.inputs.len() == 1 {
                            move0 = true;
                        }
                    }
                }
            }
            nodes.push(PlannedNode { name: n.name.clone(), in_slots, out_slot, move0, op });
        }
        let output_slots: Vec<usize> = graph
            .outputs
            .iter()
            .map(|o| {
                slot_of.get(o.as_str()).copied().with_context(|| format!("plan: missing output {o}"))
            })
            .collect::<Result<_>>()?;
        Ok(ExecPlan {
            act_mode: model.cfg.act_mode,
            nodes,
            slot_count,
            output_slots,
            tensors: b.tensors,
            qweights: b.qweights,
        })
    }

    /// Number of activation buffer slots the memory plan uses (vs one live
    /// tensor per node without reuse) — exposed for tests and diagnostics.
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Number of lowered instructions (== graph nodes) in the plan.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Run the plan on one input batch.
    pub fn execute(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        let mut slots: Vec<Tensor> = vec![Tensor::default(); self.slot_count];
        for node in &self.nodes {
            let out = self.eval(node, &mut slots, x)?;
            slots[node.out_slot] = out;
        }
        // outputs are moved out of the (about to be dropped) slot vector;
        // clone only if the same slot is listed again later
        let mut outs = Vec::with_capacity(self.output_slots.len());
        for (i, &s) in self.output_slots.iter().enumerate() {
            if self.output_slots[i + 1..].contains(&s) {
                outs.push(slots[s].clone());
            } else {
                outs.push(std::mem::take(&mut slots[s]));
            }
        }
        Ok(outs)
    }

    fn narrow(&self, mut t: Tensor) -> Tensor {
        match self.act_mode {
            ActMode::Bf16 => lowp::bf16_slice(&mut t.data),
            ActMode::F16 => lowp::f16_slice(&mut t.data),
            _ => {}
        }
        t
    }

    /// Take (move) or clone input 0, per the liveness plan.
    fn grab(node: &PlannedNode, slots: &mut [Tensor]) -> Tensor {
        if node.move0 {
            std::mem::take(&mut slots[node.in_slots[0]])
        } else {
            slots[node.in_slots[0]].clone()
        }
    }

    fn eval(&self, node: &PlannedNode, slots: &mut [Tensor], x: &Tensor) -> Result<Tensor> {
        let out = match &node.op {
            POp::Input => x.clone(),
            POp::ConvF32 { w, bias, stride, pad, groups, act } => {
                let a = &slots[node.in_slots[0]];
                let bias = bias.map(|i| &self.tensors[i]);
                let t = ops::conv2d_f32_fused(a, &self.tensors[*w], bias, *stride, *pad, *groups, *act);
                self.narrow(t)
            }
            POp::ConvI8 { w, bias, stride, pad, groups, act, round, iq } => {
                let a = &slots[node.in_slots[0]];
                let qw = &self.qweights[*w];
                let bias = bias.map(|i| &self.tensors[i]);
                let t = match iq {
                    IQuant::Static { sx, zx, sxw } => ops::conv2d_i8_fused(
                        a, qw, bias, *stride, *pad, *groups, *sx, *zx, *round, sxw, *act,
                    ),
                    IQuant::Dynamic => {
                        let (sx, zx) = ops::dyn_qparams(&a.data);
                        let sxw = ops::premul_scales(&qw.scales, qw.shape[0], sx);
                        ops::conv2d_i8_fused(
                            a, qw, bias, *stride, *pad, *groups, sx, zx, *round, &sxw, *act,
                        )
                    }
                };
                self.narrow(t)
            }
            POp::LinearF32 { w, bias, din, dout, act } => {
                let a = &slots[node.in_slots[0]];
                let rows = a.len() / din;
                let mut oshape = a.shape.clone();
                *oshape.last_mut().unwrap() = *dout;
                let bias = bias.map(|i| self.tensors[i].data.as_slice());
                let data = ops::linear_f32_tiled(&a.data, rows, *din, &self.tensors[*w].data, *dout, bias, *act);
                self.narrow(Tensor::new(oshape, data))
            }
            POp::LinearI8 { w, bias, din, act, round, iq } => {
                let a = &slots[node.in_slots[0]];
                let rows = a.len() / din;
                let qw = &self.qweights[*w];
                let mut oshape = a.shape.clone();
                *oshape.last_mut().unwrap() = qw.shape[0];
                let bias = bias.map(|i| self.tensors[i].data.as_slice());
                let data = match iq {
                    IQuant::Static { sx, zx, sxw } => ops::linear_i8_fused(
                        &a.data, rows, *din, qw, bias, *sx, *zx, *round, sxw, *act,
                    ),
                    IQuant::Dynamic => {
                        let (sx, zx) = ops::dyn_qparams(&a.data);
                        let sxw = ops::premul_scales(&qw.scales, qw.shape[0], sx);
                        ops::linear_i8_fused(&a.data, rows, *din, qw, bias, sx, zx, *round, &sxw, *act)
                    }
                };
                self.narrow(Tensor::new(oshape, data))
            }
            POp::Bn { scale, shift } => {
                let a = &slots[node.in_slots[0]];
                self.narrow(ops::bn_apply(a, scale, shift))
            }
            POp::Act(f) => {
                let mut t = Self::grab(node, slots);
                for v in t.data.iter_mut() {
                    *v = f.apply(*v);
                }
                self.narrow(t)
            }
            POp::Add => {
                let (a, b) = (&slots[node.in_slots[0]], &slots[node.in_slots[1]]);
                if a.shape != b.shape {
                    bail!("add shape mismatch at {}", node.name);
                }
                let data = a.data.iter().zip(b.data.iter()).map(|(x, y)| x + y).collect();
                self.narrow(Tensor::new(a.shape.clone(), data))
            }
            POp::Mul => {
                let (a, b) = (&slots[node.in_slots[0]], &slots[node.in_slots[1]]);
                self.narrow(ops::mul_gate(a, b))
            }
            POp::Pool { k, stride, pad, is_max } => {
                let a = &slots[node.in_slots[0]];
                self.narrow(ops::pool(a, *k, *stride, *pad, *is_max))
            }
            POp::Gap => self.narrow(ops::gap(&slots[node.in_slots[0]])),
            POp::Upsample2x => ops::upsample2x(&slots[node.in_slots[0]]),
            POp::Concat => {
                ops::concat_channels(&slots[node.in_slots[0]], &slots[node.in_slots[1]])
            }
            POp::Flatten => {
                let bsz = slots[node.in_slots[0]].shape[0];
                let t = Self::grab(node, slots);
                let rest = t.len() / bsz;
                t.reshaped(&[bsz, rest])
            }
            POp::Reshape { shape } => {
                let bsz = slots[node.in_slots[0]].shape[0];
                let t = Self::grab(node, slots);
                let mut s = vec![bsz];
                s.extend(shape.iter());
                t.reshaped(&s)
            }
            POp::LayerNorm { d, gamma, beta } => {
                let a = &slots[node.in_slots[0]];
                let g = &self.tensors[*gamma];
                let b = &self.tensors[*beta];
                self.narrow(ops::layernorm(a, *d, &g.data, &b.data))
            }
            POp::ToTokens => ops::to_tokens(&slots[node.in_slots[0]]),
            POp::TokMean => self.narrow(ops::tokmean(&slots[node.in_slots[0]])),
            POp::Attention { d, heads, proj } => {
                let xt = &slots[node.in_slots[0]];
                let (bsz, t) = (xt.shape[0], xt.shape[1]);
                let rows = bsz * t;
                let d = *d;
                let run_proj = |p: &AttnProj, input: &[f32]| -> Vec<f32> {
                    let bias = &self.tensors[p.b];
                    match &p.w {
                        ProjW::F32(i) => ops::linear_f32_tiled(
                            input, rows, d, &self.tensors[*i].data, d, Some(&bias.data), None,
                        ),
                        ProjW::I8 { w, round, iq } => {
                            let qw = &self.qweights[*w];
                            match iq {
                                IQuant::Static { sx, zx, sxw } => ops::linear_i8_fused(
                                    input, rows, d, qw, Some(&bias.data), *sx, *zx, *round, sxw,
                                    None,
                                ),
                                IQuant::Dynamic => {
                                    let (sx, zx) = ops::dyn_qparams(input);
                                    let sxw = ops::premul_scales(&qw.scales, d, sx);
                                    ops::linear_i8_fused(
                                        input, rows, d, qw, Some(&bias.data), sx, zx, *round, &sxw,
                                        None,
                                    )
                                }
                            }
                        }
                    }
                };
                let q = run_proj(&proj[0], &xt.data);
                let k = run_proj(&proj[1], &xt.data);
                let v = run_proj(&proj[2], &xt.data);
                let ctxt = ops::attention_ctx(&q, &k, &v, bsz, t, d, *heads);
                let out = run_proj(&proj[3], &ctxt);
                self.narrow(Tensor::new(vec![bsz, t, d], out))
            }
            POp::Aq { scale, zp, round, lut } => {
                // static requantization point through the 256-entry dequant LUT
                let mut t = Self::grab(node, slots);
                ops::quant_dequant_slice(&mut t.data, *scale, *zp, *round, lut);
                t
            }
            POp::AqDyn { round } => {
                // dynamic requantization point: fused range scan + in-place
                // requant at the tensor's own live range
                let mut t = Self::grab(node, slots);
                ops::quant_dequant_dyn(&mut t.data, *round);
                t
            }
            POp::AqNoop => {
                let t = Self::grab(node, slots);
                self.narrow(t)
            }
        };
        Ok(out)
    }
}

/// Arena builder for plan compilation.
struct Builder {
    tensors: Vec<Tensor>,
    qweights: Vec<QWeight>,
}

impl Builder {
    fn add_t(&mut self, t: Tensor) -> usize {
        self.tensors.push(t);
        self.tensors.len() - 1
    }

    fn add_q(&mut self, q: QWeight) -> usize {
        self.qweights.push(q);
        self.qweights.len() - 1
    }

    fn param(&mut self, model: &CompiledModel, key: &str) -> Result<usize> {
        let t = model.params.get(key).with_context(|| format!("plan: missing param {key}"))?.clone();
        Ok(self.add_t(t))
    }

    /// Input-quantization constants for an integer op reading `producer`:
    /// plan-time constants on the static path, a `Dynamic` marker when the
    /// model recomputes ranges from the live batch.
    fn iquant(
        model: &CompiledModel,
        producer: &str,
        scales: &[f32],
        cout: usize,
    ) -> Result<IQuant> {
        if model.cfg.act_mode.is_dynamic() {
            return Ok(IQuant::Dynamic);
        }
        let (sx, zx) = model.input_qparams(producer)?;
        Ok(IQuant::Static { sx, zx, sxw: ops::premul_scales(scales, cout, sx) })
    }

    fn attn_proj(
        &mut self,
        model: &CompiledModel,
        n: &Node,
        mat: &str,
        bias: &str,
        d: usize,
        round: Option<RoundMode>,
    ) -> Result<AttnProj> {
        let b = self.param(model, &format!("{}.{bias}", n.name))?;
        let wkey = format!("{}.{mat}", n.name);
        let w = match (model.cfg.weight_mode, round, model.qweights.get(&wkey)) {
            (wm, Some(round), Some(qw)) if wm.is_integer() => {
                let iq = Self::iquant(model, &n.inputs[0], &qw.scales, d)?;
                ProjW::I8 { w: self.add_q(qw.clone()), round, iq }
            }
            _ => ProjW::F32(self.add_t(model.weight_tensor(&wkey)?)),
        };
        Ok(AttnProj { w, b })
    }

    fn lower(&mut self, model: &CompiledModel, n: &Node) -> Result<POp> {
        Ok(match n.kind.as_str() {
            "input" => POp::Input,
            "conv2d" => {
                let stride = n.attr_usize("stride")?;
                let pad = n.attr_usize("pad")?;
                let groups = n.attr_usize("groups")?;
                let act = Act::from_attr(n)?;
                let bias = if n.attr_bool("bias") {
                    Some(
                        self.param(model, &format!("{}.b", n.name))
                            .with_context(|| format!("plan: conv {} bias", n.name))?,
                    )
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                match (model.cfg.weight_mode, model.int_round(), model.qweights.get(&wkey)) {
                    (wm, Some(round), Some(qw)) if wm.is_integer() => {
                        let iq = Self::iquant(model, &n.inputs[0], &qw.scales, qw.shape[0])?;
                        let qw = qw.clone();
                        POp::ConvI8 { w: self.add_q(qw), bias, stride, pad, groups, act, round, iq }
                    }
                    _ => {
                        let w = model.weight_tensor(&wkey)?;
                        POp::ConvF32 { w: self.add_t(w), bias, stride, pad, groups, act }
                    }
                }
            }
            "linear" => {
                let din = n.attr_usize("din")?;
                let dout = n.attr_usize("dout")?;
                let act = Act::from_attr(n)?;
                // mirror the interpreter's leniency: bias attr without a
                // stored bias tensor degrades to no bias
                let bias = if n.attr_bool("bias") {
                    model.params.get(&format!("{}.b", n.name)).cloned().map(|t| self.add_t(t))
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                match (model.cfg.weight_mode, model.int_round(), model.qweights.get(&wkey)) {
                    (wm, Some(round), Some(qw)) if wm.is_integer() => {
                        let iq = Self::iquant(model, &n.inputs[0], &qw.scales, dout)?;
                        let qw = qw.clone();
                        POp::LinearI8 { w: self.add_q(qw), bias, din, act, round, iq }
                    }
                    _ => {
                        let w = model.weight_tensor(&wkey)?;
                        POp::LinearF32 { w: self.add_t(w), bias, din, dout, act }
                    }
                }
            }
            "bn" => {
                let g = model
                    .params
                    .get(&format!("{}.gamma", n.name))
                    .with_context(|| format!("plan: bn {} gamma", n.name))?;
                let beta = model
                    .params
                    .get(&format!("{}.beta", n.name))
                    .with_context(|| format!("plan: bn {} beta", n.name))?;
                let mean = model
                    .bn
                    .get(&format!("{}.mean", n.name))
                    .with_context(|| format!("plan: bn {} mean", n.name))?;
                let var = model
                    .bn
                    .get(&format!("{}.var", n.name))
                    .with_context(|| format!("plan: bn {} var", n.name))?;
                let (scale, shift) =
                    ops::bn_fold_params(&g.data, &beta.data, &mean.data, &var.data, BN_EPS);
                POp::Bn { scale, shift }
            }
            kind @ ("relu" | "relu6" | "hswish" | "hsigmoid" | "sigmoid" | "silu" | "gelu") => {
                POp::Act(Act::from_kind(kind).expect("covered by match"))
            }
            "add" => POp::Add,
            "mul" => POp::Mul,
            "maxpool" | "avgpool" => POp::Pool {
                k: n.attr_usize("k")?,
                stride: n.attr_usize("stride")?,
                pad: n.attr_usize("pad")?,
                is_max: n.kind == "maxpool",
            },
            "gap" => POp::Gap,
            "upsample2x" => POp::Upsample2x,
            "concat" => POp::Concat,
            "flatten" => POp::Flatten,
            "reshape" => POp::Reshape { shape: n.shape.clone() },
            "layernorm" => POp::LayerNorm {
                d: n.attr_usize("d")?,
                gamma: self.param(model, &format!("{}.gamma", n.name))?,
                beta: self.param(model, &format!("{}.beta", n.name))?,
            },
            "to_tokens" => POp::ToTokens,
            "tokmean" => POp::TokMean,
            "attention" => {
                let d = n.attr_usize("d")?;
                let heads = n.attr_usize("heads")?;
                let round = match (model.cfg.weight_mode, model.int_round()) {
                    (wm, Some(round)) if wm.is_integer() => Some(round),
                    _ => None,
                };
                let proj = [
                    self.attn_proj(model, n, "wq", "qb", d, round)?,
                    self.attn_proj(model, n, "wk", "kb", d, round)?,
                    self.attn_proj(model, n, "wv", "vb", d, round)?,
                    self.attn_proj(model, n, "wo", "ob", d, round)?,
                ];
                POp::Attention { d, heads, proj }
            }
            "aq" => match model.cfg.act_mode {
                ActMode::Int8 { round } => {
                    let &(lo, hi) = model
                        .act_ranges
                        .get(&n.name)
                        .with_context(|| format!("plan: no range for aq {}", n.name))?;
                    let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
                    POp::Aq { scale: s, zp: z, round, lut: Box::new(ops::aq_lut(s, z)) }
                }
                ActMode::DynInt8 { round } => POp::AqDyn { round },
                _ => POp::AqNoop,
            },
            other => bail!("plan: unknown node kind {other:?}"),
        })
    }
}
