//! Execution-plan layer: a one-time compile step that lowers a backend-
//! compiled model into a flat instruction list the engine can execute with
//! zero per-run graph interpretation overhead — and, since the
//! steady-state rework, zero per-run heap allocations and zero thread
//! spawns.
//!
//! What the plan precomputes (vs the legacy interpreter in `engine::mod`):
//!
//! * **weight resolution + prepacking** — every conv/linear/attention
//!   weight is resolved once into an index into the plan's arenas AND
//!   repacked once into the cache-blocked panel-major layout the 4-way
//!   register-blocked GEMMs read linearly ([`ops::PackedF32`] /
//!   [`ops::PackedQW`] — the ahead-of-time layout transformation a vendor
//!   compiler performs). i4 payloads stay nibble-packed but panel-ordered,
//!   so the kernel unpacks one panel byte-group per k-step instead of
//!   walking four strided packed rows.
//! * **quantization constants** — per-node input (scale, zero_point), the
//!   premultiplied per-channel dequant scales `sw*sx`, and a 256-entry
//!   dequant LUT per `aq` node are fixed at plan time. Under dynamic
//!   activation scaling ([`ActMode::DynInt8`]) those constants cannot
//!   exist at plan time: the lowered op carries an `IQuant::Dynamic`
//!   marker and the executor derives (scale, zero_point) from the live
//!   input with one fused scan (`ops::dyn_qparams`), premultiplying into a
//!   scratch buffer — still allocation-free.
//! * **memory plan** — liveness-based buffer-slot assignment upgraded from
//!   slot *reuse* to slot *preallocation*: `compile` infers each slot's
//!   maximum per-sample element count (and each conv's im2col / GEMM /
//!   quantized-activation scratch high-water marks) from the graph shapes,
//!   and `execute_with` runs against a caller-owned reusable
//!   [`ExecScratch`] sized from those bounds — after the first (warmup)
//!   run at a batch size, repeated inferences touch the allocator ZERO
//!   times (asserted by `tests/steady_state.rs` with a counting global
//!   allocator). The liveness pass marks *every* last-use input (not just
//!   a single-input node's), so pass-through ops swap buffers instead of
//!   copying and residual-add / SE-gate joins accumulate in place.
//!
//! Kernels are the packed planned forms in [`ops`]: row-chunk parallel on
//! the persistent shared worker pool (`engine::pool`) with fused
//! bias+activation epilogues. Per-output accumulation order matches the
//! reference kernels, so the f32 path is bit-identical and the integer
//! paths (i8 and nibble-packed i4, static and dynamic scaling) are
//! bit-exact with the interpreter — asserted by `tests/plan_exactness.rs`
//! across the full ExecConfig matrix.
//!
//! `compile` also resolves the inner-kernel [`KernelTier`] exactly once
//! (runtime CPU-feature detection, overridable via
//! `ExecConfig::kernel_tier` or the `PALLAS_FORCE_SCALAR` environment
//! variable) and packs every panel for that tier; the bit-exactness
//! contract above holds on every tier (see `engine::simd`).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::engine::ops::{self, Act};
use crate::engine::simd::KernelTier;
use crate::engine::{lowp, ActMode, CompiledModel, BN_EPS};
use crate::qir::{Graph, Node};
use crate::tensor::{act_scale_zp, RoundMode, Tensor};

/// Input-quantization constants of one integer op: fixed at plan time from
/// the producer's static range (`ActMode::Int8`), or recomputed from the
/// live input tensor on every run (`ActMode::DynInt8` — one fused signed
/// min/max scan via [`ops::dyn_qparams`], then the same requantizing
/// GEMM epilogue; only the tiny per-channel `sw*sx` premultiply is redone,
/// never a second pass over the activation data).
#[derive(Clone)]
pub(crate) enum IQuant {
    Static { sx: f32, zx: i32, sxw: Vec<f32> },
    Dynamic,
}

/// One attention projection with its pre-resolved (and prepacked) weights.
#[derive(Clone)]
pub(crate) enum ProjW {
    F32(usize),
    I8 { w: usize, round: RoundMode, iq: IQuant },
}

#[derive(Clone)]
pub(crate) struct AttnProj {
    pub(crate) w: ProjW,
    pub(crate) b: usize,
}

/// Lowered node: every reference is an arena index, every constant is baked.
#[derive(Clone)]
pub(crate) enum POp {
    Input,
    ConvF32 {
        w: usize,
        bias: Option<usize>,
        stride: usize,
        pad: usize,
        act: Option<Act>,
    },
    ConvI8 {
        w: usize,
        bias: Option<usize>,
        stride: usize,
        pad: usize,
        act: Option<Act>,
        round: RoundMode,
        iq: IQuant,
    },
    LinearF32 { w: usize, bias: Option<usize>, act: Option<Act> },
    LinearI8 { w: usize, bias: Option<usize>, act: Option<Act>, round: RoundMode, iq: IQuant },
    Bn { scale: Vec<f32>, shift: Vec<f32> },
    Act(Act),
    Add,
    Mul,
    Pool { k: usize, stride: usize, pad: usize, is_max: bool },
    Gap,
    Upsample2x,
    Concat,
    Flatten,
    Reshape { shape: Vec<usize> },
    LayerNorm { d: usize, gamma: usize, beta: usize },
    ToTokens,
    TokMean,
    Attention { d: usize, heads: usize, proj: [AttnProj; 4] },
    Aq { scale: f32, zp: i32, round: RoundMode, lut: Box<[f32; 256]> },
    /// Dynamic requantization point: range scan + requant fused per run.
    AqDyn { round: RoundMode },
    AqNoop,
}

#[derive(Clone)]
pub(crate) struct PlannedNode {
    pub(crate) name: String,
    pub(crate) in_slots: Vec<usize>,
    pub(crate) out_slot: usize,
    /// Per-input liveness: `in_last[i]` means this node is the last
    /// consumer of input i (and it is not a graph output), so the executor
    /// may take its buffer — pass-through ops swap it into the output
    /// slot, add/mul joins accumulate into it in place. This generalizes
    /// the old single-input-only `move0` flag to every input of every
    /// node, which is what removes the copies on residual-add joins.
    pub(crate) in_last: Vec<bool>,
    pub(crate) op: POp,
}

/// Plan-time scratch high-water marks, inferred from the graph's declared
/// per-sample shapes. All fields are per batch element except `sc` and
/// `sxw` (batch-independent). `execute_with` multiplies by the live batch
/// size and `reserve`s the caller's [`ExecScratch`] accordingly, so even
/// the first run at a batch size allocates each buffer at most once, at
/// its final size.
#[derive(Clone, Default)]
pub(crate) struct ScratchSizes {
    pub(crate) slot_elems: Vec<usize>,
    pub(crate) col: usize,
    pub(crate) mat: usize,
    pub(crate) xq: usize,
    pub(crate) qkv: usize,
    pub(crate) sc: usize,
    pub(crate) sxw: usize,
    /// Maximum tensor rank (incl. batch dim) any slot ever holds — shape
    /// `Vec`s are reserved to this so buffer swaps can never force a shape
    /// reallocation in a warm run.
    pub(crate) max_rank: usize,
}

/// Caller-owned reusable executor memory: the activation slot arena plus
/// every kernel scratch buffer a planned run touches (im2col patch matrix,
/// GEMM output matrix, quantized-activation bytes, dynamic-scaling
/// premultiplies, attention q/k/v/context/score buffers, output copies).
///
/// Ownership contract: create one per executor thread (`ExecScratch::new`
/// or `Default`), hand it to every `run_with`/`execute_with` call, and
/// never share it concurrently (it is exclusive scratch — `&mut`). The
/// returned output slice borrows the scratch and is valid until the next
/// run. One scratch may serve many models and batch sizes; buffers grow to
/// the high-water mark and are then reused, so after the first (warmup)
/// run of a given shape the executor performs ZERO heap allocations.
#[derive(Default)]
pub struct ExecScratch {
    slots: Vec<Tensor>,
    outputs: Vec<Tensor>,
    col: Vec<f32>,
    mat: Vec<f32>,
    xq: Vec<u8>,
    sxw: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    ctxt: Vec<f32>,
    sc: Vec<f32>,
}

impl ExecScratch {
    /// Empty scratch; buffers are sized by the first run (see
    /// [`ExecPlan::execute_with`]).
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }
}

/// A compiled execution plan: flat instruction list + prepacked weight
/// arenas + preallocating memory plan. Built once per `CompiledModel`,
/// executed per request against a reusable [`ExecScratch`].
#[derive(Clone)]
pub struct ExecPlan {
    pub(crate) act_mode: ActMode,
    pub(crate) nodes: Vec<PlannedNode>,
    pub(crate) slot_count: usize,
    pub(crate) output_slots: Vec<usize>,
    pub(crate) tensors: Vec<Tensor>,
    pub(crate) fpanels: Vec<ops::PackedF32>,
    pub(crate) qpanels: Vec<ops::PackedQW>,
    pub(crate) sizes: ScratchSizes,
    /// Inner-kernel tier resolved once at compile time; every prepacked
    /// panel is packed for (and dispatched to) exactly this tier.
    pub(crate) tier: KernelTier,
}

/// Grow a buffer's capacity to `want` elements without touching its
/// contents (no-op — and allocation-free — once warm).
fn reserve_to<T>(v: &mut Vec<T>, want: usize) {
    if v.capacity() < want {
        v.reserve(want - v.len());
    }
}

/// Disjoint slot borrows: input `i` shared, output `o` exclusive.
fn in_out1(slots: &mut [Tensor], i: usize, o: usize) -> (&Tensor, &mut Tensor) {
    assert!(i < slots.len() && o < slots.len() && i != o, "memory plan aliased slots {i}/{o}");
    // SAFETY: bounds and i != o checked above, so the borrows are disjoint.
    unsafe {
        let base = slots.as_mut_ptr();
        (&*base.add(i), &mut *base.add(o))
    }
}

/// Disjoint slot borrows: inputs `i0`/`i1` shared (may alias each other),
/// output `o` exclusive.
fn in2_out(
    slots: &mut [Tensor],
    i0: usize,
    i1: usize,
    o: usize,
) -> (&Tensor, &Tensor, &mut Tensor) {
    assert!(
        i0 < slots.len() && i1 < slots.len() && o < slots.len() && i0 != o && i1 != o,
        "memory plan aliased slots {i0}/{i1}/{o}"
    );
    // SAFETY: o differs from both inputs (checked), and the two input
    // borrows are shared, so aliasing i0 == i1 is fine.
    unsafe {
        let base = slots.as_mut_ptr();
        (&*base.add(i0), &*base.add(i1), &mut *base.add(o))
    }
}

/// Move (buffer-swap) or copy input 0 into the output slot, per the
/// liveness plan — the pass-through entry step (act/aq/flatten/reshape).
fn pass_through(node: &PlannedNode, slots: &mut [Tensor]) {
    let (i, o) = (node.in_slots[0], node.out_slot);
    if node.in_last[0] {
        slots.swap(i, o);
    } else {
        let (a, out) = in_out1(slots, i, o);
        out.copy_from(a);
    }
}

impl ExecPlan {
    /// Lower a compiled model. Fails early (at deploy time, not request
    /// time) on missing params, ranges, or unknown ops.
    pub fn compile(model: &CompiledModel) -> Result<ExecPlan> {
        let graph = &model.graph;
        // one plan-time CPU-feature probe: every panel is packed for this
        // tier and dispatch afterwards is a branch on the stored enum
        let tier = KernelTier::resolve(model.cfg.kernel_tier);
        let mut b =
            Builder { tensors: Vec::new(), fpanels: Vec::new(), qpanels: Vec::new(), tier };
        let mut remaining: HashMap<String, usize> = graph.consumer_counts();
        let mut slot_of: HashMap<String, usize> = HashMap::new();
        let mut free: Vec<usize> = Vec::new();
        let mut slot_count = 0usize;
        let mut nodes = Vec::with_capacity(graph.nodes.len());
        for n in &graph.nodes {
            let in_slots: Vec<usize> = n
                .inputs
                .iter()
                .map(|i| {
                    slot_of
                        .get(i)
                        .copied()
                        .with_context(|| format!("plan: node {} reads unplanned input {i}", n.name))
                })
                .collect::<Result<_>>()?;
            let op = b.lower(model, n)?;
            // allocate the output slot before releasing inputs, so an output
            // never aliases a buffer the kernel still reads
            let out_slot = free.pop().unwrap_or_else(|| {
                slot_count += 1;
                slot_count - 1
            });
            slot_of.insert(n.name.clone(), out_slot);
            let mut in_last = vec![false; n.inputs.len()];
            for (idx, i) in n.inputs.iter().enumerate() {
                if let Some(c) = remaining.get_mut(i.as_str()) {
                    *c -= 1;
                    if *c == 0 && !graph.outputs.contains(i) {
                        free.push(slot_of[i.as_str()]);
                        in_last[idx] = true;
                    }
                }
            }
            nodes.push(PlannedNode { name: n.name.clone(), in_slots, out_slot, in_last, op });
        }
        let output_slots: Vec<usize> = graph
            .outputs
            .iter()
            .map(|o| {
                slot_of.get(o.as_str()).copied().with_context(|| format!("plan: missing output {o}"))
            })
            .collect::<Result<_>>()?;
        let mut plan = ExecPlan {
            act_mode: model.cfg.act_mode,
            nodes,
            slot_count,
            output_slots,
            tensors: b.tensors,
            fpanels: b.fpanels,
            qpanels: b.qpanels,
            sizes: ScratchSizes::default(),
            tier,
        };
        plan.sizes = plan.infer_sizes(graph);
        // Debug builds self-audit every freshly compiled plan: the symbolic
        // replay verifier (engine::verify) re-derives liveness, aliasing and
        // scratch bounds independently and rejects the plan outright on any
        // ERROR finding, so a planner bug can never reach an executor in
        // tests. Release builds skip this (plans are verified out-of-band by
        // `plan_audit` and the CI audit job).
        #[cfg(debug_assertions)]
        {
            use crate::engine::verify::Severity;
            let findings = plan.verify(graph);
            if let Some(f) = findings.iter().find(|f| f.severity == Severity::Error) {
                bail!("plan verifier rejected fresh plan: {f}");
            }
        }
        Ok(plan)
    }

    /// Number of activation buffer slots the memory plan uses (vs one live
    /// tensor per node without reuse) — exposed for tests and diagnostics.
    pub fn slot_count(&self) -> usize {
        self.slot_count
    }

    /// Number of lowered instructions (== graph nodes) in the plan.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Inner-kernel tier this plan was compiled for (fixed at compile
    /// time; see [`KernelTier::resolve`] for the detection/override rules).
    pub fn kernel_tier(&self) -> KernelTier {
        self.tier
    }

    /// Per-sample scratch high-water marks from the graph's declared
    /// shapes (the plan-time half of slot preallocation).
    fn infer_sizes(&self, graph: &Graph) -> ScratchSizes {
        let mut sz = ScratchSizes { slot_elems: vec![0; self.slot_count], ..Default::default() };
        for (n, pn) in graph.nodes.iter().zip(self.nodes.iter()) {
            let elems: usize = n.shape.iter().product::<usize>().max(1);
            sz.max_rank = sz.max_rank.max(n.shape.len() + 1);
            let se = &mut sz.slot_elems[pn.out_slot];
            *se = (*se).max(elems);
            match &pn.op {
                POp::ConvF32 { w, .. } => {
                    let wp = &self.fpanels[*w];
                    let rows = n.shape[1] * n.shape[2];
                    sz.col = sz.col.max(rows * wp.cols);
                    sz.mat = sz.mat.max(rows * wp.cout());
                }
                POp::ConvI8 { w, .. } => {
                    let pw = &self.qpanels[*w];
                    let rows = n.shape[1] * n.shape[2];
                    sz.col = sz.col.max(rows * pw.cols);
                    sz.mat = sz.mat.max(rows * pw.cout());
                    sz.xq = sz.xq.max(rows * pw.cols);
                    sz.sxw = sz.sxw.max(pw.cout());
                }
                POp::LinearI8 { w, .. } => {
                    let pw = &self.qpanels[*w];
                    let rows = elems / pw.cout().max(1);
                    sz.xq = sz.xq.max(rows.max(1) * pw.cols);
                    sz.sxw = sz.sxw.max(pw.cout());
                }
                POp::Attention { d, proj, .. } => {
                    let t = n.shape.first().copied().unwrap_or(1);
                    sz.qkv = sz.qkv.max(t * *d);
                    sz.sc = sz.sc.max(t);
                    if proj.iter().any(|p| matches!(p.w, ProjW::I8 { .. })) {
                        sz.xq = sz.xq.max(t * *d);
                        sz.sxw = sz.sxw.max(*d);
                    }
                }
                _ => {}
            }
        }
        // Buffer swaps (pass-through moves, in-place add/mul joins) permute
        // slot buffers across indices at run time. Union every slot pair a
        // run may swap and level each equivalence class to its max
        // requirement: with equal per-class reservations, any permutation
        // leaves per-index capacities invariant — otherwise the SECOND run
        // would find a small buffer parked in a big slot and reallocate,
        // breaking the zero-allocation contract.
        let mut parent: Vec<usize> = (0..self.slot_count).collect();
        fn root(parent: &mut [usize], mut i: usize) -> usize {
            while parent[i] != i {
                parent[i] = parent[parent[i]];
                i = parent[i];
            }
            i
        }
        let union = |parent: &mut Vec<usize>, a: usize, b: usize| {
            let (ra, rb) = (root(parent, a), root(parent, b));
            if ra != rb {
                parent[ra] = rb;
            }
        };
        for pn in &self.nodes {
            match &pn.op {
                POp::Act(_)
                | POp::Aq { .. }
                | POp::AqDyn { .. }
                | POp::AqNoop
                | POp::Flatten
                | POp::Reshape { .. } => {
                    if pn.in_last[0] {
                        union(&mut parent, pn.in_slots[0], pn.out_slot);
                    }
                }
                POp::Add => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 != i1 && pn.in_last[0] {
                        union(&mut parent, i0, pn.out_slot);
                    } else if i0 != i1 && pn.in_last[1] {
                        union(&mut parent, i1, pn.out_slot);
                    }
                }
                POp::Mul => {
                    let (i0, i1) = (pn.in_slots[0], pn.in_slots[1]);
                    if i0 != i1 && pn.in_last[0] {
                        union(&mut parent, i0, pn.out_slot);
                    }
                }
                _ => {}
            }
        }
        let mut class_max = vec![0usize; self.slot_count];
        for i in 0..self.slot_count {
            let r = root(&mut parent, i);
            class_max[r] = class_max[r].max(sz.slot_elems[i]);
        }
        for i in 0..self.slot_count {
            let r = root(&mut parent, i);
            sz.slot_elems[i] = class_max[r];
        }
        sz
    }

    /// Size the caller's scratch for this plan at a batch size. Pure
    /// capacity reservations — contents untouched, and a no-op (zero
    /// allocations) once the scratch has warmed up.
    fn reserve(&self, s: &mut ExecScratch, batch: usize) {
        // grow-only: a scratch alternating between plans must never drop a
        // warmed buffer (extra trailing slots are simply left idle)
        if s.slots.len() < self.slot_count {
            s.slots.resize_with(self.slot_count, Tensor::default);
        }
        for (slot, &e) in s.slots.iter_mut().zip(self.sizes.slot_elems.iter()) {
            reserve_to(&mut slot.data, e * batch);
            reserve_to(&mut slot.shape, self.sizes.max_rank);
        }
        reserve_to(&mut s.col, self.sizes.col * batch);
        reserve_to(&mut s.mat, self.sizes.mat * batch);
        reserve_to(&mut s.xq, self.sizes.xq * batch);
        reserve_to(&mut s.sxw, self.sizes.sxw);
        let qkv = self.sizes.qkv * batch;
        reserve_to(&mut s.q, qkv);
        reserve_to(&mut s.k, qkv);
        reserve_to(&mut s.v, qkv);
        reserve_to(&mut s.ctxt, qkv);
        reserve_to(&mut s.sc, self.sizes.sc);
        if s.outputs.len() < self.output_slots.len() {
            s.outputs.resize_with(self.output_slots.len(), Tensor::default);
        }
        for (o, &sl) in s.outputs.iter_mut().zip(self.output_slots.iter()) {
            reserve_to(&mut o.data, self.sizes.slot_elems[sl] * batch);
            reserve_to(&mut o.shape, self.sizes.max_rank);
        }
    }

    /// Run the plan on one input batch with a fresh scratch (convenience /
    /// compatibility form — allocates; the hot path is [`execute_with`]).
    ///
    /// [`execute_with`]: ExecPlan::execute_with
    pub fn execute(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        let mut scratch = ExecScratch::default();
        self.execute_with(x, &mut scratch)?;
        Ok(std::mem::take(&mut scratch.outputs))
    }

    /// Run the plan on one input batch against a caller-owned reusable
    /// [`ExecScratch`]. The returned outputs borrow the scratch (valid
    /// until its next run). After the scratch's first run at a given batch
    /// size this path performs zero heap allocations and zero thread
    /// spawns (row-chunk work goes to the persistent `engine::pool`).
    pub fn execute_with<'s>(
        &self,
        x: &Tensor,
        scratch: &'s mut ExecScratch,
    ) -> Result<&'s [Tensor]> {
        let batch = x.shape.first().copied().unwrap_or(1).max(1);
        self.reserve(scratch, batch);
        for node in &self.nodes {
            self.eval(node, scratch, x)?;
        }
        // outputs are COPIED out of the persistent slot arena (the arena
        // must survive for reuse), which also retires the old per-call
        // O(n^2) duplicate-output-slot scan: a slot listed twice in
        // `output_slots` is simply copied twice.
        for (k, &sl) in self.output_slots.iter().enumerate() {
            let dst = &mut scratch.outputs[k];
            dst.copy_from(&scratch.slots[sl]);
        }
        // slice, not the whole Vec: a grow-only scratch shared with a plan
        // that had MORE outputs still has that plan's extras parked after
        // ours
        Ok(&scratch.outputs[..self.output_slots.len()])
    }

    fn narrow_mut(&self, t: &mut Tensor) {
        match self.act_mode {
            ActMode::Bf16 => lowp::bf16_slice(&mut t.data),
            ActMode::F16 => lowp::f16_slice(&mut t.data),
            _ => {}
        }
    }

    /// One attention projection into a caller-sized buffer (`rows * d`).
    #[allow(clippy::too_many_arguments)]
    fn run_proj(
        &self,
        p: &AttnProj,
        input: &[f32],
        rows: usize,
        d: usize,
        out: &mut Vec<f32>,
        xq: &mut Vec<u8>,
        sxw_buf: &mut Vec<f32>,
    ) {
        out.resize(rows * d, 0.0);
        let bias = &self.tensors[p.b];
        match &p.w {
            ProjW::F32(i) => {
                ops::linear_f32_packed(input, rows, &self.fpanels[*i], Some(&bias.data), None, out);
            }
            ProjW::I8 { w, round, iq } => {
                let pw = &self.qpanels[*w];
                match iq {
                    IQuant::Static { sx, zx, sxw } => ops::linear_int_packed(
                        input, rows, pw, Some(&bias.data), *sx, *zx, *round, sxw, None, xq, out,
                    ),
                    IQuant::Dynamic => {
                        let (sx, zx) = ops::dyn_qparams(input);
                        ops::premul_scales_into(&pw.scales, pw.cout(), sx, sxw_buf);
                        ops::linear_int_packed(
                            input, rows, pw, Some(&bias.data), sx, zx, *round, sxw_buf.as_slice(),
                            None, xq, out,
                        );
                    }
                }
            }
        }
    }

    /// Execute one lowered node into its output slot. Every write lands in
    /// scratch-owned memory; no path allocates once the scratch is warm.
    fn eval(&self, node: &PlannedNode, s: &mut ExecScratch, x: &Tensor) -> Result<()> {
        let o = node.out_slot;
        match &node.op {
            POp::Input => {
                s.slots[o].copy_from(x);
            }
            POp::ConvF32 { w, bias, stride, pad, act } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                let bias = bias.map(|i| self.tensors[i].data.as_slice());
                ops::conv2d_f32_packed(
                    a, &self.fpanels[*w], bias, *stride, *pad, *act, &mut s.col, &mut s.mat, out,
                );
                self.narrow_mut(out);
            }
            POp::ConvI8 { w, bias, stride, pad, act, round, iq } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                let pw = &self.qpanels[*w];
                let bias = bias.map(|i| self.tensors[i].data.as_slice());
                match iq {
                    IQuant::Static { sx, zx, sxw } => ops::conv2d_int_packed(
                        a, pw, bias, *stride, *pad, *sx, *zx, *round, sxw, *act, &mut s.col,
                        &mut s.xq, &mut s.mat, out,
                    ),
                    IQuant::Dynamic => {
                        let (sx, zx) = ops::dyn_qparams(&a.data);
                        ops::premul_scales_into(&pw.scales, pw.cout(), sx, &mut s.sxw);
                        ops::conv2d_int_packed(
                            a, pw, bias, *stride, *pad, sx, zx, *round, &s.sxw, *act, &mut s.col,
                            &mut s.xq, &mut s.mat, out,
                        );
                    }
                }
                self.narrow_mut(out);
            }
            POp::LinearF32 { w, bias, act } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                let wp = &self.fpanels[*w];
                let (din, dout) = (wp.cols, wp.cout());
                let rows = a.len() / din;
                out.shape.clear();
                out.shape.extend_from_slice(&a.shape);
                *out.shape.last_mut().expect("linear output has a shape") = dout;
                out.data.resize(rows * dout, 0.0);
                let bias = bias.map(|i| self.tensors[i].data.as_slice());
                ops::linear_f32_packed(&a.data, rows, wp, bias, *act, &mut out.data);
                self.narrow_mut(out);
            }
            POp::LinearI8 { w, bias, act, round, iq } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                let pw = &self.qpanels[*w];
                let (din, dout) = (pw.cols, pw.cout());
                let rows = a.len() / din;
                out.shape.clear();
                out.shape.extend_from_slice(&a.shape);
                *out.shape.last_mut().expect("linear output has a shape") = dout;
                out.data.resize(rows * dout, 0.0);
                let bias = bias.map(|i| self.tensors[i].data.as_slice());
                match iq {
                    IQuant::Static { sx, zx, sxw } => ops::linear_int_packed(
                        &a.data, rows, pw, bias, *sx, *zx, *round, sxw, *act, &mut s.xq,
                        &mut out.data,
                    ),
                    IQuant::Dynamic => {
                        let (sx, zx) = ops::dyn_qparams(&a.data);
                        ops::premul_scales_into(&pw.scales, dout, sx, &mut s.sxw);
                        ops::linear_int_packed(
                            &a.data, rows, pw, bias, sx, zx, *round, &s.sxw, *act, &mut s.xq,
                            &mut out.data,
                        );
                    }
                }
                self.narrow_mut(out);
            }
            POp::Bn { scale, shift } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                ops::bn_apply_into(a, scale, shift, out);
                self.narrow_mut(out);
            }
            POp::Act(f) => {
                pass_through(node, &mut s.slots);
                let out = &mut s.slots[o];
                for v in out.data.iter_mut() {
                    *v = f.apply(*v);
                }
                self.narrow_mut(out);
            }
            POp::Add => {
                let (i0, i1) = (node.in_slots[0], node.in_slots[1]);
                if s.slots[i0].shape != s.slots[i1].shape {
                    bail!("add shape mismatch at {}", node.name);
                }
                if i0 != i1 && node.in_last[0] {
                    // take the left operand's buffer and accumulate in place
                    s.slots.swap(i0, o);
                    let (b, out) = in_out1(&mut s.slots, i1, o);
                    for (v, &y) in out.data.iter_mut().zip(b.data.iter()) {
                        *v += y;
                    }
                } else if i0 != i1 && node.in_last[1] {
                    s.slots.swap(i1, o);
                    let (a, out) = in_out1(&mut s.slots, i0, o);
                    for (v, &y) in out.data.iter_mut().zip(a.data.iter()) {
                        *v += y;
                    }
                } else {
                    let (a, b, out) = in2_out(&mut s.slots, i0, i1, o);
                    ops::add_into(a, b, out);
                }
                self.narrow_mut(&mut s.slots[o]);
            }
            POp::Mul => {
                let (i0, i1) = (node.in_slots[0], node.in_slots[1]);
                if i0 != i1 && node.in_last[0] {
                    // take the gated operand's buffer, apply the gate in place
                    s.slots.swap(i0, o);
                    let (b, out) = in_out1(&mut s.slots, i1, o);
                    ops::mul_gate_assign(out, b);
                } else {
                    let (a, b, out) = in2_out(&mut s.slots, i0, i1, o);
                    ops::mul_gate_into(a, b, out);
                }
                self.narrow_mut(&mut s.slots[o]);
            }
            POp::Pool { k, stride, pad, is_max } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                ops::pool_into(a, *k, *stride, *pad, *is_max, out);
                self.narrow_mut(out);
            }
            POp::Gap => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                ops::gap_into(a, out);
                self.narrow_mut(out);
            }
            POp::Upsample2x => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                ops::upsample2x_into(a, out);
            }
            POp::Concat => {
                let (a, b, out) = in2_out(&mut s.slots, node.in_slots[0], node.in_slots[1], o);
                ops::concat_channels_into(a, b, out);
            }
            POp::Flatten => {
                let bsz = s.slots[node.in_slots[0]].shape[0];
                pass_through(node, &mut s.slots);
                let out = &mut s.slots[o];
                let rest = out.len() / bsz;
                out.shape.clear();
                out.shape.extend_from_slice(&[bsz, rest]);
            }
            POp::Reshape { shape } => {
                let bsz = s.slots[node.in_slots[0]].shape[0];
                pass_through(node, &mut s.slots);
                let out = &mut s.slots[o];
                out.shape.clear();
                out.shape.push(bsz);
                out.shape.extend_from_slice(shape);
                debug_assert_eq!(out.shape.iter().product::<usize>(), out.len());
            }
            POp::LayerNorm { d, gamma, beta } => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                let g = &self.tensors[*gamma];
                let b = &self.tensors[*beta];
                ops::layernorm_into(a, *d, &g.data, &b.data, out);
                self.narrow_mut(out);
            }
            POp::ToTokens => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                ops::to_tokens_into(a, out);
            }
            POp::TokMean => {
                let (a, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                ops::tokmean_into(a, out);
                self.narrow_mut(out);
            }
            POp::Attention { d, heads, proj } => {
                let (xt, out) = in_out1(&mut s.slots, node.in_slots[0], o);
                let (bsz, t) = (xt.shape[0], xt.shape[1]);
                let rows = bsz * t;
                let d = *d;
                self.run_proj(&proj[0], &xt.data, rows, d, &mut s.q, &mut s.xq, &mut s.sxw);
                self.run_proj(&proj[1], &xt.data, rows, d, &mut s.k, &mut s.xq, &mut s.sxw);
                self.run_proj(&proj[2], &xt.data, rows, d, &mut s.v, &mut s.xq, &mut s.sxw);
                ops::attention_ctx_into(
                    &s.q, &s.k, &s.v, bsz, t, d, *heads, &mut s.ctxt, &mut s.sc,
                );
                out.reset_for_overwrite(&[bsz, t, d]);
                self.run_proj(&proj[3], &s.ctxt, rows, d, &mut out.data, &mut s.xq, &mut s.sxw);
                self.narrow_mut(out);
            }
            POp::Aq { scale, zp, round, lut } => {
                // static requantization point through the 256-entry dequant LUT
                pass_through(node, &mut s.slots);
                ops::quant_dequant_slice(&mut s.slots[o].data, *scale, *zp, *round, lut);
            }
            POp::AqDyn { round } => {
                // dynamic requantization point: fused range scan + in-place
                // requant at the tensor's own live range
                pass_through(node, &mut s.slots);
                ops::quant_dequant_dyn(&mut s.slots[o].data, *round);
            }
            POp::AqNoop => {
                pass_through(node, &mut s.slots);
                self.narrow_mut(&mut s.slots[o]);
            }
        }
        Ok(())
    }
}

/// Arena builder for plan compilation.
struct Builder {
    tensors: Vec<Tensor>,
    fpanels: Vec<ops::PackedF32>,
    qpanels: Vec<ops::PackedQW>,
    /// Resolved kernel tier every panel is packed for.
    tier: KernelTier,
}

impl Builder {
    fn add_t(&mut self, t: Tensor) -> usize {
        self.tensors.push(t);
        self.tensors.len() - 1
    }

    fn add_fp(&mut self, p: ops::PackedF32) -> usize {
        self.fpanels.push(p);
        self.fpanels.len() - 1
    }

    fn add_qp(&mut self, p: ops::PackedQW) -> usize {
        self.qpanels.push(p);
        self.qpanels.len() - 1
    }

    fn param(&mut self, model: &CompiledModel, key: &str) -> Result<usize> {
        let t = model.params.get(key).with_context(|| format!("plan: missing param {key}"))?.clone();
        Ok(self.add_t(t))
    }

    /// Input-quantization constants for an integer op reading `producer`:
    /// plan-time constants on the static path, a `Dynamic` marker when the
    /// model recomputes ranges from the live batch.
    fn iquant(
        model: &CompiledModel,
        producer: &str,
        scales: &[f32],
        cout: usize,
    ) -> Result<IQuant> {
        if model.cfg.act_mode.is_dynamic() {
            return Ok(IQuant::Dynamic);
        }
        let (sx, zx) = model.input_qparams(producer)?;
        Ok(IQuant::Static { sx, zx, sxw: ops::premul_scales(scales, cout, sx) })
    }

    fn attn_proj(
        &mut self,
        model: &CompiledModel,
        n: &Node,
        mat: &str,
        bias: &str,
        d: usize,
        round: Option<RoundMode>,
    ) -> Result<AttnProj> {
        let b = self.param(model, &format!("{}.{bias}", n.name))?;
        let wkey = format!("{}.{mat}", n.name);
        let w = match (model.cfg.weight_mode, round, model.qweights.get(&wkey)) {
            (wm, Some(round), Some(qw)) if wm.is_integer() => {
                let iq = Self::iquant(model, &n.inputs[0], &qw.scales, d)?;
                let w = self.add_qp(ops::PackedQW::pack_for(qw, 1, self.tier));
                ProjW::I8 { w, round, iq }
            }
            _ => {
                let w = ops::PackedF32::pack_for(&model.weight_tensor(&wkey)?, 1, self.tier);
                ProjW::F32(self.add_fp(w))
            }
        };
        Ok(AttnProj { w, b })
    }

    fn lower(&mut self, model: &CompiledModel, n: &Node) -> Result<POp> {
        Ok(match n.kind.as_str() {
            "input" => POp::Input,
            "conv2d" => {
                let stride = n.attr_usize("stride")?;
                let pad = n.attr_usize("pad")?;
                let groups = n.attr_usize("groups")?;
                let act = Act::from_attr(n)?;
                let bias = if n.attr_bool("bias") {
                    Some(
                        self.param(model, &format!("{}.b", n.name))
                            .with_context(|| format!("plan: conv {} bias", n.name))?,
                    )
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                match (model.cfg.weight_mode, model.int_round(), model.qweights.get(&wkey)) {
                    (wm, Some(round), Some(qw)) if wm.is_integer() => {
                        let iq = Self::iquant(model, &n.inputs[0], &qw.scales, qw.shape[0])?;
                        let w = self.add_qp(ops::PackedQW::pack_for(qw, groups, self.tier));
                        POp::ConvI8 { w, bias, stride, pad, act, round, iq }
                    }
                    _ => {
                        let w = model.weight_tensor(&wkey)?;
                        let w = self.add_fp(ops::PackedF32::pack_for(&w, groups, self.tier));
                        POp::ConvF32 { w, bias, stride, pad, act }
                    }
                }
            }
            "linear" => {
                let act = Act::from_attr(n)?;
                // mirror the interpreter's leniency: bias attr without a
                // stored bias tensor degrades to no bias
                let bias = if n.attr_bool("bias") {
                    model.params.get(&format!("{}.b", n.name)).cloned().map(|t| self.add_t(t))
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                match (model.cfg.weight_mode, model.int_round(), model.qweights.get(&wkey)) {
                    (wm, Some(round), Some(qw)) if wm.is_integer() => {
                        let dout = n.attr_usize("dout")?;
                        let iq = Self::iquant(model, &n.inputs[0], &qw.scales, dout)?;
                        let w = self.add_qp(ops::PackedQW::pack_for(qw, 1, self.tier));
                        POp::LinearI8 { w, bias, act, round, iq }
                    }
                    _ => {
                        let w = model.weight_tensor(&wkey)?;
                        let w = self.add_fp(ops::PackedF32::pack_for(&w, 1, self.tier));
                        POp::LinearF32 { w, bias, act }
                    }
                }
            }
            "bn" => {
                let g = model
                    .params
                    .get(&format!("{}.gamma", n.name))
                    .with_context(|| format!("plan: bn {} gamma", n.name))?;
                let beta = model
                    .params
                    .get(&format!("{}.beta", n.name))
                    .with_context(|| format!("plan: bn {} beta", n.name))?;
                let mean = model
                    .bn
                    .get(&format!("{}.mean", n.name))
                    .with_context(|| format!("plan: bn {} mean", n.name))?;
                let var = model
                    .bn
                    .get(&format!("{}.var", n.name))
                    .with_context(|| format!("plan: bn {} var", n.name))?;
                let (scale, shift) =
                    ops::bn_fold_params(&g.data, &beta.data, &mean.data, &var.data, BN_EPS);
                POp::Bn { scale, shift }
            }
            kind @ ("relu" | "relu6" | "hswish" | "hsigmoid" | "sigmoid" | "silu" | "gelu") => {
                POp::Act(Act::from_kind(kind).expect("covered by match"))
            }
            "add" => POp::Add,
            "mul" => POp::Mul,
            "maxpool" | "avgpool" => POp::Pool {
                k: n.attr_usize("k")?,
                stride: n.attr_usize("stride")?,
                pad: n.attr_usize("pad")?,
                is_max: n.kind == "maxpool",
            },
            "gap" => POp::Gap,
            "upsample2x" => POp::Upsample2x,
            "concat" => POp::Concat,
            "flatten" => POp::Flatten,
            "reshape" => POp::Reshape { shape: n.shape.clone() },
            "layernorm" => POp::LayerNorm {
                d: n.attr_usize("d")?,
                gamma: self.param(model, &format!("{}.gamma", n.name))?,
                beta: self.param(model, &format!("{}.beta", n.name))?,
            },
            "to_tokens" => POp::ToTokens,
            "tokmean" => POp::TokMean,
            "attention" => {
                let d = n.attr_usize("d")?;
                let heads = n.attr_usize("heads")?;
                let round = match (model.cfg.weight_mode, model.int_round()) {
                    (wm, Some(round)) if wm.is_integer() => Some(round),
                    _ => None,
                };
                let proj = [
                    self.attn_proj(model, n, "wq", "qb", d, round)?,
                    self.attn_proj(model, n, "wk", "kb", d, round)?,
                    self.attn_proj(model, n, "wv", "vb", d, round)?,
                    self.attn_proj(model, n, "wo", "ob", d, round)?,
                ];
                POp::Attention { d, heads, proj }
            }
            "aq" => match model.cfg.act_mode {
                ActMode::Int8 { round } => {
                    let &(lo, hi) = model
                        .act_ranges
                        .get(&n.name)
                        .with_context(|| format!("plan: no range for aq {}", n.name))?;
                    let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
                    POp::Aq { scale: s, zp: z, round, lut: Box::new(ops::aq_lut(s, z)) }
                }
                ActMode::DynInt8 { round } => POp::AqDyn { round },
                _ => POp::AqNoop,
            },
            other => bail!("plan: unknown node kind {other:?}"),
        })
    }
}
