//! Compute kernels for the deployment engine: f32 reference paths and the
//! bit-exact integer (i8 x i8 -> i32) paths that simulate NPU arithmetic.
//!
//! Convolution is im2col + GEMM in both precisions; the integer GEMM uses the
//! zero-point factorization  sum((xq-zx)*wq) = sum(xq*wq) - zx*sum(wq)  so the
//! inner loop is a plain i32 dot product (this is also what real INT8 NPU
//! pipelines do — the row-sum correction is precomputed per output channel,
//! at weight-quantization time: `QWeight::row_sums`).
//!
//! Two tiers of kernels live here:
//!
//! * **reference kernels** (`gemm_f32`, `linear_f32`, `conv2d_f32`,
//!   `conv2d_i8`, `linear_i8`) — the serial, unfused forms the legacy
//!   interpreter executes. They are the ground truth the plan executor is
//!   regression-tested against.
//! * **planned kernels** (`*_packed`, plus the `*_tiled`/`*_fused`
//!   row-major forms kept for benches and regression tests) — the forms
//!   the execution plan dispatches: row-chunk parallelism on the
//!   persistent shared worker pool via [`par_row_chunks`] (no per-call
//!   thread spawns), 4-way output-channel register blocking on BOTH
//!   precision paths over plan-time prepacked panel-major weights
//!   ([`PackedF32`]/[`PackedQW`]), caller-owned scratch buffers for every
//!   intermediate (`*_into` — zero allocations once warm), and a
//!   bias+activation epilogue so fused conv→bn→activation graphs finish
//!   inside the GEMM (including the i8 requantization epilogue).
//!   Per-output accumulation order is kept identical to the reference
//!   kernels, so planned f32 results are bit-identical too, and the i8
//!   path is bit-exact by construction (i32 accumulation is
//!   order-independent).
//!
//! Sub-byte weights: when a `QWeight` carries a 4-bit payload
//! (`qw.bits == 4`, two nibbles per byte per output channel), the integer
//! conv/linear entry points dispatch to [`gemm_i4_dispatch`], which unpacks
//! nibbles in-register inside the same parallel register-blocked driver and
//! reuses the zero-point/bias/activation requantization epilogue — so the
//! int4 path inherits the i8 path's bit-exactness argument unchanged.
//!
//! On top of the planned kernels sits the kernel-tier dispatch
//! (`engine::simd`): the plan resolves a [`KernelTier`] once at compile
//! time, the prepacked weights record which tier they were packed for, and
//! `gemm_f32_packed` / `gemm_int_packed` branch to the AVX2/NEON inner
//! kernels or the scalar panel kernels below — all tiers bit-identical by
//! the contract documented in `engine::simd`.

#![allow(clippy::needless_range_loop)]

use anyhow::{Context, Result};

use crate::engine::pool;
use crate::engine::simd::{self, KernelTier};
use crate::qir::Node;
use crate::tensor::quantized::{packed_row_bytes, row_sums_of};
use crate::tensor::{act_scale_zp, QWeight, RoundMode, Tensor};

/// Activation functions a vendor compiler fuses into the GEMM epilogue of
/// the preceding conv/linear (and that the engine runs as standalone nodes
/// when unfused). One definition serves both, so fusion cannot drift.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Relu,
    Relu6,
    Hswish,
    Hsigmoid,
    Sigmoid,
    Silu,
    Gelu,
}

impl Act {
    /// Map a QIR node kind (or `act=` attribute value) to the epilogue.
    pub fn from_kind(kind: &str) -> Option<Act> {
        Some(match kind {
            "relu" => Act::Relu,
            "relu6" => Act::Relu6,
            "hswish" => Act::Hswish,
            "hsigmoid" => Act::Hsigmoid,
            "sigmoid" => Act::Sigmoid,
            "silu" => Act::Silu,
            "gelu" => Act::Gelu,
            _ => return None,
        })
    }

    /// Epilogue activation tagged on a conv/linear node by the
    /// `fuse_conv_bn_act` pass, if any. The single parser both executors use.
    pub fn from_attr(n: &Node) -> Result<Option<Act>> {
        match n.attrs.get("act") {
            None => Ok(None),
            Some(a) => Act::from_kind(a)
                .map(Some)
                .with_context(|| format!("node {}: unknown fused act {a:?}", n.name)),
        }
    }

    /// Apply the activation to one value (shared by epilogues and
    /// standalone activation nodes).
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Act::Relu => v.max(0.0),
            Act::Relu6 => v.clamp(0.0, 6.0),
            Act::Hswish => v * (v + 3.0).clamp(0.0, 6.0) / 6.0,
            Act::Hsigmoid => (v + 3.0).clamp(0.0, 6.0) / 6.0,
            Act::Sigmoid => 1.0 / (1.0 + (-v).exp()),
            Act::Silu => v / (1.0 + (-v).exp()),
            Act::Gelu => {
                let c = (2.0f32 / std::f32::consts::PI).sqrt();
                0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
            }
        }
    }
}

#[inline]
pub(crate) fn apply_act(v: f32, act: Option<Act>) -> f32 {
    match act {
        Some(a) => a.apply(v),
        None => v,
    }
}

/// im2col for NCHW input: output rows = N*Ho*Wo, cols = (Cin/g)*kh*kw,
/// one matrix per group.
pub struct Im2Col {
    /// N*Ho*Wo output positions.
    pub rows: usize,
    /// (Cin/groups)*kh*kw patch elements per position.
    pub cols: usize,
    /// Row-major (rows, cols) patch matrix.
    pub data: Vec<f32>,
}

/// Lower one convolution group of an NCHW input to its im2col patch matrix.
#[allow(clippy::too_many_arguments)]
pub fn im2col_group(
    x: &Tensor,
    group: usize,
    groups: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> Im2Col {
    let mut data = Vec::new();
    let (rows, cols) = im2col_group_into(x, group, groups, kh, kw, stride, pad, ho, wo, &mut data);
    Im2Col { rows, cols, data }
}

/// [`im2col_group`] into a caller-owned buffer (cleared and zero-filled to
/// `rows * cols`; allocation-free once the buffer's capacity suffices).
/// Returns `(rows, cols)`.
#[allow(clippy::too_many_arguments)]
pub fn im2col_group_into(
    x: &Tensor,
    group: usize,
    groups: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let cg = c / groups;
    let c0 = group * cg;
    let rows = n * ho * wo;
    let cols = cg * kh * kw;
    out.clear();
    out.resize(rows * cols, 0.0);
    let data = out.as_mut_slice();
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (ni * ho + oy) * wo + ox;
                let base = row * cols;
                for ci in 0..cg {
                    let xc = &x.data[((ni * c) + c0 + ci) * h * w..((ni * c) + c0 + ci + 1) * h * w];
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let iy = iy as usize;
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            data[base + (ci * kh + ky) * kw + kx] = xc[iy * w + ix as usize];
                        }
                    }
                }
            }
        }
    }
    (rows, cols)
}

// ---------------------------------------------------------------------------
// shared parallel driver
// ---------------------------------------------------------------------------

/// Work (in MACs) below which parallel dispatch costs more than it saves,
/// and the minimum row count worth splitting (§Perf iteration 3).
const PAR_WORK_MIN: u64 = 4_000_000;
const PAR_ROWS_MIN: usize = 8;

/// Disjoint-chunk base pointer handed to pool workers; `Sync` is sound
/// because every chunk index is claimed exactly once and chunk row ranges
/// never overlap.
struct OutBase(*mut f32);

// SAFETY: workers only ever materialize pairwise-disjoint `&mut` chunks from
// this pointer (each chunk index is claimed exactly once by the pool's atomic
// cursor and row ranges never overlap), so sharing the base across threads
// cannot create aliasing mutable access.
unsafe impl Sync for OutBase {}

/// Shared row-chunk parallel driver behind every planned GEMM: splits the
/// output matrix into contiguous disjoint row ranges and runs
/// `kern(first_row, n_rows, out_chunk)` on the persistent worker pool
/// ([`pool::global`] — long-lived parked workers, no per-call thread
/// spawns) when the problem is large enough to amortize the dispatch.
/// Small problems run inline. Chunk boundaries depend only on (rows, pool
/// parallelism) and every output element is accumulated independently, so
/// results are bit-identical at any worker count.
pub(crate) fn par_row_chunks<F>(rows: usize, out: &mut [f32], out_stride: usize, work: u64, kern: F)
where
    F: Fn(usize, usize, &mut [f32]) + Sync,
{
    let out = &mut out[..rows * out_stride];
    if work <= PAR_WORK_MIN || rows < PAR_ROWS_MIN {
        kern(0, rows, out);
        return;
    }
    pool::with_current(|p| {
        let threads = p.parallelism();
        if threads <= 1 {
            kern(0, rows, out);
            return;
        }
        let chunk = rows.div_ceil(threads);
        let n_chunks = rows.div_ceil(chunk);
        let base = OutBase(out.as_mut_ptr());
        let kern = &kern;
        p.run(n_chunks, &move |i| {
            let r0 = i * chunk;
            let take = chunk.min(rows - r0);
            // SAFETY: chunk i is claimed exactly once (atomic cursor) and
            // [r0, r0+take) row ranges are pairwise disjoint.
            let mine = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r0 * out_stride), take * out_stride)
            };
            kern(r0, take, mine);
        });
    });
}

// ---------------------------------------------------------------------------
// f32 GEMM
// ---------------------------------------------------------------------------

/// Reference f32 GEMM: out[r][o] = sum_k col[r][k] * w[o][k]; w is
/// (cout_g, cols). Serial, one output at a time, 64-wide partial sums.
pub fn gemm_f32(col: &Im2Col, w: &[f32], cout_g: usize, out: &mut [f32], out_stride: usize, o0: usize) {
    const BK: usize = 64;
    for r in 0..col.rows {
        let crow = &col.data[r * col.cols..(r + 1) * col.cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        for o in 0..cout_g {
            let wrow = &w[o * col.cols..(o + 1) * col.cols];
            let mut acc = 0.0f32;
            let mut k = 0;
            while k + BK <= col.cols {
                let mut s = 0.0f32;
                for i in 0..BK {
                    s += crow[k + i] * wrow[k + i];
                }
                acc += s;
                k += BK;
            }
            for i in k..col.cols {
                acc += crow[i] * wrow[i];
            }
            orow[o0 + o] = acc;
        }
    }
}

/// Planned f32 GEMM: row-chunk parallel, 4-way output register blocking,
/// bias + activation epilogue. Per-output accumulation order (64-wide k
/// blocks, sequential within a block) is identical to [`gemm_f32`], so
/// results are bit-identical — only faster.
#[allow(clippy::too_many_arguments)]
pub fn gemm_f32_tiled(
    x: &[f32],
    rows: usize,
    cols: usize,
    w: &[f32],
    cout_g: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let work = rows as u64 * cols as u64 * cout_g as u64;
    par_row_chunks(rows, out, out_stride, work, |r0, nr, chunk| {
        gemm_f32_rows(&x[r0 * cols..(r0 + nr) * cols], nr, cols, w, cout_g, bias, act, chunk, out_stride, o0);
    });
}

#[allow(clippy::too_many_arguments)]
fn gemm_f32_rows(
    x: &[f32],
    rows: usize,
    cols: usize,
    w: &[f32],
    cout_g: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    const BK: usize = 64;
    for r in 0..rows {
        let xrow = &x[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        // 4-way output-channel register blocking: xrow stays hot in L1 and
        // four accumulators amortize its loads (mirrors the i8 kernel).
        while o + 4 <= cout_g {
            let w0 = &w[o * cols..(o + 1) * cols];
            let w1 = &w[(o + 1) * cols..(o + 2) * cols];
            let w2 = &w[(o + 2) * cols..(o + 3) * cols];
            let w3 = &w[(o + 3) * cols..(o + 4) * cols];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut k = 0;
            while k + BK <= cols {
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for i in k..k + BK {
                    let xv = xrow[i];
                    s0 += xv * w0[i];
                    s1 += xv * w1[i];
                    s2 += xv * w2[i];
                    s3 += xv * w3[i];
                }
                a0 += s0;
                a1 += s1;
                a2 += s2;
                a3 += s3;
                k += BK;
            }
            for i in k..cols {
                let xv = xrow[i];
                a0 += xv * w0[i];
                a1 += xv * w1[i];
                a2 += xv * w2[i];
                a3 += xv * w3[i];
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[o0 + oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &w[o * cols..(o + 1) * cols];
            let mut acc = 0.0f32;
            let mut k = 0;
            while k + BK <= cols {
                let mut s = 0.0f32;
                for i in k..k + BK {
                    s += xrow[i] * wrow[i];
                }
                acc += s;
                k += BK;
            }
            for i in k..cols {
                acc += xrow[i] * wrow[i];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o0 + o] = apply_act(acc, act);
            o += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// integer GEMM
// ---------------------------------------------------------------------------

/// Quantize an f32 im2col buffer to u8 (asymmetric per-tensor).
pub fn quantize_cols(col: &Im2Col, scale: f32, zp: i32, round: RoundMode) -> Vec<u8> {
    quantize_slice(&col.data, scale, zp, round)
}

/// Quantize a raw f32 slice to u8 (asymmetric per-tensor) — the single
/// definition of the activation quantization arithmetic.
pub fn quantize_slice(x: &[f32], scale: f32, zp: i32, round: RoundMode) -> Vec<u8> {
    let mut out = Vec::new();
    quantize_slice_into(x, scale, zp, round, &mut out);
    out
}

/// [`quantize_slice`] into a caller-owned buffer (allocation-free once the
/// buffer's capacity suffices — the planned executor's steady-state form).
pub fn quantize_slice_into(x: &[f32], scale: f32, zp: i32, round: RoundMode, out: &mut Vec<u8>) {
    out.clear();
    out.extend(x.iter().map(|&v| (round.round(v / scale) + zp as f32).clamp(0.0, 255.0) as u8));
}

/// Premultiplied per-output-channel dequantization scales: sw[c] * sx,
/// expanded to `cout` entries whether the scheme was per-channel or
/// per-tensor. Resolving this once per call (or once per plan) hoists the
/// per-element `w_scales[oo.min(len-1)]` branch out of the GEMM output loop.
pub fn premul_scales(w_scales: &[f32], cout: usize, sx: f32) -> Vec<f32> {
    let mut out = Vec::new();
    premul_scales_into(w_scales, cout, sx, &mut out);
    out
}

/// [`premul_scales`] into a caller-owned buffer — what the dynamic
/// activation-scaling path uses per run to stay allocation-free.
pub fn premul_scales_into(w_scales: &[f32], cout: usize, sx: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend((0..cout).map(|c| w_scales[c.min(w_scales.len() - 1)] * sx));
}

/// Integer GEMM with zero-point factorization (compatibility entry point:
/// recomputes row sums and premultiplied scales per call).
/// out[r][o0+o] = sw[o]*sx * ( sum_k xq[r][k]*wq[o][k] - zx * rowsum_w[o] ) + bias[o]
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    w_scales: &[f32],
    sx: f32,
    zx: i32,
    bias: Option<&[f32]>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let rowsum = row_sums_of(wq, cout_g);
    let sxw = premul_scales(w_scales, cout_g, sx);
    gemm_i8_dispatch(xq, rows, cols, wq, cout_g, &rowsum, &sxw, zx, bias, None, out, out_stride, o0);
}

/// Planned integer GEMM: precomputed row sums + premultiplied scales,
/// optional bias + activation requantization epilogue, row-chunk parallel.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_i8_dispatch(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let work = rows as u64 * cols as u64 * cout_g as u64;
    par_row_chunks(rows, out, out_stride, work, |r0, nr, chunk| {
        gemm_i8_rows(
            &xq[r0 * cols..(r0 + nr) * cols],
            nr, cols, wq, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride, o0,
        );
    });
}

/// Sign-extend the low nibble of a packed int4 byte to i32.
#[inline(always)]
pub(crate) fn nib_lo(b: i8) -> i32 {
    ((b << 4) >> 4) as i32
}

/// Sign-extend the high nibble of a packed int4 byte to i32.
#[inline(always)]
pub(crate) fn nib_hi(b: i8) -> i32 {
    (b >> 4) as i32
}

/// Planned int4 GEMM: same shape contract as [`gemm_i8_dispatch`] but `wq`
/// is the per-row nibble-packed payload (`cols.div_ceil(2)` bytes per
/// output channel, see `tensor::pack_int4`). Nibbles are unpacked
/// in-register inside the same row-chunk parallel / 4-way register-blocked
/// driver, and the zero-point + bias + activation requantization epilogue
/// is shared verbatim — i32 accumulation keeps the path bit-exact between
/// the planned and interpreted executors regardless of chunking.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_i4_dispatch(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let work = rows as u64 * cols as u64 * cout_g as u64;
    par_row_chunks(rows, out, out_stride, work, |r0, nr, chunk| {
        gemm_i4_rows(
            &xq[r0 * cols..(r0 + nr) * cols],
            nr, cols, wq, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride, o0,
        );
    });
}

/// Serial row-range kernel behind the int4 GEMM: mirrors [`gemm_i8_rows`]
/// with the k loop walking packed bytes (two MACs per byte, odd tail
/// handled on the low nibble only).
#[allow(clippy::too_many_arguments)]
fn gemm_i4_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let bpr = packed_row_bytes(cols);
    let pairs = cols / 2;
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * bpr..(o + 1) * bpr];
            let w1 = &wq[(o + 1) * bpr..(o + 2) * bpr];
            let w2 = &wq[(o + 2) * bpr..(o + 3) * bpr];
            let w3 = &wq[(o + 3) * bpr..(o + 4) * bpr];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for kb in 0..pairs {
                let x0 = xrow[2 * kb] as i32;
                let x1 = xrow[2 * kb + 1] as i32;
                a0 += x0 * nib_lo(w0[kb]) + x1 * nib_hi(w0[kb]);
                a1 += x0 * nib_lo(w1[kb]) + x1 * nib_hi(w1[kb]);
                a2 += x0 * nib_lo(w2[kb]) + x1 * nib_hi(w2[kb]);
                a3 += x0 * nib_lo(w3[kb]) + x1 * nib_hi(w3[kb]);
            }
            if cols % 2 == 1 {
                let x0 = xrow[cols - 1] as i32;
                a0 += x0 * nib_lo(w0[bpr - 1]);
                a1 += x0 * nib_lo(w1[bpr - 1]);
                a2 += x0 * nib_lo(w2[bpr - 1]);
                a3 += x0 * nib_lo(w3[bpr - 1]);
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * bpr..(o + 1) * bpr];
            let mut acc = 0i32;
            for kb in 0..pairs {
                acc += xrow[2 * kb] as i32 * nib_lo(wrow[kb])
                    + xrow[2 * kb + 1] as i32 * nib_hi(wrow[kb]);
            }
            if cols % 2 == 1 {
                acc += xrow[cols - 1] as i32 * nib_lo(wrow[bpr - 1]);
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// Serial row-range kernel behind the integer GEMM.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    // 4-way output-channel register blocking: the x row stays hot in L1 and
    // four i32 accumulators amortize its loads (§Perf iteration 1; the i16
    // hoist and 8-way variants measured worse — see EXPERIMENTS.md §Perf)
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * cols..(o + 1) * cols];
            let w1 = &wq[(o + 1) * cols..(o + 2) * cols];
            let w2 = &wq[(o + 2) * cols..(o + 3) * cols];
            let w3 = &wq[(o + 3) * cols..(o + 4) * cols];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for k in 0..cols {
                let x = xrow[k] as i32;
                a0 += x * w0[k] as i32;
                a1 += x * w1[k] as i32;
                a2 += x * w2[k] as i32;
                a3 += x * w3[k] as i32;
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * cols..(o + 1) * cols];
            let mut acc = 0i32;
            for k in 0..cols {
                acc += xrow[k] as i32 * wrow[k] as i32;
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// prepacked panel weights (plan-time layout transformation)
//
// The planned GEMMs read weights in 4-output-channel register blocks, but
// row-major storage makes each block walk 4 strided rows. At plan time the
// executor repacks every weight ONCE into cache-blocked panel-major form
// matched to that blocking: full panels of 4 output rows are interleaved
// k-major ([k][j] — one contiguous stream the inner loop walks linearly),
// remainder rows stay row-major after the panels, and convolution groups
// are packed independently so group slicing stays contiguous. Per-output
// accumulation order is untouched — only the addressing changes — so the
// packed kernels are bit-identical to their row-major twins (asserted in
// the tests below), and the 4-bit path unpacks nibbles per *panel byte
// group* (4 adjacent bytes = one k-step of the whole panel) instead of
// walking 4 separate packed rows.
//
// The interleave above describes the SCALAR tier. SIMD tiers
// (engine::simd) keep the integer payload row-major instead — their
// 16-wide widening dot products read each output channel's row as one
// contiguous stream — while float panels are interleaved on every tier
// (the SIMD float kernels vectorize across the 4 panel lanes). The layout
// is chosen once in pack_for() from the plan's resolved KernelTier.
// ---------------------------------------------------------------------------

/// Interleave full 4-row panels ([k][j]) and append remainder rows
/// row-major. `row_bytes` is the stored row length (elements for f32/i8,
/// packed bytes for i4 — byte-level interleave keeps each byte's nibble
/// pair intact).
fn pack_panel_rows<T: Copy>(rows: &[T], cout_g: usize, row_bytes: usize, out: &mut Vec<T>) {
    let mut o = 0;
    while o + 4 <= cout_g {
        for k in 0..row_bytes {
            for j in 0..4 {
                out.push(rows[(o + j) * row_bytes + k]);
            }
        }
        o += 4;
    }
    while o < cout_g {
        out.extend_from_slice(&rows[o * row_bytes..(o + 1) * row_bytes]);
        o += 1;
    }
}

/// An f32 weight matrix/filter repacked panel-major at plan time (see the
/// section docs). Shape is the original tensor shape (OIHW for conv,
/// (dout, din) for linear); `groups` partitions the output channels.
#[derive(Clone)]
pub struct PackedF32 {
    /// Original weight tensor shape.
    pub shape: Vec<usize>,
    /// Convolution groups (1 for linear / attention projections).
    pub groups: usize,
    /// Output channels per group.
    pub cout_g: usize,
    /// Reduction length (elements per output row).
    pub cols: usize,
    /// `groups * cout_g * cols` values: per group, full panels interleaved
    /// [k][j] followed by remainder rows row-major.
    pub data: Vec<f32>,
    /// Kernel tier the panels were packed for. Float panels share one
    /// layout across tiers (SIMD float kernels vectorize across the 4
    /// panel lanes), so the tier only selects the dispatched kernel.
    pub tier: KernelTier,
}

impl PackedF32 {
    /// Repack a row-major weight tensor (output channels on axis 0) for
    /// the scalar tier.
    pub fn pack(w: &Tensor, groups: usize) -> PackedF32 {
        PackedF32::pack_for(w, groups, KernelTier::Scalar)
    }

    /// Repack a row-major weight tensor (output channels on axis 0) for a
    /// resolved kernel tier.
    pub fn pack_for(w: &Tensor, groups: usize, tier: KernelTier) -> PackedF32 {
        let cout = if w.shape.is_empty() { 1 } else { w.shape[0].max(1) };
        let cout_g = cout / groups.max(1);
        let cols = w.data.len() / cout;
        let mut data = Vec::with_capacity(w.data.len());
        for g in 0..groups {
            pack_panel_rows(
                &w.data[g * cout_g * cols..(g + 1) * cout_g * cols],
                cout_g,
                cols,
                &mut data,
            );
        }
        PackedF32 { shape: w.shape.clone(), groups, cout_g, cols, data, tier }
    }

    /// Total output channels across all groups.
    pub fn cout(&self) -> usize {
        self.groups * self.cout_g
    }

    /// Panel-major payload of one convolution group.
    pub fn group(&self, g: usize) -> &[f32] {
        &self.data[g * self.cout_g * self.cols..(g + 1) * self.cout_g * self.cols]
    }
}

/// A quantized weight repacked panel-major at plan time: the integer
/// payload in panel order (i8 values, or nibble-packed i4 bytes — the
/// interleave is byte-level, so a panel's 4 adjacent bytes carry one
/// two-nibble k-step for each of the 4 output channels), with the scales
/// and quantize-time row sums carried over from the source [`QWeight`].
#[derive(Clone)]
pub struct PackedQW {
    /// Original weight tensor shape.
    pub shape: Vec<usize>,
    /// Convolution groups (1 for linear / attention projections).
    pub groups: usize,
    /// Output channels per group.
    pub cout_g: usize,
    /// Reduction length in ELEMENTS (nibbles for 4-bit payloads).
    pub cols: usize,
    /// Weight bit-width: 8 or 4.
    pub bits: u8,
    /// Panel-major integer payload, per group.
    pub data: Vec<i8>,
    /// Per-output-channel (or singleton) dequant scales.
    pub scales: Vec<f32>,
    /// Per-output-channel payload sums (zero-point correction term).
    pub row_sums: Vec<i32>,
    /// Kernel tier the payload was packed for: `[k][4]` panel interleave
    /// on the scalar tier, row-major on SIMD tiers (their dot-product
    /// loops read each output channel's row as one contiguous stream).
    pub tier: KernelTier,
}

impl PackedQW {
    /// Repack a quantized weight (either bit-width) for the scalar-tier
    /// panel kernels.
    pub fn pack(qw: &QWeight, groups: usize) -> PackedQW {
        PackedQW::pack_for(qw, groups, KernelTier::Scalar)
    }

    /// Repack a quantized weight (either bit-width) for a resolved kernel
    /// tier. The scalar tier interleaves full 4-row panels; SIMD tiers
    /// keep the payload row-major (group slices stay contiguous either
    /// way, so [`PackedQW::group`] is layout-agnostic).
    pub fn pack_for(qw: &QWeight, groups: usize, tier: KernelTier) -> PackedQW {
        let cout = qw.cout();
        let cout_g = cout / groups.max(1);
        let cols = qw.per_row();
        let data = if tier.interleaved_int_panels() {
            let row_bytes = if qw.bits == 4 { packed_row_bytes(cols) } else { cols };
            let mut data = Vec::with_capacity(qw.data.len());
            for g in 0..groups {
                pack_panel_rows(
                    &qw.data[g * cout_g * row_bytes..(g + 1) * cout_g * row_bytes],
                    cout_g,
                    row_bytes,
                    &mut data,
                );
            }
            data
        } else {
            qw.data.clone()
        };
        PackedQW {
            shape: qw.shape.clone(),
            groups,
            cout_g,
            cols,
            bits: qw.bits,
            data,
            scales: qw.scales.clone(),
            row_sums: qw.row_sums.clone(),
            tier,
        }
    }

    /// Total output channels across all groups.
    pub fn cout(&self) -> usize {
        self.groups * self.cout_g
    }

    /// Stored bytes per output row (packed bytes for 4-bit payloads).
    fn row_bytes(&self) -> usize {
        if self.bits == 4 {
            packed_row_bytes(self.cols)
        } else {
            self.cols
        }
    }

    /// Panel-major payload of one convolution group.
    pub fn group(&self, g: usize) -> &[i8] {
        let rb = self.row_bytes();
        &self.data[g * self.cout_g * rb..(g + 1) * self.cout_g * rb]
    }
}

/// Serial row-range kernel over panel-major f32 weights. Identical
/// per-output accumulation order (64-wide k blocks) to [`gemm_f32_rows`] —
/// only the weight addressing changes — so outputs are bit-identical.
#[allow(clippy::too_many_arguments)]
fn gemm_f32_panel_rows(
    x: &[f32],
    rows: usize,
    cols: usize,
    wp: &[f32],
    cout_g: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    const BK: usize = 64;
    for r in 0..rows {
        let xrow = &x[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            // full panel: one linear [k][4] stream for 4 accumulators
            let pan = &wp[o * cols..(o + 4) * cols];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            let mut k = 0;
            while k + BK <= cols {
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for i in k..k + BK {
                    let xv = xrow[i];
                    let wb = &pan[i * 4..i * 4 + 4];
                    s0 += xv * wb[0];
                    s1 += xv * wb[1];
                    s2 += xv * wb[2];
                    s3 += xv * wb[3];
                }
                a0 += s0;
                a1 += s1;
                a2 += s2;
                a3 += s3;
                k += BK;
            }
            for i in k..cols {
                let xv = xrow[i];
                let wb = &pan[i * 4..i * 4 + 4];
                a0 += xv * wb[0];
                a1 += xv * wb[1];
                a2 += xv * wb[2];
                a3 += xv * wb[3];
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[o0 + oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < cout_g {
            // remainder rows are stored row-major at offset o*cols
            let wrow = &wp[o * cols..(o + 1) * cols];
            let mut acc = 0.0f32;
            let mut k = 0;
            while k + BK <= cols {
                let mut s = 0.0f32;
                for i in k..k + BK {
                    s += xrow[i] * wrow[i];
                }
                acc += s;
                k += BK;
            }
            for i in k..cols {
                acc += xrow[i] * wrow[i];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o0 + o] = apply_act(acc, act);
            o += 1;
        }
    }
}

/// Serial row-range kernel over panel-major f32 weights with PLAIN
/// (unblocked-k) accumulation, mirroring [`linear_f32`] / `linear_f32_rows`
/// bit-for-bit per output — the linear / attention-projection form.
#[allow(clippy::too_many_arguments)]
fn linear_f32_panel_rows(
    x: &[f32],
    rows: usize,
    din: usize,
    wp: &[f32],
    dout: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
) {
    for r in 0..rows {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut o = 0;
        while o + 4 <= dout {
            let pan = &wp[o * din..(o + 4) * din];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for k in 0..din {
                let xv = xrow[k];
                let wb = &pan[k * 4..k * 4 + 4];
                a0 += xv * wb[0];
                a1 += xv * wb[1];
                a2 += xv * wb[2];
                a3 += xv * wb[3];
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < dout {
            let wrow = &wp[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for k in 0..din {
                acc += xrow[k] * wrow[k];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o] = apply_act(acc, act);
            o += 1;
        }
    }
}

/// Serial row-range kernel over panel-major i8 weights (bit-exact with
/// [`gemm_i8_rows`] — i32 accumulation is order-independent anyway).
#[allow(clippy::too_many_arguments)]
fn gemm_i8_panel_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wp: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let pan = &wp[o * cols..(o + 4) * cols];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for k in 0..cols {
                let x = xrow[k] as i32;
                let wb = &pan[k * 4..k * 4 + 4];
                a0 += x * wb[0] as i32;
                a1 += x * wb[1] as i32;
                a2 += x * wb[2] as i32;
                a3 += x * wb[3] as i32;
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wp[o * cols..(o + 1) * cols];
            let mut acc = 0i32;
            for k in 0..cols {
                acc += xrow[k] as i32 * wrow[k] as i32;
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// Serial row-range kernel over panel-major nibble-packed i4 weights: each
/// k-step of a full panel is 4 adjacent bytes (one per output channel),
/// unpacked together — per-panel nibble unpacking instead of walking 4
/// separate packed rows. Bit-exact with [`gemm_i4_rows`].
#[allow(clippy::too_many_arguments)]
fn gemm_i4_panel_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wp: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let bpr = packed_row_bytes(cols);
    let pairs = cols / 2;
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let pan = &wp[o * bpr..(o + 4) * bpr];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for kb in 0..pairs {
                let x0 = xrow[2 * kb] as i32;
                let x1 = xrow[2 * kb + 1] as i32;
                let wb = &pan[kb * 4..kb * 4 + 4];
                a0 += x0 * nib_lo(wb[0]) + x1 * nib_hi(wb[0]);
                a1 += x0 * nib_lo(wb[1]) + x1 * nib_hi(wb[1]);
                a2 += x0 * nib_lo(wb[2]) + x1 * nib_hi(wb[2]);
                a3 += x0 * nib_lo(wb[3]) + x1 * nib_hi(wb[3]);
            }
            if cols % 2 == 1 {
                let x0 = xrow[cols - 1] as i32;
                let wb = &pan[(bpr - 1) * 4..(bpr - 1) * 4 + 4];
                a0 += x0 * nib_lo(wb[0]);
                a1 += x0 * nib_lo(wb[1]);
                a2 += x0 * nib_lo(wb[2]);
                a3 += x0 * nib_lo(wb[3]);
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            // remainder rows: original packed-row layout at offset o*bpr
            let wrow = &wp[o * bpr..(o + 1) * bpr];
            let mut acc = 0i32;
            for kb in 0..pairs {
                acc += xrow[2 * kb] as i32 * nib_lo(wrow[kb])
                    + xrow[2 * kb + 1] as i32 * nib_hi(wrow[kb]);
            }
            if cols % 2 == 1 {
                acc += xrow[cols - 1] as i32 * nib_lo(wrow[bpr - 1]);
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// Row-chunk parallel f32 GEMM over one group's panel-major payload
/// (64-wide k blocking — the convolution form), dispatching on the tier
/// the panels were packed for. All tiers are bit-identical: the SIMD
/// float kernels vectorize across the 4 panel lanes, replaying the scalar
/// per-output accumulation order exactly.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_f32_packed(
    x: &[f32],
    rows: usize,
    cols: usize,
    wp: &[f32],
    cout_g: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
    tier: KernelTier,
) {
    let work = rows as u64 * cols as u64 * cout_g as u64;
    par_row_chunks(rows, out, out_stride, work, |r0, nr, chunk| {
        let xr = &x[r0 * cols..(r0 + nr) * cols];
        match tier {
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => {
                // SAFETY: the plan resolves Avx2 only when
                // is_x86_feature_detected!("avx2") held on this machine
                // (KernelTier::resolve), so the callee's target-feature
                // contract is met.
                unsafe {
                    simd::avx2::gemm_f32_panel_rows(
                        xr, nr, cols, wp, cout_g, bias, act, chunk, out_stride, o0,
                    )
                }
            }
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => simd::neon::gemm_f32_panel_rows(
                xr, nr, cols, wp, cout_g, bias, act, chunk, out_stride, o0,
            ),
            _ => gemm_f32_panel_rows(
                xr, nr, cols, wp, cout_g, bias, act, chunk, out_stride, o0,
            ),
        }
    });
}

/// Row-chunk parallel integer GEMM over one group's prepacked payload,
/// dispatching on the stored bit-width and the tier the payload was
/// packed for (scalar: `[k][4]` panel interleave; SIMD: row-major). i32
/// accumulation is order-independent, so every tier is bit-exact with the
/// interpreter's reference kernels.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_int_packed(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wp: &[i8],
    bits: u8,
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
    tier: KernelTier,
) {
    let work = rows as u64 * cols as u64 * cout_g as u64;
    par_row_chunks(rows, out, out_stride, work, |r0, nr, chunk| {
        let xr = &xq[r0 * cols..(r0 + nr) * cols];
        match tier {
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => {
                // SAFETY: the plan resolves Avx2 only when
                // is_x86_feature_detected!("avx2") held on this machine
                // (KernelTier::resolve), so the callees' target-feature
                // contract is met; the payload was packed row-major for
                // this tier.
                unsafe {
                    if bits == 4 {
                        simd::avx2::gemm_i4_rows(
                            xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk,
                            out_stride, o0,
                        )
                    } else {
                        simd::avx2::gemm_i8_rows(
                            xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk,
                            out_stride, o0,
                        )
                    }
                }
            }
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => {
                if bits == 4 {
                    simd::neon::gemm_i4_rows(
                        xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride,
                        o0,
                    )
                } else {
                    simd::neon::gemm_i8_rows(
                        xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride,
                        o0,
                    )
                }
            }
            _ if tier.interleaved_int_panels() => {
                if bits == 4 {
                    gemm_i4_panel_rows(
                        xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride,
                        o0,
                    )
                } else {
                    gemm_i8_panel_rows(
                        xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride,
                        o0,
                    )
                }
            }
            // A SIMD tier this target cannot execute (possible only if a
            // plan crossed machines, which the verifier rejects): the
            // payload is row-major, so the scalar row-major kernels are
            // still correct.
            _ => {
                if bits == 4 {
                    gemm_i4_rows(
                        xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride,
                        o0,
                    )
                } else {
                    gemm_i8_rows(
                        xr, nr, cols, wp, cout_g, rowsum, sxw, zx, bias, act, chunk, out_stride,
                        o0,
                    )
                }
            }
        }
    });
}

/// Planned f32 convolution over prepacked panel weights, writing every
/// intermediate into caller-owned scratch (`col` patch matrix, `mat` GEMM
/// output) and the result into `out` — allocation-free once warm. The
/// bias + activation epilogue runs inside the GEMM, like
/// [`conv2d_f32_fused`]; numerics are bit-identical to it.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_packed(
    x: &Tensor,
    wp: &PackedF32,
    bias: Option<&[f32]>,
    stride: usize,
    pad: usize,
    act: Option<Act>,
    col: &mut Vec<f32>,
    mat: &mut Vec<f32>,
    out: &mut Tensor,
) {
    let n = x.shape[0];
    let (cout, kh, kw) = (wp.cout(), wp.shape[2], wp.shape[3]);
    let (ho, wo) = conv_out_dims(x, kh, kw, stride, pad);
    let cout_g = wp.cout_g;
    mat.resize(n * ho * wo * cout, 0.0);
    for g in 0..wp.groups {
        let (rows, cols) = im2col_group_into(x, g, wp.groups, kh, kw, stride, pad, ho, wo, col);
        let bslice = bias.map(|b| &b[g * cout_g..(g + 1) * cout_g]);
        gemm_f32_packed(
            col.as_slice(), rows, cols, wp.group(g), cout_g, bslice, act, mat, cout, g * cout_g,
            wp.tier,
        );
    }
    out_mat_to_nchw_into(mat.as_slice(), n, cout, ho, wo, out);
}

/// Planned integer convolution over prepacked panel weights (i8 or
/// nibble-packed i4), scratch-buffered like [`conv2d_f32_packed`]:
/// `col` patch matrix, `xq` quantized activations, `mat` GEMM output.
/// Bias + activation run in the requantization epilogue. Bit-exact with
/// [`conv2d_i8_fused`] on the same weights.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_int_packed(
    x: &Tensor,
    pw: &PackedQW,
    bias: Option<&[f32]>,
    stride: usize,
    pad: usize,
    sx: f32,
    zx: i32,
    round: RoundMode,
    sxw: &[f32],
    act: Option<Act>,
    col: &mut Vec<f32>,
    xq: &mut Vec<u8>,
    mat: &mut Vec<f32>,
    out: &mut Tensor,
) {
    let n = x.shape[0];
    let (cout, kh, kw) = (pw.cout(), pw.shape[2], pw.shape[3]);
    let (ho, wo) = conv_out_dims(x, kh, kw, stride, pad);
    let cout_g = pw.cout_g;
    mat.resize(n * ho * wo * cout, 0.0);
    for g in 0..pw.groups {
        let (rows, cols) = im2col_group_into(x, g, pw.groups, kh, kw, stride, pad, ho, wo, col);
        quantize_slice_into(col.as_slice(), sx, zx, round, xq);
        let rowsum = &pw.row_sums[g * cout_g..(g + 1) * cout_g];
        let sxw_g = &sxw[g * cout_g..(g + 1) * cout_g];
        let bslice = bias.map(|b| &b[g * cout_g..(g + 1) * cout_g]);
        gemm_int_packed(
            xq.as_slice(), rows, cols, pw.group(g), pw.bits, cout_g, rowsum, sxw_g, zx, bslice,
            act, mat, cout, g * cout_g, pw.tier,
        );
    }
    out_mat_to_nchw_into(mat.as_slice(), n, cout, ho, wo, out);
}

/// Planned f32 linear over prepacked panel weights, writing into a
/// caller-sized `out` slice (`rows * dout`). Plain accumulation, matching
/// [`linear_f32`] bit-for-bit per output.
pub fn linear_f32_packed(
    x: &[f32],
    rows: usize,
    wp: &PackedF32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
) {
    let (din, dout) = (wp.cols, wp.cout_g);
    let work = rows as u64 * din as u64 * dout as u64;
    par_row_chunks(rows, out, dout, work, |r0, nr, chunk| {
        let xr = &x[r0 * din..(r0 + nr) * din];
        match wp.tier {
            #[cfg(target_arch = "x86_64")]
            KernelTier::Avx2 => {
                // SAFETY: the plan resolves Avx2 only when
                // is_x86_feature_detected!("avx2") held on this machine
                // (KernelTier::resolve), so the callee's target-feature
                // contract is met.
                unsafe {
                    simd::avx2::linear_f32_panel_rows(xr, nr, din, &wp.data, dout, bias, act, chunk)
                }
            }
            #[cfg(target_arch = "aarch64")]
            KernelTier::Neon => {
                simd::neon::linear_f32_panel_rows(xr, nr, din, &wp.data, dout, bias, act, chunk)
            }
            _ => linear_f32_panel_rows(xr, nr, din, &wp.data, dout, bias, act, chunk),
        }
    });
}

/// Planned integer linear over prepacked panel weights: quantizes the
/// input into the caller's `xq` scratch and runs the panel GEMM with the
/// requantization epilogue into `out` (`rows * dout`, caller-sized).
/// Bit-exact with [`linear_i8_fused`] on the same weights.
#[allow(clippy::too_many_arguments)]
pub fn linear_int_packed(
    x: &[f32],
    rows: usize,
    pw: &PackedQW,
    bias: Option<&[f32]>,
    sx: f32,
    zx: i32,
    round: RoundMode,
    sxw: &[f32],
    act: Option<Act>,
    xq: &mut Vec<u8>,
    out: &mut [f32],
) {
    let (din, dout) = (pw.cols, pw.cout());
    quantize_slice_into(x, sx, zx, round, xq);
    gemm_int_packed(
        xq.as_slice(), rows, din, &pw.data, pw.bits, dout, &pw.row_sums, sxw, zx, bias, act, out,
        dout, 0, pw.tier,
    );
}

// ---------------------------------------------------------------------------
// convolution
// ---------------------------------------------------------------------------

fn conv_out_dims(x: &Tensor, kh: usize, kw: usize, stride: usize, pad: usize) -> (usize, usize) {
    let (h, w) = (x.shape[2], x.shape[3]);
    ((h + 2 * pad - kh) / stride + 1, (w + 2 * pad - kw) / stride + 1)
}

/// (N*Ho*Wo, Cout) row-major matrix -> caller-owned NCHW tensor (every
/// element overwritten; allocation-free once the tensor's capacity
/// suffices). Bias is always fused into the GEMM epilogue on this path.
fn out_mat_to_nchw_into(mat: &[f32], n: usize, cout: usize, ho: usize, wo: usize, out: &mut Tensor) {
    out.reset_for_overwrite(&[n, cout, ho, wo]);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let r = (ni * ho + oy) * wo + ox;
                for o in 0..cout {
                    out.data[((ni * cout + o) * ho + oy) * wo + ox] = mat[r * cout + o];
                }
            }
        }
    }
}

/// (N*Ho*Wo, Cout) row-major matrix -> NCHW tensor, adding `bias` per output
/// channel when given.
fn out_mat_to_nchw(out_mat: &[f32], n: usize, cout: usize, ho: usize, wo: usize, bias: Option<&Tensor>) -> Tensor {
    let mut out = Tensor::zeros(&[n, cout, ho, wo]);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let r = (ni * ho + oy) * wo + ox;
                for o in 0..cout {
                    let mut v = out_mat[r * cout + o];
                    if let Some(b) = bias {
                        v += b.data[o];
                    }
                    out.data[((ni * cout + o) * ho + oy) * wo + ox] = v;
                }
            }
        }
    }
    out
}

/// Reference f32 convolution (NCHW, OIHW weights, groups). Serial.
pub fn conv2d_f32(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Tensor {
    let n = x.shape[0];
    let (cout, _cg, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (ho, wo) = conv_out_dims(x, kh, kw, stride, pad);
    let cout_g = cout / groups;
    let mut out_mat = vec![0.0f32; n * ho * wo * cout];
    for g in 0..groups {
        let col = im2col_group(x, g, groups, kh, kw, stride, pad, ho, wo);
        let wslice = &w.data[g * cout_g * col.cols..(g + 1) * cout_g * col.cols];
        gemm_f32(&col, wslice, cout_g, &mut out_mat, cout, g * cout_g);
    }
    out_mat_to_nchw(&out_mat, n, cout, ho, wo, bias)
}

/// Planned f32 convolution: parallel tiled GEMM with the bias + activation
/// epilogue fused in (the conv→bn→act lowering target).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_f32_fused(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    act: Option<Act>,
) -> Tensor {
    let n = x.shape[0];
    let (cout, _cg, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (ho, wo) = conv_out_dims(x, kh, kw, stride, pad);
    let cout_g = cout / groups;
    let mut out_mat = vec![0.0f32; n * ho * wo * cout];
    for g in 0..groups {
        let col = im2col_group(x, g, groups, kh, kw, stride, pad, ho, wo);
        let wslice = &w.data[g * cout_g * col.cols..(g + 1) * cout_g * col.cols];
        let bslice = bias.map(|b| &b.data[g * cout_g..(g + 1) * cout_g]);
        gemm_f32_tiled(&col.data, col.rows, col.cols, wslice, cout_g, bslice, act, &mut out_mat, cout, g * cout_g);
    }
    out_mat_to_nchw(&out_mat, n, cout, ho, wo, None)
}

/// Reference integer (W8/A8) convolution: quantizes the input with (sx, zx),
/// uses the pre-quantized weights, accumulates i32, dequantizes to f32.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8(
    x: &Tensor,
    qw: &QWeight,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    sx: f32,
    zx: i32,
    round: RoundMode,
) -> Tensor {
    let sxw = premul_scales(&qw.scales, qw.shape[0], sx);
    conv2d_i8_inner(x, qw, bias, stride, pad, groups, sx, zx, round, &sxw, None, false)
}

/// Planned integer convolution: bias + activation run in the requantization
/// epilogue of the integer GEMM, using the row sums fixed at quantize time
/// and the premultiplied dequant scales fixed at plan time.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8_fused(
    x: &Tensor,
    qw: &QWeight,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    sx: f32,
    zx: i32,
    round: RoundMode,
    sxw: &[f32],
    act: Option<Act>,
) -> Tensor {
    conv2d_i8_inner(x, qw, bias, stride, pad, groups, sx, zx, round, sxw, act, true)
}

#[allow(clippy::too_many_arguments)]
fn conv2d_i8_inner(
    x: &Tensor,
    qw: &QWeight,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    sx: f32,
    zx: i32,
    round: RoundMode,
    sxw: &[f32],
    act: Option<Act>,
    bias_in_epilogue: bool,
) -> Tensor {
    let n = x.shape[0];
    let (cout, _cg, kh, kw) = (qw.shape[0], qw.shape[1], qw.shape[2], qw.shape[3]);
    let (ho, wo) = conv_out_dims(x, kh, kw, stride, pad);
    let cout_g = cout / groups;
    let mut out_mat = vec![0.0f32; n * ho * wo * cout];
    for g in 0..groups {
        let col = im2col_group(x, g, groups, kh, kw, stride, pad, ho, wo);
        let xq = quantize_cols(&col, sx, zx, round);
        let rowsum = &qw.row_sums[g * cout_g..(g + 1) * cout_g];
        let sxw_g = &sxw[g * cout_g..(g + 1) * cout_g];
        let bslice = if bias_in_epilogue {
            bias.map(|b| &b.data[g * cout_g..(g + 1) * cout_g])
        } else {
            None
        };
        if qw.bits == 4 {
            // packed rows: packed_row_bytes(cols) bytes per output channel
            let bpr = packed_row_bytes(col.cols);
            let wslice = &qw.data[g * cout_g * bpr..(g + 1) * cout_g * bpr];
            gemm_i4_dispatch(
                &xq, col.rows, col.cols, wslice, cout_g, rowsum, sxw_g, zx, bslice, act,
                &mut out_mat, cout, g * cout_g,
            );
        } else {
            let wslice = &qw.data[g * cout_g * col.cols..(g + 1) * cout_g * col.cols];
            gemm_i8_dispatch(
                &xq, col.rows, col.cols, wslice, cout_g, rowsum, sxw_g, zx, bslice, act,
                &mut out_mat, cout, g * cout_g,
            );
        }
    }
    out_mat_to_nchw(&out_mat, n, cout, ho, wo, if bias_in_epilogue { None } else { bias })
}

// ---------------------------------------------------------------------------
// linear
// ---------------------------------------------------------------------------

/// Reference f32 linear: x (rows, din) @ w.T (dout, din) + b. Serial.
pub fn linear_f32(x: &[f32], rows: usize, din: usize, w: &Tensor, bias: Option<&Tensor>) -> Vec<f32> {
    let dout = w.shape[0];
    let mut out = vec![0.0f32; rows * dout];
    for r in 0..rows {
        let xrow = &x[r * din..(r + 1) * din];
        for o in 0..dout {
            let wrow = &w.data[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for k in 0..din {
                acc += xrow[k] * wrow[k];
            }
            if let Some(b) = bias {
                acc += b.data[o];
            }
            out[r * dout + o] = acc;
        }
    }
    out
}

/// Planned f32 linear: row-chunk parallel, 4-way output blocking, activation
/// epilogue. Plain (unblocked-k) accumulation, matching [`linear_f32`]
/// bit-for-bit per output.
#[allow(clippy::too_many_arguments)]
pub fn linear_f32_tiled(
    x: &[f32],
    rows: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * dout];
    let work = rows as u64 * din as u64 * dout as u64;
    par_row_chunks(rows, &mut out, dout, work, |r0, nr, chunk| {
        linear_f32_rows(&x[r0 * din..(r0 + nr) * din], nr, din, w, dout, bias, act, chunk);
    });
    out
}

#[allow(clippy::too_many_arguments)]
fn linear_f32_rows(
    x: &[f32],
    rows: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
) {
    for r in 0..rows {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut o = 0;
        while o + 4 <= dout {
            let w0 = &w[o * din..(o + 1) * din];
            let w1 = &w[(o + 1) * din..(o + 2) * din];
            let w2 = &w[(o + 2) * din..(o + 3) * din];
            let w3 = &w[(o + 3) * din..(o + 4) * din];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for k in 0..din {
                let xv = xrow[k];
                a0 += xv * w0[k];
                a1 += xv * w1[k];
                a2 += xv * w2[k];
                a3 += xv * w3[k];
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < dout {
            let wrow = &w[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for k in 0..din {
                acc += xrow[k] * wrow[k];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o] = apply_act(acc, act);
            o += 1;
        }
    }
}

/// Reference integer linear with asymmetric input quantization.
#[allow(clippy::too_many_arguments)]
pub fn linear_i8(
    x: &[f32],
    rows: usize,
    din: usize,
    qw: &QWeight,
    bias: Option<&Tensor>,
    sx: f32,
    zx: i32,
    round: RoundMode,
) -> Vec<f32> {
    let dout = qw.shape[0];
    let sxw = premul_scales(&qw.scales, dout, sx);
    linear_i8_inner(x, rows, din, qw, bias.map(|b| b.data.as_slice()), sx, zx, round, &sxw, None)
}

/// Planned integer linear: precomputed premultiplied scales + activation in
/// the requantization epilogue.
#[allow(clippy::too_many_arguments)]
pub fn linear_i8_fused(
    x: &[f32],
    rows: usize,
    din: usize,
    qw: &QWeight,
    bias: Option<&[f32]>,
    sx: f32,
    zx: i32,
    round: RoundMode,
    sxw: &[f32],
    act: Option<Act>,
) -> Vec<f32> {
    linear_i8_inner(x, rows, din, qw, bias, sx, zx, round, sxw, act)
}

#[allow(clippy::too_many_arguments)]
fn linear_i8_inner(
    x: &[f32],
    rows: usize,
    din: usize,
    qw: &QWeight,
    bias: Option<&[f32]>,
    sx: f32,
    zx: i32,
    round: RoundMode,
    sxw: &[f32],
    act: Option<Act>,
) -> Vec<f32> {
    let dout = qw.shape[0];
    let xq = quantize_slice(x, sx, zx, round);
    let mut out = vec![0.0f32; rows * dout];
    if qw.bits == 4 {
        gemm_i4_dispatch(&xq, rows, din, &qw.data, dout, &qw.row_sums, sxw, zx, bias, act, &mut out, dout, 0);
    } else {
        gemm_i8_dispatch(&xq, rows, din, &qw.data, dout, &qw.row_sums, sxw, zx, bias, act, &mut out, dout, 0);
    }
    out
}

// ---------------------------------------------------------------------------
// pooling
// ---------------------------------------------------------------------------

/// Max / average pooling (NCHW). A max window that is entirely padding
/// yields 0.0 (the padding value), matching every framework's semantics —
/// the seed returned f32::MIN there.
pub fn pool(a: &Tensor, k: usize, stride: usize, pad: usize, is_max: bool) -> Tensor {
    let mut out = Tensor::default();
    pool_into(a, k, stride, pad, is_max, &mut out);
    out
}

/// [`pool`] into a caller-owned tensor (allocation-free once warm).
pub fn pool_into(a: &Tensor, k: usize, stride: usize, pad: usize, is_max: bool, out: &mut Tensor) {
    let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    out.reset_for_overwrite(&[n, c, ho, wo]);
    for ni in 0..n {
        for ci in 0..c {
            let xc = &a.data[(ni * c + ci) * h * w..(ni * c + ci + 1) * h * w];
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = if is_max { f32::MIN } else { 0.0 };
                    let mut covered = false;
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = xc[iy as usize * w + ix as usize];
                            if is_max {
                                acc = acc.max(v);
                                covered = true;
                            } else {
                                acc += v;
                            }
                        }
                    }
                    if is_max && !covered {
                        acc = 0.0;
                    }
                    if !is_max {
                        acc /= (k * k) as f32;
                    }
                    out.data[((ni * c + ci) * ho + oy) * wo + ox] = acc;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// shared structural / normalization ops
//
// One definition each, executed by BOTH the legacy interpreter and the plan
// executor — same rationale as `pool`/`attention_ctx`: a numerical change in
// one path cannot silently miss the other.
// ---------------------------------------------------------------------------

/// Per-channel BN (scale, shift) from running stats:
/// scale = gamma / sqrt(var + eps), shift = beta - mean * scale.
pub fn bn_fold_params(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32], eps: f32) -> (Vec<f32>, Vec<f32>) {
    let c = gamma.len();
    let mut scale = vec![0.0f32; c];
    let mut shift = vec![0.0f32; c];
    for ci in 0..c {
        let inv = (var[ci] + eps).sqrt().recip();
        let s = gamma[ci] * inv;
        scale[ci] = s;
        shift[ci] = beta[ci] - mean[ci] * s;
    }
    (scale, shift)
}

/// Apply per-channel affine (BN) over NCHW-like data.
pub fn bn_apply(a: &Tensor, scale: &[f32], shift: &[f32]) -> Tensor {
    let mut out = Tensor::default();
    bn_apply_into(a, scale, shift, &mut out);
    out
}

/// [`bn_apply`] into a caller-owned tensor (allocation-free once warm).
pub fn bn_apply_into(a: &Tensor, scale: &[f32], shift: &[f32], out: &mut Tensor) {
    let c = scale.len();
    let spatial = a.len() / (a.shape[0] * c);
    out.reset_for_overwrite(&a.shape);
    for ni in 0..a.shape[0] {
        for ci in 0..c {
            let base = (ni * c + ci) * spatial;
            for i in 0..spatial {
                out.data[base + i] = a.data[base + i] * scale[ci] + shift[ci];
            }
        }
    }
}

/// Elementwise sum into a caller-owned tensor (shapes must match — the
/// executors check before calling). `out[i] = a[i] + b[i]`.
pub fn add_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    out.reset_for_overwrite(&a.shape);
    for (o, (&x, &y)) in out.data.iter_mut().zip(a.data.iter().zip(b.data.iter())) {
        *o = x + y;
    }
}

/// Elementwise product, broadcasting a (B, C, 1, 1) gate over (B, C, H, W)
/// when shapes differ (SE block).
pub fn mul_gate(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::default();
    mul_gate_into(a, b, &mut out);
    out
}

/// [`mul_gate`] into a caller-owned tensor (allocation-free once warm).
pub fn mul_gate_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    out.reset_for_overwrite(&a.shape);
    if a.shape == b.shape {
        for (o, (&x, &y)) in out.data.iter_mut().zip(a.data.iter().zip(b.data.iter())) {
            *o = x * y;
        }
        return;
    }
    let (bsz, c) = (a.shape[0], a.shape[1]);
    let spatial = a.len() / (bsz * c);
    for ni in 0..bsz {
        for ci in 0..c {
            let gate = b.data[ni * c + ci];
            let base = (ni * c + ci) * spatial;
            for i in 0..spatial {
                out.data[base + i] = a.data[base + i] * gate;
            }
        }
    }
}

/// In-place form of [`mul_gate`]: `out` already holds the left operand
/// (moved there by the liveness plan); applies the (possibly broadcast)
/// gate without a copy. Same arithmetic, same result bits.
pub fn mul_gate_assign(out: &mut Tensor, b: &Tensor) {
    if out.shape == b.shape {
        for (o, &y) in out.data.iter_mut().zip(b.data.iter()) {
            *o *= y;
        }
        return;
    }
    let (bsz, c) = (out.shape[0], out.shape[1]);
    let spatial = out.len() / (bsz * c);
    for ni in 0..bsz {
        for ci in 0..c {
            let gate = b.data[ni * c + ci];
            let base = (ni * c + ci) * spatial;
            for i in 0..spatial {
                out.data[base + i] *= gate;
            }
        }
    }
}

/// Global average pooling (B, C, H, W) -> (B, C, 1, 1).
pub fn gap(a: &Tensor) -> Tensor {
    let mut out = Tensor::default();
    gap_into(a, &mut out);
    out
}

/// [`gap`] into a caller-owned tensor (allocation-free once warm).
pub fn gap_into(a: &Tensor, out: &mut Tensor) {
    let (bsz, c) = (a.shape[0], a.shape[1]);
    let spatial = a.len() / (bsz * c);
    out.reset_for_overwrite(&[bsz, c, 1, 1]);
    for ni in 0..bsz {
        for ci in 0..c {
            let base = (ni * c + ci) * spatial;
            let s: f32 = a.data[base..base + spatial].iter().sum();
            out.data[ni * c + ci] = s / spatial as f32;
        }
    }
}

/// Nearest-neighbor 2x upsampling (NCHW).
pub fn upsample2x(a: &Tensor) -> Tensor {
    let mut out = Tensor::default();
    upsample2x_into(a, &mut out);
    out
}

/// [`upsample2x`] into a caller-owned tensor (allocation-free once warm).
pub fn upsample2x_into(a: &Tensor, out: &mut Tensor) {
    let (bsz, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    out.reset_for_overwrite(&[bsz, c, 2 * h, 2 * w]);
    for ni in 0..bsz {
        for ci in 0..c {
            for y in 0..2 * h {
                for xw in 0..2 * w {
                    out.data[((ni * c + ci) * 2 * h + y) * 2 * w + xw] =
                        a.data[((ni * c + ci) * h + y / 2) * w + xw / 2];
                }
            }
        }
    }
}

/// Channel concatenation of two NCHW tensors with equal spatial dims.
pub fn concat_channels(a: &Tensor, b: &Tensor) -> Tensor {
    let mut out = Tensor::default();
    concat_channels_into(a, b, &mut out);
    out
}

/// [`concat_channels`] into a caller-owned tensor (allocation-free once warm).
pub fn concat_channels_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    let (bsz, ca, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    let cb = b.shape[1];
    out.reset_for_overwrite(&[bsz, ca + cb, h, w]);
    let sp = h * w;
    for ni in 0..bsz {
        let oa = ni * (ca + cb) * sp;
        out.data[oa..oa + ca * sp].copy_from_slice(&a.data[ni * ca * sp..(ni + 1) * ca * sp]);
        out.data[oa + ca * sp..oa + (ca + cb) * sp]
            .copy_from_slice(&b.data[ni * cb * sp..(ni + 1) * cb * sp]);
    }
}

/// LayerNorm over the last dimension `d` (eps 1e-6, matching the JAX side).
pub fn layernorm(a: &Tensor, d: usize, gamma: &[f32], beta: &[f32]) -> Tensor {
    let mut out = Tensor::default();
    layernorm_into(a, d, gamma, beta, &mut out);
    out
}

/// [`layernorm`] into a caller-owned tensor (allocation-free once warm).
pub fn layernorm_into(a: &Tensor, d: usize, gamma: &[f32], beta: &[f32], out: &mut Tensor) {
    let rows = a.len() / d;
    out.reset_for_overwrite(&a.shape);
    for r in 0..rows {
        let row = &a.data[r * d..(r + 1) * d];
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = (var + 1e-6).sqrt().recip();
        for i in 0..d {
            out.data[r * d + i] = (row[i] - mean) * inv * gamma[i] + beta[i];
        }
    }
}

/// (B, C, H, W) -> (B, H*W, C) token layout.
pub fn to_tokens(a: &Tensor) -> Tensor {
    let mut out = Tensor::default();
    to_tokens_into(a, &mut out);
    out
}

/// [`to_tokens`] into a caller-owned tensor (allocation-free once warm).
pub fn to_tokens_into(a: &Tensor, out: &mut Tensor) {
    let (bsz, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    let t = h * w;
    out.reset_for_overwrite(&[bsz, t, c]);
    for ni in 0..bsz {
        for ci in 0..c {
            for p in 0..t {
                out.data[(ni * t + p) * c + ci] = a.data[(ni * c + ci) * t + p];
            }
        }
    }
}

/// Mean over the token dimension: (B, T, D) -> (B, D).
pub fn tokmean(a: &Tensor) -> Tensor {
    let mut out = Tensor::default();
    tokmean_into(a, &mut out);
    out
}

/// [`tokmean`] into a caller-owned tensor (allocation-free once warm).
pub fn tokmean_into(a: &Tensor, out: &mut Tensor) {
    let (bsz, t, d) = (a.shape[0], a.shape[1], a.shape[2]);
    // accumulates: start from zeros
    out.reset_zeroed(&[bsz, d]);
    for ni in 0..bsz {
        for p in 0..t {
            for i in 0..d {
                out.data[ni * d + i] += a.data[(ni * t + p) * d + i];
            }
        }
        for i in 0..d {
            out.data[ni * d + i] /= t as f32;
        }
    }
}

// ---------------------------------------------------------------------------
// activation quant-dequant (aq nodes)
// ---------------------------------------------------------------------------

/// 256-entry dequantization LUT for a static u8 range: lut[q] = (q - zp) * s.
pub fn aq_lut(scale: f32, zp: i32) -> [f32; 256] {
    let mut lut = [0.0f32; 256];
    for (q, e) in lut.iter_mut().enumerate() {
        *e = (q as f32 - zp as f32) * scale;
    }
    lut
}

/// In-place static quant-dequant of a slice through the u8 grid: arithmetic
/// quantization (rounding is input-dependent) + LUT dequantization. Value-
/// identical to the interpreter's `aq` formula, one multiply cheaper.
pub fn quant_dequant_slice(data: &mut [f32], scale: f32, zp: i32, round: RoundMode, lut: &[f32; 256]) {
    let zpf = zp as f32;
    for v in data.iter_mut() {
        let q = (round.round(*v / scale) + zpf).clamp(0.0, 255.0) as usize;
        *v = lut[q];
    }
}

// ---------------------------------------------------------------------------
// dynamic activation scaling (ActMode::DynInt8)
// ---------------------------------------------------------------------------

/// Per-tensor dynamic quantization parameters from the *live* activation
/// data (`ActMode::DynInt8`): a single min/max scan over the batch feeding
/// the same [`act_scale_zp`] grid construction the static path uses —
/// so a dynamic deployment needs no calibration dataset and no `act_ranges`
/// at all. Non-finite samples are skipped (one NaN frame must not poison
/// the scale); an empty or all-non-finite tensor degrades to the unit grid
/// around zero. Both executors call this exact function, which is what
/// keeps the dynamic path bit-exact between plan and interpreter.
pub fn dyn_qparams(data: &[f32]) -> (f32, i32) {
    let mut lo = f32::MAX;
    let mut hi = f32::MIN;
    for &v in data {
        if v.is_finite() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if lo > hi {
        lo = 0.0;
        hi = 1.0;
    }
    // same widening as the static path: the grid must represent zero, and a
    // degenerate (constant) tensor still gets a positive scale
    act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6))
}

/// Fused dynamic requantization for `aq` nodes: the range scan and the
/// in-place u8 quant-dequant run back to back in one kernel call — the
/// runtime-ranged analogue of [`quant_dequant_slice`], with no extra tensor
/// materialized between the scan and the requant. Returns the
/// (scale, zero_point) it used (surfaced for tests and diagnostics).
pub fn quant_dequant_dyn(data: &mut [f32], round: RoundMode) -> (f32, i32) {
    let (s, z) = dyn_qparams(data);
    let zpf = z as f32;
    for v in data.iter_mut() {
        let q = (round.round(*v / s) + zpf).clamp(0.0, 255.0);
        *v = (q - zpf) * s;
    }
    (s, z)
}

// ---------------------------------------------------------------------------
// attention core
// ---------------------------------------------------------------------------

/// Softmax attention scores + context over projected q/k/v rows
/// ((bsz*t, d) each, `heads` heads). Shared by the interpreter and the plan
/// executor so the two paths cannot drift (paper: softmax stays FP).
pub fn attention_ctx(q: &[f32], k: &[f32], v: &[f32], bsz: usize, t: usize, d: usize, heads: usize) -> Vec<f32> {
    let mut ctxt = Vec::new();
    let mut sc = Vec::new();
    attention_ctx_into(q, k, v, bsz, t, d, heads, &mut ctxt, &mut sc);
    ctxt
}

/// [`attention_ctx`] into caller-owned buffers: `ctxt` receives the
/// (bsz*t, d) context rows, `sc` is the per-query score scratch (len t,
/// fully rewritten per query — reuse keeps the hot path allocation-free).
/// Same accumulation order as the allocating form, bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn attention_ctx_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    bsz: usize,
    t: usize,
    d: usize,
    heads: usize,
    ctxt: &mut Vec<f32>,
    sc: &mut Vec<f32>,
) {
    let dh = d / heads;
    let rows = bsz * t;
    ctxt.clear();
    ctxt.resize(rows * d, 0.0);
    sc.resize(t, 0.0);
    let ctxt = ctxt.as_mut_slice();
    let sc = sc.as_mut_slice();
    let scale = 1.0 / (dh as f32).sqrt();
    for b_i in 0..bsz {
        for h_i in 0..heads {
            for ti in 0..t {
                let qoff = (b_i * t + ti) * d + h_i * dh;
                // scores over all source tokens (sc fully rewritten)
                let mut mx = f32::MIN;
                for tj in 0..t {
                    let koff = (b_i * t + tj) * d + h_i * dh;
                    let mut s = 0.0f32;
                    for e in 0..dh {
                        s += q[qoff + e] * k[koff + e];
                    }
                    sc[tj] = s * scale;
                    mx = mx.max(sc[tj]);
                }
                let mut denom = 0.0f32;
                for s in sc.iter_mut() {
                    *s = (*s - mx).exp();
                    denom += *s;
                }
                let coff = (b_i * t + ti) * d + h_i * dh;
                for tj in 0..t {
                    let a = sc[tj] / denom;
                    let voff = (b_i * t + tj) * d + h_i * dh;
                    for e in 0..dh {
                        ctxt[coff + e] += a * v[voff + e];
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{act_scale_zp, QuantScheme, Tensor};
    use crate::testutil::Rng;

    fn seq_tensor(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..n).map(|i| (i as f32) * 0.01 - 0.3).collect())
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights reproduces the input
        let x = seq_tensor(&[1, 2, 3, 3]);
        let w = Tensor::new(vec![2, 2, 1, 1], vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d_f32(&x, &w, None, 1, 0, 1);
        assert_eq!(y.shape, vec![1, 2, 3, 3]);
        for (a, b) in x.data.iter().zip(y.data.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_stride_and_pad_shapes() {
        let x = seq_tensor(&[2, 3, 8, 8]);
        let w = seq_tensor(&[4, 3, 3, 3]);
        let y = conv2d_f32(&x, &w, None, 2, 1, 1);
        assert_eq!(y.shape, vec![2, 4, 4, 4]);
    }

    #[test]
    fn depthwise_conv_groups() {
        let x = seq_tensor(&[1, 4, 5, 5]);
        let w = seq_tensor(&[4, 1, 3, 3]);
        let y = conv2d_f32(&x, &w, None, 1, 1, 4);
        assert_eq!(y.shape, vec![1, 4, 5, 5]);
        // group 0 output depends only on channel 0: perturb channel 3, check ch 0 output fixed
        let mut x2 = x.clone();
        for i in 3 * 25..4 * 25 {
            x2.data[i] += 1.0;
        }
        let y2 = conv2d_f32(&x2, &w, None, 1, 1, 4);
        assert_eq!(&y.data[..25], &y2.data[..25]);
        assert_ne!(&y.data[75..100], &y2.data[75..100]);
    }

    #[test]
    fn int8_conv_close_to_f32() {
        let x = seq_tensor(&[1, 3, 6, 6]).map(|v| v * 2.0 + 0.5);
        let w = seq_tensor(&[4, 3, 3, 3]).map(|v| v * 0.3);
        let yf = conv2d_f32(&x, &w, None, 1, 1, 1);
        let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let (lo, hi) = x.data.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let (sx, zx) = crate::tensor::act_scale_zp(lo, hi);
        let yq = conv2d_i8(&x, &qw, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
        let scale = yf.abs_max();
        for (a, b) in yf.data.iter().zip(yq.data.iter()) {
            assert!((a - b).abs() < scale * 0.05, "int8 conv drifted: {a} vs {b}");
        }
    }

    #[test]
    fn linear_matches_manual() {
        let w = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::new(vec![2], vec![0.5, -0.5]);
        let x = vec![1.0, 1.0, 1.0, 2.0, 0.0, -1.0];
        let y = linear_f32(&x, 2, 3, &w, Some(&b));
        // row2: [2,0,-1]·[1,2,3] = -1 + 0.5; [2,0,-1]·[4,5,6] = 2 - 0.5
        assert_eq!(y, vec![6.5, 14.5, -0.5, 1.5]);
    }

    #[test]
    fn int8_linear_close_to_f32() {
        let w = seq_tensor(&[8, 16]).map(|v| v * 0.2);
        let x: Vec<f32> = (0..32).map(|i| (i as f32) * 0.1 - 1.0).collect();
        let yf = linear_f32(&x, 2, 16, &w, None);
        let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let (sx, zx) = crate::tensor::act_scale_zp(-1.0, 2.2);
        let yq = linear_i8(&x, 2, 16, &qw, None, sx, zx, RoundMode::TiesEven);
        for (a, b) in yf.iter().zip(yq.iter()) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }

    #[test]
    fn tiled_f32_gemm_bit_matches_reference() {
        let mut rng = Rng::new(0x7E57);
        // odd sizes exercise the 4-way remainder and the k tail
        for (rows, cols, cout) in [(3, 70, 5), (17, 129, 9), (33, 64, 4)] {
            let x = rng.normal_vec(rows * cols, 1.0);
            let w = rng.normal_vec(cout * cols, 0.3);
            let col = Im2Col { rows, cols, data: x.clone() };
            let mut a = vec![0.0f32; rows * cout];
            gemm_f32(&col, &w, cout, &mut a, cout, 0);
            let mut b = vec![0.0f32; rows * cout];
            gemm_f32_tiled(&x, rows, cols, &w, cout, None, None, &mut b, cout, 0);
            assert_eq!(a, b, "tiled f32 gemm drifted at {rows}x{cols}x{cout}");
        }
    }

    #[test]
    fn tiled_linear_bit_matches_reference() {
        let mut rng = Rng::new(0x11E4);
        let (rows, din, dout) = (7, 37, 11);
        let w = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.2));
        let b = Tensor::new(vec![dout], rng.normal_vec(dout, 0.5));
        let x = rng.normal_vec(rows * din, 1.0);
        let a = linear_f32(&x, rows, din, &w, Some(&b));
        let t = linear_f32_tiled(&x, rows, din, &w.data, dout, Some(&b.data), None);
        assert_eq!(a, t);
    }

    #[test]
    fn fused_conv_epilogue_matches_separate_ops() {
        let mut rng = Rng::new(0xF00D);
        let x = Tensor::new(vec![2, 3, 7, 7], rng.normal_vec(2 * 3 * 49, 1.0));
        let w = Tensor::new(vec![6, 3, 3, 3], rng.normal_vec(6 * 27, 0.2));
        let b = Tensor::new(vec![6], rng.normal_vec(6, 0.3));
        let base = conv2d_f32(&x, &w, Some(&b), 1, 1, 1);
        let relu_after = base.map(|v| Act::Relu.apply(v));
        let fused = conv2d_f32_fused(&x, &w, Some(&b), 1, 1, 1, Some(Act::Relu));
        assert_eq!(relu_after.data, fused.data);

        // integer path: epilogue (bias + act inside the requant) must equal
        // the unfused kernel followed by the activation
        let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let (sx, zx) = act_scale_zp(-3.0, 3.0);
        let yq = conv2d_i8(&x, &qw, Some(&b), 1, 1, 1, sx, zx, RoundMode::TiesEven);
        let yq_relu = yq.map(|v| Act::Relu.apply(v));
        let sxw = premul_scales(&qw.scales, qw.shape[0], sx);
        let yq_fused =
            conv2d_i8_fused(&x, &qw, Some(&b), 1, 1, 1, sx, zx, RoundMode::TiesEven, &sxw, Some(Act::Relu));
        assert_eq!(yq_relu.data, yq_fused.data);
    }

    #[test]
    fn maxpool_all_padding_window_is_zero() {
        // k=1 s=2 p=1 on a 1x1 input: every window lands in padding. The seed
        // returned f32::MIN for those outputs.
        let x = Tensor::new(vec![1, 1, 1, 1], vec![-5.0]);
        let y = pool(&x, 1, 2, 1, true);
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        for &v in &y.data {
            assert_eq!(v, 0.0, "all-padding max window must be 0.0, got {v}");
        }
        // windows that do cover real pixels are unchanged
        let x2 = Tensor::new(vec![1, 1, 2, 2], vec![-1.0, -2.0, -3.0, -4.0]);
        let y2 = pool(&x2, 2, 1, 1, true);
        assert_eq!(y2.data[0], -1.0); // top-left window sees only x[0,0]
    }

    #[test]
    fn aq_lut_matches_arithmetic_dequant() {
        let (s, z) = act_scale_zp(-1.3, 2.7);
        let lut = aq_lut(s, z);
        let mut rng = Rng::new(0xA0);
        let mut data = rng.normal_vec(512, 1.5);
        let expect: Vec<f32> = data
            .iter()
            .map(|&v| {
                let q = (RoundMode::TiesEven.round(v / s) + z as f32).clamp(0.0, 255.0);
                (q - z as f32) * s
            })
            .collect();
        quant_dequant_slice(&mut data, s, z, RoundMode::TiesEven, &lut);
        assert_eq!(data, expect);
    }

    #[test]
    fn int4_conv_bit_matches_unpacked_int8_values() {
        // A 4-bit packed QWeight and an 8-bit QWeight holding the SAME
        // nibble values (same scales, same row sums) must produce bitwise
        // identical conv outputs: the packed kernel only changes how the
        // weights are stored, never the arithmetic.
        let mut rng = Rng::new(0x14B);
        // odd channel count and odd im2col width exercise the nibble tail
        let x = Tensor::new(vec![2, 3, 7, 7], rng.normal_vec(2 * 3 * 49, 1.0));
        let w = Tensor::new(vec![5, 3, 3, 3], rng.normal_vec(5 * 27, 0.2));
        let q4 = QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4);
        assert_eq!(q4.bits, 4);
        let q8_twin = QWeight::from_parts(q4.shape.clone(), q4.unpacked_data(), q4.scales.clone());
        assert_eq!(q4.row_sums, q8_twin.row_sums);
        let (sx, zx) = act_scale_zp(-3.0, 3.0);
        let y4 = conv2d_i8(&x, &q4, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
        let y8 = conv2d_i8(&x, &q8_twin, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
        assert_eq!(y4.data, y8.data, "packed int4 conv drifted from its unpacked twin");

        // fused epilogue on the int4 path == unfused + activation after
        let b = Tensor::new(vec![5], rng.normal_vec(5, 0.3));
        let base = conv2d_i8(&x, &q4, Some(&b), 1, 1, 1, sx, zx, RoundMode::TiesEven);
        let relu_after = base.map(|v| Act::Relu.apply(v));
        let sxw = premul_scales(&q4.scales, q4.shape[0], sx);
        let fused =
            conv2d_i8_fused(&x, &q4, Some(&b), 1, 1, 1, sx, zx, RoundMode::TiesEven, &sxw, Some(Act::Relu));
        assert_eq!(relu_after.data, fused.data);
    }

    #[test]
    fn int4_linear_bit_matches_unpacked_int8_values() {
        let mut rng = Rng::new(0x14C);
        // odd din exercises the packed-row tail nibble
        let (rows, din, dout) = (6, 37, 9);
        let w = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.2));
        let x = rng.normal_vec(rows * din, 1.0);
        let q4 = QWeight::quantize_bits(&w, QuantScheme::PerTensorSym, RoundMode::HalfAway, 4);
        let q8_twin = QWeight::from_parts(q4.shape.clone(), q4.unpacked_data(), q4.scales.clone());
        let (sx, zx) = act_scale_zp(-2.0, 2.5);
        let y4 = linear_i8(&x, rows, din, &q4, None, sx, zx, RoundMode::HalfAway);
        let y8 = linear_i8(&x, rows, din, &q8_twin, None, sx, zx, RoundMode::HalfAway);
        assert_eq!(y4, y8, "packed int4 linear drifted from its unpacked twin");
    }

    #[test]
    fn int4_conv_tracks_f32_within_coarser_noise() {
        // the 16-level grid is coarser than int8 but must stay a faithful
        // approximation on a well-scaled layer
        let x = seq_tensor(&[1, 3, 6, 6]).map(|v| v * 2.0 + 0.5);
        let w = seq_tensor(&[4, 3, 3, 3]).map(|v| v * 0.3);
        let yf = conv2d_f32(&x, &w, None, 1, 1, 1);
        let qw = QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, 4);
        let (lo, hi) = x.data.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let (sx, zx) = crate::tensor::act_scale_zp(lo, hi);
        let yq = conv2d_i8(&x, &qw, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
        let scale = yf.abs_max();
        for (a, b) in yf.data.iter().zip(yq.data.iter()) {
            assert!((a - b).abs() < scale * 0.25, "int4 conv drifted: {a} vs {b}");
        }
    }

    #[test]
    fn dyn_qparams_matches_static_grid_on_true_range() {
        // when the static range IS the tensor's own min/max, dynamic and
        // static quantization must land on the identical grid
        let mut rng = Rng::new(0xD7);
        let data = rng.normal_vec(512, 1.3);
        let (lo, hi) = data.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let expect = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
        assert_eq!(dyn_qparams(&data), expect);
    }

    #[test]
    fn dyn_qparams_skips_non_finite_and_survives_degenerate_input() {
        // a NaN/inf sample must not poison the scale
        let (s, z) = dyn_qparams(&[f32::NAN, -1.5, f32::INFINITY, 3.0]);
        assert_eq!((s, z), act_scale_zp(-1.5, 3.0));
        // empty / all-non-finite: fall back to the unit grid, never NaN
        for data in [&[][..], &[f32::NAN, f32::NEG_INFINITY][..]] {
            let (s, z) = dyn_qparams(data);
            assert!(s > 0.0 && s.is_finite() && (0..=255).contains(&z));
        }
        // constant tensor: positive scale, value survives the round trip
        let mut c = vec![5.0f32; 16];
        let (s, _) = quant_dequant_dyn(&mut c, RoundMode::TiesEven);
        assert!(s > 0.0);
        for &v in &c {
            assert!((v - 5.0).abs() <= s, "constant 5.0 drifted to {v}");
        }
    }

    #[test]
    fn quant_dequant_dyn_equals_static_at_observed_range() {
        let mut rng = Rng::new(0xD8);
        let data = rng.normal_vec(256, 0.8);
        let (lo, hi) = data.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
        let lut = aq_lut(s, z);
        let mut st = data.clone();
        quant_dequant_slice(&mut st, s, z, RoundMode::TiesEven, &lut);
        let mut dy = data.clone();
        let used = quant_dequant_dyn(&mut dy, RoundMode::TiesEven);
        assert_eq!(used, (s, z));
        assert_eq!(st, dy, "dynamic requant must reuse the static arithmetic");
    }

    #[test]
    fn packed_f32_conv_and_linear_bit_match_row_major() {
        let mut rng = Rng::new(0x9A11);
        // odd cout exercises the remainder-row path after full panels
        let x = Tensor::new(vec![2, 3, 7, 7], rng.normal_vec(2 * 3 * 49, 1.0));
        let w = Tensor::new(vec![6, 3, 3, 3], rng.normal_vec(6 * 27, 0.2));
        let b = Tensor::new(vec![6], rng.normal_vec(6, 0.3));
        let reference = conv2d_f32_fused(&x, &w, Some(&b), 1, 1, 1, Some(Act::Relu));
        let wp = PackedF32::pack(&w, 1);
        let (mut col, mut mat, mut out) = (Vec::new(), Vec::new(), Tensor::default());
        conv2d_f32_packed(
            &x, &wp, Some(&b.data), 1, 1, Some(Act::Relu), &mut col, &mut mat, &mut out,
        );
        assert_eq!(out.shape, reference.shape);
        assert_eq!(out.data, reference.data, "packed f32 conv drifted from row-major");

        // depthwise: cout_g == 1, every group is a remainder row
        let wd = Tensor::new(vec![3, 1, 3, 3], rng.normal_vec(27, 0.2));
        let refd = conv2d_f32_fused(&x, &wd, None, 1, 1, 3, None);
        let wpd = PackedF32::pack(&wd, 3);
        conv2d_f32_packed(&x, &wpd, None, 1, 1, None, &mut col, &mut mat, &mut out);
        assert_eq!(out.data, refd.data, "packed depthwise conv drifted");

        // linear: odd dout, plain accumulation must match linear_f32
        let (rows, din, dout) = (5, 37, 11);
        let wl = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.2));
        let bl = Tensor::new(vec![dout], rng.normal_vec(dout, 0.5));
        let xl = rng.normal_vec(rows * din, 1.0);
        let refl = linear_f32(&xl, rows, din, &wl, Some(&bl));
        let wpl = PackedF32::pack(&wl, 1);
        let mut outl = vec![0.0f32; rows * dout];
        linear_f32_packed(&xl, rows, &wpl, Some(&bl.data), None, &mut outl);
        assert_eq!(outl, refl, "packed f32 linear drifted from reference");
    }

    #[test]
    fn packed_int_conv_and_linear_bit_match_row_major() {
        let mut rng = Rng::new(0x9A12);
        let x = Tensor::new(vec![2, 3, 7, 7], rng.normal_vec(2 * 3 * 49, 1.0));
        // odd cout (panel tail) and odd im2col width (nibble tail)
        let w = Tensor::new(vec![5, 3, 3, 3], rng.normal_vec(5 * 27, 0.2));
        let b = Tensor::new(vec![5], rng.normal_vec(5, 0.3));
        let (sx, zx) = act_scale_zp(-3.0, 3.0);
        let (mut col, mut xq, mut mat, mut out) =
            (Vec::new(), Vec::new(), Vec::new(), Tensor::default());
        for bits in [8u8, 4] {
            let qw =
                QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits);
            let sxw = premul_scales(&qw.scales, qw.shape[0], sx);
            let reference = conv2d_i8_fused(
                &x, &qw, Some(&b), 1, 1, 1, sx, zx, RoundMode::TiesEven, &sxw, Some(Act::Relu),
            );
            let pw = PackedQW::pack(&qw, 1);
            assert_eq!(pw.bits, bits);
            conv2d_int_packed(
                &x, &pw, Some(&b.data), 1, 1, sx, zx, RoundMode::TiesEven, &sxw, Some(Act::Relu),
                &mut col, &mut xq, &mut mat, &mut out,
            );
            assert_eq!(out.shape, reference.shape);
            assert_eq!(out.data, reference.data, "packed int{bits} conv drifted from row-major");

            // linear with odd din (tail nibble) and odd dout (panel tail)
            let (rows, din, dout) = (6, 37, 9);
            let wl = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.2));
            let ql =
                QWeight::quantize_bits(&wl, QuantScheme::PerTensorSym, RoundMode::HalfAway, bits);
            let xl = rng.normal_vec(rows * din, 1.0);
            let sxwl = premul_scales(&ql.scales, dout, sx);
            let refl =
                linear_i8_fused(&xl, rows, din, &ql, None, sx, zx, RoundMode::HalfAway, &sxwl, None);
            let pl = PackedQW::pack(&ql, 1);
            let mut outl = vec![0.0f32; rows * dout];
            linear_int_packed(
                &xl, rows, &pl, None, sx, zx, RoundMode::HalfAway, &sxwl, None, &mut xq, &mut outl,
            );
            assert_eq!(outl, refl, "packed int{bits} linear drifted from row-major");
        }
    }

    #[test]
    fn into_kernels_reuse_buffers_without_reallocating() {
        let mut rng = Rng::new(0x9A13);
        let x = Tensor::new(vec![1, 4, 8, 8], rng.normal_vec(4 * 64, 1.0));
        let w = Tensor::new(vec![8, 4, 3, 3], rng.normal_vec(8 * 36, 0.2));
        let wp = PackedF32::pack(&w, 1);
        let (mut col, mut mat, mut out) = (Vec::new(), Vec::new(), Tensor::default());
        conv2d_f32_packed(&x, &wp, None, 1, 1, None, &mut col, &mut mat, &mut out);
        let caps = (col.capacity(), mat.capacity(), out.data.capacity());
        let first = out.data.clone();
        conv2d_f32_packed(&x, &wp, None, 1, 1, None, &mut col, &mut mat, &mut out);
        assert_eq!(out.data, first, "warm rerun changed the result");
        assert_eq!(
            (col.capacity(), mat.capacity(), out.data.capacity()),
            caps,
            "warm rerun grew a scratch buffer"
        );
    }

    #[test]
    fn pack_for_simd_tiers_stores_row_major_int_payload() {
        // SIMD tiers must keep the integer payload row-major (identity
        // pack); the scalar tier interleaves panels. pack_for itself is
        // layout-only, so this holds on every host architecture.
        let mut rng = Rng::new(0x9A14);
        let w = Tensor::new(vec![6, 8], rng.normal_vec(48, 0.2));
        for bits in [8u8, 4] {
            let qw =
                QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits);
            for tier in [KernelTier::Avx2, KernelTier::Neon] {
                let p = PackedQW::pack_for(&qw, 1, tier);
                assert_eq!(p.tier, tier);
                assert_eq!(p.data, qw.data, "int{bits} {tier:?} payload must be row-major");
            }
            let ps = PackedQW::pack_for(&qw, 1, KernelTier::Scalar);
            assert_ne!(ps.data, qw.data, "int{bits} scalar payload must be panel-interleaved");
        }
        // float panels share one layout across tiers
        let fs = PackedF32::pack_for(&w, 1, KernelTier::Scalar);
        let fv = PackedF32::pack_for(&w, 1, KernelTier::Avx2);
        assert_eq!(fs.data, fv.data, "f32 panel layout must be tier-independent");
    }

    #[test]
    fn simd_tier_bit_matches_scalar_tier_on_packed_kernels() {
        // When this machine has a SIMD tier, every packed entry point must
        // produce bit-identical outputs on it vs the scalar tier. (On a
        // scalar-only host — or under PALLAS_FORCE_SCALAR — both packs
        // resolve identically and the test is vacuous but still runs.)
        let tier = KernelTier::detect();
        let mut rng = Rng::new(0x9A15);
        let x = Tensor::new(vec![2, 3, 7, 7], rng.normal_vec(2 * 3 * 49, 1.0));
        // odd cout (panel/row tail) and odd im2col width (nibble tail)
        let w = Tensor::new(vec![5, 3, 3, 3], rng.normal_vec(5 * 27, 0.2));
        let b = Tensor::new(vec![5], rng.normal_vec(5, 0.3));
        let (sx, zx) = act_scale_zp(-3.0, 3.0);
        let (mut col, mut xq, mut mat) = (Vec::new(), Vec::new(), Vec::new());
        let (mut out_s, mut out_v) = (Tensor::default(), Tensor::default());
        for bits in [8u8, 4] {
            let qw =
                QWeight::quantize_bits(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven, bits);
            let sxw = premul_scales(&qw.scales, qw.shape[0], sx);
            let ps = PackedQW::pack_for(&qw, 1, KernelTier::Scalar);
            let pv = PackedQW::pack_for(&qw, 1, tier);
            conv2d_int_packed(
                &x, &ps, Some(&b.data), 1, 1, sx, zx, RoundMode::TiesEven, &sxw, Some(Act::Relu),
                &mut col, &mut xq, &mut mat, &mut out_s,
            );
            conv2d_int_packed(
                &x, &pv, Some(&b.data), 1, 1, sx, zx, RoundMode::TiesEven, &sxw, Some(Act::Relu),
                &mut col, &mut xq, &mut mat, &mut out_v,
            );
            assert_eq!(
                out_s.data, out_v.data,
                "int{bits} conv: {tier:?} tier drifted from scalar tier"
            );

            // linear with odd din (tail nibble / k % 16 != 0) and odd dout
            let (rows, din, dout) = (6, 37, 9);
            let wl = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.2));
            let ql =
                QWeight::quantize_bits(&wl, QuantScheme::PerTensorSym, RoundMode::HalfAway, bits);
            let xl = rng.normal_vec(rows * din, 1.0);
            let sxwl = premul_scales(&ql.scales, dout, sx);
            let ls = PackedQW::pack_for(&ql, 1, KernelTier::Scalar);
            let lv = PackedQW::pack_for(&ql, 1, tier);
            let (mut outl_s, mut outl_v) = (vec![0.0f32; rows * dout], vec![0.0f32; rows * dout]);
            linear_int_packed(
                &xl, rows, &ls, None, sx, zx, RoundMode::HalfAway, &sxwl, None, &mut xq,
                &mut outl_s,
            );
            linear_int_packed(
                &xl, rows, &lv, None, sx, zx, RoundMode::HalfAway, &sxwl, None, &mut xq,
                &mut outl_v,
            );
            assert_eq!(
                outl_s, outl_v,
                "int{bits} linear: {tier:?} tier drifted from scalar tier"
            );
        }

        // float path: lane-wise panel vectorization must replay the scalar
        // accumulation order exactly
        let fs = PackedF32::pack_for(&w, 1, KernelTier::Scalar);
        let fv = PackedF32::pack_for(&w, 1, tier);
        conv2d_f32_packed(
            &x, &fs, Some(&b.data), 1, 1, Some(Act::Relu), &mut col, &mut mat, &mut out_s,
        );
        conv2d_f32_packed(
            &x, &fv, Some(&b.data), 1, 1, Some(Act::Relu), &mut col, &mut mat, &mut out_v,
        );
        assert_eq!(out_s.data, out_v.data, "f32 conv: {tier:?} tier drifted from scalar tier");

        let (rows, din, dout) = (5, 67, 11);
        let wl = Tensor::new(vec![dout, din], rng.normal_vec(dout * din, 0.2));
        let xl = rng.normal_vec(rows * din, 1.0);
        let ls = PackedF32::pack_for(&wl, 1, KernelTier::Scalar);
        let lv = PackedF32::pack_for(&wl, 1, tier);
        let (mut outl_s, mut outl_v) = (vec![0.0f32; rows * dout], vec![0.0f32; rows * dout]);
        linear_f32_packed(&xl, rows, &ls, None, None, &mut outl_s);
        linear_f32_packed(&xl, rows, &lv, None, None, &mut outl_v);
        assert_eq!(outl_s, outl_v, "f32 linear: {tier:?} tier drifted from scalar tier");
    }

    #[test]
    fn gemm_i8_wrapper_matches_precomputed_path() {
        let mut rng = Rng::new(0x18);
        let (rows, cols, cout) = (9, 33, 6);
        let xq: Vec<u8> = (0..rows * cols).map(|_| rng.below(256) as u8).collect();
        let wq: Vec<i8> = (0..cout * cols).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let scales = vec![0.013f32; 1]; // per-tensor
        let (sx, zx) = (0.02f32, 117);
        let mut a = vec![0.0f32; rows * cout];
        gemm_i8(&xq, rows, cols, &wq, cout, &scales, sx, zx, None, &mut a, cout, 0);
        let rowsum = row_sums_of(&wq, cout);
        let sxw = premul_scales(&scales, cout, sx);
        let mut b = vec![0.0f32; rows * cout];
        gemm_i8_dispatch(&xq, rows, cols, &wq, cout, &rowsum, &sxw, zx, None, None, &mut b, cout, 0);
        assert_eq!(a, b);
    }
}
