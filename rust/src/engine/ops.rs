//! Compute kernels for the deployment engine: f32 reference paths and the
//! bit-exact integer (i8 x i8 -> i32) paths that simulate NPU arithmetic.
//!
//! Convolution is im2col + GEMM in both precisions; the integer GEMM uses the
//! zero-point factorization  sum((xq-zx)*wq) = sum(xq*wq) - zx*sum(wq)  so the
//! inner loop is a plain i32 dot product (this is also what real INT8 NPU
//! pipelines do — the row-sum correction is precomputed per output channel).

use crate::tensor::{QWeight, RoundMode, Tensor};

/// im2col for NCHW input: output rows = N*Ho*Wo, cols = (Cin/g)*kh*kw,
/// one matrix per group.
pub struct Im2Col {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
pub fn im2col_group(
    x: &Tensor,
    group: usize,
    groups: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    ho: usize,
    wo: usize,
) -> Im2Col {
    let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let cg = c / groups;
    let c0 = group * cg;
    let rows = n * ho * wo;
    let cols = cg * kh * kw;
    let mut data = vec![0.0f32; rows * cols];
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = (ni * ho + oy) * wo + ox;
                let base = row * cols;
                for ci in 0..cg {
                    let xc = &x.data[((ni * c) + c0 + ci) * h * w..((ni * c) + c0 + ci + 1) * h * w];
                    for ky in 0..kh {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        let iy = iy as usize;
                        for kx in 0..kw {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            data[base + (ci * kh + ky) * kw + kx] = xc[iy * w + ix as usize];
                        }
                    }
                }
            }
        }
    }
    Im2Col { rows, cols, data }
}

/// f32 GEMM: out[r][o] += sum_k col[r][k] * w[o][k]; w is (cout_g, cols).
pub fn gemm_f32(col: &Im2Col, w: &[f32], cout_g: usize, out: &mut [f32], out_stride: usize, o0: usize) {
    const BK: usize = 64;
    for r in 0..col.rows {
        let crow = &col.data[r * col.cols..(r + 1) * col.cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        for o in 0..cout_g {
            let wrow = &w[o * col.cols..(o + 1) * col.cols];
            let mut acc = 0.0f32;
            let mut k = 0;
            while k + BK <= col.cols {
                let mut s = 0.0f32;
                for i in 0..BK {
                    s += crow[k + i] * wrow[k + i];
                }
                acc += s;
                k += BK;
            }
            for i in k..col.cols {
                acc += crow[i] * wrow[i];
            }
            orow[o0 + o] = acc;
        }
    }
}

/// Quantize an f32 im2col buffer to u8 (asymmetric per-tensor).
pub fn quantize_cols(col: &Im2Col, scale: f32, zp: i32, round: RoundMode) -> Vec<u8> {
    col.data
        .iter()
        .map(|&v| (round.round(v / scale) + zp as f32).clamp(0.0, 255.0) as u8)
        .collect()
}

/// Integer GEMM with zero-point factorization.
/// out[r][o0+o] = sw[o]*sx * ( sum_k xq[r][k]*wq[o][k]  -  zx * rowsum_w[o] ) + bias[o]
#[allow(clippy::too_many_arguments)]
pub fn gemm_i8(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    w_scales: &[f32],
    sx: f32,
    zx: i32,
    bias: Option<&[f32]>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    // per-output-channel weight row sums (the zero-point correction)
    let mut rowsum = vec![0i32; cout_g];
    for o in 0..cout_g {
        let mut s = 0i32;
        for &w in &wq[o * cols..(o + 1) * cols] {
            s += w as i32;
        }
        rowsum[o] = s;
    }
    // §Perf iteration 3: parallelize across row chunks (disjoint outputs)
    // when the problem is large enough to amortize thread spawn
    let work = rows as u64 * cols as u64 * cout_g as u64;
    if work > 4_000_000 && rows >= 8 {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
        let chunk = rows.div_ceil(threads);
        let rowsum_ref = &rowsum;
        std::thread::scope(|scope| {
            let mut rest: &mut [f32] = out;
            let mut r0 = 0usize;
            while r0 < rows {
                let take = chunk.min(rows - r0);
                let (mine, tail) = rest.split_at_mut(take * out_stride);
                rest = tail;
                let start = r0;
                scope.spawn(move || {
                    gemm_i8_rows(
                        &xq[start * cols..(start + take) * cols],
                        take, cols, wq, cout_g, rowsum_ref, w_scales, sx, zx, bias, mine,
                        out_stride, o0,
                    );
                });
                r0 += take;
            }
        });
        return;
    }
    gemm_i8_rows(xq, rows, cols, wq, cout_g, &rowsum, w_scales, sx, zx, bias, out, out_stride, o0);
}

/// Serial row-range kernel behind `gemm_i8`.
#[allow(clippy::too_many_arguments)]
fn gemm_i8_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    w_scales: &[f32],
    sx: f32,
    zx: i32,
    bias: Option<&[f32]>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    // 4-way output-channel register blocking: the x row stays hot in L1 and
    // four i32 accumulators amortize its loads (§Perf iteration 1; the i16
    // hoist and 8-way variants measured worse — see EXPERIMENTS.md §Perf)
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * cols..(o + 1) * cols];
            let w1 = &wq[(o + 1) * cols..(o + 2) * cols];
            let w2 = &wq[(o + 2) * cols..(o + 3) * cols];
            let w3 = &wq[(o + 3) * cols..(o + 4) * cols];
            let (mut a0, mut a1, mut a2, mut a3) = (0i32, 0i32, 0i32, 0i32);
            for k in 0..cols {
                let x = xrow[k] as i32;
                a0 += x * w0[k] as i32;
                a1 += x * w1[k] as i32;
                a2 += x * w2[k] as i32;
                a3 += x * w3[k] as i32;
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let s = w_scales[oo.min(w_scales.len() - 1)] * sx;
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = corrected as f32 * s + b;
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * cols..(o + 1) * cols];
            let mut acc = 0i32;
            for k in 0..cols {
                acc += xrow[k] as i32 * wrow[k] as i32;
            }
            acc -= zx * rowsum[o];
            let s = w_scales[o.min(w_scales.len() - 1)] * sx;
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = acc as f32 * s + b;
            o += 1;
        }
    }
}

/// f32 convolution (NCHW, OIHW weights, groups).
pub fn conv2d_f32(
    x: &Tensor,
    w: &Tensor,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
) -> Tensor {
    let n = x.shape[0];
    let (cout, _cg, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    let (h, wdim) = (x.shape[2], x.shape[3]);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wdim + 2 * pad - kw) / stride + 1;
    let cout_g = cout / groups;
    let mut out_mat = vec![0.0f32; n * ho * wo * cout];
    for g in 0..groups {
        let col = im2col_group(x, g, groups, kh, kw, stride, pad, ho, wo);
        let wslice = &w.data[g * cout_g * col.cols..(g + 1) * cout_g * col.cols];
        gemm_f32(&col, wslice, cout_g, &mut out_mat, cout, g * cout_g);
    }
    let mut out = Tensor::zeros(&[n, cout, ho, wo]);
    // out_mat is (N*Ho*Wo, Cout) -> NCHW
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let r = (ni * ho + oy) * wo + ox;
                for o in 0..cout {
                    let mut v = out_mat[r * cout + o];
                    if let Some(b) = bias {
                        v += b.data[o];
                    }
                    out.data[((ni * cout + o) * ho + oy) * wo + ox] = v;
                }
            }
        }
    }
    out
}

/// Integer (W8/A8) convolution: quantizes the input with (sx, zx), uses the
/// pre-quantized weights, accumulates i32, dequantizes to f32 output.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_i8(
    x: &Tensor,
    qw: &QWeight,
    bias: Option<&Tensor>,
    stride: usize,
    pad: usize,
    groups: usize,
    sx: f32,
    zx: i32,
    round: RoundMode,
) -> Tensor {
    let n = x.shape[0];
    let (cout, _cg, kh, kw) = (qw.shape[0], qw.shape[1], qw.shape[2], qw.shape[3]);
    let (h, wdim) = (x.shape[2], x.shape[3]);
    let ho = (h + 2 * pad - kh) / stride + 1;
    let wo = (wdim + 2 * pad - kw) / stride + 1;
    let cout_g = cout / groups;
    let mut out_mat = vec![0.0f32; n * ho * wo * cout];
    for g in 0..groups {
        let col = im2col_group(x, g, groups, kh, kw, stride, pad, ho, wo);
        let xq = quantize_cols(&col, sx, zx, round);
        let wslice = &qw.data[g * cout_g * col.cols..(g + 1) * cout_g * col.cols];
        let sl = if qw.scales.len() == 1 {
            qw.scales.clone()
        } else {
            qw.scales[g * cout_g..(g + 1) * cout_g].to_vec()
        };
        gemm_i8(
            &xq, col.rows, col.cols, wslice, cout_g, &sl, sx, zx, None, &mut out_mat, cout,
            g * cout_g,
        );
    }
    let mut out = Tensor::zeros(&[n, cout, ho, wo]);
    for ni in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let r = (ni * ho + oy) * wo + ox;
                for o in 0..cout {
                    let mut v = out_mat[r * cout + o];
                    if let Some(b) = bias {
                        v += b.data[o];
                    }
                    out.data[((ni * cout + o) * ho + oy) * wo + ox] = v;
                }
            }
        }
    }
    out
}

/// f32 linear: x (rows, din) @ w.T (dout, din) + b.
pub fn linear_f32(x: &[f32], rows: usize, din: usize, w: &Tensor, bias: Option<&Tensor>) -> Vec<f32> {
    let dout = w.shape[0];
    let mut out = vec![0.0f32; rows * dout];
    for r in 0..rows {
        let xrow = &x[r * din..(r + 1) * din];
        for o in 0..dout {
            let wrow = &w.data[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for k in 0..din {
                acc += xrow[k] * wrow[k];
            }
            if let Some(b) = bias {
                acc += b.data[o];
            }
            out[r * dout + o] = acc;
        }
    }
    out
}

/// Integer linear with asymmetric input quantization.
#[allow(clippy::too_many_arguments)]
pub fn linear_i8(
    x: &[f32],
    rows: usize,
    din: usize,
    qw: &QWeight,
    bias: Option<&Tensor>,
    sx: f32,
    zx: i32,
    round: RoundMode,
) -> Vec<f32> {
    let dout = qw.shape[0];
    let xq: Vec<u8> = x
        .iter()
        .map(|&v| (round.round(v / sx) + zx as f32).clamp(0.0, 255.0) as u8)
        .collect();
    let mut out = vec![0.0f32; rows * dout];
    let bias_slice = bias.map(|b| b.data.as_slice());
    gemm_i8(&xq, rows, din, &qw.data, dout, &qw.scales, sx, zx, bias_slice, &mut out, dout, 0);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{QuantScheme, Tensor};

    fn seq_tensor(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..n).map(|i| (i as f32) * 0.01 - 0.3).collect())
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 conv with identity weights reproduces the input
        let x = seq_tensor(&[1, 2, 3, 3]);
        let w = Tensor::new(vec![2, 2, 1, 1], vec![1.0, 0.0, 0.0, 1.0]);
        let y = conv2d_f32(&x, &w, None, 1, 0, 1);
        assert_eq!(y.shape, vec![1, 2, 3, 3]);
        for (a, b) in x.data.iter().zip(y.data.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_stride_and_pad_shapes() {
        let x = seq_tensor(&[2, 3, 8, 8]);
        let w = seq_tensor(&[4, 3, 3, 3]);
        let y = conv2d_f32(&x, &w, None, 2, 1, 1);
        assert_eq!(y.shape, vec![2, 4, 4, 4]);
    }

    #[test]
    fn depthwise_conv_groups() {
        let x = seq_tensor(&[1, 4, 5, 5]);
        let w = seq_tensor(&[4, 1, 3, 3]);
        let y = conv2d_f32(&x, &w, None, 1, 1, 4);
        assert_eq!(y.shape, vec![1, 4, 5, 5]);
        // group 0 output depends only on channel 0: perturb channel 3, check ch 0 output fixed
        let mut x2 = x.clone();
        for i in 3 * 25..4 * 25 {
            x2.data[i] += 1.0;
        }
        let y2 = conv2d_f32(&x2, &w, None, 1, 1, 4);
        assert_eq!(&y.data[..25], &y2.data[..25]);
        assert_ne!(&y.data[75..100], &y2.data[75..100]);
    }

    #[test]
    fn int8_conv_close_to_f32() {
        let x = seq_tensor(&[1, 3, 6, 6]).map(|v| v * 2.0 + 0.5);
        let w = seq_tensor(&[4, 3, 3, 3]).map(|v| v * 0.3);
        let yf = conv2d_f32(&x, &w, None, 1, 1, 1);
        let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let (lo, hi) = x.data.iter().fold((f32::MAX, f32::MIN), |(l, h), &v| (l.min(v), h.max(v)));
        let (sx, zx) = crate::tensor::act_scale_zp(lo, hi);
        let yq = conv2d_i8(&x, &qw, None, 1, 1, 1, sx, zx, RoundMode::TiesEven);
        let scale = yf.abs_max();
        for (a, b) in yf.data.iter().zip(yq.data.iter()) {
            assert!((a - b).abs() < scale * 0.05, "int8 conv drifted: {a} vs {b}");
        }
    }

    #[test]
    fn linear_matches_manual() {
        let w = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::new(vec![2], vec![0.5, -0.5]);
        let x = vec![1.0, 1.0, 1.0, 2.0, 0.0, -1.0];
        let y = linear_f32(&x, 2, 3, &w, Some(&b));
        // row2: [2,0,-1]·[1,2,3] = -1 + 0.5; [2,0,-1]·[4,5,6] = 2 - 0.5
        assert_eq!(y, vec![6.5, 14.5, -0.5, 1.5]);
    }

    #[test]
    fn int8_linear_close_to_f32() {
        let w = seq_tensor(&[8, 16]).map(|v| v * 0.2);
        let x: Vec<f32> = (0..32).map(|i| (i as f32) * 0.1 - 1.0).collect();
        let yf = linear_f32(&x, 2, 16, &w, None);
        let qw = QWeight::quantize(&w, QuantScheme::PerChannelSym, RoundMode::TiesEven);
        let (sx, zx) = crate::tensor::act_scale_zp(-1.0, 2.2);
        let yq = linear_i8(&x, 2, 16, &qw, None, sx, zx, RoundMode::TiesEven);
        for (a, b) in yf.iter().zip(yq.iter()) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }
}
