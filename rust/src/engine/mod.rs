//! Deployment inference engine: executes a QIR graph at the precision a
//! simulated vendor backend chose.
//!
//! Precision model (matches how real NPU toolchains behave at tensor
//! granularity):
//! * weights: f32, or pre-quantized i8 (per-channel or per-tensor symmetric)
//! * activations: f32, bf16/f16 round-trips at op boundaries, or asymmetric
//!   u8 with *static* per-node ranges fixed at compile time (calibration or
//!   embedded QAT scales) — "STATIC (no runtime dyn)" in paper Table 4.
//! * integer compute paths accumulate in i32 (ops.rs); softmax / layernorm /
//!   SE gates stay in float, as on real NPUs.

pub mod lowp;
pub mod ops;

use std::collections::{BTreeMap, HashMap};

use anyhow::{bail, Context, Result};

use crate::qir::{Graph, Node};
use crate::tensor::{act_scale_zp, QWeight, RoundMode, Tensor};

/// Weight precision chosen by a backend compiler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightMode {
    F32,
    Int8,
}

/// Activation precision chosen by a backend compiler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActMode {
    F32,
    Bf16,
    F16,
    /// Static asymmetric u8 with compile-time ranges.
    Int8 { round: RoundMode },
}

#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    pub weight_mode: WeightMode,
    pub act_mode: ActMode,
}

impl ExecConfig {
    pub const FP32: ExecConfig = ExecConfig { weight_mode: WeightMode::F32, act_mode: ActMode::F32 };
}

/// A backend-compiled model: transformed graph + prepared weights + static
/// activation ranges. Produced by `backends::*`, executed here.
pub struct CompiledModel {
    pub graph: Graph,
    /// Float parameters (post graph passes, e.g. BN-folded).
    pub params: BTreeMap<String, Tensor>,
    /// BN running stats for graphs that keep explicit bn nodes.
    pub bn: BTreeMap<String, Tensor>,
    /// Pre-quantized weights keyed by param key (e.g. "s0.b0.c1.w").
    pub qweights: HashMap<String, QWeight>,
    /// Static per-node output ranges (lo, hi) from calibration / QAT scales.
    pub act_ranges: HashMap<String, (f32, f32)>,
    pub cfg: ExecConfig,
}

const BN_EPS: f32 = 1e-5;

impl CompiledModel {
    /// Run and return the graph outputs.
    pub fn run(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        let mut sink = |_: &str, _: &Tensor| {};
        self.run_inner(x, &mut sink)
    }

    /// Run, invoking `observe(node_name, output)` on every node output
    /// (used by calibration and by the distribution metrics).
    pub fn run_observe(
        &self,
        x: &Tensor,
        observe: &mut dyn FnMut(&str, &Tensor),
    ) -> Result<Vec<Tensor>> {
        self.run_inner(x, observe)
    }

    fn narrow(&self, mut t: Tensor) -> Tensor {
        match self.cfg.act_mode {
            ActMode::Bf16 => lowp::narrow_slice(&mut t.data, lowp::bf16),
            ActMode::F16 => lowp::narrow_slice(&mut t.data, lowp::f16),
            _ => {}
        }
        t
    }

    /// (scale, zero_point) for quantizing the *input* of a compute node,
    /// taken from the producer's static range.
    fn input_qparams(&self, producer: &str) -> Result<(f32, i32)> {
        let &(lo, hi) = self
            .act_ranges
            .get(producer)
            .with_context(|| format!("no calibrated range for node {producer}"))?;
        Ok(act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6)))
    }

    fn int8_round(&self) -> Option<RoundMode> {
        match self.cfg.act_mode {
            ActMode::Int8 { round } => Some(round),
            _ => None,
        }
    }

    fn weight_tensor(&self, key: &str) -> Result<Tensor> {
        if self.cfg.weight_mode == WeightMode::Int8 {
            if let Some(qw) = self.qweights.get(key) {
                return Ok(qw.dequantize());
            }
        }
        self.params.get(key).cloned().with_context(|| format!("missing param {key}"))
    }

    fn run_inner(
        &self,
        x: &Tensor,
        observe: &mut dyn FnMut(&str, &Tensor),
    ) -> Result<Vec<Tensor>> {
        let mut vals: HashMap<String, Tensor> = HashMap::new();
        let mut remaining = self.graph.consumer_counts();
        for n in &self.graph.nodes {
            let out = self.eval_node(n, &vals, x)?;
            observe(&n.name, &out);
            vals.insert(n.name.clone(), out);
            // free dead inputs
            for i in &n.inputs {
                if let Some(c) = remaining.get_mut(i.as_str()) {
                    *c -= 1;
                    if *c == 0 && !self.graph.outputs.contains(i) {
                        vals.remove(i.as_str());
                    }
                }
            }
        }
        self.graph
            .outputs
            .iter()
            .map(|o| vals.get(o).cloned().with_context(|| format!("missing output {o}")))
            .collect()
    }

    fn eval_node(&self, n: &Node, vals: &HashMap<String, Tensor>, x: &Tensor) -> Result<Tensor> {
        let get = |i: usize| -> Result<&Tensor> {
            vals.get(&n.inputs[i]).with_context(|| format!("missing value {}", n.inputs[i]))
        };
        let out = match n.kind.as_str() {
            "input" => x.clone(),
            "conv2d" => {
                let a = get(0)?;
                let stride = n.attr_usize("stride")?;
                let pad = n.attr_usize("pad")?;
                let groups = n.attr_usize("groups")?;
                let bias = if n.attr_bool("bias") {
                    Some(self.params.get(&format!("{}.b", n.name)).context("missing bias")?)
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                match (self.cfg.weight_mode, self.int8_round(), self.qweights.get(&wkey)) {
                    (WeightMode::Int8, Some(round), Some(qw)) => {
                        let (sx, zx) = self.input_qparams(&n.inputs[0])?;
                        ops::conv2d_i8(a, qw, bias, stride, pad, groups, sx, zx, round)
                    }
                    _ => {
                        let w = self.weight_tensor(&wkey)?;
                        self.narrow(ops::conv2d_f32(a, &w, bias, stride, pad, groups))
                    }
                }
            }
            "linear" => {
                let a = get(0)?;
                let din = n.attr_usize("din")?;
                let rows = a.len() / din;
                let bias = if n.attr_bool("bias") {
                    self.params.get(&format!("{}.b", n.name))
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                let dout = n.attr_usize("dout")?;
                let mut oshape = a.shape.clone();
                *oshape.last_mut().unwrap() = dout;
                let data = match (self.cfg.weight_mode, self.int8_round(), self.qweights.get(&wkey)) {
                    (WeightMode::Int8, Some(round), Some(qw)) => {
                        let (sx, zx) = self.input_qparams(&n.inputs[0])?;
                        ops::linear_i8(&a.data, rows, din, qw, bias, sx, zx, round)
                    }
                    _ => {
                        let w = self.weight_tensor(&wkey)?;
                        ops::linear_f32(&a.data, rows, din, &w, bias)
                    }
                };
                self.narrow(Tensor::new(oshape, data))
            }
            "bn" => {
                let a = get(0)?;
                let g = &self.params[&format!("{}.gamma", n.name)];
                let b = &self.params[&format!("{}.beta", n.name)];
                let mean = &self.bn[&format!("{}.mean", n.name)];
                let var = &self.bn[&format!("{}.var", n.name)];
                let c = g.len();
                let mut out = a.clone();
                let spatial = a.len() / (a.shape[0] * c);
                for ni in 0..a.shape[0] {
                    for ci in 0..c {
                        let inv = (var.data[ci] + BN_EPS).sqrt().recip();
                        let scale = g.data[ci] * inv;
                        let shift = b.data[ci] - mean.data[ci] * scale;
                        let base = (ni * c + ci) * spatial;
                        for i in 0..spatial {
                            out.data[base + i] = a.data[base + i] * scale + shift;
                        }
                    }
                }
                self.narrow(out)
            }
            "relu" => self.narrow(get(0)?.map(|v| v.max(0.0))),
            "relu6" => self.narrow(get(0)?.map(|v| v.clamp(0.0, 6.0))),
            "hswish" => self.narrow(get(0)?.map(|v| v * (v + 3.0).clamp(0.0, 6.0) / 6.0)),
            "hsigmoid" => self.narrow(get(0)?.map(|v| (v + 3.0).clamp(0.0, 6.0) / 6.0)),
            "sigmoid" => self.narrow(get(0)?.map(|v| 1.0 / (1.0 + (-v).exp()))),
            "silu" => self.narrow(get(0)?.map(|v| v / (1.0 + (-v).exp()))),
            "gelu" => self.narrow(get(0)?.map(|v| {
                let c = (2.0f32 / std::f32::consts::PI).sqrt();
                0.5 * v * (1.0 + (c * (v + 0.044715 * v * v * v)).tanh())
            })),
            "add" => {
                let (a, b) = (get(0)?, get(1)?);
                if a.shape != b.shape {
                    bail!("add shape mismatch at {}", n.name);
                }
                let data = a.data.iter().zip(b.data.iter()).map(|(x, y)| x + y).collect();
                self.narrow(Tensor::new(a.shape.clone(), data))
            }
            "mul" => {
                let (a, b) = (get(0)?, get(1)?);
                let out = if a.shape == b.shape {
                    let data = a.data.iter().zip(b.data.iter()).map(|(x, y)| x * y).collect();
                    Tensor::new(a.shape.clone(), data)
                } else {
                    // broadcast (B, C, 1, 1) gate over (B, C, H, W) — SE block
                    let (bsz, c) = (a.shape[0], a.shape[1]);
                    let spatial = a.len() / (bsz * c);
                    let mut out = a.clone();
                    for ni in 0..bsz {
                        for ci in 0..c {
                            let gate = b.data[ni * c + ci];
                            let base = (ni * c + ci) * spatial;
                            for i in 0..spatial {
                                out.data[base + i] *= gate;
                            }
                        }
                    }
                    out
                };
                self.narrow(out)
            }
            "maxpool" | "avgpool" => self.narrow(pool(
                get(0)?,
                n.attr_usize("k")?,
                n.attr_usize("stride")?,
                n.attr_usize("pad")?,
                n.kind == "maxpool",
            )),
            "gap" => {
                let a = get(0)?;
                let (bsz, c) = (a.shape[0], a.shape[1]);
                let spatial = a.len() / (bsz * c);
                let mut out = Tensor::zeros(&[bsz, c, 1, 1]);
                for ni in 0..bsz {
                    for ci in 0..c {
                        let base = (ni * c + ci) * spatial;
                        let s: f32 = a.data[base..base + spatial].iter().sum();
                        out.data[ni * c + ci] = s / spatial as f32;
                    }
                }
                self.narrow(out)
            }
            "upsample2x" => {
                let a = get(0)?;
                let (bsz, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
                let mut out = Tensor::zeros(&[bsz, c, 2 * h, 2 * w]);
                for ni in 0..bsz {
                    for ci in 0..c {
                        for y in 0..2 * h {
                            for xw in 0..2 * w {
                                out.data[((ni * c + ci) * 2 * h + y) * 2 * w + xw] =
                                    a.data[((ni * c + ci) * h + y / 2) * w + xw / 2];
                            }
                        }
                    }
                }
                out
            }
            "concat" => {
                let (a, b) = (get(0)?, get(1)?);
                let (bsz, ca, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
                let cb = b.shape[1];
                let mut out = Tensor::zeros(&[bsz, ca + cb, h, w]);
                let sp = h * w;
                for ni in 0..bsz {
                    let oa = ni * (ca + cb) * sp;
                    out.data[oa..oa + ca * sp]
                        .copy_from_slice(&a.data[ni * ca * sp..(ni + 1) * ca * sp]);
                    out.data[oa + ca * sp..oa + (ca + cb) * sp]
                        .copy_from_slice(&b.data[ni * cb * sp..(ni + 1) * cb * sp]);
                }
                out
            }
            "flatten" => {
                let a = get(0)?;
                let bsz = a.shape[0];
                let rest = a.len() / bsz;
                a.clone().reshaped(&[bsz, rest])
            }
            "reshape" => {
                let a = get(0)?;
                let bsz = a.shape[0];
                let mut shape = vec![bsz];
                shape.extend(n.shape.iter());
                a.clone().reshaped(&shape)
            }
            "layernorm" => {
                let a = get(0)?;
                let d = n.attr_usize("d")?;
                let rows = a.len() / d;
                let g = &self.params[&format!("{}.gamma", n.name)];
                let b = &self.params[&format!("{}.beta", n.name)];
                let mut out = a.clone();
                for r in 0..rows {
                    let row = &a.data[r * d..(r + 1) * d];
                    let mean = row.iter().sum::<f32>() / d as f32;
                    let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
                    let inv = (var + 1e-6).sqrt().recip();
                    for i in 0..d {
                        out.data[r * d + i] = (row[i] - mean) * inv * g.data[i] + b.data[i];
                    }
                }
                self.narrow(out)
            }
            "attention" => self.narrow(self.attention(n, get(0)?)?),
            "to_tokens" => {
                let a = get(0)?;
                let (bsz, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
                let t = h * w;
                let mut out = Tensor::zeros(&[bsz, t, c]);
                for ni in 0..bsz {
                    for ci in 0..c {
                        for p in 0..t {
                            out.data[(ni * t + p) * c + ci] = a.data[(ni * c + ci) * t + p];
                        }
                    }
                }
                out
            }
            "tokmean" => {
                let a = get(0)?;
                let (bsz, t, d) = (a.shape[0], a.shape[1], a.shape[2]);
                let mut out = Tensor::zeros(&[bsz, d]);
                for ni in 0..bsz {
                    for p in 0..t {
                        for i in 0..d {
                            out.data[ni * d + i] += a.data[(ni * t + p) * d + i];
                        }
                    }
                    for i in 0..d {
                        out.data[ni * d + i] /= t as f32;
                    }
                }
                self.narrow(out)
            }
            "aq" => {
                // integer requantization point: quant-dequant at static range
                let a = get(0)?;
                match self.int8_round() {
                    Some(round) => {
                        let &(lo, hi) = self
                            .act_ranges
                            .get(&n.name)
                            .with_context(|| format!("no range for aq {}", n.name))?;
                        let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
                        a.map(|v| {
                            let q = (round.round(v / s) + z as f32).clamp(0.0, 255.0);
                            (q - z as f32) * s
                        })
                    }
                    None => self.narrow(a.clone()),
                }
            }
            other => bail!("engine: unknown node kind {other:?}"),
        };
        Ok(out)
    }

    fn attention(&self, n: &Node, x: &Tensor) -> Result<Tensor> {
        let d = n.attr_usize("d")?;
        let heads = n.attr_usize("heads")?;
        let dh = d / heads;
        let (bsz, t) = (x.shape[0], x.shape[1]);
        let rows = bsz * t;

        let proj = |mat: &str, bias: &str| -> Result<Vec<f32>> {
            let wkey = format!("{}.{mat}", n.name);
            let b = &self.params[&format!("{}.{bias}", n.name)];
            match (self.cfg.weight_mode, self.int8_round(), self.qweights.get(&wkey)) {
                (WeightMode::Int8, Some(round), Some(qw)) => {
                    let (sx, zx) = self.input_qparams(&n.inputs[0])?;
                    Ok(ops::linear_i8(&x.data, rows, d, qw, Some(b), sx, zx, round))
                }
                _ => {
                    let w = self.weight_tensor(&wkey)?;
                    Ok(ops::linear_f32(&x.data, rows, d, &w, Some(b)))
                }
            }
        };
        let q = proj("wq", "qb")?;
        let k = proj("wk", "kb")?;
        let v = proj("wv", "vb")?;
        // scores + context in f32 (paper: softmax stays FP)
        let mut ctxt = vec![0.0f32; rows * d];
        let scale = 1.0 / (dh as f32).sqrt();
        for b_i in 0..bsz {
            for h_i in 0..heads {
                for ti in 0..t {
                    let qoff = (b_i * t + ti) * d + h_i * dh;
                    // scores over all source tokens
                    let mut sc = vec![0.0f32; t];
                    let mut mx = f32::MIN;
                    for tj in 0..t {
                        let koff = (b_i * t + tj) * d + h_i * dh;
                        let mut s = 0.0f32;
                        for e in 0..dh {
                            s += q[qoff + e] * k[koff + e];
                        }
                        sc[tj] = s * scale;
                        mx = mx.max(sc[tj]);
                    }
                    let mut denom = 0.0f32;
                    for s in sc.iter_mut() {
                        *s = (*s - mx).exp();
                        denom += *s;
                    }
                    let coff = (b_i * t + ti) * d + h_i * dh;
                    for tj in 0..t {
                        let a = sc[tj] / denom;
                        let voff = (b_i * t + tj) * d + h_i * dh;
                        for e in 0..dh {
                            ctxt[coff + e] += a * v[voff + e];
                        }
                    }
                }
            }
        }
        // output projection on the context
        let wkey = format!("{}.wo", n.name);
        let b = &self.params[&format!("{}.ob", n.name)];
        let out = match (self.cfg.weight_mode, self.int8_round(), self.qweights.get(&wkey)) {
            (WeightMode::Int8, Some(round), Some(qw)) => {
                // context range: reuse the block input's range as a proxy
                let (sx, zx) = self.input_qparams(&n.inputs[0])?;
                ops::linear_i8(&ctxt, rows, d, qw, Some(b), sx, zx, round)
            }
            _ => {
                let w = self.weight_tensor(&wkey)?;
                ops::linear_f32(&ctxt, rows, d, &w, Some(b))
            }
        };
        Ok(Tensor::new(vec![bsz, t, d], out))
    }
}

fn pool(a: &Tensor, k: usize, stride: usize, pad: usize, is_max: bool) -> Tensor {
    let (n, c, h, w) = (a.shape[0], a.shape[1], a.shape[2], a.shape[3]);
    let ho = (h + 2 * pad - k) / stride + 1;
    let wo = (w + 2 * pad - k) / stride + 1;
    let mut out = Tensor::zeros(&[n, c, ho, wo]);
    for ni in 0..n {
        for ci in 0..c {
            let xc = &a.data[(ni * c + ci) * h * w..(ni * c + ci + 1) * h * w];
            for oy in 0..ho {
                for ox in 0..wo {
                    let mut acc = if is_max { f32::MIN } else { 0.0 };
                    for ky in 0..k {
                        let iy = (oy * stride + ky) as isize - pad as isize;
                        if iy < 0 || iy >= h as isize {
                            if is_max {
                                acc = acc.max(f32::MIN);
                            }
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * stride + kx) as isize - pad as isize;
                            if ix < 0 || ix >= w as isize {
                                continue;
                            }
                            let v = xc[iy as usize * w + ix as usize];
                            if is_max {
                                acc = acc.max(v);
                            } else {
                                acc += v;
                            }
                        }
                    }
                    if !is_max {
                        acc /= (k * k) as f32;
                    }
                    out.data[((ni * c + ci) * ho + oy) * wo + ox] = acc;
                }
            }
        }
    }
    out
}

/// Build an FP32 reference CompiledModel straight from a checkpoint's
/// param/bn sections (the "ONNX FP32" analogue all backends are compared to).
pub fn fp32_model(graph: Graph, params: BTreeMap<String, Tensor>, bn: BTreeMap<String, Tensor>) -> CompiledModel {
    CompiledModel {
        graph,
        params,
        bn,
        qweights: HashMap::new(),
        act_ranges: HashMap::new(),
        cfg: ExecConfig::FP32,
    }
}
