//! Deployment inference engine: executes a QIR graph at the precision a
//! simulated vendor backend chose.
//!
//! Precision model (matches how real NPU toolchains behave at tensor
//! granularity):
//! * weights: f32, or pre-quantized i8 / nibble-packed i4 (per-channel or
//!   per-tensor symmetric)
//! * activations: f32, bf16/f16 round-trips at op boundaries, or asymmetric
//!   u8 — either with *static* per-node ranges fixed at compile time
//!   (calibration or embedded QAT scales; "STATIC (no runtime dyn)" in paper
//!   Table 4), or with *dynamic* per-tensor ranges computed from the live
//!   batch at every quantization point ([`ActMode::DynInt8`] — the
//!   calibration-free "dynamic" column of the same table).
//! * integer compute paths accumulate in i32 (ops.rs); softmax / layernorm /
//!   SE gates stay in float, as on real NPUs.
//!
//! Two executors share one `CompiledModel` (see engine/README.md):
//! * the **execution plan** ([`plan::ExecPlan`]) — compiled once per model,
//!   serves `run()`: pre-resolved weights, precomputed quant constants,
//!   liveness-planned buffers, parallel tiled kernels with fused epilogues.
//! * the **legacy interpreter** — walks the graph by name per call; serves
//!   `run_observe()` (calibration / metrics need per-node taps) and
//!   `run_interpreted()` (the reference the plan is regression-tested
//!   against, bit-exact on the int8 path).

/// bf16/f16 round-trip narrowing for the low-precision activation modes.
pub mod lowp;
/// Compute kernels: f32 reference paths + bit-exact integer GEMMs.
pub mod ops;
/// The execution-plan compiler and executor (the hot path behind `run`).
pub mod plan;
/// Persistent shared worker pool behind the parallel kernels (no per-call
/// thread spawns; one team serves every executor thread in the process).
pub mod pool;
/// Kernel tiers: plan-time CPU-feature detection and the AVX2/NEON SIMD
/// inner kernels behind the planned GEMMs (scalar fallback always kept).
pub mod simd;
/// Static plan auditor: interval/overflow analysis, symbolic plan replay
/// (liveness + aliasing + scratch bounds), and qparam sanity checks.
pub mod verify;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::OnceLock;

use anyhow::{bail, Context, Result};

pub use plan::ExecScratch;
pub use simd::KernelTier;

use crate::qir::{Graph, Node};
use crate::tensor::{act_scale_zp, QWeight, RoundMode, Tensor};

/// Weight precision chosen by a backend compiler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WeightMode {
    F32,
    Int8,
    /// Packed sub-byte weights (two nibbles per byte, `QWeight::bits == 4`).
    Int4,
}

impl WeightMode {
    /// Integer weight path (pre-quantized `QWeight` payloads, int GEMM).
    #[inline]
    pub fn is_integer(self) -> bool {
        matches!(self, WeightMode::Int8 | WeightMode::Int4)
    }

    /// Bit-width the backend must quantize `QWeight`s at for this mode.
    #[inline]
    pub fn weight_bits(self) -> u8 {
        match self {
            WeightMode::Int4 => 4,
            _ => 8,
        }
    }
}

/// Activation precision chosen by a backend compiler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ActMode {
    /// Full-precision f32 activations.
    F32,
    /// bfloat16 round-trips at op boundaries.
    Bf16,
    /// IEEE half-precision round-trips at op boundaries.
    F16,
    /// Static asymmetric u8 with compile-time ranges.
    Int8 { round: RoundMode },
    /// Dynamic asymmetric u8: per-tensor (lo, hi) computed from the *actual
    /// batch* at every quantization point at run time — needs no calibration
    /// dataset and no `act_ranges` ("dynamic activation scaling" in paper
    /// Table 4). Costs a fused range scan per node (`ops::dyn_qparams`),
    /// modelled in `perfmodel` as the per-node dynamic-scaling overhead.
    DynInt8 { round: RoundMode },
}

impl ActMode {
    /// Integer (u8) activation path, static or dynamic.
    #[inline]
    pub fn is_integer(self) -> bool {
        matches!(self, ActMode::Int8 { .. } | ActMode::DynInt8 { .. })
    }

    /// Rounding mode of the integer activation grid, if any.
    #[inline]
    pub fn round(self) -> Option<RoundMode> {
        match self {
            ActMode::Int8 { round } | ActMode::DynInt8 { round } => Some(round),
            _ => None,
        }
    }

    /// True when activation ranges are computed from the live batch.
    #[inline]
    pub fn is_dynamic(self) -> bool {
        matches!(self, ActMode::DynInt8 { .. })
    }
}

/// The (weight precision, activation precision) pair a backend compiled at.
#[derive(Clone, Copy, Debug)]
pub struct ExecConfig {
    /// Weight storage/compute mode.
    pub weight_mode: WeightMode,
    /// Activation precision and scaling mode.
    pub act_mode: ActMode,
    /// Inner-kernel tier override for the execution plan: `None`
    /// auto-detects the best tier this machine supports at plan time
    /// ([`KernelTier::resolve`]); `Some(tier)` requests a specific tier
    /// (degraded to scalar if the host cannot run it). The
    /// `PALLAS_FORCE_SCALAR` environment variable overrides both. All
    /// tiers are bit-identical, so this never changes results — only
    /// speed.
    pub kernel_tier: Option<KernelTier>,
}

impl ExecConfig {
    /// Full-precision reference configuration (the "ONNX FP32" analogue).
    pub const FP32: ExecConfig =
        ExecConfig { weight_mode: WeightMode::F32, act_mode: ActMode::F32, kernel_tier: None };
}

/// A backend-compiled model: transformed graph + prepared weights + static
/// activation ranges. Produced by `backends::*`, executed here.
///
/// The execution plan is compiled once and cached (`OnceLock`): the pub
/// fields must be treated as frozen after the first `plan()`/`run()` call —
/// mutating graph/params/qweights/act_ranges afterwards would leave `run()`
/// answering from the stale plan while `run_interpreted()` sees the new
/// state. Build a fresh `CompiledModel::new` instead of mutating in place.
///
/// **Thread-safety contract** (see engine/README.md): because execution is
/// `&self` over owned data plus that `OnceLock`'d plan, a planned
/// `CompiledModel` is `Send + Sync` — server workers share one deployment
/// lock-free through a plain `Arc`, no mutex. Asserted at compile time below.
pub struct CompiledModel {
    /// The backend-lowered QIR graph (BN folded, activations maybe fused).
    pub graph: Graph,
    /// Float parameters (post graph passes, e.g. BN-folded).
    pub params: BTreeMap<String, Tensor>,
    /// BN running stats for graphs that keep explicit bn nodes.
    pub bn: BTreeMap<String, Tensor>,
    /// Pre-quantized weights keyed by param key (e.g. "s0.b0.c1.w").
    pub qweights: HashMap<String, QWeight>,
    /// Static per-node output ranges (lo, hi) from calibration / QAT scales.
    /// Empty — and never read — under [`ActMode::DynInt8`], where ranges are
    /// recomputed from the live batch at every quantization point.
    pub act_ranges: HashMap<String, (f32, f32)>,
    /// Precision configuration the backend compiled this model at.
    pub cfg: ExecConfig,
    /// Lazily compiled execution plan (the hot path behind `run`).
    exec_plan: OnceLock<plan::ExecPlan>,
}

pub(crate) const BN_EPS: f32 = 1e-5;

thread_local! {
    /// Per-thread reusable executor scratch behind [`CompiledModel::run`]:
    /// each executor thread (serving worker, bench loop, test) warms one
    /// arena and then reruns allocation-free, whatever mix of deployments
    /// it serves (buffers grow to the high-water mark across models).
    static RUN_SCRATCH: RefCell<plan::ExecScratch> = RefCell::new(plan::ExecScratch::new());
}

// Compile-time proof of the frozen-after-plan contract: every field of
// `CompiledModel` (graph, params, qweights, ranges, `OnceLock<ExecPlan>`) is
// owned data, so the whole deployment crosses threads and is shared `&self`
// by the serving workers without locks. If a future change smuggles in a
// non-Sync field (Rc, RefCell, raw pointer), this stops compiling.
const _: () = {
    const fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<CompiledModel>();
};

impl CompiledModel {
    /// Assemble a compiled model from its backend-produced parts. The
    /// execution plan is lowered lazily (or eagerly by `plan()`).
    pub fn new(
        graph: Graph,
        params: BTreeMap<String, Tensor>,
        bn: BTreeMap<String, Tensor>,
        qweights: HashMap<String, QWeight>,
        act_ranges: HashMap<String, (f32, f32)>,
        cfg: ExecConfig,
    ) -> CompiledModel {
        CompiledModel { graph, params, bn, qweights, act_ranges, cfg, exec_plan: OnceLock::new() }
    }

    /// The compiled execution plan, lowering the model on first use.
    /// Backends call this at compile time so deployments ship with a ready
    /// plan and plan errors surface at deploy, not per-request.
    pub fn plan(&self) -> Result<&plan::ExecPlan> {
        if let Some(p) = self.exec_plan.get() {
            return Ok(p);
        }
        let p = plan::ExecPlan::compile(self)
            .with_context(|| format!("compiling execution plan for graph {}", self.graph.name))?;
        Ok(self.exec_plan.get_or_init(|| p))
    }

    /// Run and return the graph outputs (plan-based executor). Executes
    /// against a per-thread reusable [`ExecScratch`], so repeated calls
    /// from the same thread (a serving worker, a bench loop) hit the
    /// allocator only for the returned output clones; use [`Self::run_with`]
    /// with a caller-owned scratch for the fully zero-allocation form.
    pub fn run(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        RUN_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            let outs = self.plan()?.execute_with(x, &mut scratch)?;
            Ok(outs.to_vec())
        })
    }

    /// Run against a caller-owned reusable [`ExecScratch`]: the
    /// zero-allocation steady-state entry point. The returned outputs
    /// borrow the scratch and are valid until its next run. See the
    /// scratch's docs for the ownership/reuse contract.
    pub fn run_with<'s>(&self, x: &Tensor, scratch: &'s mut ExecScratch) -> Result<&'s [Tensor]> {
        self.plan()?.execute_with(x, scratch)
    }

    /// Per-sample input shape (batch dim excluded) declared by the graph's
    /// input node — the serving router uses it to reject mis-shaped requests
    /// before they can poison a batch.
    pub fn input_shape(&self) -> Option<Vec<usize>> {
        self.graph.nodes.iter().find(|n| n.kind == "input").map(|n| n.shape.clone())
    }

    /// Run through the legacy per-node interpreter (the reference
    /// implementation the plan is regression-tested against).
    pub fn run_interpreted(&self, x: &Tensor) -> Result<Vec<Tensor>> {
        let mut sink = |_: &str, _: &Tensor| {};
        self.run_inner(x, &mut sink)
    }

    /// Run, invoking `observe(node_name, output)` on every node output
    /// (used by calibration and by the distribution metrics). Interpreted:
    /// observers need per-node taps the planned executor does not keep.
    pub fn run_observe(
        &self,
        x: &Tensor,
        observe: &mut dyn FnMut(&str, &Tensor),
    ) -> Result<Vec<Tensor>> {
        self.run_inner(x, observe)
    }

    fn narrow(&self, mut t: Tensor) -> Tensor {
        match self.cfg.act_mode {
            ActMode::Bf16 => lowp::bf16_slice(&mut t.data),
            ActMode::F16 => lowp::f16_slice(&mut t.data),
            _ => {}
        }
        t
    }

    /// (scale, zero_point) for quantizing the *input* of a compute node,
    /// taken from the producer's static range.
    pub(crate) fn input_qparams(&self, producer: &str) -> Result<(f32, i32)> {
        let &(lo, hi) = self
            .act_ranges
            .get(producer)
            .with_context(|| format!("no calibrated range for node {producer}"))?;
        Ok(act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6)))
    }

    /// Input quantization parameters for a compute node: from the producer's
    /// static range under [`ActMode::Int8`], or computed on the spot from the
    /// live input data under [`ActMode::DynInt8`].
    pub(crate) fn act_qparams(&self, producer: &str, data: &[f32]) -> Result<(f32, i32)> {
        if self.cfg.act_mode.is_dynamic() {
            return Ok(ops::dyn_qparams(data));
        }
        self.input_qparams(producer)
    }

    /// Rounding mode of the integer activation grid (static or dynamic),
    /// `None` on the float activation paths.
    pub(crate) fn int_round(&self) -> Option<RoundMode> {
        self.cfg.act_mode.round()
    }

    pub(crate) fn weight_tensor(&self, key: &str) -> Result<Tensor> {
        if self.cfg.weight_mode.is_integer() {
            if let Some(qw) = self.qweights.get(key) {
                return Ok(qw.dequantize());
            }
        }
        self.params.get(key).cloned().with_context(|| format!("missing param {key}"))
    }

    fn run_inner(
        &self,
        x: &Tensor,
        observe: &mut dyn FnMut(&str, &Tensor),
    ) -> Result<Vec<Tensor>> {
        let mut vals: HashMap<String, Tensor> = HashMap::new();
        let mut remaining = self.graph.consumer_counts();
        for n in &self.graph.nodes {
            let out = self.eval_node(n, &vals, x)?;
            observe(&n.name, &out);
            vals.insert(n.name.clone(), out);
            // free dead inputs
            for i in &n.inputs {
                if let Some(c) = remaining.get_mut(i.as_str()) {
                    *c -= 1;
                    if *c == 0 && !self.graph.outputs.contains(i) {
                        vals.remove(i.as_str());
                    }
                }
            }
        }
        self.graph
            .outputs
            .iter()
            .map(|o| vals.get(o).cloned().with_context(|| format!("missing output {o}")))
            .collect()
    }

    fn eval_node(&self, n: &Node, vals: &HashMap<String, Tensor>, x: &Tensor) -> Result<Tensor> {
        let get = |i: usize| -> Result<&Tensor> {
            vals.get(&n.inputs[i]).with_context(|| format!("missing value {}", n.inputs[i]))
        };
        let out = match n.kind.as_str() {
            "input" => x.clone(),
            "conv2d" => {
                let a = get(0)?;
                let stride = n.attr_usize("stride")?;
                let pad = n.attr_usize("pad")?;
                let groups = n.attr_usize("groups")?;
                let bias = if n.attr_bool("bias") {
                    Some(self.params.get(&format!("{}.b", n.name)).context("missing bias")?)
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                let mut t = match (self.cfg.weight_mode, self.int_round(), self.qweights.get(&wkey)) {
                    (wm, Some(round), Some(qw)) if wm.is_integer() => {
                        let (sx, zx) = self.act_qparams(&n.inputs[0], &a.data)?;
                        ops::conv2d_i8(a, qw, bias, stride, pad, groups, sx, zx, round)
                    }
                    _ => {
                        let w = self.weight_tensor(&wkey)?;
                        ops::conv2d_f32(a, &w, bias, stride, pad, groups)
                    }
                };
                if let Some(act) = ops::Act::from_attr(n)? {
                    t = t.map(|v| act.apply(v));
                }
                self.narrow(t)
            }
            "linear" => {
                let a = get(0)?;
                let din = n.attr_usize("din")?;
                let rows = a.len() / din;
                let bias = if n.attr_bool("bias") {
                    self.params.get(&format!("{}.b", n.name))
                } else {
                    None
                };
                let wkey = format!("{}.w", n.name);
                let dout = n.attr_usize("dout")?;
                let mut oshape = a.shape.clone();
                *oshape.last_mut().unwrap() = dout;
                let data = match (self.cfg.weight_mode, self.int_round(), self.qweights.get(&wkey)) {
                    (wm, Some(round), Some(qw)) if wm.is_integer() => {
                        let (sx, zx) = self.act_qparams(&n.inputs[0], &a.data)?;
                        ops::linear_i8(&a.data, rows, din, qw, bias, sx, zx, round)
                    }
                    _ => {
                        let w = self.weight_tensor(&wkey)?;
                        ops::linear_f32(&a.data, rows, din, &w, bias)
                    }
                };
                let mut t = Tensor::new(oshape, data);
                if let Some(act) = ops::Act::from_attr(n)? {
                    t = t.map(|v| act.apply(v));
                }
                self.narrow(t)
            }
            "bn" => {
                let a = get(0)?;
                let g = &self.params[&format!("{}.gamma", n.name)];
                let b = &self.params[&format!("{}.beta", n.name)];
                let mean = &self.bn[&format!("{}.mean", n.name)];
                let var = &self.bn[&format!("{}.var", n.name)];
                let (scale, shift) =
                    ops::bn_fold_params(&g.data, &b.data, &mean.data, &var.data, BN_EPS);
                self.narrow(ops::bn_apply(a, &scale, &shift))
            }
            "relu" | "relu6" | "hswish" | "hsigmoid" | "sigmoid" | "silu" | "gelu" => {
                let act = ops::Act::from_kind(&n.kind).expect("covered by match");
                self.narrow(get(0)?.map(|v| act.apply(v)))
            }
            "add" => {
                let (a, b) = (get(0)?, get(1)?);
                if a.shape != b.shape {
                    bail!("add shape mismatch at {}", n.name);
                }
                let data = a.data.iter().zip(b.data.iter()).map(|(x, y)| x + y).collect();
                self.narrow(Tensor::new(a.shape.clone(), data))
            }
            "mul" => {
                let (a, b) = (get(0)?, get(1)?);
                self.narrow(ops::mul_gate(a, b))
            }
            "maxpool" | "avgpool" => self.narrow(ops::pool(
                get(0)?,
                n.attr_usize("k")?,
                n.attr_usize("stride")?,
                n.attr_usize("pad")?,
                n.kind == "maxpool",
            )),
            "gap" => self.narrow(ops::gap(get(0)?)),
            "upsample2x" => ops::upsample2x(get(0)?),
            "concat" => ops::concat_channels(get(0)?, get(1)?),
            "flatten" => {
                let a = get(0)?;
                let bsz = a.shape[0];
                let rest = a.len() / bsz;
                a.clone().reshaped(&[bsz, rest])
            }
            "reshape" => {
                let a = get(0)?;
                let bsz = a.shape[0];
                let mut shape = vec![bsz];
                shape.extend(n.shape.iter());
                a.clone().reshaped(&shape)
            }
            "layernorm" => {
                let a = get(0)?;
                let d = n.attr_usize("d")?;
                let g = &self.params[&format!("{}.gamma", n.name)];
                let b = &self.params[&format!("{}.beta", n.name)];
                self.narrow(ops::layernorm(a, d, &g.data, &b.data))
            }
            "attention" => self.narrow(self.attention(n, get(0)?)?),
            "to_tokens" => ops::to_tokens(get(0)?),
            "tokmean" => self.narrow(ops::tokmean(get(0)?)),
            "aq" => {
                // integer requantization point: quant-dequant at the static
                // range, or at the tensor's own live range when dynamic
                let a = get(0)?;
                match self.cfg.act_mode {
                    ActMode::Int8 { round } => {
                        let &(lo, hi) = self
                            .act_ranges
                            .get(&n.name)
                            .with_context(|| format!("no range for aq {}", n.name))?;
                        let (s, z) = act_scale_zp(lo.min(0.0), hi.max(lo + 1e-6));
                        a.map(|v| {
                            let q = (round.round(v / s) + z as f32).clamp(0.0, 255.0);
                            (q - z as f32) * s
                        })
                    }
                    ActMode::DynInt8 { round } => {
                        let mut t = a.clone();
                        ops::quant_dequant_dyn(&mut t.data, round);
                        t
                    }
                    _ => self.narrow(a.clone()),
                }
            }
            other => bail!("engine: unknown node kind {other:?}"),
        };
        Ok(out)
    }

    fn attention(&self, n: &Node, x: &Tensor) -> Result<Tensor> {
        let d = n.attr_usize("d")?;
        let heads = n.attr_usize("heads")?;
        let (bsz, t) = (x.shape[0], x.shape[1]);
        let rows = bsz * t;

        let proj = |input: &[f32], mat: &str, bias: &str| -> Result<Vec<f32>> {
            let wkey = format!("{}.{mat}", n.name);
            let b = &self.params[&format!("{}.{bias}", n.name)];
            match (self.cfg.weight_mode, self.int_round(), self.qweights.get(&wkey)) {
                (wm, Some(round), Some(qw)) if wm.is_integer() => {
                    // static: block-input range as proxy for every projection;
                    // dynamic: each projection ranges its own live input
                    let (sx, zx) = self.act_qparams(&n.inputs[0], input)?;
                    Ok(ops::linear_i8(input, rows, d, qw, Some(b), sx, zx, round))
                }
                _ => {
                    let w = self.weight_tensor(&wkey)?;
                    Ok(ops::linear_f32(input, rows, d, &w, Some(b)))
                }
            }
        };
        let q = proj(&x.data, "wq", "qb")?;
        let k = proj(&x.data, "wk", "kb")?;
        let v = proj(&x.data, "wv", "vb")?;
        // scores + context in f32 (paper: softmax stays FP)
        let ctxt = ops::attention_ctx(&q, &k, &v, bsz, t, d, heads);
        // output projection on the context (ctxt range: the block input's
        // range serves as a proxy on the int8 path)
        let out = proj(&ctxt, "wo", "ob")?;
        Ok(Tensor::new(vec![bsz, t, d], out))
    }
}

/// Build an FP32 reference CompiledModel straight from a checkpoint's
/// param/bn sections (the "ONNX FP32" analogue all backends are compared to).
pub fn fp32_model(graph: Graph, params: BTreeMap<String, Tensor>, bn: BTreeMap<String, Tensor>) -> CompiledModel {
    CompiledModel::new(graph, params, bn, HashMap::new(), HashMap::new(), ExecConfig::FP32)
}
