//! NEON inner kernels (aarch64). Mirror of [`super::avx2`] at 128-bit
//! width; see the module docs in [`super`] for the tier contract:
//!
//! * integer kernels read ROW-MAJOR weights and widen u8→i16 / i8→i16
//!   before `vmlal_s16` widening multiply-accumulates into i32 lanes —
//!   exact (a pair product is at most `255·128`), and i32 accumulation is
//!   order-independent, so outputs are bit-identical to the scalar
//!   kernels.
//! * float kernels read the scalar tier's `[k][4]`-interleaved panels and
//!   vectorize ACROSS the panel: the four accumulator lanes are the scalar
//!   kernel's `a0..a3`, updated with separate `vmulq_f32` + `vaddq_f32`
//!   per k step (never `vmlaq_f32`/`vfmaq_f32`, which may fuse), so each
//!   lane replays the scalar accumulation order bit-for-bit.
//!
//! NEON is architecturally baseline on aarch64, so [`super::KernelTier::Neon`]
//! is always available there, these functions need no `#[target_feature]`
//! attribute (the intrinsics are statically enabled), dispatch calls are
//! safe, and only the pointer-based loads/stores are `unsafe`.

use std::arch::aarch64::*;

use crate::engine::ops::{apply_act, nib_hi, nib_lo, Act};
use crate::tensor::quantized::packed_row_bytes;

/// Multiply-accumulate 16 widened activation lanes against one 16-byte i8
/// weight vector: four `vmlal_s16` steps into the i32x4 accumulator.
#[inline]
fn mac16(acc: int32x4_t, xl: int16x8_t, xh: int16x8_t, wv: int8x16_t) -> int32x4_t {
    let wl = vmovl_s8(vget_low_s8(wv));
    let wh = vmovl_s8(vget_high_s8(wv));
    let mut v = vmlal_s16(acc, vget_low_s16(xl), vget_low_s16(wl));
    v = vmlal_s16(v, vget_high_s16(xl), vget_high_s16(wl));
    v = vmlal_s16(v, vget_low_s16(xh), vget_low_s16(wh));
    vmlal_s16(v, vget_high_s16(xh), vget_high_s16(wh))
}

/// Unpack 8 nibble-packed int4 bytes into 16 sign-extended i8 values in k
/// order: byte `b` carries `k = 2b` in its low nibble and `k = 2b + 1` in
/// its high nibble.
#[inline]
fn unpack_nibbles16(v: uint8x8_t) -> int8x16_t {
    let lo = vand_u8(v, vdup_n_u8(0x0f));
    let hi = vshr_n_u8::<4>(v);
    // 4-bit sign extension: (n ^ 8) - 8 maps 0..=15 to -8..=7
    let eight = vdup_n_s8(8);
    let lo = vsub_s8(veor_s8(vreinterpret_s8_u8(lo), eight), eight);
    let hi = vsub_s8(veor_s8(vreinterpret_s8_u8(hi), eight), eight);
    let z = vzip_s8(lo, hi);
    vcombine_s8(z.0, z.1)
}

/// Row-range NEON kernel over row-major i8 weights: bit-identical to the
/// scalar kernels (shared requantization epilogue, order-independent i32
/// accumulation), 16 k-steps per vector iteration, 4-way output-channel
/// register blocking sharing one widened activation vector.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_i8_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let kb = cols - cols % 16;
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * cols..(o + 1) * cols];
            let w1 = &wq[(o + 1) * cols..(o + 2) * cols];
            let w2 = &wq[(o + 2) * cols..(o + 3) * cols];
            let w3 = &wq[(o + 3) * cols..(o + 4) * cols];
            let mut v0 = vdupq_n_s32(0);
            let mut v1 = vdupq_n_s32(0);
            let mut v2 = vdupq_n_s32(0);
            let mut v3 = vdupq_n_s32(0);
            let mut k = 0;
            while k + 16 <= cols {
                // SAFETY: k + 16 <= cols and each of the five row slices
                // holds `cols` bytes, so every 16-byte load is in bounds.
                let (xv, wv0, wv1, wv2, wv3) = unsafe {
                    (
                        vld1q_u8(xrow.as_ptr().add(k)),
                        vld1q_s8(w0.as_ptr().add(k)),
                        vld1q_s8(w1.as_ptr().add(k)),
                        vld1q_s8(w2.as_ptr().add(k)),
                        vld1q_s8(w3.as_ptr().add(k)),
                    )
                };
                // u8 values (0..=255) fit the positive i16 range
                let xl = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(xv)));
                let xh = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(xv)));
                v0 = mac16(v0, xl, xh, wv0);
                v1 = mac16(v1, xl, xh, wv1);
                v2 = mac16(v2, xl, xh, wv2);
                v3 = mac16(v3, xl, xh, wv3);
                k += 16;
            }
            let mut a0 = vaddvq_s32(v0);
            let mut a1 = vaddvq_s32(v1);
            let mut a2 = vaddvq_s32(v2);
            let mut a3 = vaddvq_s32(v3);
            for i in kb..cols {
                let x = xrow[i] as i32;
                a0 += x * w0[i] as i32;
                a1 += x * w1[i] as i32;
                a2 += x * w2[i] as i32;
                a3 += x * w3[i] as i32;
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * cols..(o + 1) * cols];
            let mut v = vdupq_n_s32(0);
            let mut k = 0;
            while k + 16 <= cols {
                // SAFETY: k + 16 <= cols; xrow and wrow both hold `cols`
                // bytes, so both 16-byte loads are in bounds.
                let (xv, wv) = unsafe {
                    (vld1q_u8(xrow.as_ptr().add(k)), vld1q_s8(wrow.as_ptr().add(k)))
                };
                let xl = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(xv)));
                let xh = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(xv)));
                v = mac16(v, xl, xh, wv);
                k += 16;
            }
            let mut acc = vaddvq_s32(v);
            for i in kb..cols {
                acc += xrow[i] as i32 * wrow[i] as i32;
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// Row-range NEON kernel over row-major nibble-packed i4 weights: 8 packed
/// bytes (16 k-steps) unpacked per vector iteration via
/// [`unpack_nibbles16`], then the same widening MAC as the i8 kernel. The
/// sub-16 byte tail and the odd-column low nibble run the scalar helpers.
/// Bit-identical to `gemm_i4_rows` / `gemm_i4_panel_rows` in `engine::ops`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_i4_rows(
    xq: &[u8],
    rows: usize,
    cols: usize,
    wq: &[i8],
    cout_g: usize,
    rowsum: &[i32],
    sxw: &[f32],
    zx: i32,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    let bpr = packed_row_bytes(cols);
    let pairs = cols / 2;
    let vb = pairs - pairs % 8;
    for r in 0..rows {
        let xrow = &xq[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let w0 = &wq[o * bpr..(o + 1) * bpr];
            let w1 = &wq[(o + 1) * bpr..(o + 2) * bpr];
            let w2 = &wq[(o + 2) * bpr..(o + 3) * bpr];
            let w3 = &wq[(o + 3) * bpr..(o + 4) * bpr];
            let mut v0 = vdupq_n_s32(0);
            let mut v1 = vdupq_n_s32(0);
            let mut v2 = vdupq_n_s32(0);
            let mut v3 = vdupq_n_s32(0);
            let mut b = 0;
            while b + 8 <= vb {
                // SAFETY: b + 8 <= vb <= pairs <= bpr, so each 8-byte
                // weight load is in bounds (slices hold `bpr` bytes, and the
                // weight bytes are i8 reinterpreted as u8 below); 2b + 16 <=
                // 2·pairs <= cols keeps the 16-byte activation load in
                // bounds too.
                let (xv, wv0, wv1, wv2, wv3) = unsafe {
                    (
                        vld1q_u8(xrow.as_ptr().add(2 * b)),
                        vld1_u8(w0.as_ptr().add(b).cast()),
                        vld1_u8(w1.as_ptr().add(b).cast()),
                        vld1_u8(w2.as_ptr().add(b).cast()),
                        vld1_u8(w3.as_ptr().add(b).cast()),
                    )
                };
                let xl = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(xv)));
                let xh = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(xv)));
                v0 = mac16(v0, xl, xh, unpack_nibbles16(wv0));
                v1 = mac16(v1, xl, xh, unpack_nibbles16(wv1));
                v2 = mac16(v2, xl, xh, unpack_nibbles16(wv2));
                v3 = mac16(v3, xl, xh, unpack_nibbles16(wv3));
                b += 8;
            }
            let mut a0 = vaddvq_s32(v0);
            let mut a1 = vaddvq_s32(v1);
            let mut a2 = vaddvq_s32(v2);
            let mut a3 = vaddvq_s32(v3);
            for kb in vb..pairs {
                let x0 = xrow[2 * kb] as i32;
                let x1 = xrow[2 * kb + 1] as i32;
                a0 += x0 * nib_lo(w0[kb]) + x1 * nib_hi(w0[kb]);
                a1 += x0 * nib_lo(w1[kb]) + x1 * nib_hi(w1[kb]);
                a2 += x0 * nib_lo(w2[kb]) + x1 * nib_hi(w2[kb]);
                a3 += x0 * nib_lo(w3[kb]) + x1 * nib_hi(w3[kb]);
            }
            if cols % 2 == 1 {
                let x0 = xrow[cols - 1] as i32;
                a0 += x0 * nib_lo(w0[bpr - 1]);
                a1 += x0 * nib_lo(w1[bpr - 1]);
                a2 += x0 * nib_lo(w2[bpr - 1]);
                a3 += x0 * nib_lo(w3[bpr - 1]);
            }
            for (j, acc) in [a0, a1, a2, a3].into_iter().enumerate() {
                let oo = o + j;
                let corrected = acc - zx * rowsum[oo];
                let b = bias.map_or(0.0, |b| b[oo]);
                orow[o0 + oo] = apply_act(corrected as f32 * sxw[oo] + b, act);
            }
            o += 4;
        }
        while o < cout_g {
            let wrow = &wq[o * bpr..(o + 1) * bpr];
            let mut v = vdupq_n_s32(0);
            let mut b = 0;
            while b + 8 <= vb {
                // SAFETY: b + 8 <= vb <= pairs <= bpr bounds the 8-byte
                // weight load; 2b + 16 <= cols bounds the activation load.
                let (xv, wv) = unsafe {
                    (vld1q_u8(xrow.as_ptr().add(2 * b)), vld1_u8(wrow.as_ptr().add(b).cast()))
                };
                let xl = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(xv)));
                let xh = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(xv)));
                v = mac16(v, xl, xh, unpack_nibbles16(wv));
                b += 8;
            }
            let mut acc = vaddvq_s32(v);
            for kb in vb..pairs {
                acc += xrow[2 * kb] as i32 * nib_lo(wrow[kb])
                    + xrow[2 * kb + 1] as i32 * nib_hi(wrow[kb]);
            }
            if cols % 2 == 1 {
                acc += xrow[cols - 1] as i32 * nib_lo(wrow[bpr - 1]);
            }
            acc -= zx * rowsum[o];
            let b = bias.map_or(0.0, |b| b[o]);
            orow[o0 + o] = apply_act(acc as f32 * sxw[o] + b, act);
            o += 1;
        }
    }
}

/// 4-lane twin of the scalar `gemm_f32_panel_rows` (the 64-wide k-blocked
/// convolution form). Each accumulator LANE replays the scalar kernel's
/// per-output operation sequence — separate mul and add per k step, block
/// partials folded in the same order — so outputs are bit-identical.
/// Remainder rows (< 4 channels) run the scalar loop unchanged.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_f32_panel_rows(
    x: &[f32],
    rows: usize,
    cols: usize,
    wp: &[f32],
    cout_g: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
    out_stride: usize,
    o0: usize,
) {
    const BK: usize = 64;
    for r in 0..rows {
        let xrow = &x[r * cols..(r + 1) * cols];
        let orow = &mut out[r * out_stride..(r + 1) * out_stride];
        let mut o = 0;
        while o + 4 <= cout_g {
            let pan = &wp[o * cols..(o + 4) * cols];
            let mut a = vdupq_n_f32(0.0);
            let mut k = 0;
            while k + BK <= cols {
                let mut s = vdupq_n_f32(0.0);
                for i in k..k + BK {
                    // SAFETY: i < cols, so the 4-wide load at i*4 ends at
                    // i*4 + 4 <= 4*cols == pan.len().
                    let wv = unsafe { vld1q_f32(pan.as_ptr().add(i * 4)) };
                    s = vaddq_f32(s, vmulq_f32(vdupq_n_f32(xrow[i]), wv));
                }
                a = vaddq_f32(a, s);
                k += BK;
            }
            for i in k..cols {
                // SAFETY: i < cols, as above.
                let wv = unsafe { vld1q_f32(pan.as_ptr().add(i * 4)) };
                a = vaddq_f32(a, vmulq_f32(vdupq_n_f32(xrow[i]), wv));
            }
            let mut lanes = [0.0f32; 4];
            // SAFETY: `lanes` is 16 writable bytes.
            unsafe { vst1q_f32(lanes.as_mut_ptr(), a) };
            for (j, acc) in lanes.into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[o0 + oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < cout_g {
            // remainder rows are stored row-major at offset o*cols; this is
            // the scalar remainder loop verbatim
            let wrow = &wp[o * cols..(o + 1) * cols];
            let mut acc = 0.0f32;
            let mut k = 0;
            while k + BK <= cols {
                let mut s = 0.0f32;
                for i in k..k + BK {
                    s += xrow[i] * wrow[i];
                }
                acc += s;
                k += BK;
            }
            for i in k..cols {
                acc += xrow[i] * wrow[i];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o0 + o] = apply_act(acc, act);
            o += 1;
        }
    }
}

/// 4-lane twin of the scalar `linear_f32_panel_rows` (plain unblocked
/// accumulation — the linear / attention-projection form). Same lane
/// contract as [`gemm_f32_panel_rows`]: bit-identical outputs.
#[allow(clippy::too_many_arguments)]
pub(crate) fn linear_f32_panel_rows(
    x: &[f32],
    rows: usize,
    din: usize,
    wp: &[f32],
    dout: usize,
    bias: Option<&[f32]>,
    act: Option<Act>,
    out: &mut [f32],
) {
    for r in 0..rows {
        let xrow = &x[r * din..(r + 1) * din];
        let orow = &mut out[r * dout..(r + 1) * dout];
        let mut o = 0;
        while o + 4 <= dout {
            let pan = &wp[o * din..(o + 4) * din];
            let mut a = vdupq_n_f32(0.0);
            for k in 0..din {
                // SAFETY: k < din, so the 4-wide load at k*4 ends at
                // k*4 + 4 <= 4*din == pan.len().
                let wv = unsafe { vld1q_f32(pan.as_ptr().add(k * 4)) };
                a = vaddq_f32(a, vmulq_f32(vdupq_n_f32(xrow[k]), wv));
            }
            let mut lanes = [0.0f32; 4];
            // SAFETY: `lanes` is 16 writable bytes.
            unsafe { vst1q_f32(lanes.as_mut_ptr(), a) };
            for (j, acc) in lanes.into_iter().enumerate() {
                let oo = o + j;
                let mut v = acc;
                if let Some(b) = bias {
                    v += b[oo];
                }
                orow[oo] = apply_act(v, act);
            }
            o += 4;
        }
        while o < dout {
            let wrow = &wp[o * din..(o + 1) * din];
            let mut acc = 0.0f32;
            for k in 0..din {
                acc += xrow[k] * wrow[k];
            }
            if let Some(b) = bias {
                acc += b[o];
            }
            orow[o] = apply_act(acc, act);
            o += 1;
        }
    }
}
